"""AOT pipeline: lower every L2 entry point to HLO text + manifest.json.

HLO *text* (never ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(what the published ``xla`` 0.1.6 Rust crate links) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Usage (from python/):  python -m compile.aot --out ../artifacts
Incremental: entries are re-lowered only if missing or --force.

The manifest records everything the Rust side needs to be self-contained:
batch/tensor shapes, flat-parameter layouts with init specs (Rust
re-initializes parameters itself), per-entry argument/result signatures,
and the paper's exact parameter counts (cross-checked here at build time).
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, models

# Entries that do not depend on the auxiliary architecture: lowered once
# per dataset (from the first aux config) instead of once per aux variant.
SHARED_ENTRIES = (
    "client_fwd",
    "server_train_step",
    "server_fwd_bwd",
    "client_bwd",
    "eval_step",
)
AUX_ENTRIES = ("client_train_step", "aux_eval_step")


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(args):
    return [{"shape": list(a.shape), "dtype": a.dtype.name} for a in args]


def _result_sig(fn, args):
    out = jax.eval_shape(fn, *args)
    if not isinstance(out, tuple):
        out = (out,)
    return [{"shape": list(o.shape), "dtype": o.dtype.name} for o in out]


def lower_entry(fn, args, path, force):
    if os.path.exists(path) and not force:
        return False
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(text)
    os.replace(tmp, path)
    return True


def check_paper_counts(dataset, meta, aux_arch):
    """Fail the build if any layout diverges from the paper's counts."""
    want = models.PAPER_COUNTS[dataset]
    got_c, got_s = meta["client_size"], meta["server_size"]
    got_a = meta["aux_size"]
    if got_c != want["client"]:
        raise AssertionError(f"{dataset} client params {got_c} != paper {want['client']}")
    if got_s != want["server"]:
        raise AssertionError(f"{dataset} server params {got_s} != paper {want['server']}")
    if got_a != want["aux"][aux_arch]:
        raise AssertionError(
            f"{dataset}/{aux_arch} aux params {got_a} != paper {want['aux'][aux_arch]}"
        )


def build(out_dir, datasets=None, force=False, verbose=True):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": 1, "configs": {}}
    datasets = datasets or list(models.CONFIGS)
    n_lowered = 0
    for ds in datasets:
        cfg = models.CONFIGS[ds]
        ds_dir = os.path.join(out_dir, ds)
        os.makedirs(ds_dir, exist_ok=True)
        ds_manifest = {
            "batch": cfg["batch"],
            "input": cfg["input"],
            "classes": cfg["classes"],
            "smashed": cfg["smashed"],
            "entries": {},
            "aux": {},
        }
        first_aux = cfg["aux_archs"][0]
        for aux_arch in cfg["aux_archs"]:
            entries, meta = model.make_entries(ds, aux_arch)
            check_paper_counts(ds, meta, aux_arch)
            if aux_arch == first_aux:
                ds_manifest["client_layout"] = meta["client_layout"]
                ds_manifest["client_size"] = meta["client_size"]
                ds_manifest["server_layout"] = meta["server_layout"]
                ds_manifest["server_size"] = meta["server_size"]
                ds_manifest["smashed_size"] = meta["smashed_size"]
                for name in SHARED_ENTRIES:
                    fn, args = entries[name]
                    rel = f"{ds}/{name}.hlo.txt"
                    did = lower_entry(fn, args, os.path.join(out_dir, rel), force)
                    n_lowered += did
                    if verbose and did:
                        print(f"  lowered {rel}", file=sys.stderr)
                    ds_manifest["entries"][name] = {
                        "file": rel,
                        "args": _sig(args),
                        "results": _result_sig(fn, args),
                    }
            aux_m = {
                "layout": meta["aux_layout"],
                "size": meta["aux_size"],
                "entries": {},
            }
            for name in AUX_ENTRIES:
                fn, args = entries[name]
                rel = f"{ds}/{name}_{aux_arch}.hlo.txt"
                did = lower_entry(fn, args, os.path.join(out_dir, rel), force)
                n_lowered += did
                if verbose and did:
                    print(f"  lowered {rel}", file=sys.stderr)
                aux_m["entries"][name] = {
                    "file": rel,
                    "args": _sig(args),
                    "results": _result_sig(fn, args),
                }
            ds_manifest["aux"][aux_arch] = aux_m
        manifest["configs"][ds] = ds_manifest
    path = os.path.join(out_dir, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"wrote {path} ({n_lowered} entries lowered)", file=sys.stderr)
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--datasets", nargs="*", default=None,
                    help="subset of configs (default: all)")
    ap.add_argument("--force", action="store_true", help="re-lower everything")
    args = ap.parse_args()
    build(args.out, args.datasets, args.force)


if __name__ == "__main__":
    main()
