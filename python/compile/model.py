"""L2 training-step entry points — the functions AOT-lowered to HLO.

Each entry is a pure function over flat parameter vectors, mini-batch
tensors and scalar hyperparameters; `compile.aot` lowers every entry to HLO
text that the Rust runtime (`rust/src/runtime/`) loads and executes. The
mapping to the paper:

  client_train_step  Eq. (8): one local SGD step on (x_c, a_c) using the
                     auxiliary local loss  F_{c,i}(x_c, a_c)     [AN, CSE]
  client_fwd         g_{x_c}(z): smashed data for upload         [all]
  server_train_step  Eq. (11): event-triggered server update on
                     arriving smashed data                       [AN, CSE]
  server_fwd_bwd     SplitFed server step: update x_s AND return the
                     cut-layer gradient (optionally clipped by global
                     norm — the paper adds clipping to FSL_OC)   [MC, OC]
  client_bwd         SplitFed client step from the upstream cut-layer
                     gradient (dropout replayed via ``seed``)    [MC, OC]
  eval_step          full-model logits, train=False              [all]

All entries also return the pre-update gradient L2 norm where meaningful,
so the Rust side can record the convergence traces of Propositions 1-2.
"""

import jax
import jax.numpy as jnp

from . import models
from .kernels import softmax_xent


def _sgd(flat, grad, lr):
    return flat - lr * grad


def _anchor(x, *scalars):
    """Add 0.0 * scalar to ``x`` so every entry parameter stays live.

    XLA prunes unused parameters when lowering stablehlo -> HLO; the Rust
    runtime supplies the full manifest signature, so a pruned parameter
    (e.g. ``seed`` on the dropout-free CIFAR model) would make execution
    fail with an argument-count mismatch. Multiplying by exact 0.0 is a
    numeric no-op for finite inputs.
    """
    extra = sum(jnp.asarray(s, jnp.float32) * 0.0 for s in scalars)
    return x + extra


def _gnorm(*grads):
    return jnp.sqrt(sum(jnp.sum(g * g) for g in grads))


def _clip_by_global_norm(g, clip):
    """Scale g so its global norm is at most ``clip`` (clip<=0 disables)."""
    norm = jnp.sqrt(jnp.sum(g * g))
    do_clip = jnp.logical_and(clip > 0.0, norm > clip)
    scale = jnp.where(do_clip, clip / jnp.maximum(norm, 1e-12), 1.0)
    return g * scale


def make_entries(dataset, aux_arch):
    """Build the entry-point callables + example args for one config.

    Returns dict: name -> (fn, example_args tuple of ShapeDtypeStructs).
    """
    cfg = models.CONFIGS[dataset]
    b = cfg["batch"]
    client_layout, client_n = cfg["client_layout"]()
    server_layout, server_n = cfg["server_layout"]()
    aux_layout, aux_n = cfg["aux_layout"](aux_arch)
    cf, sf, af = cfg["client_forward"], cfg["server_forward"], cfg["aux_forward"]
    smashed_shape = tuple([b] + cfg["smashed"])
    smashed_n = int(jnp.prod(jnp.array(cfg["smashed"])))

    f32 = jnp.float32
    i32 = jnp.int32
    S = jax.ShapeDtypeStruct
    x_s = S(tuple([b] + cfg["input"]), f32)
    y_s = S((b,), i32)
    lr_s = S((), f32)
    seed_s = S((), i32)
    clip_s = S((), f32)
    xc_s = S((client_n,), f32)
    ac_s = S((aux_n,), f32)
    xs_s = S((server_n,), f32)
    sm_s = S(smashed_shape, f32)

    def client_train_step(xc, ac, x, y, lr, seed):
        def loss_fn(xc, ac):
            smashed = cf(models.unpack(xc, client_layout), x, seed, train=True)
            logits = af(models.unpack(ac, aux_layout), smashed, aux_arch)
            return softmax_xent(logits, y)

        loss, (gxc, gac) = jax.value_and_grad(loss_fn, argnums=(0, 1))(xc, ac)
        loss = _anchor(loss, lr, seed)
        return _sgd(xc, gxc, lr), _sgd(ac, gac, lr), loss, _gnorm(gxc, gac)

    def client_fwd(xc, x, seed):
        return _anchor(cf(models.unpack(xc, client_layout), x, seed, train=True), seed)

    def server_train_step(xs, smashed, y, lr, seed):
        def loss_fn(xs):
            logits = sf(models.unpack(xs, server_layout), smashed, seed, train=True)
            return softmax_xent(logits, y)

        loss, gxs = jax.value_and_grad(loss_fn)(xs)
        loss = _anchor(loss, lr, seed)
        return _sgd(xs, gxs, lr), loss, _gnorm(gxs)

    def server_fwd_bwd(xs, smashed, y, lr, seed, clip):
        def loss_fn(xs, smashed):
            logits = sf(models.unpack(xs, server_layout), smashed, seed, train=True)
            return softmax_xent(logits, y)

        loss, (gxs, gsm) = jax.value_and_grad(loss_fn, argnums=(0, 1))(xs, smashed)
        gxs = _clip_by_global_norm(gxs, clip)
        gsm_flat = _clip_by_global_norm(gsm.reshape(-1), clip)
        gsm = gsm_flat.reshape(smashed.shape)
        loss = _anchor(loss, lr, seed, clip)
        return _sgd(xs, gxs, lr), gsm, loss, _gnorm(gxs)

    def client_bwd(xc, x, gsm, lr, seed, clip):
        def fwd(xc):
            return cf(models.unpack(xc, client_layout), x, seed, train=True)

        _, vjp = jax.vjp(fwd, xc)
        (gxc,) = vjp(gsm)
        gxc = _clip_by_global_norm(gxc, clip)
        return _anchor(_sgd(xc, gxc, lr), seed, clip), _gnorm(gxc)

    def eval_step(xc, xs, x):
        smashed = cf(models.unpack(xc, client_layout), x, 0, train=False)
        return sf(models.unpack(xs, server_layout), smashed, 0, train=False)

    def aux_eval_step(xc, ac, x):
        """Client-only inference through the auxiliary head (used by the
        local-model ablation; not a paper figure but a natural probe)."""
        smashed = cf(models.unpack(xc, client_layout), x, 0, train=False)
        return af(models.unpack(ac, aux_layout), smashed, aux_arch)

    entries = {
        "client_train_step": (client_train_step, (xc_s, ac_s, x_s, y_s, lr_s, seed_s)),
        "client_fwd": (client_fwd, (xc_s, x_s, seed_s)),
        "server_train_step": (server_train_step, (xs_s, sm_s, y_s, lr_s, seed_s)),
        "server_fwd_bwd": (server_fwd_bwd, (xs_s, sm_s, y_s, lr_s, seed_s, clip_s)),
        "client_bwd": (client_bwd, (xc_s, x_s, sm_s, lr_s, seed_s, clip_s)),
        "eval_step": (eval_step, (xc_s, xs_s, x_s)),
        "aux_eval_step": (aux_eval_step, (xc_s, ac_s, x_s)),
    }
    meta = {
        "client_layout": client_layout,
        "client_size": client_n,
        "server_layout": server_layout,
        "server_size": server_n,
        "aux_layout": aux_layout,
        "aux_size": aux_n,
        "smashed_size": smashed_n,
    }
    return entries, meta
