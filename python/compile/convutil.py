"""Convolution expressed as im2col + the Pallas matmul kernel.

This is the TPU-shaped formulation (DESIGN.md SSHardware-Adaptation): instead
of a direct sliding-window kernel (the GPU/threadblock idiom), the input is
unfolded into patch rows and the contraction runs on the MXU-targeted tiled
matmul. Gradients flow through the unfold (pure slicing/concat, which XLA
transposes for free) and the matmul's custom Pallas VJP.

Only stride-1 convolutions appear in the paper's models; spatial reduction
is done by the pooling kernel.
"""

import jax.numpy as jnp

from .kernels import matmul


def conv2d(x, w, b=None, padding="VALID"):
    """2-D convolution, NHWC x HWIO -> NHWC, stride 1.

    Args:
      x: f32[B, H, W, Cin]
      w: f32[KH, KW, Cin, Cout]
      b: optional f32[Cout] bias (added by the caller's activation kernel
         when fused; provided here only for standalone use/tests).
      padding: "SAME" or "VALID".
    """
    kh, kw, cin, cout = w.shape
    if x.shape[-1] != cin:
        raise ValueError(f"channel mismatch: x {x.shape} vs w {w.shape}")
    if padding == "SAME":
        ph0, ph1 = (kh - 1) // 2, kh // 2
        pw0, pw1 = (kw - 1) // 2, kw // 2
        x = jnp.pad(x, ((0, 0), (ph0, ph1), (pw0, pw1), (0, 0)))
    elif padding != "VALID":
        raise ValueError(f"bad padding {padding!r}")
    bsz, hp, wp, _ = x.shape
    oh, ow = hp - kh + 1, wp - kw + 1
    patches = im2col(x, kh, kw)  # [B, OH, OW, KH*KW*Cin]
    out = matmul(
        patches.reshape(bsz * oh * ow, kh * kw * cin),
        w.reshape(kh * kw * cin, cout),
    ).reshape(bsz, oh, ow, cout)
    if b is not None:
        out = out + b
    return out


def im2col(x, kh, kw):
    """Unfold stride-1 patches: f32[B,H,W,C] -> f32[B,OH,OW,KH*KW*C].

    Patch layout is (kh, kw) major / channel minor, matching
    ``w.reshape(kh*kw*cin, cout)`` for HWIO weights.
    """
    _, h, w_, _ = x.shape
    oh, ow = h - kh + 1, w_ - kw + 1
    slices = [
        x[:, i : i + oh, j : j + ow, :] for i in range(kh) for j in range(kw)
    ]
    return jnp.concatenate(slices, axis=-1)


def conv1x1(x, w):
    """Pointwise convolution f32[B,H,W,Cin] x f32[Cin,Cout] via matmul."""
    bsz, h, w_, cin = x.shape
    cout = w.shape[1]
    return matmul(x.reshape(bsz * h * w_, cin), w).reshape(bsz, h, w_, cout)
