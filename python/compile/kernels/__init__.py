"""L1 Pallas kernels for CSE-FSL.

Every kernel is written with ``pallas_call(..., interpret=True)`` so it
lowers to plain HLO executable by the CPU PJRT plugin (real-TPU Mosaic
lowering is a compile-only target on this box; see DESIGN.md
SSHardware-Adaptation).

Kernels on the training path are wrapped in ``jax.custom_vjp`` with Pallas
kernels on *both* forward and backward passes, so the L2 graphs in
``compile.model`` differentiate through them without falling back to
XLA-generated gradients.
"""

from .matmul import matmul, matmul_nograd
from .softmax_xent import softmax_xent, softmax_logits
from .elementwise import bias_relu, bias_add
from .pool import maxpool2x2
from .lrn import lrn

__all__ = [
    "matmul",
    "matmul_nograd",
    "softmax_xent",
    "softmax_logits",
    "bias_relu",
    "bias_add",
    "maxpool2x2",
    "lrn",
]
