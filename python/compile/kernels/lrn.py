"""Local response normalization (across channels) Pallas kernel.

The paper's CIFAR-10 client stack follows the classic TF CIFAR tutorial:
``lrn(x, depth_radius=4, bias=1.0, alpha=0.001/9, beta=0.75)``:

    s_i = bias + alpha * sum_{|j-i| <= r} x_j^2
    y_i = x_i * s_i^{-beta}

The channel-windowed sum is a static unrolled sum of 2r+1 shifted slices
(r is a compile-time constant), so the kernel stays a single VMEM pass.

Backward (analytic, also a Pallas kernel):

    dx_i = g_i * s_i^{-beta}
           - 2*alpha*beta * x_i * sum_{|j-i| <= r} g_j x_j s_j^{-beta-1}
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

RADIUS = 4
BIAS = 1.0
ALPHA = 0.001 / 9.0
BETA = 0.75


def _win_sum(x, radius):
    """Sum over a (2r+1)-wide channel window, zero padded at the edges."""
    c = x.shape[-1]
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(radius, radius)])
    acc = jnp.zeros_like(x)
    for d in range(2 * radius + 1):
        acc = acc + xp[..., d : d + c]
    return acc


def _lrn_fwd_kernel(x_ref, y_ref, s_ref, *, radius, bias, alpha, beta):
    x = x_ref[...]
    s = bias + alpha * _win_sum(x * x, radius)
    s_ref[...] = s
    y_ref[...] = x * s ** (-beta)


def _lrn_bwd_kernel(x_ref, s_ref, g_ref, dx_ref, *, radius, bias, alpha, beta):
    x = x_ref[...]
    s = s_ref[...]
    g = g_ref[...]
    inner = g * x * s ** (-beta - 1.0)
    dx_ref[...] = g * s ** (-beta) - 2.0 * alpha * beta * x * _win_sum(inner, radius)


def _as2d(x):
    return x.reshape(-1, x.shape[-1])


@jax.custom_vjp
def lrn(x):
    """LRN over the channel (last) axis of f32[..., C]."""
    y, _ = _lrn_fwd(x)
    return y


def _lrn_fwd(x):
    shape = x.shape
    x2 = _as2d(x).astype(jnp.float32)
    kern = functools.partial(
        _lrn_fwd_kernel, radius=RADIUS, bias=BIAS, alpha=ALPHA, beta=BETA
    )
    y, s = pl.pallas_call(
        kern,
        out_shape=(
            jax.ShapeDtypeStruct(x2.shape, jnp.float32),
            jax.ShapeDtypeStruct(x2.shape, jnp.float32),
        ),
        interpret=True,
    )(x2)
    return y.reshape(shape), (x2, s, shape)


def _lrn_bwd(res, g):
    x2, s, shape = res
    g2 = _as2d(g).astype(jnp.float32)
    kern = functools.partial(
        _lrn_bwd_kernel, radius=RADIUS, bias=BIAS, alpha=ALPHA, beta=BETA
    )
    dx = pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct(x2.shape, jnp.float32),
        interpret=True,
    )(x2, s, g2)
    return (dx.reshape(shape),)


lrn.defvjp(_lrn_fwd, _lrn_bwd)
