"""Fused softmax + cross-entropy Pallas kernel with custom VJP.

This is the local-loss head the paper's auxiliary network exists to feed
(Eq. (5)) and the server-side loss (Eq. (7)). Fusing softmax with the
cross-entropy keeps the logits row resident in VMEM: one pass computes the
row max, the exponentials, the normalizer, and the per-row loss without
materializing intermediate arrays in HBM.

Backward is the classic closed form  dlogits = (softmax(z) - onehot(y)) * g
(with the 1/B mean folding into ``g``), again as a Pallas kernel.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwd_kernel(logits_ref, onehot_ref, loss_ref, probs_ref):
    z = logits_ref[...]
    zmax = jnp.max(z, axis=-1, keepdims=True)
    ez = jnp.exp(z - zmax)
    denom = jnp.sum(ez, axis=-1, keepdims=True)
    probs = ez / denom
    probs_ref[...] = probs
    # loss_i = logsumexp(z_i) - z_i[y_i]
    lse = jnp.log(denom[..., 0]) + zmax[..., 0]
    picked = jnp.sum(z * onehot_ref[...], axis=-1)
    loss_ref[...] = lse - picked


def _bwd_kernel(probs_ref, onehot_ref, g_ref, dz_ref):
    # g is the per-row upstream cotangent (the 1/B of the mean loss is
    # already folded in by the caller).
    dz_ref[...] = (probs_ref[...] - onehot_ref[...]) * g_ref[...][:, None]


def _run_fwd(logits, onehot):
    b, c = logits.shape
    return pl.pallas_call(
        _fwd_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b, c), jnp.float32),
        ),
        interpret=True,
    )(logits.astype(jnp.float32), onehot)


def _run_bwd(probs, onehot, g_rows):
    b, c = probs.shape
    return pl.pallas_call(
        _bwd_kernel,
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.float32),
        interpret=True,
    )(probs, onehot, g_rows)


def softmax_logits(logits):
    """Softmax probabilities via the fused kernel (labels ignored)."""
    b, c = logits.shape
    dummy = jnp.zeros((b, c), jnp.float32)
    _, probs = _run_fwd(logits, dummy)
    return probs


@jax.custom_vjp
def softmax_xent(logits, labels):
    """Mean softmax cross-entropy.

    Args:
      logits: f32[B, C]
      labels: i32[B] class indices in [0, C)
    Returns:
      scalar f32 mean loss over the batch.
    """
    c = logits.shape[1]
    onehot = jax.nn.one_hot(labels, c, dtype=jnp.float32)
    loss_rows, _ = _run_fwd(logits, onehot)
    return jnp.mean(loss_rows)


def _xent_fwd(logits, labels):
    c = logits.shape[1]
    onehot = jax.nn.one_hot(labels, c, dtype=jnp.float32)
    loss_rows, probs = _run_fwd(logits, onehot)
    return jnp.mean(loss_rows), (probs, onehot)


def _xent_bwd(res, g):
    probs, onehot = res
    b = probs.shape[0]
    g_rows = jnp.full((b,), g / b, jnp.float32)
    dz = _run_bwd(probs, onehot, g_rows)
    return dz, None


softmax_xent.defvjp(_xent_fwd, _xent_bwd)
