"""Elementwise Pallas kernels: fused bias-add(+ReLU) with mask backward.

These fuse the bias broadcast with the activation so the post-matmul tile
is touched once while still VMEM-resident, instead of two HBM round trips.
Inputs are treated as (rows, features): callers flatten any leading batch/
spatial dims; the bias broadcasts over rows.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bias_relu_fwd_kernel(x_ref, b_ref, y_ref, mask_ref):
    pre = x_ref[...] + b_ref[...][None, :]
    mask = (pre > 0.0).astype(jnp.float32)
    mask_ref[...] = mask
    y_ref[...] = pre * mask


def _bias_relu_bwd_kernel(mask_ref, g_ref, dx_ref):
    dx_ref[...] = g_ref[...] * mask_ref[...]


def _bias_add_kernel(x_ref, b_ref, y_ref):
    y_ref[...] = x_ref[...] + b_ref[...][None, :]


def _as2d(x):
    return x.reshape(-1, x.shape[-1])


@jax.custom_vjp
def bias_relu(x, b):
    """relu(x + b) with b broadcast over the last axis."""
    y, _ = _bias_relu_fwd(x, b)
    return y


def _bias_relu_fwd(x, b):
    shape = x.shape
    x2 = _as2d(x).astype(jnp.float32)
    y, mask = pl.pallas_call(
        _bias_relu_fwd_kernel,
        out_shape=(
            jax.ShapeDtypeStruct(x2.shape, jnp.float32),
            jax.ShapeDtypeStruct(x2.shape, jnp.float32),
        ),
        interpret=True,
    )(x2, b.astype(jnp.float32))
    return y.reshape(shape), (mask, shape)


def _bias_relu_bwd(res, g):
    mask, shape = res
    g2 = _as2d(g).astype(jnp.float32)
    dx = pl.pallas_call(
        _bias_relu_bwd_kernel,
        out_shape=jax.ShapeDtypeStruct(g2.shape, jnp.float32),
        interpret=True,
    )(mask, g2)
    # d/db sums the masked cotangent over rows.
    db = jnp.sum(dx, axis=0)
    return dx.reshape(shape), db


bias_relu.defvjp(lambda x, b: _bias_relu_fwd(x, b), _bias_relu_bwd)


def bias_add(x, b):
    """x + b (broadcast over last axis) through a Pallas kernel.

    Linear, so the standard JVP/VJP machinery handles gradients; we only
    attach a custom VJP to keep the backward free of pallas_call transpose
    rules (pallas_call has no transpose in interpret mode).
    """
    return _bias_add(x, b)


@jax.custom_vjp
def _bias_add(x, b):
    shape = x.shape
    x2 = _as2d(x).astype(jnp.float32)
    y = pl.pallas_call(
        _bias_add_kernel,
        out_shape=jax.ShapeDtypeStruct(x2.shape, jnp.float32),
        interpret=True,
    )(x2, b.astype(jnp.float32))
    return y.reshape(shape)


def _bias_add_fwd(x, b):
    return _bias_add(x, b), x.shape


def _bias_add_bwd(shape, g):
    g2 = _as2d(g)
    return g.reshape(shape), jnp.sum(g2, axis=0)


_bias_add.defvjp(_bias_add_fwd, _bias_add_bwd)
