"""Pure-jnp correctness oracles for every Pallas kernel.

These are the ground truth the pytest suite compares the kernels against
(values *and* gradients, via jax.grad through these definitions). They are
also the "roofline reference" for the L1 performance comparison in
EXPERIMENTS.md SSPerf.

Conventions deliberately match the kernels:
  * maxpool backward gives the full cotangent to every element attaining
    the window max (tie duplication — measure-zero on continuous inputs);
  * LRN uses the TF CIFAR-tutorial constants (r=4, bias=1, alpha=1e-3/9,
    beta=0.75).
"""

import jax
import jax.numpy as jnp

# NB: `from . import lrn` would resolve to the *function* re-exported by
# __init__.py, not the module — import the submodule explicitly.
from .lrn import RADIUS as _LRN_R, BIAS as _LRN_BIAS, ALPHA as _LRN_ALPHA, BETA as _LRN_BETA


def matmul(a, b):
    return jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))


def softmax_xent(logits, labels):
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - picked)


def softmax_logits(logits):
    return jax.nn.softmax(logits.astype(jnp.float32), axis=-1)


def bias_relu(x, b):
    return jax.nn.relu(x.astype(jnp.float32) + b.astype(jnp.float32))


def bias_add(x, b):
    return x.astype(jnp.float32) + b.astype(jnp.float32)


def maxpool2x2(x):
    b, h, w, c = x.shape
    xr = x.reshape(b, h // 2, 2, w // 2, 2, c)
    return jnp.max(xr, axis=(2, 4))


def lrn(x):
    x = x.astype(jnp.float32)
    c = x.shape[-1]
    r = _LRN_R
    xp = jnp.pad(x * x, [(0, 0)] * (x.ndim - 1) + [(r, r)])
    acc = sum(xp[..., d : d + c] for d in range(2 * r + 1))
    s = _LRN_BIAS + _LRN_ALPHA * acc
    return x * s ** (-_LRN_BETA)
