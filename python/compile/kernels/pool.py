"""2x2 stride-2 max-pool Pallas kernel with mask backward.

Both paper models pool with non-overlapping 2x2 windows, so the pool is a
reshape + max over the two window axes — no sliding-window gather needed,
which keeps the kernel a pure VMEM-resident reduction.

Backward distributes the cotangent to every element that attained the
window max (ties share the gradient, matching the ``ref.py`` oracle).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pool_fwd_kernel(x_ref, y_ref):
    x = x_ref[...]
    b, h, w, c = x.shape
    xr = x.reshape(b, h // 2, 2, w // 2, 2, c)
    y_ref[...] = jnp.max(jnp.max(xr, axis=4), axis=2)


def _pool_bwd_kernel(x_ref, y_ref, g_ref, dx_ref):
    x = x_ref[...]
    b, h, w, c = x.shape
    # Broadcast the window max / cotangent back to input resolution.
    yb = jnp.repeat(jnp.repeat(y_ref[...], 2, axis=1), 2, axis=2)
    gb = jnp.repeat(jnp.repeat(g_ref[...], 2, axis=1), 2, axis=2)
    dx_ref[...] = jnp.where(x == yb, gb, 0.0)


@jax.custom_vjp
def maxpool2x2(x):
    """Max-pool f32[B,H,W,C] -> f32[B,H/2,W/2,C]; H, W must be even."""
    y, _ = _pool_fwd(x)
    return y


def _pool_fwd(x):
    b, h, w, c = x.shape
    if h % 2 or w % 2:
        raise ValueError(f"maxpool2x2 needs even H, W; got {x.shape}")
    y = pl.pallas_call(
        _pool_fwd_kernel,
        out_shape=jax.ShapeDtypeStruct((b, h // 2, w // 2, c), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32))
    return y, (x, y)


def _pool_bwd(res, g):
    x, y = res
    dx = pl.pallas_call(
        _pool_bwd_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), y, g.astype(jnp.float32))
    return (dx,)


maxpool2x2.defvjp(_pool_fwd, _pool_bwd)
