"""Tiled Pallas matmul with a custom VJP whose backward passes are also
Pallas matmuls.

This is the single compute hot-spot of CSE-FSL: every dense layer, the
1x1-conv auxiliary heads, and the 5x5/3x3 convolutions (via im2col in
``compile.convutil``) all reduce to this kernel.

TPU-style structure (DESIGN.md SSHardware-Adaptation): the grid iterates
over (M/bm, N/bn, K/bk) output/contraction tiles; each (bm, bk) x (bk, bn)
tile product targets the MXU systolic array and accumulates in a VMEM-
resident f32 output tile. Inputs whose dimensions are not multiples of the
tile sizes are zero-padded outside the kernel (zero rows/cols contribute
nothing to the contraction) and the result is sliced back.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile-size policy.
#
# On a real TPU the natural tile is 128x128x128 (MXU lane width); under
# interpret=True on CPU every grid step costs a dynamic-slice round trip,
# so we instead pick the largest tiles that keep the working set under a
# "VMEM budget" — usually a 1x1x1 grid (single resident tile), splitting
# the M axis only for very large im2col matmuls. Set CSE_FSL_TPU_TILES=1
# at AOT time to force the 128-tile TPU-shaped schedule (what DESIGN.md
# §Perf-estimates reasons about); numerics are identical either way and
# the test suite exercises both paths.
import os

BM, BN, BK = 128, 128, 128

# ~64 MB of f32 working set per grid step (a*b + out tiles).
_ELEM_BUDGET = 16_000_000


def _auto_blocks(m, k, n):
    if os.environ.get("CSE_FSL_TPU_TILES") == "1":
        return min(BM, m), min(BN, n), min(BK, k)
    # Keep N and K whole (they are small in every model here: <= 9216),
    # split M until the per-step working set fits the budget.
    bm = m
    while bm > 1 and bm * k + k * n + bm * n > _ELEM_BUDGET:
        bm = (bm + 1) // 2
    return bm, n, k


def _mm_kernel(a_ref, b_ref, o_ref):
    """One grid step: o[i, j] (+)= a[i, l] @ b[l, j]."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(x, m0, m1):
    """Zero-pad a 2-D array so its dims are multiples of (m0, m1)."""
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 == 0 and p1 == 0:
        return x
    return jnp.pad(x, ((0, p0), (0, p1)))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_nograd(a, b, bm=None, bn=None, bk=None):
    """Pallas tiled matmul, no custom gradient attached.

    Used directly by the backward passes (to avoid recursive custom_vjp)
    and exported for benchmarking against the jnp reference.
    """
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError(f"matmul expects 2-D operands, got {a.shape} @ {b.shape}")
    if a.shape[1] != b.shape[0]:
        raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
    m, k = a.shape
    _, n = b.shape
    auto_m, auto_n, auto_k = _auto_blocks(m, k, n)
    bm_ = min(bm or auto_m, m)
    bn_ = min(bn or auto_n, n)
    bk_ = min(bk or auto_k, k)
    ap = _pad_to(a.astype(jnp.float32), bm_, bk_)
    bp = _pad_to(b.astype(jnp.float32), bk_, bn_)
    mp, kp = ap.shape
    _, np_ = bp.shape
    out = pl.pallas_call(
        _mm_kernel,
        grid=(mp // bm_, np_ // bn_, kp // bk_),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk_, bn_), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(ap, bp)
    if (mp, np_) != (m, n):
        out = out[:m, :n]
    return out


@jax.custom_vjp
def matmul(a, b):
    """``a @ b`` through the Pallas kernel, differentiable.

    Backward:  dA = g @ B^T,  dB = A^T @ g  — both again Pallas matmuls.
    """
    return matmul_nograd(a, b)


def _matmul_fwd(a, b):
    return matmul_nograd(a, b), (a, b)


def _matmul_bwd(res, g):
    a, b = res
    da = matmul_nograd(g, b.T)
    db = matmul_nograd(a.T, g)
    return da, db


matmul.defvjp(_matmul_fwd, _matmul_bwd)
