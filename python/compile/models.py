"""L2 split-model definitions over flat parameter vectors.

The flat f32 parameter vector is the ABI between the JAX compute layer and
the Rust coordinator: Rust initializes, aggregates (FedAvg, Eq. (14)),
serializes, and byte-accounts parameter vectors; JAX only sees them as a
single `f32[P]` input and unpacks with static slices (differentiable, so
`jax.grad` w.r.t. the flat vector just works).

Architectures reproduce the paper exactly (Section VI-A), validated against
the printed parameter counts:

CIFAR-10 (B=50, 32x32x3, 10 classes)
  client : conv5x5 SAME 3->64 +ReLU, maxpool2x2, LRN,
           conv5x5 VALID 64->64 +ReLU, LRN, maxpool2x2  -> smashed 6x6x64
           params = 107,328                         (paper Table III text)
  server : FC 2304->384 +ReLU, FC 384->192 +ReLU, FC 192->10
           params = 960,970
  aux    : MLP 2304->10 = 23,050; CNN(1x1 64->c)+MLP 36c->10:
           c=54: 22,960  c=27: 11,485  c=14: 5,960  c=7: 2,985 (Table III)

F-EMNIST (B=10, 28x28x1, 62 classes)
  client : conv3x3 VALID 1->32 +ReLU, conv3x3 VALID 32->64 +ReLU,
           maxpool2x2, dropout(0.25)                -> smashed 12x12x64
           params = 18,816
  server : FC 9216->128 +ReLU, dropout(0.5), FC 128->62
           params = 1,187,774
  aux    : MLP 9216->62 = 571,454; CNN(1x1 64->c)+MLP 144c->62:
           c=64: 575,614  c=32: 287,838  c=8: 72,006  c=2: 18,048 (Table IV)
"""

import math

import jax
import jax.numpy as jnp

from .convutil import conv2d, conv1x1
from .kernels import bias_relu, bias_add, maxpool2x2, lrn, matmul


# --------------------------------------------------------------- layouts


def _spec(name, shape, init, fan_in=None):
    size = int(math.prod(shape))
    if init == "he":
        std = math.sqrt(2.0 / fan_in)
        init_d = {"kind": "normal", "std": std}
    elif init == "glorot":
        # Output heads: smaller scale keeps the initial loss near ln(C)
        # and matches the classic TF-CIFAR-tutorial small-std fc init.
        std = math.sqrt(1.0 / fan_in)
        init_d = {"kind": "normal", "std": std}
    elif init == "zero":
        init_d = {"kind": "zero"}
    else:
        raise ValueError(init)
    return {"name": name, "shape": list(shape), "size": size, "init": init_d}


def build_layout(specs):
    """Assign offsets; returns (layout list, total size)."""
    off = 0
    out = []
    for s in specs:
        s = dict(s)
        s["offset"] = off
        off += s["size"]
        out.append(s)
    return out, off


def layout_size(layout):
    return sum(s["size"] for s in layout)


def unpack(flat, layout):
    """Split a flat f32[P] vector into named tensors (static slices)."""
    out = {}
    for s in layout:
        off, size = s["offset"], s["size"]
        out[s["name"]] = flat[off : off + size].reshape(s["shape"])
    return out


def cifar_client_layout():
    return build_layout([
        _spec("conv1_w", (5, 5, 3, 64), "he", fan_in=5 * 5 * 3),
        _spec("conv1_b", (64,), "zero"),
        _spec("conv2_w", (5, 5, 64, 64), "he", fan_in=5 * 5 * 64),
        _spec("conv2_b", (64,), "zero"),
    ])


def cifar_server_layout():
    return build_layout([
        _spec("fc1_w", (2304, 384), "he", fan_in=2304),
        _spec("fc1_b", (384,), "zero"),
        _spec("fc2_w", (384, 192), "he", fan_in=384),
        _spec("fc2_b", (192,), "zero"),
        _spec("fc3_w", (192, 10), "glorot", fan_in=192),
        _spec("fc3_b", (10,), "zero"),
    ])


def cifar_aux_layout(arch):
    """arch: "mlp" or "cnn<channels>" (e.g. "cnn54")."""
    if arch == "mlp":
        return build_layout([
            _spec("aux_fc_w", (2304, 10), "glorot", fan_in=2304),
            _spec("aux_fc_b", (10,), "zero"),
        ])
    c = int(arch[3:])
    return build_layout([
        _spec("aux_conv_w", (64, c), "he", fan_in=64),
        _spec("aux_conv_b", (c,), "zero"),
        _spec("aux_fc_w", (36 * c, 10), "glorot", fan_in=36 * c),
        _spec("aux_fc_b", (10,), "zero"),
    ])


def femnist_client_layout():
    return build_layout([
        _spec("conv1_w", (3, 3, 1, 32), "he", fan_in=3 * 3 * 1),
        _spec("conv1_b", (32,), "zero"),
        _spec("conv2_w", (3, 3, 32, 64), "he", fan_in=3 * 3 * 32),
        _spec("conv2_b", (64,), "zero"),
    ])


def femnist_server_layout():
    return build_layout([
        _spec("fc1_w", (9216, 128), "he", fan_in=9216),
        _spec("fc1_b", (128,), "zero"),
        _spec("fc2_w", (128, 62), "glorot", fan_in=128),
        _spec("fc2_b", (62,), "zero"),
    ])


def femnist_aux_layout(arch):
    if arch == "mlp":
        return build_layout([
            _spec("aux_fc_w", (9216, 62), "glorot", fan_in=9216),
            _spec("aux_fc_b", (62,), "zero"),
        ])
    c = int(arch[3:])
    return build_layout([
        _spec("aux_conv_w", (64, c), "he", fan_in=64),
        _spec("aux_conv_b", (c,), "zero"),
        _spec("aux_fc_w", (144 * c, 62), "glorot", fan_in=144 * c),
        _spec("aux_fc_b", (62,), "zero"),
    ])


# ------------------------------------------------------------- forwards


def _dropout(x, rate, seed, tag, train):
    """Deterministic dropout from an i32 seed (replayable for client_bwd)."""
    if not train or rate <= 0.0:
        return x
    key = jax.random.fold_in(jax.random.PRNGKey(seed), tag)
    keep = 1.0 - rate
    mask = jax.random.bernoulli(key, keep, x.shape).astype(jnp.float32)
    return x * mask / keep


def cifar_client_forward(params, x, seed, train):
    """f32[B,32,32,3] -> smashed f32[B,6,6,64]. ``seed`` unused (no dropout)
    but kept so every dataset has the same client entry signature."""
    del seed, train
    h = conv2d(x, params["conv1_w"], padding="SAME")
    h = bias_relu(h, params["conv1_b"])
    h = maxpool2x2(h)  # 32 -> 16
    h = lrn(h)
    h = conv2d(h, params["conv2_w"], padding="VALID")  # 16 -> 12
    h = bias_relu(h, params["conv2_b"])
    h = lrn(h)
    h = maxpool2x2(h)  # 12 -> 6
    return h


def cifar_server_forward(params, smashed, seed, train):
    del seed, train
    b = smashed.shape[0]
    h = smashed.reshape(b, 2304)
    h = bias_relu(matmul(h, params["fc1_w"]), params["fc1_b"])
    h = bias_relu(matmul(h, params["fc2_w"]), params["fc2_b"])
    return bias_add(matmul(h, params["fc3_w"]), params["fc3_b"])


def cifar_aux_forward(params, smashed, arch):
    b = smashed.shape[0]
    if arch == "mlp":
        h = smashed.reshape(b, 2304)
    else:
        h = conv1x1(smashed, params["aux_conv_w"])
        h = bias_relu(h, params["aux_conv_b"])
        h = h.reshape(b, -1)
    return bias_add(matmul(h, params["aux_fc_w"]), params["aux_fc_b"])


def femnist_client_forward(params, x, seed, train):
    h = conv2d(x, params["conv1_w"], padding="VALID")  # 28 -> 26
    h = bias_relu(h, params["conv1_b"])
    h = conv2d(h, params["conv2_w"], padding="VALID")  # 26 -> 24
    h = bias_relu(h, params["conv2_b"])
    h = maxpool2x2(h)  # 24 -> 12
    h = _dropout(h, 0.25, seed, tag=1, train=train)
    return h


def femnist_server_forward(params, smashed, seed, train):
    b = smashed.shape[0]
    h = smashed.reshape(b, 9216)
    h = bias_relu(matmul(h, params["fc1_w"]), params["fc1_b"])
    h = _dropout(h, 0.5, seed, tag=2, train=train)
    return bias_add(matmul(h, params["fc2_w"]), params["fc2_b"])


def femnist_aux_forward(params, smashed, arch):
    b = smashed.shape[0]
    if arch == "mlp":
        h = smashed.reshape(b, 9216)
    else:
        h = conv1x1(smashed, params["aux_conv_w"])
        h = bias_relu(h, params["aux_conv_b"])
        h = h.reshape(b, -1)
    return bias_add(matmul(h, params["aux_fc_w"]), params["aux_fc_b"])


# ------------------------------------------------------------- registry

CONFIGS = {
    "cifar": {
        "batch": 50,
        "input": [32, 32, 3],
        "classes": 10,
        "smashed": [6, 6, 64],
        "aux_archs": ["mlp", "cnn54", "cnn27", "cnn14", "cnn7"],
        "client_layout": cifar_client_layout,
        "server_layout": cifar_server_layout,
        "aux_layout": cifar_aux_layout,
        "client_forward": cifar_client_forward,
        "server_forward": cifar_server_forward,
        "aux_forward": cifar_aux_forward,
    },
    "femnist": {
        "batch": 10,
        "input": [28, 28, 1],
        "classes": 62,
        "smashed": [12, 12, 64],
        "aux_archs": ["mlp", "cnn64", "cnn32", "cnn8", "cnn2"],
        "client_layout": femnist_client_layout,
        "server_layout": femnist_server_layout,
        "aux_layout": femnist_aux_layout,
        "client_forward": femnist_client_forward,
        "server_forward": femnist_server_forward,
        "aux_forward": femnist_aux_forward,
    },
}

# Paper-printed parameter counts, asserted in tests and at AOT time.
PAPER_COUNTS = {
    "cifar": {
        "client": 107_328,
        "server": 960_970,
        "aux": {"mlp": 23_050, "cnn54": 22_960, "cnn27": 11_485,
                "cnn14": 5_960, "cnn7": 2_985},
    },
    "femnist": {
        "client": 18_816,
        "server": 1_187_774,
        "aux": {"mlp": 571_454, "cnn64": 575_614, "cnn32": 287_838,
                "cnn8": 72_006, "cnn2": 18_048},
    },
}
