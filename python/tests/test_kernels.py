"""L1 kernel correctness: every Pallas kernel vs the pure-jnp oracle.

Values AND gradients are checked (gradients matter twice here: the custom
VJPs are hand-written Pallas kernels, and the whole L2 training path
differentiates through them). Hypothesis sweeps shapes; fixed-seed cases
pin the exact shapes the paper's models use.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

ATOL, RTOL = 1e-4, 1e-4


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def assert_close(a, b, atol=ATOL, rtol=RTOL):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol, rtol=rtol)


# ---------------------------------------------------------------- matmul


@settings(max_examples=12, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    seed=st.integers(0, 2**16),
)
def test_matmul_matches_ref(m, k, n, seed):
    a = rand(seed, m, k)
    b = rand(seed + 1, k, n)
    assert_close(kernels.matmul(a, b), ref.matmul(a, b))


@pytest.mark.parametrize(
    "m,k,n",
    [
        (50 * 6 * 6, 64, 54),  # aux 1x1 conv (CIFAR, B=50)
        (50, 2304, 384),  # server fc1 (CIFAR)
        (10, 9216, 128),  # server fc1 (F-EMNIST, B=10)
        (200, 75, 64),  # im2col conv tile
        (1, 1, 1),
        (128, 128, 128),  # exactly one tile
        (129, 257, 130),  # just past tile boundaries
    ],
)
def test_matmul_model_shapes(m, k, n):
    a = rand(m + k, m, k)
    b = rand(n + k, k, n)
    assert_close(kernels.matmul(a, b), ref.matmul(a, b))


def test_matmul_grads():
    a = rand(7, 33, 21)
    b = rand(8, 21, 17)

    def f_kern(a, b):
        return jnp.sum(jnp.sin(kernels.matmul(a, b)))

    def f_ref(a, b):
        return jnp.sum(jnp.sin(ref.matmul(a, b)))

    ga_k, gb_k = jax.grad(f_kern, argnums=(0, 1))(a, b)
    ga_r, gb_r = jax.grad(f_ref, argnums=(0, 1))(a, b)
    assert_close(ga_k, ga_r)
    assert_close(gb_k, gb_r)


def test_matmul_rejects_bad_shapes():
    with pytest.raises(ValueError):
        kernels.matmul_nograd(jnp.zeros((3, 4)), jnp.zeros((5, 6)))
    with pytest.raises(ValueError):
        kernels.matmul_nograd(jnp.zeros((3,)), jnp.zeros((3, 2)))


# ---------------------------------------------------------- softmax_xent


@settings(max_examples=12, deadline=None)
@given(b=st.integers(1, 64), c=st.integers(2, 70), seed=st.integers(0, 2**16))
def test_softmax_xent_matches_ref(b, c, seed):
    logits = rand(seed, b, c) * 3.0
    labels = jax.random.randint(jax.random.PRNGKey(seed + 1), (b,), 0, c)
    assert_close(kernels.softmax_xent(logits, labels), ref.softmax_xent(logits, labels))


def test_softmax_xent_grad_closed_form():
    b, c = 10, 62
    logits = rand(3, b, c)
    labels = jax.random.randint(jax.random.PRNGKey(4), (b,), 0, c)
    g_k = jax.grad(kernels.softmax_xent)(logits, labels)
    g_r = jax.grad(ref.softmax_xent)(logits, labels)
    assert_close(g_k, g_r)


def test_softmax_xent_extreme_logits_stable():
    logits = jnp.array([[1e4, -1e4, 0.0], [-1e4, 1e4, 0.0]], jnp.float32)
    labels = jnp.array([0, 1], jnp.int32)
    loss = kernels.softmax_xent(logits, labels)
    assert np.isfinite(float(loss))
    assert float(loss) < 1e-3


def test_softmax_logits_rows_sum_to_one():
    p = kernels.softmax_logits(rand(5, 50, 10))
    assert_close(jnp.sum(p, axis=-1), jnp.ones(50))
    assert_close(p, ref.softmax_logits(rand(5, 50, 10)))


# ------------------------------------------------------------ elementwise


@settings(max_examples=10, deadline=None)
@given(r=st.integers(1, 80), f=st.integers(1, 80), seed=st.integers(0, 2**16))
def test_bias_relu_matches_ref(r, f, seed):
    x = rand(seed, r, f)
    b = rand(seed + 1, f)
    assert_close(kernels.bias_relu(x, b), ref.bias_relu(x, b))


def test_bias_relu_grad():
    x = rand(11, 20, 30)
    b = rand(12, 30)

    def f(fn):
        return lambda x, b: jnp.sum(fn(x, b) ** 2)

    gx_k, gb_k = jax.grad(f(kernels.bias_relu), argnums=(0, 1))(x, b)
    gx_r, gb_r = jax.grad(f(ref.bias_relu), argnums=(0, 1))(x, b)
    assert_close(gx_k, gx_r)
    assert_close(gb_k, gb_r)


def test_bias_relu_4d_input():
    x = rand(13, 2, 8, 8, 16)
    b = rand(14, 16)
    assert_close(kernels.bias_relu(x, b), ref.bias_relu(x, b))


def test_bias_add_matches_ref_and_grad():
    x = rand(15, 9, 13)
    b = rand(16, 13)
    assert_close(kernels.bias_add(x, b), ref.bias_add(x, b))
    gx, gb = jax.grad(lambda x, b: jnp.sum(jnp.cos(kernels.bias_add(x, b))), (0, 1))(x, b)
    rx, rb = jax.grad(lambda x, b: jnp.sum(jnp.cos(ref.bias_add(x, b))), (0, 1))(x, b)
    assert_close(gx, rx)
    assert_close(gb, rb)


# ------------------------------------------------------------------ pool


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 8),
    h=st.integers(1, 12),
    w=st.integers(1, 12),
    c=st.integers(1, 32),
    seed=st.integers(0, 2**16),
)
def test_maxpool_matches_ref(b, h, w, c, seed):
    x = rand(seed, b, 2 * h, 2 * w, c)
    assert_close(kernels.maxpool2x2(x), ref.maxpool2x2(x))


def test_maxpool_grad():
    x = rand(21, 3, 8, 8, 5)
    g_k = jax.grad(lambda x: jnp.sum(kernels.maxpool2x2(x) ** 2))(x)
    g_r = jax.grad(lambda x: jnp.sum(ref.maxpool2x2(x) ** 2))(x)
    assert_close(g_k, g_r)


def test_maxpool_odd_shape_rejected():
    with pytest.raises(ValueError):
        kernels.maxpool2x2(jnp.zeros((1, 3, 4, 2)))


# ------------------------------------------------------------------- lrn


@settings(max_examples=8, deadline=None)
@given(r=st.integers(1, 20), c=st.integers(1, 70), seed=st.integers(0, 2**16))
def test_lrn_matches_ref(r, c, seed):
    x = rand(seed, r, c) * 2.0
    assert_close(kernels.lrn(x), ref.lrn(x))


def test_lrn_model_shape_and_grad():
    x = rand(31, 50, 16, 16, 64)  # CIFAR post-pool1 shape
    assert_close(kernels.lrn(x), ref.lrn(x))
    g_k = jax.grad(lambda x: jnp.sum(jnp.tanh(kernels.lrn(x))))(x)
    g_r = jax.grad(lambda x: jnp.sum(jnp.tanh(ref.lrn(x))))(x)
    assert_close(g_k, g_r, atol=3e-4, rtol=3e-4)


def test_lrn_grad_vs_numerical():
    x = rand(33, 4, 9)
    f = lambda x: jnp.sum(kernels.lrn(x) * jnp.arange(9.0))
    g = jax.grad(f)(x)
    eps = 1e-3
    num = np.zeros_like(np.asarray(x))
    xn = np.asarray(x)
    for i in range(4):
        for j in range(9):
            xp, xm = xn.copy(), xn.copy()
            xp[i, j] += eps
            xm[i, j] -= eps
            num[i, j] = (float(f(jnp.asarray(xp))) - float(f(jnp.asarray(xm)))) / (2 * eps)
    np.testing.assert_allclose(np.asarray(g), num, atol=5e-3, rtol=5e-3)


# -------------------------------------------------- jit/lowering sanity


def test_kernels_lower_inside_jit_to_hlo_text():
    """The whole point: kernel graphs must lower to HLO *text* (the AOT
    interchange format the Rust runtime loads)."""

    def f(a, b, labels):
        return kernels.softmax_xent(kernels.matmul(a, b), labels)

    lowered = jax.jit(f).lower(
        jax.ShapeDtypeStruct((8, 16), jnp.float32),
        jax.ShapeDtypeStruct((16, 10), jnp.float32),
        jax.ShapeDtypeStruct((8,), jnp.int32),
    )
    from compile.aot import to_hlo_text

    text = to_hlo_text(lowered)
    assert "ENTRY" in text and len(text) > 100


def test_matmul_forced_multi_tile_grid_matches_ref():
    """The TPU-shaped multi-tile path (grid > 1 on every axis) must agree
    with the single-tile fast path and the oracle."""
    a = rand(91, 129, 257)
    b = rand(92, 257, 130)
    out_tiled = kernels.matmul_nograd(a, b, bm=32, bn=32, bk=32)
    out_auto = kernels.matmul_nograd(a, b)
    assert_close(out_tiled, ref.matmul(a, b), atol=3e-4, rtol=3e-4)
    assert_close(out_tiled, out_auto, atol=3e-4, rtol=3e-4)


def test_matmul_tpu_tiles_env(monkeypatch):
    monkeypatch.setenv("CSE_FSL_TPU_TILES", "1")
    a = rand(93, 200, 150)
    b = rand(94, 150, 140)
    assert_close(kernels.matmul_nograd(a, b, bm=None, bn=None, bk=None),
                 ref.matmul(a, b), atol=3e-4, rtol=3e-4)
