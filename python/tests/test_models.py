"""L2 model tests: exact paper parameter counts, shapes, training-step
semantics (loss decreases, SGD algebra, dropout replay), split-vs-monolith
gradient equivalence, and clipping behaviour.

Small batches are used where the entry allows it — make_entries only fixes
batch size at AOT time; here we call the python callables directly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, models

jax.config.update("jax_platform_name", "cpu")


def flat_init(layout, total, seed=0):
    """He/zero init identical in spirit to rust/src/model/init.rs."""
    key = jax.random.PRNGKey(seed)
    parts = []
    for spec in layout:
        key, sub = jax.random.split(key)
        if spec["init"]["kind"] == "zero":
            parts.append(jnp.zeros((spec["size"],), jnp.float32))
        else:
            std = spec["init"]["std"]
            parts.append(std * jax.random.normal(sub, (spec["size"],), jnp.float32))
    flat = jnp.concatenate(parts)
    assert flat.shape[0] == total
    return flat


@pytest.mark.parametrize("dataset", ["cifar", "femnist"])
def test_paper_param_counts_exact(dataset):
    cfg = models.CONFIGS[dataset]
    want = models.PAPER_COUNTS[dataset]
    _, client_n = cfg["client_layout"]()
    _, server_n = cfg["server_layout"]()
    assert client_n == want["client"]
    assert server_n == want["server"]
    for aux_arch, count in want["aux"].items():
        _, aux_n = cfg["aux_layout"](aux_arch)
        assert aux_n == count, f"{dataset}/{aux_arch}"


@pytest.mark.parametrize("dataset", ["cifar", "femnist"])
def test_layout_offsets_are_contiguous(dataset):
    cfg = models.CONFIGS[dataset]
    for layout, total in (cfg["client_layout"](), cfg["server_layout"](),
                          cfg["aux_layout"](cfg["aux_archs"][1])):
        off = 0
        for spec in layout:
            assert spec["offset"] == off
            assert spec["size"] == int(np.prod(spec["shape"]))
            off += spec["size"]
        assert off == total


@pytest.mark.parametrize("dataset,aux", [("cifar", "mlp"), ("cifar", "cnn27"),
                                         ("femnist", "cnn8")])
def test_smashed_and_logit_shapes(dataset, aux):
    cfg = models.CONFIGS[dataset]
    b = 4
    entries, meta = model.make_entries(dataset, aux)
    xc = flat_init(meta["client_layout"], meta["client_size"])
    xs = flat_init(meta["server_layout"], meta["server_size"])
    ac = flat_init(meta["aux_layout"], meta["aux_size"])
    x = jax.random.normal(jax.random.PRNGKey(1), tuple([b] + cfg["input"]))
    smashed = cfg["client_forward"](models.unpack(xc, meta["client_layout"]), x, 0, True)
    assert smashed.shape == tuple([b] + cfg["smashed"])
    logits = cfg["server_forward"](models.unpack(xs, meta["server_layout"]), smashed, 0, False)
    assert logits.shape == (b, cfg["classes"])
    alog = cfg["aux_forward"](models.unpack(ac, meta["aux_layout"]), smashed, aux)
    assert alog.shape == (b, cfg["classes"])


def _setup(dataset="cifar", aux="cnn27", b=None, seed=0):
    cfg = models.CONFIGS[dataset]
    entries, meta = model.make_entries(dataset, aux)
    b = b or cfg["batch"]
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, tuple([b] + cfg["input"]), jnp.float32)
    y = jax.random.randint(jax.random.PRNGKey(seed + 1), (b,), 0, cfg["classes"])
    xc = flat_init(meta["client_layout"], meta["client_size"], seed + 2)
    ac = flat_init(meta["aux_layout"], meta["aux_size"], seed + 3)
    xs = flat_init(meta["server_layout"], meta["server_size"], seed + 4)
    return cfg, entries, meta, x, y, xc, ac, xs


def test_client_train_step_reduces_local_loss():
    cfg, entries, meta, x, y, xc, ac, xs = _setup(b=8)
    step = jax.jit(entries["client_train_step"][0])
    lr = jnp.float32(0.01)
    losses = []
    for i in range(6):
        xc, ac, loss, gnorm = step(xc, ac, x, y, lr, jnp.int32(i))
        losses.append(float(loss))
        assert float(gnorm) > 0.0
    assert losses[-1] < losses[0], losses


def test_server_train_step_reduces_server_loss():
    cfg, entries, meta, x, y, xc, ac, xs = _setup(b=8)
    sm = jax.jit(entries["client_fwd"][0])(xc, x, jnp.int32(0))
    step = jax.jit(entries["server_train_step"][0])
    losses = []
    for i in range(10):
        xs, loss, gnorm = step(xs, sm, y, jnp.float32(0.005), jnp.int32(i))
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_sgd_update_algebra():
    """x' = x - lr * g exactly: running with lr=0 must be an identity."""
    cfg, entries, meta, x, y, xc, ac, xs = _setup(b=4)
    xc2, ac2, _, _ = entries["client_train_step"][0](xc, ac, x, y, jnp.float32(0.0), jnp.int32(0))
    np.testing.assert_allclose(np.asarray(xc2), np.asarray(xc))
    np.testing.assert_allclose(np.asarray(ac2), np.asarray(ac))


def test_split_fwd_bwd_equals_monolithic_grad():
    """FSL_MC decomposition check: client_fwd + server_fwd_bwd + client_bwd
    must implement exactly one SGD step of the *joint* model."""
    cfg, entries, meta, x, y, xc, ac, xs = _setup(dataset="cifar", b=4)
    lr = jnp.float32(0.1)
    seed = jnp.int32(7)
    noclip = jnp.float32(0.0)

    sm = entries["client_fwd"][0](xc, x, seed)
    xs2, gsm, loss, _ = entries["server_fwd_bwd"][0](xs, sm, y, lr, seed, noclip)
    xc2, _ = entries["client_bwd"][0](xc, x, gsm, lr, seed, noclip)

    # Monolithic reference
    cl, sl = meta["client_layout"], meta["server_layout"]

    def joint_loss(xc, xs):
        smashed = cfg["client_forward"](models.unpack(xc, cl), x, seed, True)
        logits = cfg["server_forward"](models.unpack(xs, sl), smashed, seed, True)
        from compile.kernels import softmax_xent
        return softmax_xent(logits, y)

    l, (gxc, gxs) = jax.value_and_grad(joint_loss, (0, 1))(xc, xs)
    np.testing.assert_allclose(float(loss), float(l), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(xs2), np.asarray(xs - lr * gxs), atol=1e-6)
    np.testing.assert_allclose(np.asarray(xc2), np.asarray(xc - lr * gxc), atol=1e-6)


def test_femnist_dropout_replay_is_deterministic():
    """client_bwd must replay the same dropout mask as client_fwd (same
    seed) — otherwise FSL_MC on F-EMNIST silently trains on wrong grads."""
    cfg, entries, meta, x, y, xc, ac, xs = _setup("femnist", "mlp", b=4)
    s1 = entries["client_fwd"][0](xc, x, jnp.int32(3))
    s2 = entries["client_fwd"][0](xc, x, jnp.int32(3))
    s3 = entries["client_fwd"][0](xc, x, jnp.int32(4))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    assert not np.allclose(np.asarray(s1), np.asarray(s3))
    # dropout actually drops ~25% of activations
    frac_zero = float(np.mean(np.asarray(s1) == 0.0))
    assert 0.15 < frac_zero


def test_eval_step_has_no_dropout_noise():
    cfg, entries, meta, x, y, xc, ac, xs = _setup("femnist", "mlp", b=4)
    l1 = entries["eval_step"][0](xc, xs, x)
    l2 = entries["eval_step"][0](xc, xs, x)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_gradient_clipping_caps_global_norm():
    cfg, entries, meta, x, y, xc, ac, xs = _setup(b=4)
    lr = jnp.float32(1.0)
    sm = entries["client_fwd"][0](xc, x, jnp.int32(0))
    clip = jnp.float32(1e-3)
    xs2, gsm, _, gnorm = entries["server_fwd_bwd"][0](xs, sm, y, lr, jnp.int32(0), clip)
    # post-clip server grad = (xs - xs2) / lr has norm <= clip
    g = np.asarray(xs - xs2)
    assert np.linalg.norm(g) <= float(clip) * 1.001
    gsm_norm = np.linalg.norm(np.asarray(gsm).ravel())
    assert gsm_norm <= float(clip) * 1.001


def test_clip_disabled_is_identity():
    cfg, entries, meta, x, y, xc, ac, xs = _setup(b=4)
    lr = jnp.float32(0.1)
    sm = entries["client_fwd"][0](xc, x, jnp.int32(0))
    a = entries["server_fwd_bwd"][0](xs, sm, y, lr, jnp.int32(0), jnp.float32(0.0))
    b_ = entries["server_fwd_bwd"][0](xs, sm, y, lr, jnp.int32(0), jnp.float32(1e12))
    np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b_[0]), atol=1e-7)


def test_aux_eval_step_shapes():
    cfg, entries, meta, x, y, xc, ac, xs = _setup("cifar", "cnn14", b=4)
    logits = entries["aux_eval_step"][0](xc, ac, x)
    assert logits.shape == (4, 10)


def test_unpack_roundtrip():
    layout, total = models.CONFIGS["cifar"]["client_layout"]()
    flat = jnp.arange(total, dtype=jnp.float32)
    tensors = models.unpack(flat, layout)
    rebuilt = jnp.concatenate([tensors[s["name"]].reshape(-1) for s in layout])
    np.testing.assert_array_equal(np.asarray(rebuilt), np.asarray(flat))
