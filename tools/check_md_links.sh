#!/usr/bin/env bash
# In-repo markdown link check: every relative [text](path) link in the
# repo's top-level docs (and the coordinator contract doc) must resolve
# to a file or directory in the tree. External links (scheme://),
# pure anchors (#...), and absolute paths are skipped. Run from anywhere;
# CI runs it after checkout.
set -euo pipefail

cd "$(dirname "$0")/.."

docs=(
  README.md
  ARCHITECTURE.md
  EXPERIMENTS.md
  ROADMAP.md
  rust/src/coordinator/README.md
)

fail=0
for doc in "${docs[@]}"; do
  if [ ! -f "$doc" ]; then
    echo "MISSING DOC: $doc"
    fail=1
    continue
  fi
  dir=$(dirname "$doc")
  # Extract (target) of every inline markdown link, one per line.
  targets=$(grep -oE '\]\([^)#[:space:]]+[^)]*\)' "$doc" | sed -E 's/^\]\(//; s/\)$//' || true)
  while IFS= read -r t; do
    [ -z "$t" ] && continue
    case "$t" in
      *://*|mailto:*|\#*|/*) continue ;;
    esac
    # Drop trailing anchors: path.md#section -> path.md
    p="${t%%#*}"
    [ -z "$p" ] && continue
    if [ ! -e "$dir/$p" ]; then
      echo "BROKEN LINK: $doc -> $t"
      fail=1
    fi
  done <<< "$targets"
done

if [ "$fail" -ne 0 ]; then
  echo "markdown link check failed"
  exit 1
fi
echo "markdown link check passed"
