//! Pure-Rust mock engine with transparent linear dynamics.
//!
//! Implements [`SplitEngine`] without PJRT so coordinator logic (routing,
//! batching, event ordering, aggregation, accounting) can be tested and
//! property-checked in microseconds. Dynamics are deliberately simple and
//! analytically predictable:
//!
//! * each model part has a fixed target vector T (derived from a seed);
//!   the "loss" of a step is ||params - T||²/(2·len) and the SGD update is
//!   exact gradient descent on it, so params converge geometrically and
//!   FedAvg of converging clients also converges (linear dynamics);
//! * smashed data is an affine function of (mean(x_c), batch images) so
//!   server steps depend on client state (ordering effects measurable);
//! * eval logits score class c by -(distance of params to target) + a
//!   per-sample signature so accuracy rises as training proceeds.

use crate::util::prng::Rng;

use super::{ClientStepOut, EngineError, ServerFwdBwdOut, ServerStepOut, SplitEngine};

/// The linear-dynamics mock engine (see module docs).
#[derive(Clone, Debug)]
pub struct MockEngine {
    /// Batch size.
    pub batch: usize,
    /// Output classes.
    pub classes: usize,
    /// Input elements per sample.
    pub input_len: usize,
    /// Smashed elements per sample.
    pub smashed_len: usize,
    target_client: Vec<f32>,
    target_aux: Vec<f32>,
    target_server: Vec<f32>,
}

impl MockEngine {
    /// Build a mock engine with the given geometry; `seed` fixes the
    /// target vectors (and hence the whole dynamics).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        batch: usize,
        classes: usize,
        input_len: usize,
        smashed_len: usize,
        client_size: usize,
        aux_size: usize,
        server_size: usize,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::new(seed);
        let mk = |n: usize, rng: &mut Rng| (0..n).map(|_| rng.normal() as f32).collect();
        MockEngine {
            batch,
            classes,
            input_len,
            smashed_len,
            target_client: mk(client_size, &mut rng),
            target_aux: mk(aux_size, &mut rng),
            target_server: mk(server_size, &mut rng),
        }
    }

    /// A small default geometry for tests.
    pub fn small(seed: u64) -> Self {
        MockEngine::new(4, 3, 8, 6, 32, 8, 24, seed)
    }

    fn check(&self, name: &str, len: usize, want: usize) -> Result<(), EngineError> {
        if len != want {
            return Err(EngineError::Shape(format!("{name}: len {len} != {want}")));
        }
        Ok(())
    }

    fn quad_step(params: &[f32], target: &[f32], lr: f32) -> (Vec<f32>, f32, f32) {
        // loss = ||p - T||^2 / (2 len); grad = (p - T)/len
        let n = params.len() as f32;
        let mut new = Vec::with_capacity(params.len());
        let mut loss = 0f32;
        let mut gsq = 0f32;
        for (&p, &t) in params.iter().zip(target) {
            let g = (p - t) / n;
            loss += (p - t) * (p - t);
            gsq += g * g;
            new.push(p - lr * g);
        }
        (new, loss / (2.0 * n), gsq.sqrt())
    }

    /// Target vectors (tests place models "at convergence").
    pub fn targets(&self) -> (&[f32], &[f32], &[f32]) {
        (&self.target_client, &self.target_aux, &self.target_server)
    }

    /// Euclidean distance of a client model from its target.
    pub fn client_distance(&self, xc: &[f32]) -> f32 {
        xc.iter()
            .zip(&self.target_client)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt()
    }

    /// Euclidean distance of a server model from its target.
    pub fn server_distance(&self, xs: &[f32]) -> f32 {
        xs.iter()
            .zip(&self.target_server)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt()
    }
}

impl SplitEngine for MockEngine {
    fn batch(&self) -> usize {
        self.batch
    }
    fn classes(&self) -> usize {
        self.classes
    }
    fn input_len(&self) -> usize {
        self.input_len
    }
    fn smashed_len(&self) -> usize {
        self.smashed_len
    }
    fn client_size(&self) -> usize {
        self.target_client.len()
    }
    fn server_size(&self) -> usize {
        self.target_server.len()
    }
    fn aux_size(&self) -> usize {
        self.target_aux.len()
    }

    fn client_train_step(
        &self,
        xc: &[f32],
        ac: &[f32],
        images: &[f32],
        labels: &[i32],
        lr: f32,
        _seed: i32,
    ) -> Result<ClientStepOut, EngineError> {
        self.check("xc", xc.len(), self.client_size())?;
        self.check("ac", ac.len(), self.aux_size())?;
        self.check("images", images.len(), self.batch * self.input_len)?;
        self.check("labels", labels.len(), self.batch)?;
        let (mut new_client, l1, g1) = Self::quad_step(xc, &self.target_client, lr);
        let (new_aux, l2, g2) = Self::quad_step(ac, &self.target_aux, lr);
        // Weak data coupling: different mini-batches perturb the update
        // differently (so clients genuinely diverge between aggregations)
        // without disturbing convergence.
        for (j, v) in new_client.iter_mut().enumerate() {
            *v += 1e-3 * lr * images[(j * 7) % images.len()];
        }
        Ok(ClientStepOut {
            new_client,
            new_aux,
            loss: l1 + l2,
            grad_norm: (g1 * g1 + g2 * g2).sqrt(),
        })
    }

    fn client_fwd(&self, xc: &[f32], images: &[f32], seed: i32) -> Result<Vec<f32>, EngineError> {
        self.check("xc", xc.len(), self.client_size())?;
        self.check("images", images.len(), self.batch * self.input_len)?;
        let mean_xc: f32 = xc.iter().sum::<f32>() / xc.len() as f32;
        // bounded seed jitter (dropout-mask stand-in): different seeds
        // give different smashed data, equal seeds replay exactly.
        let jitter = 0.01 * ((seed.rem_euclid(997)) as f32 / 997.0);
        let mut out = Vec::with_capacity(self.batch * self.smashed_len);
        for b in 0..self.batch {
            for j in 0..self.smashed_len {
                let img = images[b * self.input_len + (j % self.input_len)];
                out.push(mean_xc + 0.5 * img + jitter);
            }
        }
        Ok(out)
    }

    fn server_train_step(
        &self,
        xs: &[f32],
        smashed: &[f32],
        labels: &[i32],
        lr: f32,
        _seed: i32,
    ) -> Result<ServerStepOut, EngineError> {
        self.check("xs", xs.len(), self.server_size())?;
        self.check("smashed", smashed.len(), self.batch * self.smashed_len)?;
        self.check("labels", labels.len(), self.batch)?;
        let (mut new_server, loss, grad_norm) = Self::quad_step(xs, &self.target_server, lr);
        // Couple the update (weakly) to the arriving smashed data so
        // update ORDER is observable in tests.
        let s_mean: f32 = smashed.iter().sum::<f32>() / smashed.len() as f32;
        for v in &mut new_server {
            *v += 1e-4 * lr * s_mean;
        }
        Ok(ServerStepOut { new_server, loss, grad_norm })
    }

    fn server_fwd_bwd(
        &self,
        xs: &[f32],
        smashed: &[f32],
        labels: &[i32],
        lr: f32,
        seed: i32,
        clip: f32,
    ) -> Result<ServerFwdBwdOut, EngineError> {
        let step = self.server_train_step(xs, smashed, labels, lr, seed)?;
        // Cut-layer gradient points the smashed data toward zero (any
        // fixed linear map works for coordinator testing).
        let mut grad: Vec<f32> = smashed.iter().map(|&s| 0.1 * s).collect();
        if clip > 0.0 {
            let norm: f32 = grad.iter().map(|g| g * g).sum::<f32>().sqrt();
            if norm > clip {
                let scale = clip / norm;
                grad.iter_mut().for_each(|g| *g *= scale);
            }
        }
        Ok(ServerFwdBwdOut {
            new_server: step.new_server,
            grad_smashed: grad,
            loss: step.loss,
            grad_norm: step.grad_norm,
        })
    }

    fn client_bwd(
        &self,
        xc: &[f32],
        images: &[f32],
        grad_smashed: &[f32],
        lr: f32,
        _seed: i32,
        clip: f32,
    ) -> Result<(Vec<f32>, f32), EngineError> {
        self.check("xc", xc.len(), self.client_size())?;
        self.check("images", images.len(), self.batch * self.input_len)?;
        self.check("gsm", grad_smashed.len(), self.batch * self.smashed_len)?;
        // Chain rule through the mock client_fwd: d smashed / d xc is
        // uniform (1/len per element), plus the quadratic pull to target
        // so MC/OC training also converges in mock-land.
        let gsum: f32 = grad_smashed.iter().sum::<f32>() / xc.len() as f32;
        let n = xc.len() as f32;
        let mut new = Vec::with_capacity(xc.len());
        let mut gsq = 0f32;
        for (&p, &t) in xc.iter().zip(&self.target_client) {
            let mut g = (p - t) / n + gsum * 1e-3;
            if clip > 0.0 {
                g = g.clamp(-clip, clip);
            }
            gsq += g * g;
            new.push(p - lr * g);
        }
        Ok((new, gsq.sqrt()))
    }

    fn eval_step(&self, xc: &[f32], xs: &[f32], images: &[f32]) -> Result<Vec<f32>, EngineError> {
        self.check("xc", xc.len(), self.client_size())?;
        self.check("xs", xs.len(), self.server_size())?;
        self.check("images", images.len(), self.batch * self.input_len)?;
        // Per-sample true class signature: argmax over class buckets of
        // the sample's pixel sums. The model "knows" it better as params
        // approach targets: logits = signature * quality - noise(dist).
        let dist = self.client_distance(xc) + self.server_distance(xs);
        let quality = 1.0 / (1.0 + dist);
        let mut logits = Vec::with_capacity(self.batch * self.classes);
        for b in 0..self.batch {
            let img = &images[b * self.input_len..(b + 1) * self.input_len];
            for c in 0..self.classes {
                let sig: f32 = img
                    .iter()
                    .skip(c)
                    .step_by(self.classes)
                    .sum();
                // distance-dependent deterministic "confusion"
                let confusion = ((b + c) as f32 * 0.7).sin() * dist * 0.1;
                logits.push(sig * quality + confusion);
            }
        }
        Ok(logits)
    }

    fn aux_eval_step(
        &self,
        xc: &[f32],
        ac: &[f32],
        images: &[f32],
    ) -> Result<Vec<f32>, EngineError> {
        self.check("ac", ac.len(), self.aux_size())?;
        // Reuse eval_step quality with the aux distance instead.
        let dist = self.client_distance(xc)
            + ac.iter()
                .zip(&self.target_aux)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>()
                .sqrt();
        let quality = 1.0 / (1.0 + dist);
        let mut logits = Vec::with_capacity(self.batch * self.classes);
        for b in 0..self.batch {
            let img = &images[b * self.input_len..(b + 1) * self.input_len];
            for c in 0..self.classes {
                let sig: f32 = img.iter().skip(c).step_by(self.classes).sum();
                logits.push(sig * quality);
            }
        }
        Ok(logits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn zeros(e: &MockEngine) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<i32>) {
        (
            vec![0.0; e.client_size()],
            vec![0.0; e.aux_size()],
            vec![0.0; e.server_size()],
            vec![0.1; e.batch * e.input_len],
            vec![0; e.batch],
        )
    }

    #[test]
    fn client_step_converges_to_target() {
        let e = MockEngine::small(1);
        let (mut xc, mut ac, _, x, y) = zeros(&e);
        let d0 = e.client_distance(&xc);
        for i in 0..200 {
            let out = e.client_train_step(&xc, &ac, &x, &y, 4.0, i).unwrap();
            xc = out.new_client;
            ac = out.new_aux;
        }
        assert!(e.client_distance(&xc) < d0 * 0.2);
    }

    #[test]
    fn server_step_converges_and_losses_decrease() {
        let e = MockEngine::small(2);
        let (xc, _, mut xs, x, y) = zeros(&e);
        let sm = e.client_fwd(&xc, &x, 0).unwrap();
        let mut losses = Vec::new();
        for i in 0..50 {
            let out = e.server_train_step(&xs, &sm, &y, 4.0, i).unwrap();
            xs = out.new_server;
            losses.push(out.loss);
        }
        assert!(losses.last().unwrap() < &losses[0]);
    }

    #[test]
    fn shapes_are_enforced() {
        let e = MockEngine::small(3);
        let (xc, ac, _, x, y) = zeros(&e);
        assert!(e.client_train_step(&xc[1..], &ac, &x, &y, 0.1, 0).is_err());
        assert!(e.client_fwd(&xc, &x[1..], 0).is_err());
        let sm = e.client_fwd(&xc, &x, 0).unwrap();
        assert_eq!(sm.len(), e.batch() * e.smashed_len());
        assert!(e.server_train_step(&xc, &sm, &y, 0.1, 0).is_err()); // wrong vec
    }

    #[test]
    fn eval_quality_improves_with_training() {
        let e = MockEngine::small(4);
        let (xc0, _, xs0, x, _) = zeros(&e);
        // aux_eval has no confusion term: signal magnitude rises
        // monotonically as params approach targets.
        let far = e.aux_eval_step(&xc0, &vec![0.0; e.aux_size()], &x).unwrap();
        let near = e
            .aux_eval_step(&e.target_client.clone(), &e.target_aux.clone(), &x)
            .unwrap();
        let mag = |v: &[f32]| v.iter().map(|x| x.abs()).sum::<f32>();
        assert!(mag(&near) > mag(&far));
        // eval_step is deterministic
        let a = e.eval_step(&xc0, &xs0, &x).unwrap();
        let b = e.eval_step(&xc0, &xs0, &x).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn clip_caps_grad_smashed() {
        let e = MockEngine::small(5);
        let (xc, _, xs, x, y) = zeros(&e);
        let sm = e.client_fwd(&xc, &x, 0).unwrap();
        let out = e.server_fwd_bwd(&xs, &sm, &y, 0.1, 0, 1e-4).unwrap();
        let norm: f32 = out.grad_smashed.iter().map(|g| g * g).sum::<f32>().sqrt();
        assert!(norm <= 1e-4 * 1.001);
    }

    #[test]
    fn server_update_depends_on_smashed_order_observably() {
        let e = MockEngine::small(6);
        let (xc, _, xs, x, y) = zeros(&e);
        let sm1 = e.client_fwd(&xc, &x, 1).unwrap();
        let sm2 = e.client_fwd(&xc, &x, 2).unwrap();
        let a = e
            .server_train_step(
                &e.server_train_step(&xs, &sm1, &y, 0.5, 0).unwrap().new_server,
                &sm2,
                &y,
                0.5,
                0,
            )
            .unwrap()
            .new_server;
        let b = e
            .server_train_step(
                &e.server_train_step(&xs, &sm2, &y, 0.5, 0).unwrap().new_server,
                &sm1,
                &y,
                0.5,
                0,
            )
            .unwrap()
            .new_server;
        // different order, *slightly* different trajectory (paper Fig. 6:
        // nearly identical, not bitwise identical)
        assert!(crate::model::aggregate::max_abs_diff(&a, &b) > 0.0);
        assert!(crate::model::aggregate::max_abs_diff(&a, &b) < 1e-2);
    }
}
