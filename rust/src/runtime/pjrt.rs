//! The real engine: AOT HLO artifacts executed on the PJRT CPU client.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO **text** is parsed with
//! `HloModuleProto::from_text_file` (the text parser reassigns the 64-bit
//! instruction ids jax ≥ 0.5 emits, which xla_extension 0.5.1 would
//! otherwise reject), compiled once per entry, and cached for the whole
//! run. Marshalling is flat `Vec<f32>`/`Vec<i32>` ↔ `xla::Literal`.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use super::artifact::{AuxConfig, DatasetConfig, Dtype, Entry, Manifest, TensorSig};
use super::{ClientStepOut, EngineError, ServerFwdBwdOut, ServerStepOut, SplitEngine};

fn xerr(e: xla::Error) -> EngineError {
    EngineError::Xla(e.to_string())
}

/// Shared PJRT client + compiled-executable cache. One per process;
/// engines for different (dataset, aux) configs share it.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    exes: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// Compilation stats (observability; quoted in EXPERIMENTS.md).
    pub compiles: RefCell<usize>,
}

impl PjrtRuntime {
    pub fn new() -> Result<Rc<Self>, EngineError> {
        let client = xla::PjRtClient::cpu().map_err(xerr)?;
        Ok(Rc::new(PjrtRuntime {
            client,
            exes: RefCell::new(HashMap::new()),
            compiles: RefCell::new(0),
        }))
    }

    fn executable(&self, entry: &Entry) -> Result<Rc<xla::PjRtLoadedExecutable>, EngineError> {
        let key = entry.file.to_string_lossy().to_string();
        if let Some(exe) = self.exes.borrow().get(&key) {
            return Ok(exe.clone());
        }
        let path = entry.file.to_str().ok_or_else(|| {
            EngineError::Xla(format!("non-utf8 artifact path {:?}", entry.file))
        })?;
        let proto = xla::HloModuleProto::from_text_file(path).map_err(xerr)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp).map_err(xerr)?);
        *self.compiles.borrow_mut() += 1;
        self.exes.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }
}

/// Argument value passed to an entry.
pub enum Arg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
    ScalarF32(f32),
    ScalarI32(i32),
}

impl Arg<'_> {
    fn to_literal(&self, sig: &TensorSig) -> Result<xla::Literal, EngineError> {
        let want: usize = sig.len();
        let dims: Vec<i64> = sig.shape.iter().map(|&d| d as i64).collect();
        match (self, sig.dtype) {
            (Arg::F32(v), Dtype::F32) => {
                if v.len() != want {
                    return Err(EngineError::Shape(format!(
                        "f32 arg len {} != sig {want} (shape {:?})",
                        v.len(),
                        sig.shape
                    )));
                }
                xla::Literal::vec1(v).reshape(&dims).map_err(xerr)
            }
            (Arg::I32(v), Dtype::I32) => {
                if v.len() != want {
                    return Err(EngineError::Shape(format!(
                        "i32 arg len {} != sig {want}",
                        v.len()
                    )));
                }
                xla::Literal::vec1(v).reshape(&dims).map_err(xerr)
            }
            (Arg::ScalarF32(x), Dtype::F32) => {
                if !sig.shape.is_empty() {
                    return Err(EngineError::Shape("scalar f32 vs non-scalar sig".into()));
                }
                Ok(xla::Literal::scalar(*x))
            }
            (Arg::ScalarI32(x), Dtype::I32) => {
                if !sig.shape.is_empty() {
                    return Err(EngineError::Shape("scalar i32 vs non-scalar sig".into()));
                }
                Ok(xla::Literal::scalar(*x))
            }
            _ => Err(EngineError::Shape(format!(
                "dtype mismatch against sig {:?}",
                sig.dtype
            ))),
        }
    }
}

/// A decoded result tensor.
#[derive(Clone, Debug)]
pub enum Value {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Value {
    pub fn into_f32(self) -> Result<Vec<f32>, EngineError> {
        match self {
            Value::F32(v) => Ok(v),
            Value::I32(_) => Err(EngineError::Shape("expected f32 result".into())),
        }
    }

    pub fn scalar_f32(&self) -> Result<f32, EngineError> {
        match self {
            Value::F32(v) if v.len() == 1 => Ok(v[0]),
            _ => Err(EngineError::Shape("expected scalar f32 result".into())),
        }
    }
}

impl PjrtRuntime {
    /// Execute `entry` with `args`, returning decoded result tensors.
    pub fn exec(&self, entry: &Entry, args: &[Arg<'_>]) -> Result<Vec<Value>, EngineError> {
        if args.len() != entry.args.len() {
            return Err(EngineError::Shape(format!(
                "{}: {} args provided, {} expected",
                entry.name,
                args.len(),
                entry.args.len()
            )));
        }
        let exe = self.executable(entry)?;
        let literals: Vec<xla::Literal> = args
            .iter()
            .zip(&entry.args)
            .map(|(a, sig)| a.to_literal(sig))
            .collect::<Result<_, _>>()?;
        let result = exe.execute::<xla::Literal>(&literals).map_err(xerr)?[0][0]
            .to_literal_sync()
            .map_err(xerr)?;
        // aot.py lowers with return_tuple=True: output is always a tuple.
        let parts = result.to_tuple().map_err(xerr)?;
        if parts.len() != entry.results.len() {
            return Err(EngineError::Shape(format!(
                "{}: {} results, {} expected",
                entry.name,
                parts.len(),
                entry.results.len()
            )));
        }
        parts
            .into_iter()
            .zip(&entry.results)
            .map(|(lit, sig)| {
                Ok(match sig.dtype {
                    Dtype::F32 => Value::F32(lit.to_vec::<f32>().map_err(xerr)?),
                    Dtype::I32 => Value::I32(lit.to_vec::<i32>().map_err(xerr)?),
                })
            })
            .collect()
    }
}

/// [`SplitEngine`] backed by PJRT for one (dataset, aux) configuration.
pub struct PjrtEngine {
    rt: Rc<PjrtRuntime>,
    cfg: DatasetConfig,
    aux: AuxConfig,
}

impl PjrtEngine {
    pub fn new(
        rt: Rc<PjrtRuntime>,
        manifest: &Manifest,
        dataset: &str,
        aux_arch: &str,
    ) -> Result<Self, EngineError> {
        let cfg = manifest.config(dataset)?.clone();
        let aux = cfg.aux(aux_arch)?.clone();
        Ok(PjrtEngine { rt, cfg, aux })
    }

    fn shared(&self, name: &str) -> Result<&Entry, EngineError> {
        Ok(self.cfg.entry(name)?)
    }

    fn aux_entry(&self, name: &str) -> Result<&Entry, EngineError> {
        self.aux
            .entries
            .get(name)
            .ok_or_else(|| EngineError::Shape(format!("missing aux entry {name:?}")))
    }

    pub fn dataset(&self) -> &str {
        &self.cfg.name
    }

    pub fn aux_arch(&self) -> &str {
        &self.aux.arch
    }

    pub fn config(&self) -> &DatasetConfig {
        &self.cfg
    }

    pub fn runtime(&self) -> &Rc<PjrtRuntime> {
        &self.rt
    }
}

impl SplitEngine for PjrtEngine {
    fn batch(&self) -> usize {
        self.cfg.batch
    }
    fn classes(&self) -> usize {
        self.cfg.classes
    }
    fn input_len(&self) -> usize {
        self.cfg.input_len()
    }
    fn smashed_len(&self) -> usize {
        self.cfg.smashed_size
    }
    fn client_size(&self) -> usize {
        self.cfg.client_layout.total
    }
    fn server_size(&self) -> usize {
        self.cfg.server_layout.total
    }
    fn aux_size(&self) -> usize {
        self.aux.size
    }

    fn client_train_step(
        &self,
        xc: &[f32],
        ac: &[f32],
        images: &[f32],
        labels: &[i32],
        lr: f32,
        seed: i32,
    ) -> Result<ClientStepOut, EngineError> {
        let entry = self.aux_entry("client_train_step")?;
        let mut out = self.rt.exec(
            entry,
            &[
                Arg::F32(xc),
                Arg::F32(ac),
                Arg::F32(images),
                Arg::I32(labels),
                Arg::ScalarF32(lr),
                Arg::ScalarI32(seed),
            ],
        )?;
        let grad_norm = out.pop().unwrap().scalar_f32()?;
        let loss = out.pop().unwrap().scalar_f32()?;
        let new_aux = out.pop().unwrap().into_f32()?;
        let new_client = out.pop().unwrap().into_f32()?;
        Ok(ClientStepOut { new_client, new_aux, loss, grad_norm })
    }

    fn client_fwd(&self, xc: &[f32], images: &[f32], seed: i32) -> Result<Vec<f32>, EngineError> {
        let entry = self.shared("client_fwd")?;
        let mut out =
            self.rt.exec(entry, &[Arg::F32(xc), Arg::F32(images), Arg::ScalarI32(seed)])?;
        out.pop().unwrap().into_f32()
    }

    fn server_train_step(
        &self,
        xs: &[f32],
        smashed: &[f32],
        labels: &[i32],
        lr: f32,
        seed: i32,
    ) -> Result<ServerStepOut, EngineError> {
        let entry = self.shared("server_train_step")?;
        let mut out = self.rt.exec(
            entry,
            &[
                Arg::F32(xs),
                Arg::F32(smashed),
                Arg::I32(labels),
                Arg::ScalarF32(lr),
                Arg::ScalarI32(seed),
            ],
        )?;
        let grad_norm = out.pop().unwrap().scalar_f32()?;
        let loss = out.pop().unwrap().scalar_f32()?;
        let new_server = out.pop().unwrap().into_f32()?;
        Ok(ServerStepOut { new_server, loss, grad_norm })
    }

    fn server_fwd_bwd(
        &self,
        xs: &[f32],
        smashed: &[f32],
        labels: &[i32],
        lr: f32,
        seed: i32,
        clip: f32,
    ) -> Result<ServerFwdBwdOut, EngineError> {
        let entry = self.shared("server_fwd_bwd")?;
        let mut out = self.rt.exec(
            entry,
            &[
                Arg::F32(xs),
                Arg::F32(smashed),
                Arg::I32(labels),
                Arg::ScalarF32(lr),
                Arg::ScalarI32(seed),
                Arg::ScalarF32(clip),
            ],
        )?;
        let grad_norm = out.pop().unwrap().scalar_f32()?;
        let loss = out.pop().unwrap().scalar_f32()?;
        let grad_smashed = out.pop().unwrap().into_f32()?;
        let new_server = out.pop().unwrap().into_f32()?;
        Ok(ServerFwdBwdOut { new_server, grad_smashed, loss, grad_norm })
    }

    fn client_bwd(
        &self,
        xc: &[f32],
        images: &[f32],
        grad_smashed: &[f32],
        lr: f32,
        seed: i32,
        clip: f32,
    ) -> Result<(Vec<f32>, f32), EngineError> {
        let entry = self.shared("client_bwd")?;
        let mut out = self.rt.exec(
            entry,
            &[
                Arg::F32(xc),
                Arg::F32(images),
                Arg::F32(grad_smashed),
                Arg::ScalarF32(lr),
                Arg::ScalarI32(seed),
                Arg::ScalarF32(clip),
            ],
        )?;
        let gnorm = out.pop().unwrap().scalar_f32()?;
        let new_client = out.pop().unwrap().into_f32()?;
        Ok((new_client, gnorm))
    }

    fn eval_step(&self, xc: &[f32], xs: &[f32], images: &[f32]) -> Result<Vec<f32>, EngineError> {
        let entry = self.shared("eval_step")?;
        let mut out = self.rt.exec(entry, &[Arg::F32(xc), Arg::F32(xs), Arg::F32(images)])?;
        out.pop().unwrap().into_f32()
    }

    fn aux_eval_step(
        &self,
        xc: &[f32],
        ac: &[f32],
        images: &[f32],
    ) -> Result<Vec<f32>, EngineError> {
        let entry = self.aux_entry("aux_eval_step")?;
        let mut out = self.rt.exec(entry, &[Arg::F32(xc), Arg::F32(ac), Arg::F32(images)])?;
        out.pop().unwrap().into_f32()
    }
}
