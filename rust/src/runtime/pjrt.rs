//! The real engine: AOT HLO artifacts executed on the PJRT CPU client.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO **text** is parsed with
//! `HloModuleProto::from_text_file` (the text parser reassigns the 64-bit
//! instruction ids jax ≥ 0.5 emits, which xla_extension 0.5.1 would
//! otherwise reject), compiled once per entry, and cached for the whole
//! run. Marshalling is flat `Vec<f32>`/`Vec<i32>` ↔ `xla::Literal`.
//!
//! The XLA bindings are only present in environments that vendor the
//! `xla` crate, so the real implementation is gated behind the `pjrt`
//! cargo feature. Without it an API-compatible stub is compiled:
//! construction fails with a clear error, the type system stays intact
//! (`Harness`, examples, and benches build unchanged), and every test
//! that needs real artifacts skips itself exactly as it does when
//! `make artifacts` has not run.
//!
//! Both variants expose the same surface:
//! * `PjrtRuntime::new() -> Result<Arc<PjrtRuntime>, EngineError>`
//! * `PjrtRuntime::compiles() -> usize` (compilation counter)
//! * `PjrtEngine::new(rt, &manifest, dataset, aux)` implementing
//!   [`SplitEngine`] (which now requires `Sync` for the parallel round
//!   engine — the runtime serializes PJRT access behind a mutex).

#[cfg(feature = "pjrt")]
pub use real::{Arg, PjrtEngine, PjrtRuntime, Value};

#[cfg(not(feature = "pjrt"))]
pub use stub::{PjrtEngine, PjrtRuntime};

#[cfg(feature = "pjrt")]
mod real {
    use std::collections::HashMap;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::{Arc, Mutex};

    use super::super::artifact::{AuxConfig, DatasetConfig, Dtype, Entry, Manifest, TensorSig};
    use super::super::{
        ClientStepOut, EngineError, ServerFwdBwdOut, ServerStepOut, SplitEngine,
    };

    fn xerr(e: xla::Error) -> EngineError {
        EngineError::Xla(e.to_string())
    }

    /// Shared PJRT client + compiled-executable cache. One per process;
    /// engines for different (dataset, aux) configs share it. All PJRT
    /// calls are serialized behind `inner` — the CPU client is a single
    /// device, so concurrent submission buys nothing, and the mutex makes
    /// the engine `Sync` for the parallel coordinator.
    pub struct PjrtRuntime {
        inner: Mutex<Inner>,
        compiles: AtomicUsize,
    }

    struct Inner {
        client: xla::PjRtClient,
        exes: HashMap<String, Arc<xla::PjRtLoadedExecutable>>,
    }

    // SAFETY: all access to the PJRT client and executable cache goes
    // through the `inner` mutex; the raw xla handles are never shared
    // across threads without it.
    unsafe impl Send for PjrtRuntime {}
    unsafe impl Sync for PjrtRuntime {}

    impl PjrtRuntime {
        /// Start the PJRT CPU client.
        pub fn new() -> Result<Arc<Self>, EngineError> {
            let client = xla::PjRtClient::cpu().map_err(xerr)?;
            Ok(Arc::new(PjrtRuntime {
                inner: Mutex::new(Inner { client, exes: HashMap::new() }),
                compiles: AtomicUsize::new(0),
            }))
        }

        /// Number of HLO entries compiled so far (observability; quoted
        /// in EXPERIMENTS.md).
        pub fn compiles(&self) -> usize {
            self.compiles.load(Ordering::Relaxed)
        }

        fn executable(
            &self,
            inner: &mut Inner,
            entry: &Entry,
        ) -> Result<Arc<xla::PjRtLoadedExecutable>, EngineError> {
            let key = entry.file.to_string_lossy().to_string();
            if let Some(exe) = inner.exes.get(&key) {
                return Ok(exe.clone());
            }
            let path = entry.file.to_str().ok_or_else(|| {
                EngineError::Xla(format!("non-utf8 artifact path {:?}", entry.file))
            })?;
            let proto = xla::HloModuleProto::from_text_file(path).map_err(xerr)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = Arc::new(inner.client.compile(&comp).map_err(xerr)?);
            self.compiles.fetch_add(1, Ordering::Relaxed);
            inner.exes.insert(key, exe.clone());
            Ok(exe)
        }
    }

    /// Argument value passed to an entry.
    pub enum Arg<'a> {
        /// Flat f32 tensor.
        F32(&'a [f32]),
        /// Flat i32 tensor.
        I32(&'a [i32]),
        /// f32 scalar.
        ScalarF32(f32),
        /// i32 scalar.
        ScalarI32(i32),
    }

    impl Arg<'_> {
        fn to_literal(&self, sig: &TensorSig) -> Result<xla::Literal, EngineError> {
            let want: usize = sig.len();
            let dims: Vec<i64> = sig.shape.iter().map(|&d| d as i64).collect();
            match (self, sig.dtype) {
                (Arg::F32(v), Dtype::F32) => {
                    if v.len() != want {
                        return Err(EngineError::Shape(format!(
                            "f32 arg len {} != sig {want} (shape {:?})",
                            v.len(),
                            sig.shape
                        )));
                    }
                    xla::Literal::vec1(v).reshape(&dims).map_err(xerr)
                }
                (Arg::I32(v), Dtype::I32) => {
                    if v.len() != want {
                        return Err(EngineError::Shape(format!(
                            "i32 arg len {} != sig {want}",
                            v.len()
                        )));
                    }
                    xla::Literal::vec1(v).reshape(&dims).map_err(xerr)
                }
                (Arg::ScalarF32(x), Dtype::F32) => {
                    if !sig.shape.is_empty() {
                        return Err(EngineError::Shape("scalar f32 vs non-scalar sig".into()));
                    }
                    Ok(xla::Literal::scalar(*x))
                }
                (Arg::ScalarI32(x), Dtype::I32) => {
                    if !sig.shape.is_empty() {
                        return Err(EngineError::Shape("scalar i32 vs non-scalar sig".into()));
                    }
                    Ok(xla::Literal::scalar(*x))
                }
                _ => Err(EngineError::Shape(format!(
                    "dtype mismatch against sig {:?}",
                    sig.dtype
                ))),
            }
        }
    }

    /// A decoded result tensor.
    #[derive(Clone, Debug)]
    pub enum Value {
        /// Flat f32 tensor.
        F32(Vec<f32>),
        /// Flat i32 tensor.
        I32(Vec<i32>),
    }

    impl Value {
        /// Unwrap an f32 tensor result.
        pub fn into_f32(self) -> Result<Vec<f32>, EngineError> {
            match self {
                Value::F32(v) => Ok(v),
                Value::I32(_) => Err(EngineError::Shape("expected f32 result".into())),
            }
        }

        /// Read a one-element f32 result as a scalar.
        pub fn scalar_f32(&self) -> Result<f32, EngineError> {
            match self {
                Value::F32(v) if v.len() == 1 => Ok(v[0]),
                _ => Err(EngineError::Shape("expected scalar f32 result".into())),
            }
        }
    }

    impl PjrtRuntime {
        /// Execute `entry` with `args`, returning decoded result tensors.
        pub fn exec(&self, entry: &Entry, args: &[Arg<'_>]) -> Result<Vec<Value>, EngineError> {
            if args.len() != entry.args.len() {
                return Err(EngineError::Shape(format!(
                    "{}: {} args provided, {} expected",
                    entry.name,
                    args.len(),
                    entry.args.len()
                )));
            }
            let mut inner = self
                .inner
                .lock()
                .map_err(|_| EngineError::Parallel("pjrt runtime mutex poisoned".into()))?;
            let exe = self.executable(&mut inner, entry)?;
            let literals: Vec<xla::Literal> = args
                .iter()
                .zip(&entry.args)
                .map(|(a, sig)| a.to_literal(sig))
                .collect::<Result<_, _>>()?;
            let result = exe.execute::<xla::Literal>(&literals).map_err(xerr)?[0][0]
                .to_literal_sync()
                .map_err(xerr)?;
            // aot.py lowers with return_tuple=True: output is always a tuple.
            let parts = result.to_tuple().map_err(xerr)?;
            if parts.len() != entry.results.len() {
                return Err(EngineError::Shape(format!(
                    "{}: {} results, {} expected",
                    entry.name,
                    parts.len(),
                    entry.results.len()
                )));
            }
            parts
                .into_iter()
                .zip(&entry.results)
                .map(|(lit, sig)| {
                    Ok(match sig.dtype {
                        Dtype::F32 => Value::F32(lit.to_vec::<f32>().map_err(xerr)?),
                        Dtype::I32 => Value::I32(lit.to_vec::<i32>().map_err(xerr)?),
                    })
                })
                .collect()
        }
    }

    /// [`SplitEngine`] backed by PJRT for one (dataset, aux) configuration.
    pub struct PjrtEngine {
        rt: Arc<PjrtRuntime>,
        cfg: DatasetConfig,
        aux: AuxConfig,
    }

    impl PjrtEngine {
        /// Bind one (dataset, aux) manifest configuration to the runtime.
        pub fn new(
            rt: Arc<PjrtRuntime>,
            manifest: &Manifest,
            dataset: &str,
            aux_arch: &str,
        ) -> Result<Self, EngineError> {
            let cfg = manifest.config(dataset)?.clone();
            let aux = cfg.aux(aux_arch)?.clone();
            Ok(PjrtEngine { rt, cfg, aux })
        }

        fn shared(&self, name: &str) -> Result<&Entry, EngineError> {
            Ok(self.cfg.entry(name)?)
        }

        fn aux_entry(&self, name: &str) -> Result<&Entry, EngineError> {
            self.aux
                .entries
                .get(name)
                .ok_or_else(|| EngineError::Shape(format!("missing aux entry {name:?}")))
        }

        /// Dataset name this engine serves.
        pub fn dataset(&self) -> &str {
            &self.cfg.name
        }

        /// Auxiliary architecture this engine serves.
        pub fn aux_arch(&self) -> &str {
            &self.aux.arch
        }

        /// The bound dataset configuration.
        pub fn config(&self) -> &DatasetConfig {
            &self.cfg
        }

        /// The shared runtime.
        pub fn runtime(&self) -> &Arc<PjrtRuntime> {
            &self.rt
        }
    }

    impl SplitEngine for PjrtEngine {
        fn batch(&self) -> usize {
            self.cfg.batch
        }
        fn classes(&self) -> usize {
            self.cfg.classes
        }
        fn input_len(&self) -> usize {
            self.cfg.input_len()
        }
        fn smashed_len(&self) -> usize {
            self.cfg.smashed_size
        }
        fn client_size(&self) -> usize {
            self.cfg.client_layout.total
        }
        fn server_size(&self) -> usize {
            self.cfg.server_layout.total
        }
        fn aux_size(&self) -> usize {
            self.aux.size
        }

        fn client_train_step(
            &self,
            xc: &[f32],
            ac: &[f32],
            images: &[f32],
            labels: &[i32],
            lr: f32,
            seed: i32,
        ) -> Result<ClientStepOut, EngineError> {
            let entry = self.aux_entry("client_train_step")?;
            let mut out = self.rt.exec(
                entry,
                &[
                    Arg::F32(xc),
                    Arg::F32(ac),
                    Arg::F32(images),
                    Arg::I32(labels),
                    Arg::ScalarF32(lr),
                    Arg::ScalarI32(seed),
                ],
            )?;
            let grad_norm = out.pop().unwrap().scalar_f32()?;
            let loss = out.pop().unwrap().scalar_f32()?;
            let new_aux = out.pop().unwrap().into_f32()?;
            let new_client = out.pop().unwrap().into_f32()?;
            Ok(ClientStepOut { new_client, new_aux, loss, grad_norm })
        }

        fn client_fwd(
            &self,
            xc: &[f32],
            images: &[f32],
            seed: i32,
        ) -> Result<Vec<f32>, EngineError> {
            let entry = self.shared("client_fwd")?;
            let mut out =
                self.rt.exec(entry, &[Arg::F32(xc), Arg::F32(images), Arg::ScalarI32(seed)])?;
            out.pop().unwrap().into_f32()
        }

        fn server_train_step(
            &self,
            xs: &[f32],
            smashed: &[f32],
            labels: &[i32],
            lr: f32,
            seed: i32,
        ) -> Result<ServerStepOut, EngineError> {
            let entry = self.shared("server_train_step")?;
            let mut out = self.rt.exec(
                entry,
                &[
                    Arg::F32(xs),
                    Arg::F32(smashed),
                    Arg::I32(labels),
                    Arg::ScalarF32(lr),
                    Arg::ScalarI32(seed),
                ],
            )?;
            let grad_norm = out.pop().unwrap().scalar_f32()?;
            let loss = out.pop().unwrap().scalar_f32()?;
            let new_server = out.pop().unwrap().into_f32()?;
            Ok(ServerStepOut { new_server, loss, grad_norm })
        }

        fn server_fwd_bwd(
            &self,
            xs: &[f32],
            smashed: &[f32],
            labels: &[i32],
            lr: f32,
            seed: i32,
            clip: f32,
        ) -> Result<ServerFwdBwdOut, EngineError> {
            let entry = self.shared("server_fwd_bwd")?;
            let mut out = self.rt.exec(
                entry,
                &[
                    Arg::F32(xs),
                    Arg::F32(smashed),
                    Arg::I32(labels),
                    Arg::ScalarF32(lr),
                    Arg::ScalarI32(seed),
                    Arg::ScalarF32(clip),
                ],
            )?;
            let grad_norm = out.pop().unwrap().scalar_f32()?;
            let loss = out.pop().unwrap().scalar_f32()?;
            let grad_smashed = out.pop().unwrap().into_f32()?;
            let new_server = out.pop().unwrap().into_f32()?;
            Ok(ServerFwdBwdOut { new_server, grad_smashed, loss, grad_norm })
        }

        fn client_bwd(
            &self,
            xc: &[f32],
            images: &[f32],
            grad_smashed: &[f32],
            lr: f32,
            seed: i32,
            clip: f32,
        ) -> Result<(Vec<f32>, f32), EngineError> {
            let entry = self.shared("client_bwd")?;
            let mut out = self.rt.exec(
                entry,
                &[
                    Arg::F32(xc),
                    Arg::F32(images),
                    Arg::F32(grad_smashed),
                    Arg::ScalarF32(lr),
                    Arg::ScalarI32(seed),
                    Arg::ScalarF32(clip),
                ],
            )?;
            let gnorm = out.pop().unwrap().scalar_f32()?;
            let new_client = out.pop().unwrap().into_f32()?;
            Ok((new_client, gnorm))
        }

        fn eval_step(
            &self,
            xc: &[f32],
            xs: &[f32],
            images: &[f32],
        ) -> Result<Vec<f32>, EngineError> {
            let entry = self.shared("eval_step")?;
            let mut out =
                self.rt.exec(entry, &[Arg::F32(xc), Arg::F32(xs), Arg::F32(images)])?;
            out.pop().unwrap().into_f32()
        }

        fn aux_eval_step(
            &self,
            xc: &[f32],
            ac: &[f32],
            images: &[f32],
        ) -> Result<Vec<f32>, EngineError> {
            let entry = self.aux_entry("aux_eval_step")?;
            let mut out =
                self.rt.exec(entry, &[Arg::F32(xc), Arg::F32(ac), Arg::F32(images)])?;
            out.pop().unwrap().into_f32()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::sync::Arc;

    use super::super::artifact::Manifest;
    use super::super::{
        ClientStepOut, EngineError, ServerFwdBwdOut, ServerStepOut, SplitEngine,
    };

    const UNAVAILABLE: &str = "PJRT engine unavailable: this build has no `pjrt` feature \
         (vendor the xla crate and build with `--features pjrt`); \
         use runtime::mock::MockEngine for engine-independent work";

    /// Uninhabited marker: a stub `PjrtEngine` can never be constructed,
    /// so the `SplitEngine` methods below are statically unreachable.
    enum Void {}

    /// Stub runtime: constructible API, but `new()` always fails.
    pub struct PjrtRuntime {
        _priv: (),
    }

    impl PjrtRuntime {
        /// Always fails with a hint to build with `--features pjrt`.
        pub fn new() -> Result<Arc<Self>, EngineError> {
            Err(EngineError::Xla(UNAVAILABLE.into()))
        }

        /// Compilation counter (always 0 in the stub).
        pub fn compiles(&self) -> usize {
            0
        }
    }

    /// Stub engine: the type exists so `Harness`, examples, and benches
    /// compile without the xla bindings, but no value can exist.
    pub struct PjrtEngine {
        void: Void,
    }

    impl PjrtEngine {
        /// Always fails with a hint to build with `--features pjrt`.
        pub fn new(
            _rt: Arc<PjrtRuntime>,
            _manifest: &Manifest,
            _dataset: &str,
            _aux_arch: &str,
        ) -> Result<Self, EngineError> {
            Err(EngineError::Xla(UNAVAILABLE.into()))
        }

        /// Statically unreachable (no stub engine can exist).
        pub fn dataset(&self) -> &str {
            match self.void {}
        }

        /// Statically unreachable (no stub engine can exist).
        pub fn aux_arch(&self) -> &str {
            match self.void {}
        }
    }

    impl SplitEngine for PjrtEngine {
        fn batch(&self) -> usize {
            match self.void {}
        }
        fn classes(&self) -> usize {
            match self.void {}
        }
        fn input_len(&self) -> usize {
            match self.void {}
        }
        fn smashed_len(&self) -> usize {
            match self.void {}
        }
        fn client_size(&self) -> usize {
            match self.void {}
        }
        fn server_size(&self) -> usize {
            match self.void {}
        }
        fn aux_size(&self) -> usize {
            match self.void {}
        }

        fn client_train_step(
            &self,
            _xc: &[f32],
            _ac: &[f32],
            _images: &[f32],
            _labels: &[i32],
            _lr: f32,
            _seed: i32,
        ) -> Result<ClientStepOut, EngineError> {
            match self.void {}
        }

        fn client_fwd(
            &self,
            _xc: &[f32],
            _images: &[f32],
            _seed: i32,
        ) -> Result<Vec<f32>, EngineError> {
            match self.void {}
        }

        fn server_train_step(
            &self,
            _xs: &[f32],
            _smashed: &[f32],
            _labels: &[i32],
            _lr: f32,
            _seed: i32,
        ) -> Result<ServerStepOut, EngineError> {
            match self.void {}
        }

        fn server_fwd_bwd(
            &self,
            _xs: &[f32],
            _smashed: &[f32],
            _labels: &[i32],
            _lr: f32,
            _seed: i32,
            _clip: f32,
        ) -> Result<ServerFwdBwdOut, EngineError> {
            match self.void {}
        }

        fn client_bwd(
            &self,
            _xc: &[f32],
            _images: &[f32],
            _grad_smashed: &[f32],
            _lr: f32,
            _seed: i32,
            _clip: f32,
        ) -> Result<(Vec<f32>, f32), EngineError> {
            match self.void {}
        }

        fn eval_step(
            &self,
            _xc: &[f32],
            _xs: &[f32],
            _images: &[f32],
        ) -> Result<Vec<f32>, EngineError> {
            match self.void {}
        }

        fn aux_eval_step(
            &self,
            _xc: &[f32],
            _ac: &[f32],
            _images: &[f32],
        ) -> Result<Vec<f32>, EngineError> {
            match self.void {}
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn stub_construction_fails_with_hint() {
            let err = PjrtRuntime::new().err().expect("stub must not construct");
            assert!(err.to_string().contains("pjrt"), "{err}");
        }
    }
}
