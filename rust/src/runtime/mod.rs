//! Runtime: loading and executing the AOT HLO artifacts via PJRT.
//!
//! * [`artifact`] — manifest parsing (what Python built).
//! * [`pjrt`] — the real engine: `PjRtClient::cpu()` →
//!   `HloModuleProto::from_text_file` → compile → execute.
//! * [`mock`] — a pure-Rust engine with linear dynamics, implementing the
//!   same [`SplitEngine`] trait, for fast coordinator tests/properties.
//!
//! The coordinator is generic over [`SplitEngine`], the six-entry compute
//! interface of a split model (DESIGN.md L2 table).

pub mod artifact;
pub mod mock;
pub mod pjrt;

use std::path::PathBuf;

/// Resolve the artifacts directory: `$CSE_FSL_ARTIFACTS` or
/// `<workspace>/artifacts`.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("CSE_FSL_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Anything that can go wrong loading or executing an engine.
#[derive(Debug)]
pub enum EngineError {
    /// Manifest loading/validation failed.
    Artifact(artifact::ArtifactError),
    /// XLA/PJRT compilation or execution failed (or the stub was used).
    Xla(String),
    /// A tensor argument/result had the wrong length or dtype.
    Shape(String),
    /// A parallel round-engine worker failed outside an engine call
    /// (lost result, poisoned channel). Never raised on the sequential
    /// path.
    Parallel(String),
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::Artifact(e) => write!(f, "artifact error: {e}"),
            EngineError::Xla(msg) => write!(f, "xla error: {msg}"),
            EngineError::Shape(msg) => write!(f, "shape error: {msg}"),
            EngineError::Parallel(msg) => write!(f, "parallel engine error: {msg}"),
        }
    }
}

impl std::error::Error for EngineError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            EngineError::Artifact(e) => Some(e),
            _ => None,
        }
    }
}

impl From<artifact::ArtifactError> for EngineError {
    fn from(e: artifact::ArtifactError) -> Self {
        EngineError::Artifact(e)
    }
}

/// Output of one local client step (Eq. (8)).
#[derive(Clone, Debug)]
pub struct ClientStepOut {
    /// Updated client-side model.
    pub new_client: Vec<f32>,
    /// Updated auxiliary network.
    pub new_aux: Vec<f32>,
    /// Auxiliary loss on this batch.
    pub loss: f32,
    /// Gradient norm of the step.
    pub grad_norm: f32,
}

/// Output of one event-triggered server step (Eq. (11)).
#[derive(Clone, Debug)]
pub struct ServerStepOut {
    /// Updated server-side model.
    pub new_server: Vec<f32>,
    /// Server loss on the arriving batch.
    pub loss: f32,
    /// Gradient norm of the step.
    pub grad_norm: f32,
}

/// Output of the SplitFed server fwd+bwd (FSL_MC / FSL_OC).
#[derive(Clone, Debug)]
pub struct ServerFwdBwdOut {
    /// Updated server-side model.
    pub new_server: Vec<f32>,
    /// Cut-layer gradient to send back to the client.
    pub grad_smashed: Vec<f32>,
    /// Split loss on this batch.
    pub loss: f32,
    /// Gradient norm of the step.
    pub grad_norm: f32,
}

/// The six-entry compute interface of one (dataset, aux) configuration.
///
/// All tensors are flat `Vec<f32>` / `Vec<i32>` in the layouts fixed by
/// the manifest; batch size is baked in at AOT time.
///
/// `Sync` is part of the contract: the coordinator's parallel round
/// engine shares one engine reference across its client worker threads
/// (`coordinator/round.rs`), so every implementation must be safe to
/// call concurrently from `&self`. Engines must also be deterministic
/// functions of their arguments — the parallel and sequential schedules
/// are required to produce bit-identical runs.
pub trait SplitEngine: Sync {
    /// AOT-fixed batch size.
    fn batch(&self) -> usize;
    /// Number of output classes.
    fn classes(&self) -> usize;
    /// Input elements per sample.
    fn input_len(&self) -> usize;
    /// Smashed-data elements per sample.
    fn smashed_len(&self) -> usize;
    /// Client-side model parameter count.
    fn client_size(&self) -> usize;
    /// Server-side model parameter count.
    fn server_size(&self) -> usize;
    /// Auxiliary-network parameter count.
    fn aux_size(&self) -> usize;

    /// Eq. (8): local step on (x_c, a_c) with the auxiliary loss.
    fn client_train_step(
        &self,
        xc: &[f32],
        ac: &[f32],
        images: &[f32],
        labels: &[i32],
        lr: f32,
        seed: i32,
    ) -> Result<ClientStepOut, EngineError>;

    /// Smashed data g_{x_c}(z) for one batch.
    fn client_fwd(&self, xc: &[f32], images: &[f32], seed: i32)
        -> Result<Vec<f32>, EngineError>;

    /// Eq. (11): server update from arriving smashed data.
    fn server_train_step(
        &self,
        xs: &[f32],
        smashed: &[f32],
        labels: &[i32],
        lr: f32,
        seed: i32,
    ) -> Result<ServerStepOut, EngineError>;

    /// SplitFed server step: update AND return cut-layer gradient
    /// (clip > 0 enables global-norm clipping — the FSL_OC fix).
    fn server_fwd_bwd(
        &self,
        xs: &[f32],
        smashed: &[f32],
        labels: &[i32],
        lr: f32,
        seed: i32,
        clip: f32,
    ) -> Result<ServerFwdBwdOut, EngineError>;

    /// SplitFed client step from the upstream cut-layer gradient; the
    /// same `seed` as the matching client_fwd replays dropout.
    fn client_bwd(
        &self,
        xc: &[f32],
        images: &[f32],
        grad_smashed: &[f32],
        lr: f32,
        seed: i32,
        clip: f32,
    ) -> Result<(Vec<f32>, f32), EngineError>;

    /// Full-model logits (train=False), flattened [batch * classes].
    fn eval_step(&self, xc: &[f32], xs: &[f32], images: &[f32])
        -> Result<Vec<f32>, EngineError>;

    /// Client-only logits through the auxiliary head.
    fn aux_eval_step(&self, xc: &[f32], ac: &[f32], images: &[f32])
        -> Result<Vec<f32>, EngineError>;
}
