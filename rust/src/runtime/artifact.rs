//! AOT artifact manifest (`artifacts/manifest.json`) parsing.
//!
//! The manifest is emitted by `python/compile/aot.py` and is the complete
//! description of what Python built: per-dataset model geometry, flat
//! parameter layouts with init specs, and per-entry HLO file + signature.
//! Loading it is the only coupling between the Rust binary and the Python
//! build — there is no Python at run time.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::model::layout::Layout;
use crate::util::json::{Json, JsonError};

/// Anything that can go wrong loading the manifest.
#[derive(Debug)]
pub enum ArtifactError {
    /// Reading `manifest.json` failed.
    Io(std::io::Error),
    /// The manifest was not valid JSON.
    Json(JsonError),
    /// The manifest parsed but violated an invariant.
    Invalid(String),
}

impl std::fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArtifactError::Io(e) => write!(f, "manifest io error: {e}"),
            ArtifactError::Json(e) => write!(f, "manifest parse error: {e}"),
            ArtifactError::Invalid(msg) => write!(f, "manifest: {msg}"),
        }
    }
}

impl std::error::Error for ArtifactError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ArtifactError::Io(e) => Some(e),
            ArtifactError::Json(e) => Some(e),
            ArtifactError::Invalid(_) => None,
        }
    }
}

impl From<std::io::Error> for ArtifactError {
    fn from(e: std::io::Error) -> Self {
        ArtifactError::Io(e)
    }
}

impl From<JsonError> for ArtifactError {
    fn from(e: JsonError) -> Self {
        ArtifactError::Json(e)
    }
}

/// dtype of a tensor argument/result.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    /// 32-bit float.
    F32,
    /// 32-bit signed integer.
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype, ArtifactError> {
        match s {
            "float32" => Ok(Dtype::F32),
            "int32" => Ok(Dtype::I32),
            other => Err(ArtifactError::Invalid(format!("unsupported dtype {other:?}"))),
        }
    }
}

/// Shape + dtype signature of one entry argument/result.
#[derive(Clone, Debug)]
pub struct TensorSig {
    /// Tensor shape (empty = scalar).
    pub shape: Vec<usize>,
    /// Element dtype.
    pub dtype: Dtype,
}

impl TensorSig {
    /// Total element count.
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    /// Whether the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn from_json(j: &Json) -> Result<TensorSig, ArtifactError> {
        Ok(TensorSig {
            shape: j.get("shape")?.as_usize_vec()?,
            dtype: Dtype::parse(j.get("dtype")?.as_str()?)?,
        })
    }
}

/// One lowered entry point: HLO file + argument/result signatures.
#[derive(Clone, Debug)]
pub struct Entry {
    /// Entry-point name (e.g. `client_train_step`).
    pub name: String,
    /// Path of the HLO text file.
    pub file: PathBuf,
    /// Argument signatures, in call order.
    pub args: Vec<TensorSig>,
    /// Result signatures, in tuple order.
    pub results: Vec<TensorSig>,
}

impl Entry {
    fn from_json(name: &str, dir: &Path, j: &Json) -> Result<Entry, ArtifactError> {
        let file = dir.join(j.get("file")?.as_str()?);
        let args = j
            .get("args")?
            .as_arr()?
            .iter()
            .map(TensorSig::from_json)
            .collect::<Result<_, _>>()?;
        let results = j
            .get("results")?
            .as_arr()?
            .iter()
            .map(TensorSig::from_json)
            .collect::<Result<_, _>>()?;
        Ok(Entry { name: name.to_string(), file, args, results })
    }
}

/// Auxiliary-network variant: its layout + aux-specific entries.
#[derive(Clone, Debug)]
pub struct AuxConfig {
    /// Architecture name (manifest key).
    pub arch: String,
    /// Flat parameter layout.
    pub layout: Layout,
    /// Parameter count (= layout total).
    pub size: usize,
    /// Aux-specific entry points.
    pub entries: BTreeMap<String, Entry>,
}

/// One dataset configuration (cifar / femnist).
#[derive(Clone, Debug)]
pub struct DatasetConfig {
    /// Dataset name (manifest key).
    pub name: String,
    /// AOT-fixed batch size.
    pub batch: usize,
    /// Input sample shape.
    pub input: Vec<usize>,
    /// Number of output classes.
    pub classes: usize,
    /// Smashed-data shape per sample.
    pub smashed: Vec<usize>,
    /// Smashed elements per sample.
    pub smashed_size: usize,
    /// Client-side model layout.
    pub client_layout: Layout,
    /// Server-side model layout.
    pub server_layout: Layout,
    /// Aux-independent entry points.
    pub entries: BTreeMap<String, Entry>,
    /// Available auxiliary-network variants.
    pub aux: BTreeMap<String, AuxConfig>,
}

impl DatasetConfig {
    /// Input elements per sample.
    pub fn input_len(&self) -> usize {
        self.input.iter().product()
    }

    /// Bytes of one sample's smashed data (f32).
    pub fn smashed_bytes_per_sample(&self) -> u64 {
        (self.smashed_size * 4) as u64
    }

    /// Look an aux-independent entry point up by name.
    pub fn entry(&self, name: &str) -> Result<&Entry, ArtifactError> {
        self.entries
            .get(name)
            .ok_or_else(|| ArtifactError::Invalid(format!("missing entry {name:?}")))
    }

    /// Look an auxiliary-network variant up by architecture name.
    pub fn aux(&self, arch: &str) -> Result<&AuxConfig, ArtifactError> {
        self.aux
            .get(arch)
            .ok_or_else(|| ArtifactError::Invalid(format!("missing aux arch {arch:?}")))
    }
}

/// The parsed AOT manifest: everything Python built.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Artifacts directory (HLO file paths resolve against it).
    pub dir: PathBuf,
    /// Per-dataset configurations.
    pub configs: BTreeMap<String, DatasetConfig>,
}

impl Manifest {
    /// Load and parse `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest, ArtifactError> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest JSON text, resolving file paths against `dir`.
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest, ArtifactError> {
        let j = Json::parse(text)?;
        let format = j.get("format")?.as_usize()?;
        if format != 1 {
            return Err(ArtifactError::Invalid(format!("unknown manifest format {format}")));
        }
        let mut configs = BTreeMap::new();
        for (name, cfg) in j.get("configs")?.as_obj()? {
            let client_layout = Layout::from_json(cfg.get("client_layout")?)?;
            let server_layout = Layout::from_json(cfg.get("server_layout")?)?;
            let client_size = cfg.get("client_size")?.as_usize()?;
            let server_size = cfg.get("server_size")?.as_usize()?;
            if client_layout.total != client_size || server_layout.total != server_size {
                return Err(ArtifactError::Invalid(format!(
                    "{name}: layout totals disagree with sizes"
                )));
            }
            let mut entries = BTreeMap::new();
            for (ename, ej) in cfg.get("entries")?.as_obj()? {
                entries.insert(ename.clone(), Entry::from_json(ename, &dir, ej)?);
            }
            let mut aux = BTreeMap::new();
            for (arch, aj) in cfg.get("aux")?.as_obj()? {
                let layout = Layout::from_json(aj.get("layout")?)?;
                let size = aj.get("size")?.as_usize()?;
                if layout.total != size {
                    return Err(ArtifactError::Invalid(format!(
                        "{name}/{arch}: aux layout total {} != size {size}",
                        layout.total
                    )));
                }
                let mut aentries = BTreeMap::new();
                for (ename, ej) in aj.get("entries")?.as_obj()? {
                    aentries.insert(ename.clone(), Entry::from_json(ename, &dir, ej)?);
                }
                aux.insert(
                    arch.clone(),
                    AuxConfig { arch: arch.clone(), layout, size, entries: aentries },
                );
            }
            configs.insert(
                name.clone(),
                DatasetConfig {
                    name: name.clone(),
                    batch: cfg.get("batch")?.as_usize()?,
                    input: cfg.get("input")?.as_usize_vec()?,
                    classes: cfg.get("classes")?.as_usize()?,
                    smashed: cfg.get("smashed")?.as_usize_vec()?,
                    smashed_size: cfg.get("smashed_size")?.as_usize()?,
                    client_layout,
                    server_layout,
                    entries,
                    aux,
                },
            );
        }
        Ok(Manifest { dir, configs })
    }

    /// Look a dataset configuration up by name.
    pub fn config(&self, name: &str) -> Result<&DatasetConfig, ArtifactError> {
        self.configs
            .get(name)
            .ok_or_else(|| ArtifactError::Invalid(format!("unknown dataset {name:?}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) const MINI_MANIFEST: &str = r#"{
      "format": 1,
      "configs": {
        "toy": {
          "batch": 2, "input": [4, 4, 1], "classes": 3,
          "smashed": [2, 2, 1], "smashed_size": 4,
          "client_size": 6, "server_size": 3,
          "client_layout": [
            {"name":"w","shape":[2,3],"offset":0,"size":6,
             "init":{"kind":"normal","std":0.1}}],
          "server_layout": [
            {"name":"v","shape":[3],"offset":0,"size":3,
             "init":{"kind":"zero"}}],
          "entries": {
            "eval_step": {"file": "toy/eval_step.hlo.txt",
              "args": [{"shape":[6],"dtype":"float32"}],
              "results": [{"shape":[2,3],"dtype":"float32"}]}
          },
          "aux": {
            "mlp": {
              "size": 2,
              "layout": [
                {"name":"a","shape":[2],"offset":0,"size":2,
                 "init":{"kind":"zero"}}],
              "entries": {
                "client_train_step": {"file": "toy/cts_mlp.hlo.txt",
                  "args": [{"shape":[],"dtype":"int32"}],
                  "results": [{"shape":[],"dtype":"float32"}]}
              }
            }
          }
        }
      }
    }"#;

    #[test]
    fn parses_mini_manifest() {
        let m = Manifest::parse(MINI_MANIFEST, PathBuf::from("/a")).unwrap();
        let c = m.config("toy").unwrap();
        assert_eq!(c.batch, 2);
        assert_eq!(c.input_len(), 16);
        assert_eq!(c.smashed_bytes_per_sample(), 16);
        assert_eq!(c.client_layout.total, 6);
        let e = c.entry("eval_step").unwrap();
        assert_eq!(e.file, PathBuf::from("/a/toy/eval_step.hlo.txt"));
        assert_eq!(e.args[0].dtype, Dtype::F32);
        assert_eq!(e.results[0].len(), 6);
        let aux = c.aux("mlp").unwrap();
        assert_eq!(aux.size, 2);
        assert!(aux.entries.contains_key("client_train_step"));
        assert!(c.aux("nope").is_err());
        assert!(m.config("nope").is_err());
    }

    #[test]
    fn rejects_bad_format() {
        let bad = MINI_MANIFEST.replace("\"format\": 1", "\"format\": 99");
        assert!(Manifest::parse(&bad, PathBuf::new()).is_err());
    }

    #[test]
    fn rejects_size_mismatch() {
        let bad = MINI_MANIFEST.replace("\"client_size\": 6", "\"client_size\": 7");
        assert!(Manifest::parse(&bad, PathBuf::new()).is_err());
    }

    #[test]
    fn loads_real_manifest_if_built() {
        // Integration-level check against the actual AOT output when the
        // artifacts exist (CI runs `make artifacts` first).
        let dir = crate::runtime::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let cifar = m.config("cifar").unwrap();
        assert_eq!(cifar.client_layout.total, 107_328);
        assert_eq!(cifar.server_layout.total, 960_970);
        assert_eq!(cifar.aux("mlp").unwrap().size, 23_050);
        let fem = m.config("femnist").unwrap();
        assert_eq!(fem.client_layout.total, 18_816);
        assert_eq!(fem.server_layout.total, 1_187_774);
        assert_eq!(fem.aux("cnn2").unwrap().size, 18_048);
    }
}
