//! Timeline recording for the asynchronous schedule (paper Fig. 3).
//!
//! Records spans (client local training, uploads, server updates, idle
//! gaps) against the simulated clock, computes the utilization metrics
//! the paper argues about (server idle fraction, straggler stall), and
//! renders an ASCII Gantt chart for `examples/async_timeline.rs`.

use super::event::SimTime;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    ClientCompute,
    Upload,
    Download,
    ServerUpdate,
    Aggregate,
}

#[derive(Clone, Debug)]
pub struct Span {
    pub kind: SpanKind,
    /// Client id, or None for server-side spans.
    pub who: Option<usize>,
    pub start: SimTime,
    pub end: SimTime,
    pub label: String,
}

#[derive(Clone, Debug, Default)]
pub struct Timeline {
    pub spans: Vec<Span>,
}

impl Timeline {
    pub fn record(
        &mut self,
        kind: SpanKind,
        who: Option<usize>,
        start: SimTime,
        end: SimTime,
        label: impl Into<String>,
    ) {
        debug_assert!(end >= start);
        self.spans.push(Span { kind, who, start, end, label: label.into() });
    }

    pub fn end_time(&self) -> SimTime {
        self.spans.iter().map(|s| s.end).fold(0.0, f64::max)
    }

    /// Total busy time of the server (update + aggregate spans).
    pub fn server_busy(&self) -> f64 {
        self.spans
            .iter()
            .filter(|s| matches!(s.kind, SpanKind::ServerUpdate | SpanKind::Aggregate))
            .map(|s| s.end - s.start)
            .sum()
    }

    /// Server idle fraction over the full run: 1 - busy/total.
    pub fn server_idle_fraction(&self) -> f64 {
        let total = self.end_time();
        if total <= 0.0 {
            return 0.0;
        }
        (1.0 - self.server_busy() / total).clamp(0.0, 1.0)
    }

    /// First-to-last gap between clients finishing their uploads in a
    /// window — the straggler spread the synchronous barrier pays for.
    pub fn straggler_spread(&self) -> f64 {
        let uploads: Vec<&Span> =
            self.spans.iter().filter(|s| s.kind == SpanKind::Upload).collect();
        if uploads.is_empty() {
            return 0.0;
        }
        let first = uploads.iter().map(|s| s.end).fold(f64::MAX, f64::min);
        let last = uploads.iter().map(|s| s.end).fold(f64::MIN, f64::max);
        last - first
    }

    /// ASCII Gantt chart: one row per client plus a server row.
    pub fn ascii_gantt(&self, columns: usize) -> String {
        let total = self.end_time().max(1e-9);
        let n_clients = self
            .spans
            .iter()
            .filter_map(|s| s.who)
            .max()
            .map(|m| m + 1)
            .unwrap_or(0);
        let mut rows: Vec<Vec<u8>> = vec![vec![b'.'; columns]; n_clients + 1];
        for s in &self.spans {
            let row = match s.who {
                Some(c) => c,
                None => n_clients,
            };
            let a = ((s.start / total) * columns as f64) as usize;
            let b = (((s.end / total) * columns as f64).ceil() as usize).clamp(a + 1, columns);
            let ch = match s.kind {
                SpanKind::ClientCompute => b'#',
                SpanKind::Upload => b'^',
                SpanKind::Download => b'v',
                SpanKind::ServerUpdate => b'S',
                SpanKind::Aggregate => b'A',
            };
            for cell in &mut rows[row][a..b.min(columns)] {
                *cell = ch;
            }
        }
        let mut out = String::new();
        for (i, row) in rows.iter().enumerate() {
            let name = if i < n_clients {
                format!("client {i:>2}")
            } else {
                "server   ".to_string()
            };
            out.push_str(&format!("{name} |{}|\n", String::from_utf8_lossy(row)));
        }
        out.push_str(&format!(
            "legend: #=compute ^=upload v=download S=server-update A=aggregate  total={total:.3}s\n"
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tl() -> Timeline {
        let mut t = Timeline::default();
        t.record(SpanKind::ClientCompute, Some(0), 0.0, 1.0, "c0 train");
        t.record(SpanKind::Upload, Some(0), 1.0, 1.5, "c0 up");
        t.record(SpanKind::ServerUpdate, None, 1.5, 2.0, "s upd");
        t.record(SpanKind::Upload, Some(1), 3.0, 4.0, "c1 up");
        t
    }

    #[test]
    fn metrics() {
        let t = tl();
        assert_eq!(t.end_time(), 4.0);
        assert!((t.server_busy() - 0.5).abs() < 1e-12);
        assert!((t.server_idle_fraction() - (1.0 - 0.5 / 4.0)).abs() < 1e-12);
        assert!((t.straggler_spread() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn gantt_renders_all_rows() {
        let g = tl().ascii_gantt(40);
        assert_eq!(g.lines().count(), 4); // 2 clients + server + legend
        assert!(g.contains('#'));
        assert!(g.contains('^'));
        assert!(g.contains('S'));
    }

    #[test]
    fn empty_timeline_is_benign() {
        let t = Timeline::default();
        assert_eq!(t.end_time(), 0.0);
        assert_eq!(t.server_idle_fraction(), 0.0);
        assert_eq!(t.straggler_spread(), 0.0);
    }
}
