//! Timeline recording for the asynchronous schedule (paper Fig. 3).
//!
//! Records spans (client local training, uploads, server updates, idle
//! gaps) against the simulated clock, computes the utilization metrics
//! the paper argues about (server idle fraction, straggler stall), and
//! renders an ASCII Gantt chart for `examples/async_timeline.rs`.

use super::event::SimTime;

/// What an actor was doing during a span.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// Client-side local computation (training, fwd/bwd).
    ClientCompute,
    /// Client → server transmission.
    Upload,
    /// Server → client transmission.
    Download,
    /// One event-triggered server model update.
    ServerUpdate,
    /// Server-side FedAvg barrier.
    Aggregate,
}

/// One recorded interval of simulated activity.
#[derive(Clone, Debug, PartialEq)]
pub struct Span {
    /// What the actor was doing.
    pub kind: SpanKind,
    /// Client id, or None for server-side spans. With a sharded server
    /// (`server_shards > 1`) all shard executors share the `None` actor
    /// and annotate their shard in the label (`… s<k>`); server spans
    /// from different shards may then legitimately overlap in time.
    pub who: Option<usize>,
    /// Server executor lane that produced the span
    /// ([`Timeline::record_in_lane`]); `None` for client spans and for
    /// server-side barriers that occupy every lane (aggregation).
    pub lane: Option<usize>,
    /// Span start (simulated seconds).
    pub start: SimTime,
    /// Span end (>= start).
    pub end: SimTime,
    /// Free-form annotation (rendered in the Gantt chart).
    pub label: String,
}

/// The recorded schedule. Timelines are mergeable: the parallel round
/// engine records each client's spans into a worker-local timeline and
/// [`Timeline::append`]s them in canonical order (client id, then time),
/// reproducing the sequential span order bit-for-bit.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Timeline {
    /// Recorded spans, in recording order.
    pub spans: Vec<Span>,
}

impl Timeline {
    /// Record one span (end must not precede start).
    pub fn record(
        &mut self,
        kind: SpanKind,
        who: Option<usize>,
        start: SimTime,
        end: SimTime,
        label: impl Into<String>,
    ) {
        debug_assert!(end >= start);
        self.spans.push(Span { kind, who, lane: None, start, end, label: label.into() });
    }

    /// Record one span attributed to a server executor lane (the
    /// sharded server phase; `who` stays the server actor `None`).
    /// Lane attribution feeds the per-lane busy/idle accounting
    /// ([`Timeline::lane_busy`]).
    pub fn record_in_lane(
        &mut self,
        kind: SpanKind,
        who: Option<usize>,
        lane: usize,
        start: SimTime,
        end: SimTime,
        label: impl Into<String>,
    ) {
        debug_assert!(end >= start);
        self.spans.push(Span { kind, who, lane: Some(lane), start, end, label: label.into() });
    }

    /// Append another timeline's spans (in their recorded order).
    pub fn append(&mut self, mut other: Timeline) {
        self.spans.append(&mut other.spans);
    }

    /// Latest span end (the simulated run time).
    pub fn end_time(&self) -> SimTime {
        self.spans.iter().map(|s| s.end).fold(0.0, f64::max)
    }

    /// Client ids appearing in the timeline, ascending and deduplicated.
    pub fn client_ids(&self) -> Vec<usize> {
        let mut ids: Vec<usize> = self.spans.iter().filter_map(|s| s.who).collect();
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    /// Worst pairwise overlap between spans of one actor (`Some(client)`
    /// or `None` for the server); 0.0 when the actor's schedule is
    /// consistent (no actor can do two things at once).
    pub fn max_overlap(&self, who: Option<usize>) -> f64 {
        let mut windows: Vec<(SimTime, SimTime)> = self
            .spans
            .iter()
            .filter(|s| s.who == who)
            .map(|s| (s.start, s.end))
            .collect();
        windows.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
        let mut worst = 0.0f64;
        let mut frontier = f64::NEG_INFINITY;
        for (start, end) in windows {
            if frontier > start {
                // A span nested inside an earlier one overlaps only up to
                // its own end, not to the earlier span's.
                worst = worst.max(frontier.min(end) - start);
            }
            frontier = frontier.max(end);
        }
        worst
    }

    /// Total busy time of one actor: the sum of its span durations.
    /// Actor `None` is the server as a whole; with a sharded server that
    /// sums across lanes (use [`Timeline::lane_busy`] for per-executor
    /// accounting).
    pub fn actor_busy(&self, who: Option<usize>) -> f64 {
        self.spans.iter().filter(|s| s.who == who).map(|s| s.end - s.start).sum()
    }

    /// Busy seconds per server executor lane over the run (`lanes` =
    /// executor count; at least one). Lane-tagged server spans count
    /// toward their lane; untagged server-side spans — the aggregation
    /// barrier, or records from before lane attribution — occupy every
    /// executor, so they count toward all lanes.
    pub fn lane_busy(&self, lanes: usize) -> Vec<f64> {
        let lanes = lanes.max(1);
        let mut busy = vec![0.0f64; lanes];
        for s in &self.spans {
            if !matches!(s.kind, SpanKind::ServerUpdate | SpanKind::Aggregate) {
                continue;
            }
            let d = s.end - s.start;
            match s.lane {
                Some(l) if l < lanes => busy[l] += d,
                Some(_) => {}
                None => busy.iter_mut().for_each(|b| *b += d),
            }
        }
        busy
    }

    /// Critical-path lower bound on the makespan: the busiest single
    /// actor. No schedule, however well packed, can finish before its
    /// busiest client or its busiest server executor lane — each actor's
    /// spans are serialized (`max_overlap` invariant), so its busy total
    /// bounds the wall clock from below. The run summary reports
    /// `critical_path / end_time` as scheduling efficiency (1.0 = the
    /// schedule is as short as its busiest actor allows).
    pub fn critical_path(&self, lanes: usize) -> f64 {
        let mut per_client: std::collections::BTreeMap<usize, f64> =
            std::collections::BTreeMap::new();
        for s in &self.spans {
            if let Some(c) = s.who {
                *per_client.entry(c).or_insert(0.0) += s.end - s.start;
            }
        }
        let client_max = per_client.values().fold(0.0f64, |a, &b| a.max(b));
        let lane_max = self.lane_busy(lanes).into_iter().fold(0.0f64, f64::max);
        client_max.max(lane_max)
    }

    /// Total busy time of the server (update + aggregate spans). With a
    /// sharded server this sums across shard executors, so it can exceed
    /// the wall-clock span — use it as aggregate work, not utilization.
    pub fn server_busy(&self) -> f64 {
        self.spans
            .iter()
            .filter(|s| matches!(s.kind, SpanKind::ServerUpdate | SpanKind::Aggregate))
            .map(|s| s.end - s.start)
            .sum()
    }

    /// Server idle fraction over the full run: 1 - busy/total, clamped
    /// to [0, 1] (a k-shard server summing k busy executors can exceed
    /// the wall clock; the clamp reports "never idle" in that regime).
    pub fn server_idle_fraction(&self) -> f64 {
        let total = self.end_time();
        if total <= 0.0 {
            return 0.0;
        }
        (1.0 - self.server_busy() / total).clamp(0.0, 1.0)
    }

    /// First-to-last gap between clients finishing their uploads in a
    /// window — the straggler spread the synchronous barrier pays for.
    pub fn straggler_spread(&self) -> f64 {
        let uploads: Vec<&Span> =
            self.spans.iter().filter(|s| s.kind == SpanKind::Upload).collect();
        if uploads.is_empty() {
            return 0.0;
        }
        let first = uploads.iter().map(|s| s.end).fold(f64::MAX, f64::min);
        let last = uploads.iter().map(|s| s.end).fold(f64::MIN, f64::max);
        last - first
    }

    /// ASCII Gantt chart: one row per client plus a server row.
    pub fn ascii_gantt(&self, columns: usize) -> String {
        let total = self.end_time().max(1e-9);
        let n_clients = self
            .spans
            .iter()
            .filter_map(|s| s.who)
            .max()
            .map(|m| m + 1)
            .unwrap_or(0);
        let mut rows: Vec<Vec<u8>> = vec![vec![b'.'; columns]; n_clients + 1];
        for s in &self.spans {
            let row = match s.who {
                Some(c) => c,
                None => n_clients,
            };
            let a = ((s.start / total) * columns as f64) as usize;
            let b = (((s.end / total) * columns as f64).ceil() as usize).clamp(a + 1, columns);
            let ch = match s.kind {
                SpanKind::ClientCompute => b'#',
                SpanKind::Upload => b'^',
                SpanKind::Download => b'v',
                SpanKind::ServerUpdate => b'S',
                SpanKind::Aggregate => b'A',
            };
            for cell in &mut rows[row][a..b.min(columns)] {
                *cell = ch;
            }
        }
        let mut out = String::new();
        for (i, row) in rows.iter().enumerate() {
            let name = if i < n_clients {
                format!("client {i:>2}")
            } else {
                "server   ".to_string()
            };
            out.push_str(&format!("{name} |{}|\n", String::from_utf8_lossy(row)));
        }
        out.push_str(&format!(
            "legend: #=compute ^=upload v=download S=server-update A=aggregate  total={total:.3}s\n"
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tl() -> Timeline {
        let mut t = Timeline::default();
        t.record(SpanKind::ClientCompute, Some(0), 0.0, 1.0, "c0 train");
        t.record(SpanKind::Upload, Some(0), 1.0, 1.5, "c0 up");
        t.record(SpanKind::ServerUpdate, None, 1.5, 2.0, "s upd");
        t.record(SpanKind::Upload, Some(1), 3.0, 4.0, "c1 up");
        t
    }

    #[test]
    fn metrics() {
        let t = tl();
        assert_eq!(t.end_time(), 4.0);
        assert!((t.server_busy() - 0.5).abs() < 1e-12);
        assert!((t.server_idle_fraction() - (1.0 - 0.5 / 4.0)).abs() < 1e-12);
        assert!((t.straggler_spread() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn gantt_renders_all_rows() {
        let g = tl().ascii_gantt(40);
        assert_eq!(g.lines().count(), 4); // 2 clients + server + legend
        assert!(g.contains('#'));
        assert!(g.contains('^'));
        assert!(g.contains('S'));
    }

    #[test]
    fn empty_timeline_is_benign() {
        let t = Timeline::default();
        assert_eq!(t.end_time(), 0.0);
        assert_eq!(t.server_idle_fraction(), 0.0);
        assert_eq!(t.straggler_spread(), 0.0);
        assert_eq!(t.max_overlap(None), 0.0);
        assert!(t.client_ids().is_empty());
    }

    #[test]
    fn append_preserves_order_and_equality() {
        let whole = tl();
        let mut merged = Timeline::default();
        let mut part1 = Timeline::default();
        part1.record(SpanKind::ClientCompute, Some(0), 0.0, 1.0, "c0 train");
        part1.record(SpanKind::Upload, Some(0), 1.0, 1.5, "c0 up");
        let mut part2 = Timeline::default();
        part2.record(SpanKind::ServerUpdate, None, 1.5, 2.0, "s upd");
        part2.record(SpanKind::Upload, Some(1), 3.0, 4.0, "c1 up");
        merged.append(part1);
        merged.append(part2);
        assert_eq!(merged, whole);
        assert_eq!(merged.client_ids(), vec![0, 1]);
    }

    #[test]
    fn lane_accounting_and_critical_path() {
        let mut t = Timeline::default();
        // Client 0 busy for 1.5s total; client 1 for 1.0s.
        t.record(SpanKind::ClientCompute, Some(0), 0.0, 1.0, "c0 train");
        t.record(SpanKind::Upload, Some(0), 1.0, 1.5, "c0 up");
        t.record(SpanKind::Upload, Some(1), 0.0, 1.0, "c1 up");
        // Two server lanes: lane 0 busy 0.5s, lane 1 busy 2.0s, plus a
        // 0.25s aggregation barrier that occupies both.
        t.record_in_lane(SpanKind::ServerUpdate, None, 0, 1.5, 2.0, "u s0");
        t.record_in_lane(SpanKind::ServerUpdate, None, 1, 1.0, 3.0, "u s1");
        t.record(SpanKind::Aggregate, None, 3.0, 3.25, "fedavg");
        assert_eq!(t.spans[0].lane, None);
        assert_eq!(t.spans[3].lane, Some(0));
        let busy = t.lane_busy(2);
        assert!((busy[0] - 0.75).abs() < 1e-12, "{busy:?}");
        assert!((busy[1] - 2.25).abs() < 1e-12, "{busy:?}");
        assert!((t.actor_busy(Some(0)) - 1.5).abs() < 1e-12);
        assert!((t.actor_busy(None) - 2.75).abs() < 1e-12);
        // Busiest actor: lane 1 at 2.25s. Always <= makespan.
        let cp = t.critical_path(2);
        assert!((cp - 2.25).abs() < 1e-12, "{cp}");
        assert!(cp <= t.end_time());
        // A narrower lane view keeps in-range and untagged spans and
        // drops out-of-range lanes (a caller mismatch, not a panic).
        let one = t.lane_busy(1);
        assert!((one[0] - 0.75).abs() < 1e-12, "{one:?}");
        // Empty timeline is benign.
        assert_eq!(Timeline::default().critical_path(3), 0.0);
        assert_eq!(Timeline::default().lane_busy(2), vec![0.0, 0.0]);
    }

    #[test]
    fn overlap_detection() {
        let t = tl();
        assert_eq!(t.max_overlap(Some(0)), 0.0);
        assert_eq!(t.max_overlap(None), 0.0);
        let mut bad = Timeline::default();
        bad.record(SpanKind::ClientCompute, Some(2), 0.0, 2.0, "a");
        bad.record(SpanKind::Upload, Some(2), 1.25, 3.0, "b");
        assert!((bad.max_overlap(Some(2)) - 0.75).abs() < 1e-12);
        assert_eq!(bad.max_overlap(Some(9)), 0.0, "unknown actor has no spans");
        // A span nested in a longer one overlaps only its own duration.
        let mut nested = Timeline::default();
        nested.record(SpanKind::ClientCompute, Some(3), 0.0, 2.0, "outer");
        nested.record(SpanKind::Upload, Some(3), 0.5, 0.75, "inner");
        assert!((nested.max_overlap(Some(3)) - 0.25).abs() < 1e-12);
    }
}
