//! Client heterogeneity + network delay models.
//!
//! Converts the byte counts from `comm::accounting` and per-client compute
//! profiles into simulated delays (Fig. 3's staggered arrivals). Each
//! client draws a persistent speed profile at setup — "variations in
//! training and communication delays across client devices" — plus
//! per-operation jitter.

use crate::util::prng::Rng;

/// Persistent per-client performance profile.
#[derive(Clone, Debug)]
pub struct ClientProfile {
    /// Seconds of compute per training batch.
    pub batch_time: f64,
    /// Uplink bandwidth, bytes/second.
    pub up_bps: f64,
    /// Downlink bandwidth, bytes/second.
    pub down_bps: f64,
    /// Fixed per-message latency, seconds.
    pub rtt: f64,
    /// Multiplicative jitter sigma (log-normal) on every operation.
    pub jitter: f64,
}

/// Heterogeneity model parameters.
#[derive(Clone, Debug)]
pub struct NetModel {
    /// Mean seconds per training batch.
    pub mean_batch_time: f64,
    /// Log-normal sigma of per-client batch speed (heterogeneity).
    pub speed_sigma: f64,
    /// Mean uplink bytes/sec.
    pub mean_up_bps: f64,
    /// Mean downlink bytes/sec.
    pub mean_down_bps: f64,
    /// Log-normal sigma of per-client bandwidth.
    pub bw_sigma: f64,
    /// Mean one-way latency.
    pub mean_rtt: f64,
    /// Per-operation jitter sigma.
    pub jitter: f64,
    /// Seconds of server compute per arriving smashed batch update.
    pub server_update_time: f64,
}

impl NetModel {
    /// An edge-device-flavored default: ~10 ms/batch compute, ~20 Mbit/s
    /// up, ~100 Mbit/s down, 20 ms latency, 2x client heterogeneity.
    pub fn edge_default() -> Self {
        NetModel {
            mean_batch_time: 0.010,
            speed_sigma: 0.6,
            mean_up_bps: 2.5e6,
            mean_down_bps: 12.5e6,
            bw_sigma: 0.5,
            mean_rtt: 0.020,
            jitter: 0.10,
            server_update_time: 0.004,
        }
    }

    /// Homogeneous variant (no client-to-client spread, no jitter) —
    /// isolates algorithmic ordering from hardware noise in tests.
    pub fn homogeneous() -> Self {
        NetModel {
            speed_sigma: 0.0,
            bw_sigma: 0.0,
            jitter: 0.0,
            ..Self::edge_default()
        }
    }

    /// Heavy-tailed heterogeneity: a much wider log-normal spread of
    /// per-client speed and bandwidth than [`NetModel::edge_default`]
    /// (a few clients are order-of-magnitude stragglers). This is the
    /// regime the cost-aware scheduling policies and the balanced shard
    /// map are for — used by the scheduler benches and tests.
    pub fn heavy_tailed() -> Self {
        NetModel {
            speed_sigma: 1.5,
            bw_sigma: 1.0,
            ..Self::edge_default()
        }
    }

    /// Draw a persistent profile for one client.
    pub fn sample_profile(&self, rng: &mut Rng) -> ClientProfile {
        let spd = if self.speed_sigma > 0.0 { rng.lognormal(1.0, self.speed_sigma) } else { 1.0 };
        let bw = if self.bw_sigma > 0.0 { rng.lognormal(1.0, self.bw_sigma) } else { 1.0 };
        ClientProfile {
            batch_time: self.mean_batch_time * spd,
            up_bps: self.mean_up_bps * bw,
            down_bps: self.mean_down_bps * bw,
            rtt: self.mean_rtt,
            jitter: self.jitter,
        }
    }

    /// The persistent profile of client `id`, derived *per id* from a
    /// non-mutated profile root stream: `profile_for(root, id)` is a
    /// pure function of `(model, root, id)`, so a population engine can
    /// materialize any client's profile on activation — in any order,
    /// any number of times — and always get the same draw the resident
    /// engine gets. This replaces the old sequential
    /// `sample_profile(&mut prng)` loop at trainer setup, whose draws
    /// depended on every lower client id having been sampled first.
    pub fn profile_for(&self, prof_root: &Rng, id: u64) -> ClientProfile {
        self.sample_profile(&mut prof_root.split(id))
    }

}

impl ClientProfile {
    fn jittered(&self, base: f64, rng: &mut Rng) -> f64 {
        if self.jitter > 0.0 {
            base * rng.lognormal(1.0, self.jitter)
        } else {
            base
        }
    }

    /// Compute time for `batches` local training batches.
    pub fn compute_delay(&self, batches: usize, rng: &mut Rng) -> f64 {
        self.jittered(self.batch_time * batches as f64, rng)
    }

    /// Uplink transmission time for a payload.
    pub fn upload_delay(&self, bytes: u64, rng: &mut Rng) -> f64 {
        self.jittered(self.rtt + bytes as f64 / self.up_bps, rng)
    }

    /// Downlink transmission time for a payload.
    pub fn download_delay(&self, bytes: u64, rng: &mut Rng) -> f64 {
        self.jittered(self.rtt + bytes as f64 / self.down_bps, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn homogeneous_is_deterministic() {
        let m = NetModel::homogeneous();
        let mut rng = Rng::new(1);
        let p1 = m.sample_profile(&mut rng);
        let p2 = m.sample_profile(&mut rng);
        assert_eq!(p1.batch_time, p2.batch_time);
        let mut r = Rng::new(2);
        assert_eq!(p1.compute_delay(10, &mut r), p1.batch_time * 10.0);
        // upload delay = rtt + bytes/bw exactly
        let d = p1.upload_delay(2_500_000, &mut r);
        assert!((d - (0.020 + 1.0)).abs() < 1e-9, "{d}");
    }

    #[test]
    fn heterogeneous_profiles_spread() {
        let m = NetModel::edge_default();
        let mut rng = Rng::new(3);
        let speeds: Vec<f64> = (0..64).map(|_| m.sample_profile(&mut rng).batch_time).collect();
        let min = speeds.iter().cloned().fold(f64::MAX, f64::min);
        let max = speeds.iter().cloned().fold(f64::MIN, f64::max);
        assert!(max / min > 2.0, "expected heterogeneity, got {min}..{max}");
    }

    #[test]
    fn heavy_tailed_spreads_wider_than_default() {
        let base = NetModel::edge_default();
        let heavy = NetModel::heavy_tailed();
        assert!(heavy.speed_sigma > base.speed_sigma);
        assert!(heavy.bw_sigma > base.bw_sigma);
        // Same means: only the spread changes.
        assert_eq!(heavy.mean_batch_time, base.mean_batch_time);
        assert_eq!(heavy.mean_up_bps, base.mean_up_bps);
    }

    #[test]
    fn profile_for_is_order_independent_and_matches_split() {
        let m = NetModel::heavy_tailed();
        let root = Rng::new(0xBEEF);
        // Same (root, id) → same profile, regardless of how many other
        // ids were materialized before, and `split` is non-mutating so
        // the root itself never advances.
        let a = m.profile_for(&root, 7);
        for id in [0u64, 3, 1_000_000, 7] {
            let _ = m.profile_for(&root, id);
        }
        let b = m.profile_for(&root, 7);
        assert_eq!(a.batch_time, b.batch_time);
        assert_eq!(a.up_bps, b.up_bps);
        assert_eq!(a.down_bps, b.down_bps);
        // And it is exactly sample_profile on the derived child stream.
        let c = m.sample_profile(&mut root.split(7));
        assert_eq!(a.batch_time, c.batch_time);
        assert_eq!(a.up_bps, c.up_bps);
        // Distinct ids draw distinct profiles under heterogeneity.
        let d = m.profile_for(&root, 8);
        assert_ne!(a.batch_time, d.batch_time);
    }

    #[test]
    fn delays_monotone_in_size() {
        let m = NetModel::homogeneous();
        let mut rng = Rng::new(4);
        let p = m.sample_profile(&mut rng);
        assert!(p.upload_delay(10_000, &mut rng) < p.upload_delay(10_000_000, &mut rng));
        assert!(p.compute_delay(1, &mut rng) < p.compute_delay(50, &mut rng));
    }

    #[test]
    fn downlink_faster_than_uplink_by_default() {
        let m = NetModel::homogeneous();
        let mut rng = Rng::new(5);
        let p = m.sample_profile(&mut rng);
        let up = p.upload_delay(1_000_000, &mut rng);
        let down = p.download_delay(1_000_000, &mut rng);
        assert!(down < up);
    }
}
