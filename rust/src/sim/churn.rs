//! Client churn & reliability models: generative availability processes,
//! mid-round failure rates, and server-side resilience policies.
//!
//! The population engine (PR 6) opened two flat knobs — a per-round
//! i.i.d. availability Bernoulli and a straggler cutoff. This module
//! generalizes both into a composable subsystem threaded through BOTH
//! client engines (resident + streaming population):
//!
//! * [`ChurnModel`] — *who shows up*: a generative availability process
//!   evaluated per (round, client). [`ChurnModel::Iid`] replays the
//!   legacy `availability` draw sequence bit-identically (pinned by
//!   `tests/churn_properties.rs`); [`ChurnModel::Diurnal`],
//!   [`ChurnModel::MarkovOnOff`], and [`ChurnModel::Correlated`] add
//!   time-of-day waves, sticky per-client sessions, and cluster-wide
//!   blackout rounds — the failure mode i.i.d. models cannot express.
//! * `ChurnConfig::fail_rate` — *who dies mid-round*: a sampled client
//!   can crash after computing a prefix of its h batches, leaving a
//!   partial smashed upload on the wire (half the wire bytes ledgered,
//!   no message delivered — see `coordinator::round::run_local_client`).
//! * [`ResiliencePolicy`] — *what the server does about it*: wait for
//!   everyone, cut stragglers past a window, or guard a minimum quorum
//!   with deterministic replacement re-sampling.
//!
//! # Determinism
//!
//! Every draw derives from non-mutating `(round, id)` splits of a root
//! stream ([`ChurnState::new`]; the root is `run_root.split_str(
//! "availability")`, the legacy population stream, so `Iid{p}` replays
//! the pre-churn path draw-for-draw). No draw advances any other
//! stream, so the bit-determinism contract — parallel == sequential,
//! any sched, resident ≡ population — survives every model: the only
//! thing churn can change is *which* clients participate. The Markov
//! model's per-client session state is memoized in [`ChurnState`] but
//! remains a pure function of `(id, round)`: state is always advanced
//! from round 0 through consecutive transition draws, so query order
//! (and engine choice) cannot change it.

use std::collections::BTreeMap;

use crate::util::prng::Rng;

/// A generative per-round client availability process.
///
/// Evaluated by [`ChurnState::is_available`] per `(round, id)`; the
/// default ([`ChurnModel::Iid`] at `p = 1.0`) draws nothing and admits
/// everyone — the contract-covered full-participation behavior.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ChurnModel {
    /// Independent per-(round, client) Bernoulli: each sampled
    /// participant sits the round out with probability `1 - p`.
    /// Bit-identical to the pre-churn `availability` knob of the
    /// population engine (same root stream, same split structure, and
    /// `p = 1.0` performs no draws at all).
    Iid {
        /// Per-round availability in (0, 1].
        p: f64,
    },
    /// A diurnal wave: availability at round `t` is
    /// `1 - amplitude * 0.5 * (1 + sin(2π (t / period_rounds + phase)))`
    /// — full participation at the trough of the sine, `1 - amplitude`
    /// at its peak — with the same independent per-(round, id) draw
    /// structure as [`ChurnModel::Iid`].
    Diurnal {
        /// Peak participation drop in [0, 1] (0 = always full).
        amplitude: f64,
        /// Rounds per day (>= 1).
        period_rounds: usize,
        /// Phase offset in cycles (0.25 = start at the availability
        /// minimum's quarter-wave).
        phase: f64,
    },
    /// Sticky per-client on/off sessions: a two-state Markov chain per
    /// client, initialized at its stationary distribution
    /// `π_up = p_up / (p_up + p_down)` and advanced one transition per
    /// round. Over long horizons the realized occupancy converges to
    /// `π_up` (pinned by `tests/churn_properties.rs`).
    MarkovOnOff {
        /// Down → up transition probability per round, in (0, 1].
        p_up: f64,
        /// Up → down transition probability per round, in [0, 1].
        p_down: f64,
    },
    /// Cluster-wide blackout rounds: client `id` belongs to cluster
    /// `id % clusters`, and each (round, cluster) pair independently
    /// blacks out with probability `p_outage` — every client of a
    /// blacked-out cluster misses the round together, the correlated
    /// failure mode no i.i.d. process can express.
    Correlated {
        /// Number of failure-correlated client clusters (>= 1).
        clusters: usize,
        /// Per-round whole-cluster outage probability in [0, 1).
        p_outage: f64,
    },
}

impl Default for ChurnModel {
    fn default() -> Self {
        ChurnModel::Iid { p: 1.0 }
    }
}

impl ChurnModel {
    /// Whether this model admits every client every round without
    /// drawing (the contract default: `Iid` at `p = 1.0`).
    pub fn is_full(&self) -> bool {
        matches!(self, ChurnModel::Iid { p } if *p == 1.0)
    }

    /// Short cache-key tag (the `-c` segment of `RunSpec::key`; only
    /// non-default models are keyed).
    pub fn tag(&self) -> String {
        match self {
            ChurnModel::Iid { p } => format!("iid{p}"),
            ChurnModel::Diurnal { amplitude, period_rounds, phase } => {
                if *phase == 0.0 {
                    format!("diur{amplitude}x{period_rounds}")
                } else {
                    format!("diur{amplitude}x{period_rounds}p{phase}")
                }
            }
            ChurnModel::MarkovOnOff { p_up, p_down } => format!("mk{p_up}-{p_down}"),
            ChurnModel::Correlated { clusters, p_outage } => {
                format!("corr{clusters}x{p_outage}")
            }
        }
    }

    /// Parse the CLI spelling: `none` | `iid:<p>` |
    /// `diurnal:<amplitude>:<period>[:<phase>]` | `markov:<p_up>:<p_down>`
    /// | `correlated:<clusters>:<p_outage>`.
    pub fn parse(s: &str) -> Result<ChurnModel, String> {
        let low = s.to_ascii_lowercase();
        if low == "none" || low == "full" {
            return Ok(ChurnModel::Iid { p: 1.0 });
        }
        let parts: Vec<&str> = low.split(':').collect();
        let bad = || {
            format!(
                "bad churn model {s:?} (expected none | iid:<p> | \
                 diurnal:<amplitude>:<period>[:<phase>] | markov:<p_up>:<p_down> | \
                 correlated:<clusters>:<p_outage>)"
            )
        };
        let f = |v: &str| v.parse::<f64>().map_err(|_| bad());
        let model = match (parts[0], parts.len()) {
            ("iid", 2) => ChurnModel::Iid { p: f(parts[1])? },
            ("diurnal", 3) => ChurnModel::Diurnal {
                amplitude: f(parts[1])?,
                period_rounds: parts[2].parse().map_err(|_| bad())?,
                phase: 0.0,
            },
            ("diurnal", 4) => ChurnModel::Diurnal {
                amplitude: f(parts[1])?,
                period_rounds: parts[2].parse().map_err(|_| bad())?,
                phase: f(parts[3])?,
            },
            ("markov", 3) => {
                ChurnModel::MarkovOnOff { p_up: f(parts[1])?, p_down: f(parts[2])? }
            }
            ("correlated", 3) => ChurnModel::Correlated {
                clusters: parts[1].parse().map_err(|_| bad())?,
                p_outage: f(parts[2])?,
            },
            _ => return Err(bad()),
        };
        model.validate()?;
        Ok(model)
    }

    /// Check the model parameters; returns a human-readable reason when
    /// they cannot run (NaN and out-of-range values are rejected here,
    /// at config build time, instead of flowing into the engines).
    pub fn validate(&self) -> Result<(), String> {
        match *self {
            ChurnModel::Iid { p } => {
                if !(p > 0.0 && p <= 1.0) {
                    return Err(format!("churn iid: availability {p} outside (0, 1]"));
                }
            }
            ChurnModel::Diurnal { amplitude, period_rounds, phase } => {
                if !(amplitude >= 0.0 && amplitude <= 1.0) {
                    return Err(format!("churn diurnal: amplitude {amplitude} outside [0, 1]"));
                }
                if period_rounds == 0 {
                    return Err("churn diurnal: period must be >= 1 round".into());
                }
                if !phase.is_finite() {
                    return Err(format!("churn diurnal: non-finite phase {phase}"));
                }
            }
            ChurnModel::MarkovOnOff { p_up, p_down } => {
                if !(p_up > 0.0 && p_up <= 1.0) {
                    return Err(format!("churn markov: p_up {p_up} outside (0, 1]"));
                }
                if !(p_down >= 0.0 && p_down <= 1.0) {
                    return Err(format!("churn markov: p_down {p_down} outside [0, 1]"));
                }
            }
            ChurnModel::Correlated { clusters, p_outage } => {
                if clusters == 0 {
                    return Err("churn correlated: clusters must be >= 1".into());
                }
                if !(p_outage >= 0.0 && p_outage < 1.0) {
                    return Err(format!(
                        "churn correlated: p_outage {p_outage} outside [0, 1)"
                    ));
                }
            }
        }
        Ok(())
    }
}

impl std::fmt::Display for ChurnModel {
    /// The canonical CLI spelling ([`ChurnModel::parse`] round-trips it).
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ChurnModel::Iid { p } if *p == 1.0 => write!(f, "none"),
            ChurnModel::Iid { p } => write!(f, "iid:{p}"),
            ChurnModel::Diurnal { amplitude, period_rounds, phase } => {
                if *phase == 0.0 {
                    write!(f, "diurnal:{amplitude}:{period_rounds}")
                } else {
                    write!(f, "diurnal:{amplitude}:{period_rounds}:{phase}")
                }
            }
            ChurnModel::MarkovOnOff { p_up, p_down } => write!(f, "markov:{p_up}:{p_down}"),
            ChurnModel::Correlated { clusters, p_outage } => {
                write!(f, "correlated:{clusters}:{p_outage}")
            }
        }
    }
}

/// What the server does about missing / late cohort members.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ResiliencePolicy {
    /// Process every arrival, however late (the contract default, and
    /// the pre-churn behavior without a straggler cutoff).
    WaitAll,
    /// Drop any smashed upload arriving more than `secs` simulated
    /// seconds after the round's first arrival (the pre-churn
    /// `straggler_cutoff` knob, now on both engines).
    Cutoff {
        /// Dropout window past the round's first arrival (>= 0).
        secs: f64,
    },
    /// Partial aggregation with a minimum-cohort guard: after the churn
    /// filter, if fewer than `ceil(min_frac * planned)` participants
    /// survive and `resample` is set, replacements are re-sampled
    /// deterministically from the still-available population (bounded
    /// rejection sampling off a per-round stream); a still-short round
    /// proceeds with whoever is left. `Quorum { min_frac: 1.0,
    /// resample: false }` is byte-identical to [`ResiliencePolicy::
    /// WaitAll`] (no draws are ever taken when the quorum is met).
    Quorum {
        /// Minimum surviving fraction of the planned cohort, in (0, 1].
        min_frac: f64,
        /// Re-sample deterministic replacements when below quorum.
        resample: bool,
    },
}

impl Default for ResiliencePolicy {
    fn default() -> Self {
        ResiliencePolicy::WaitAll
    }
}

impl ResiliencePolicy {
    /// Check the policy parameters (NaN / negative windows rejected at
    /// config build time).
    pub fn validate(&self) -> Result<(), String> {
        match *self {
            ResiliencePolicy::WaitAll => {}
            ResiliencePolicy::Cutoff { secs } => {
                if !(secs.is_finite() && secs >= 0.0) {
                    return Err(format!("straggler cutoff {secs} must be finite and >= 0"));
                }
            }
            ResiliencePolicy::Quorum { min_frac, .. } => {
                if !(min_frac > 0.0 && min_frac <= 1.0) {
                    return Err(format!("quorum fraction {min_frac} outside (0, 1]"));
                }
            }
        }
        Ok(())
    }

    /// The straggler window when this policy cuts stragglers.
    pub fn cutoff(&self) -> Option<f64> {
        match *self {
            ResiliencePolicy::Cutoff { secs } => Some(secs),
            _ => None,
        }
    }
}

impl std::fmt::Display for ResiliencePolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ResiliencePolicy::WaitAll => write!(f, "wait-all"),
            ResiliencePolicy::Cutoff { secs } => write!(f, "cutoff:{secs}"),
            ResiliencePolicy::Quorum { min_frac, resample } => {
                write!(f, "quorum:{min_frac}{}", if *resample { ":resample" } else { "" })
            }
        }
    }
}

/// The full churn & reliability configuration of a run: availability
/// model × mid-round failure rate × server resilience policy. The
/// default is the contract point — full availability, no failures,
/// wait for everyone — under which no churn draw ever happens and
/// every golden record is byte-unchanged.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ChurnConfig {
    /// Who shows up each round.
    pub model: ChurnModel,
    /// Probability (per sampled participant per round) of dying
    /// mid-round after computing a prefix of its batches, in [0, 1).
    pub fail_rate: f64,
    /// What the server does about missing / late members.
    pub policy: ResiliencePolicy,
}

impl ChurnConfig {
    /// Whether this is the contract default (no draws anywhere).
    pub fn is_default(&self) -> bool {
        self.model.is_full()
            && self.fail_rate == 0.0
            && self.policy == ResiliencePolicy::WaitAll
    }

    /// Check every knob; rejections name the offending parameter.
    pub fn validate(&self) -> Result<(), String> {
        self.model.validate()?;
        if !(self.fail_rate >= 0.0 && self.fail_rate < 1.0) {
            return Err(format!("fail-rate {} outside [0, 1)", self.fail_rate));
        }
        self.policy.validate()
    }

    /// The cache-key suffix: empty at the default (preset key strings
    /// are pinned literally), one segment per non-default knob.
    pub fn key_suffix(&self) -> String {
        let mut s = String::new();
        if !self.model.is_full() {
            s.push_str(&format!("-c{}", self.model.tag()));
        }
        if self.fail_rate > 0.0 {
            s.push_str(&format!("-f{}", self.fail_rate));
        }
        match self.policy {
            ResiliencePolicy::WaitAll => {}
            ResiliencePolicy::Cutoff { secs } => s.push_str(&format!("-cut{secs}")),
            ResiliencePolicy::Quorum { min_frac, resample } => {
                s.push_str(&format!("-q{min_frac}{}", if resample { "r" } else { "" }));
            }
        }
        s
    }

    /// The run-label suffix: empty at the default, human-readable tags
    /// otherwise (rides into `RunRecord::label` and series CSVs).
    pub fn label_suffix(&self) -> String {
        let mut s = String::new();
        if !self.model.is_full() {
            s.push_str(&format!(" {}", self.model.tag()));
        }
        if self.fail_rate > 0.0 {
            s.push_str(&format!(" fail{}", self.fail_rate));
        }
        match self.policy {
            ResiliencePolicy::WaitAll => {}
            ResiliencePolicy::Cutoff { secs } => s.push_str(&format!(" cut{secs}")),
            ResiliencePolicy::Quorum { min_frac, resample } => {
                s.push_str(&format!(" q{min_frac}{}", if resample { "r" } else { "" }));
            }
        }
        s
    }
}

/// Per-run reliability counters, accumulated by the trainer and
/// surfaced through `RunRecord` / summary JSON.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ChurnStats {
    /// Sampled participants removed by the availability model.
    pub clients_dropped: u64,
    /// Replacement participants admitted by quorum re-sampling.
    pub clients_replaced: u64,
    /// Participants that died mid-round after a partial upload.
    pub partial_failures: u64,
    /// Smashed uploads dropped by the straggler cutoff.
    pub stragglers_dropped: u64,
}

/// The trainer-side churn evaluator: the root draw stream plus the
/// Markov models' memoized per-client session state (carried across
/// rounds alongside the population engine's retire/carry machinery —
/// like a client's private RNG stream, it survives retirement).
pub struct ChurnState {
    /// Root stream: `run_root.split_str("availability")` — the legacy
    /// population availability stream, never advanced.
    root: Rng,
    /// Per-client Markov session state: id → (round advanced to, up?).
    /// Memoization only — the state at any round is a pure function of
    /// `(id, round)` because chains always advance from round 0 through
    /// consecutive per-round transition draws.
    markov: BTreeMap<usize, (usize, bool)>,
}

impl ChurnState {
    /// Build the evaluator from the run's root stream (the constructor
    /// derives the `"availability"` child — callers pass the same root
    /// the trainer was seeded from, so `Iid{p}` replays the legacy
    /// population draw sequence bit-identically).
    pub fn new(run_root: &Rng) -> ChurnState {
        ChurnState { root: run_root.split_str("availability"), markov: BTreeMap::new() }
    }

    /// Whether client `id` is available in round `t` under `model`.
    /// Every draw comes from a non-mutating `(t, id)`-derived split, so
    /// calls never perturb any other stream; `&mut self` is only the
    /// Markov memoization.
    pub fn is_available(&mut self, model: &ChurnModel, t: usize, id: usize) -> bool {
        match *model {
            ChurnModel::Iid { p } => {
                // Exactly the legacy population path: no draw at full
                // availability, else `avail_root.split(t).split(id)`.
                if p == 1.0 {
                    return true;
                }
                self.root.split(t as u64).split(id as u64).uniform() < p
            }
            ChurnModel::Diurnal { amplitude, period_rounds, phase } => {
                let cycle = t as f64 / period_rounds as f64 + phase;
                let p = 1.0
                    - amplitude
                        * 0.5
                        * (1.0 + (2.0 * std::f64::consts::PI * cycle).sin());
                self.root.split(t as u64).split(id as u64).uniform() < p
            }
            ChurnModel::MarkovOnOff { p_up, p_down } => self.markov_up(t, id, p_up, p_down),
            ChurnModel::Correlated { clusters, p_outage } => {
                let cluster = (id % clusters) as u64;
                let mut r = self.root.split(t as u64).split(0xC0AA ^ cluster);
                r.uniform() >= p_outage
            }
        }
    }

    /// Advance client `id`'s Markov chain to round `t` and report its
    /// state. Initialization draws the stationary occupancy at round 0;
    /// each subsequent round takes exactly one transition draw from
    /// `root.split(round).split(id)`. Every draw is a non-mutating
    /// split, so the state at round `t` is a pure function of
    /// `(id, t)`: a query behind the memoized frontier recomputes the
    /// same chain from round 0 and leaves the memo untouched.
    fn markov_up(&mut self, t: usize, id: usize, p_up: f64, p_down: f64) -> bool {
        let (mut round, mut up) = match self.markov.get(&id) {
            Some(&(r, u)) if r <= t => (r, u),
            _ => {
                let pi_up = p_up / (p_up + p_down);
                let mut r = self.root.split(0x4D41_524B ^ id as u64);
                (0, r.uniform() < pi_up)
            }
        };
        while round < t {
            round += 1;
            let u = self.root.split(round as u64).split(id as u64).uniform();
            up = if up { u >= p_down } else { u < p_up };
        }
        let entry = self.markov.entry(id).or_insert((round, up));
        if entry.0 <= round {
            *entry = (round, up);
        }
        up
    }

    /// The per-round replacement re-sampling stream of the quorum
    /// policy (independent of every availability draw; taken only when
    /// a round is below quorum, so `Quorum{1.0}` never draws).
    pub fn resample_stream(&self, t: usize) -> Rng {
        self.root.split(t as u64 ^ 0x7E5A_11CE)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_full_participation_and_draws_nothing() {
        let cfg = ChurnConfig::default();
        assert!(cfg.is_default());
        assert!(cfg.model.is_full());
        assert_eq!(cfg.key_suffix(), "");
        assert_eq!(cfg.label_suffix(), "");
        assert!(cfg.validate().is_ok());
        let mut st = ChurnState::new(&Rng::new(1));
        for t in 0..8 {
            for id in 0..8 {
                assert!(st.is_available(&ChurnModel::default(), t, id));
            }
        }
    }

    #[test]
    fn iid_replays_the_legacy_availability_draw() {
        // The legacy population filter was, verbatim:
        //   let round_avail = avail_root.split(t);
        //   retain(|&i| round_avail.split(i).uniform() < avail)
        // with avail_root = root.split_str("availability").
        let root = Rng::new(42);
        let mut st = ChurnState::new(&root);
        let legacy_root = root.split_str("availability");
        let model = ChurnModel::Iid { p: 0.6 };
        for t in 0..16usize {
            let round_avail = legacy_root.split(t as u64);
            for id in 0..32usize {
                let mut r = round_avail.split(id as u64);
                let legacy = r.uniform() < 0.6;
                assert_eq!(st.is_available(&model, t, id), legacy, "t={t} id={id}");
            }
        }
    }

    #[test]
    fn markov_is_query_order_independent() {
        let model = ChurnModel::MarkovOnOff { p_up: 0.3, p_down: 0.2 };
        // Forward, per-round queries...
        let mut a = ChurnState::new(&Rng::new(7));
        let dense: Vec<Vec<bool>> =
            (0..20).map(|t| (0..10).map(|id| a.is_available(&model, t, id)).collect()).collect();
        // ...must agree with sparse, out-of-order queries.
        let mut b = ChurnState::new(&Rng::new(7));
        for &(t, id) in &[(19usize, 3usize), (5, 3), (0, 9), (12, 0), (19, 0), (7, 7)] {
            assert_eq!(b.is_available(&model, t, id), dense[t][id], "t={t} id={id}");
        }
        // Note (t=5, id=3) after (t=19, id=3): memoized state is ahead
        // of the query — recompute from scratch must agree too.
        let mut c = ChurnState::new(&Rng::new(7));
        assert_eq!(c.is_available(&model, 5, 3), dense[5][3]);
    }

    #[test]
    fn markov_occupancy_approaches_stationary() {
        let (p_up, p_down) = (0.3, 0.1);
        let model = ChurnModel::MarkovOnOff { p_up, p_down };
        let mut st = ChurnState::new(&Rng::new(11));
        let (mut up, mut total) = (0u64, 0u64);
        for t in 0..400usize {
            for id in 0..50usize {
                total += 1;
                if st.is_available(&model, t, id) {
                    up += 1;
                }
            }
        }
        let occupancy = up as f64 / total as f64;
        let pi = p_up / (p_up + p_down);
        assert!((occupancy - pi).abs() < 0.03, "occupancy {occupancy} vs π_up {pi}");
    }

    #[test]
    fn correlated_blacks_out_whole_clusters() {
        let model = ChurnModel::Correlated { clusters: 4, p_outage: 0.5 };
        let mut st = ChurnState::new(&Rng::new(3));
        let mut saw_outage = false;
        for t in 0..64usize {
            for cluster in 0..4usize {
                // Every member of a cluster shares the round's fate.
                let members: Vec<bool> = (0..5)
                    .map(|k| st.is_available(&model, t, cluster + 4 * k))
                    .collect();
                assert!(
                    members.iter().all(|&m| m == members[0]),
                    "t={t} cluster={cluster}: split cluster fate {members:?}"
                );
                saw_outage |= !members[0];
            }
        }
        assert!(saw_outage, "p_outage 0.5 over 64 rounds must black something out");
    }

    #[test]
    fn diurnal_wave_moves_availability() {
        let model = ChurnModel::Diurnal { amplitude: 1.0, period_rounds: 4, phase: 0.25 };
        let mut st = ChurnState::new(&Rng::new(5));
        // phase 0.25 puts round 0 at the sine peak: availability 0.
        let admitted = (0..200).filter(|&id| st.is_available(&model, 0, id)).count();
        assert_eq!(admitted, 0, "amplitude 1 at the peak admits nobody");
        // Half a period later the wave is at its trough: availability 1.
        let admitted = (0..200).filter(|&id| st.is_available(&model, 2, id)).count();
        assert_eq!(admitted, 200, "trough admits everyone");
    }

    #[test]
    fn model_parse_display_roundtrip_and_rejections() {
        for s in
            ["none", "iid:0.7", "diurnal:0.5:24", "diurnal:0.5:24:0.25", "markov:0.9:0.1", "correlated:8:0.3"]
        {
            let m = ChurnModel::parse(s).unwrap();
            assert_eq!(ChurnModel::parse(&m.to_string()).unwrap(), m, "{s}");
        }
        assert_eq!(ChurnModel::parse("none").unwrap(), ChurnModel::Iid { p: 1.0 });
        assert_eq!(ChurnModel::parse("iid:1").unwrap().to_string(), "none");
        // Each rejection path, by parameter.
        assert!(ChurnModel::parse("iid:0").is_err(), "p = 0");
        assert!(ChurnModel::parse("iid:1.5").is_err(), "p > 1");
        assert!(ChurnModel::parse("iid:NaN").is_err(), "NaN availability");
        assert!(ChurnModel::parse("diurnal:1.5:24").is_err(), "amplitude > 1");
        assert!(ChurnModel::parse("diurnal:0.5:0").is_err(), "period 0");
        assert!(ChurnModel::parse("markov:0:0.5").is_err(), "p_up = 0");
        assert!(ChurnModel::parse("markov:0.5:1.5").is_err(), "p_down > 1");
        assert!(ChurnModel::parse("correlated:0:0.3").is_err(), "0 clusters");
        assert!(ChurnModel::parse("correlated:4:1").is_err(), "certain outage");
        assert!(ChurnModel::parse("weibull:1:2").is_err(), "unknown model");
        assert!(ChurnModel::parse("iid").is_err(), "missing parameter");
    }

    #[test]
    fn policy_and_config_validation_paths() {
        assert!(ResiliencePolicy::WaitAll.validate().is_ok());
        assert!(ResiliencePolicy::Cutoff { secs: 0.0 }.validate().is_ok());
        assert!(ResiliencePolicy::Cutoff { secs: -1.0 }.validate().is_err(), "negative cutoff");
        assert!(
            ResiliencePolicy::Cutoff { secs: f64::NAN }.validate().is_err(),
            "NaN cutoff"
        );
        assert!(
            ResiliencePolicy::Quorum { min_frac: 0.5, resample: true }.validate().is_ok()
        );
        assert!(
            ResiliencePolicy::Quorum { min_frac: 0.0, resample: false }.validate().is_err(),
            "zero quorum"
        );
        assert!(
            ResiliencePolicy::Quorum { min_frac: f64::NAN, resample: false }
                .validate()
                .is_err(),
            "NaN quorum"
        );
        let bad_rate = ChurnConfig { fail_rate: 1.0, ..ChurnConfig::default() };
        assert!(bad_rate.validate().is_err(), "fail_rate 1 would kill every round");
        let bad_rate = ChurnConfig { fail_rate: f64::NAN, ..ChurnConfig::default() };
        assert!(bad_rate.validate().is_err(), "NaN fail_rate");
        let bad_model =
            ChurnConfig { model: ChurnModel::Iid { p: f64::NAN }, ..ChurnConfig::default() };
        assert!(bad_model.validate().is_err(), "NaN availability through the config");
        assert_eq!(ResiliencePolicy::Cutoff { secs: 2.5 }.cutoff(), Some(2.5));
        assert_eq!(ResiliencePolicy::WaitAll.cutoff(), None);
    }

    #[test]
    fn key_and_label_suffixes_name_every_non_default_knob() {
        let cfg = ChurnConfig {
            model: ChurnModel::Correlated { clusters: 8, p_outage: 0.3 },
            fail_rate: 0.1,
            policy: ResiliencePolicy::Quorum { min_frac: 0.5, resample: true },
        };
        assert_eq!(cfg.key_suffix(), "-ccorr8x0.3-f0.1-q0.5r");
        assert_eq!(cfg.label_suffix(), " corr8x0.3 fail0.1 q0.5r");
        let cut = ChurnConfig {
            model: ChurnModel::Iid { p: 0.7 },
            policy: ResiliencePolicy::Cutoff { secs: 1.5 },
            ..ChurnConfig::default()
        };
        assert_eq!(cut.key_suffix(), "-ciid0.7-cut1.5");
        // Distinct configs never alias a key segment.
        let quorum_no_resample = ChurnConfig {
            policy: ResiliencePolicy::Quorum { min_frac: 0.5, resample: false },
            ..ChurnConfig::default()
        };
        let quorum_resample = ChurnConfig {
            policy: ResiliencePolicy::Quorum { min_frac: 0.5, resample: true },
            ..ChurnConfig::default()
        };
        assert_ne!(quorum_no_resample.key_suffix(), quorum_resample.key_suffix());
    }

    #[test]
    fn draws_never_mutate_the_root_stream() {
        // Two evaluators fed different query patterns produce identical
        // answers for the same (model, t, id) — the root never advances.
        let model = ChurnModel::Iid { p: 0.4 };
        let mut a = ChurnState::new(&Rng::new(9));
        let mut b = ChurnState::new(&Rng::new(9));
        for id in 0..64usize {
            let _ = a.is_available(&model, 0, id);
        }
        for t in 0..8usize {
            for id in (0..64usize).rev() {
                assert_eq!(
                    a.is_available(&model, t, id),
                    b.is_available(&model, t, id),
                    "t={t} id={id}"
                );
            }
        }
    }
}
