//! Deterministic event-driven simulation clock.
//!
//! The paper's asynchronous claims (Fig. 3, Fig. 6) are about *arrival
//! orders and idle time* under heterogeneous client compute/network
//! delays. A binary-heap event queue reproduces those schedules exactly
//! and reproducibly — and lets the coordinator measure wall-clock-style
//! metrics (server idle time, straggler stalls) without real multi-machine
//! nondeterminism. Ties are broken by insertion sequence so equal-time
//! events keep FIFO order.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulated time in seconds.
pub type SimTime = f64;

#[derive(Clone, Debug)]
struct Scheduled<E> {
    time: SimTime,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap: smaller time first; FIFO on ties. `total_cmp` keeps
        // the ordering a true total order even for exotic timestamps —
        // non-finite times are rejected at scheduling time, so every
        // comparison the heap sees is over finite floats.
        other.time.total_cmp(&self.time).then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Event queue + clock.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    now: SimTime,
    seq: u64,
    processed: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    /// An empty queue with the clock at 0.
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), now: 0.0, seq: 0, processed: 0 }
    }

    /// Current simulated time (timestamp of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of scheduled events not yet popped.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether no events are pending.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Total events popped so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Schedule `event` at absolute time `at`.
    ///
    /// `at` must be finite and `>= now()`: a NaN timestamp would poison
    /// the heap's ordering, and a past timestamp would silently reorder
    /// history. Both are bugs in the caller's schedule arithmetic, so
    /// they panic in **every** build profile (the queue drives the
    /// round engine; a corrupted schedule must never limp on in
    /// release).
    ///
    /// # Panics
    /// If `at` is non-finite or earlier than the current clock.
    pub fn schedule_at(&mut self, at: SimTime, event: E) {
        assert!(at.is_finite(), "non-finite event time: {at}");
        assert!(at >= self.now, "scheduling into the past: {at} < {}", self.now);
        self.heap.push(Scheduled { time: at, seq: self.seq, event });
        self.seq += 1;
    }

    /// Schedule `event` after a relative delay.
    ///
    /// # Panics
    /// If `delay` is non-finite or negative (see [`EventQueue::schedule_at`]).
    pub fn schedule_in(&mut self, delay: SimTime, event: E) {
        assert!(
            delay.is_finite() && delay >= 0.0,
            "bad relative delay: {delay} (must be finite and >= 0)"
        );
        self.schedule_at(self.now + delay, event);
    }

    /// Pop the next event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let s = self.heap.pop()?;
        self.now = s.time;
        self.processed += 1;
        Some((s.time, s.event))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_by_time() {
        let mut q = EventQueue::new();
        q.schedule_at(3.0, "c");
        q.schedule_at(1.0, "a");
        q.schedule_at(2.0, "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
        assert_eq!(q.now(), 3.0);
        assert_eq!(q.processed(), 3);
    }

    #[test]
    fn fifo_on_ties() {
        let mut q = EventQueue::new();
        q.schedule_at(1.0, 1);
        q.schedule_at(1.0, 2);
        q.schedule_at(1.0, 3);
        let order: Vec<_> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn relative_scheduling_advances_clock() {
        let mut q = EventQueue::new();
        q.schedule_in(5.0, "x");
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 5.0);
        q.schedule_in(2.0, "y");
        let (t2, _) = q.pop().unwrap();
        assert_eq!(t2, 7.0);
    }

    // The two latent time-ordering bugs, pinned: before the hard
    // validation, a NaN timestamp compared `Ordering::Equal` against
    // everything (silently corrupting heap order), and a past timestamp
    // was silently clamped to `now` with only a debug_assert guarding it
    // (compiled out of release builds). Both must now panic in every
    // build profile — these tests run under `--release` in CI via
    // `cargo test --release`-equivalent tiers, where `debug_assert!`
    // alone would never fire.

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn nan_timestamp_rejected() {
        let mut q = EventQueue::new();
        q.schedule_at(f64::NAN, "x");
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn infinite_timestamp_rejected() {
        let mut q = EventQueue::new();
        q.schedule_at(f64::INFINITY, "x");
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    fn past_timestamp_rejected_not_rewritten() {
        let mut q = EventQueue::new();
        q.schedule_at(5.0, "a");
        let _ = q.pop();
        // now = 5.0; scheduling at 3.0 used to be silently rewritten to
        // 5.0 in release builds.
        q.schedule_at(3.0, "b");
    }

    #[test]
    #[should_panic(expected = "bad relative delay")]
    fn negative_delay_rejected() {
        let mut q = EventQueue::new();
        q.schedule_in(-1.0, "x");
    }

    #[test]
    #[should_panic(expected = "bad relative delay")]
    fn nan_delay_rejected() {
        let mut q = EventQueue::new();
        q.schedule_in(f64::NAN, "x");
    }

    #[test]
    fn validation_fires_in_release_builds_too() {
        // Belt-and-braces: catch_unwind proves the panic is a real
        // `assert!` (present in all profiles), not a `debug_assert!`.
        let caught = std::panic::catch_unwind(|| {
            let mut q = EventQueue::new();
            q.schedule_at(f64::NAN, 0u8);
        });
        assert!(caught.is_err(), "NaN timestamps must panic even with debug assertions off");
        let caught = std::panic::catch_unwind(|| {
            let mut q = EventQueue::new();
            q.schedule_at(2.0, 0u8);
            let _ = q.pop();
            q.schedule_at(1.0, 0u8);
        });
        assert!(caught.is_err(), "past timestamps must panic even with debug assertions off");
    }

    #[test]
    fn interleaved_schedule_and_pop() {
        let mut q = EventQueue::new();
        q.schedule_at(1.0, 1);
        q.schedule_at(10.0, 10);
        let (_, e) = q.pop().unwrap();
        assert_eq!(e, 1);
        // scheduling relative to the advanced clock
        q.schedule_in(1.0, 2);
        let (t, e) = q.pop().unwrap();
        assert_eq!((t, e), (2.0, 2));
        assert_eq!(q.pop().unwrap(), (10.0, 10));
    }
}
