//! Event-driven simulation substrate: deterministic clock ([`event`]),
//! client heterogeneity / network delay models ([`netmodel`]), client
//! churn & reliability models ([`churn`]), and Fig.-3-style timeline
//! recording ([`timeline`]).

pub mod churn;
pub mod event;
pub mod netmodel;
pub mod timeline;
