//! Drivers for every figure in the paper's evaluation (Figs. 4–9).
//!
//! Each driver reproduces the figure's series with the shared [`Harness`]
//! (cached runs), prints the curve table, and writes per-series CSVs
//! under `results/`. Absolute accuracies differ from the paper (synthetic
//! data — DESIGN.md §Substitutions); the reproduction targets are the
//! paper's *orderings and trends*, restated in each driver's doc.

use crate::coordinator::config::{ArrivalOrder, Parallelism, ShardMapKind};
use crate::coordinator::methods::Method;
use crate::metrics::recorder::RunRecord;
use crate::sched::SchedPolicy;
use crate::sim::churn::ChurnConfig;
use crate::util::csvio::Csv;

use super::common::{
    cifar_workload, curve_table, femnist_workload, Dist, Harness, RunSpec, Scale, Workload,
};
use super::sweep::{self, SweepOptions};

pub(crate) fn base_spec(dataset: &str, aux: &str, w: Workload) -> RunSpec {
    RunSpec {
        dataset: dataset.into(),
        aux: aux.into(),
        method: Method::CseFsl.spec(),
        n_clients: 5,
        participation: 0,
        dist: Dist::Iid,
        arrival: ArrivalOrder::ByDelay,
        lr0: if dataset == "cifar" { 0.01 } else { 0.05 },
        seed: 1,
        workload: w,
        // Figure sweeps default to the full-machine fan-out with
        // work-stealing dealing; results are bit-identical to Sequential
        // round-robin (coordinator/README.md), only wall-clock changes.
        parallelism: Parallelism::auto(),
        server_shards: 1,
        sched: SchedPolicy::WorkStealing,
        shard_map: ShardMapKind::Contiguous,
        churn: ChurnConfig::default(),
    }
}

fn write_series_csv(harness: &Harness, name: &str, runs: &[&RunRecord]) {
    let mut csv = Csv::new(&["series", "round", "accuracy", "load_gb", "train_loss"]);
    for r in runs {
        for rr in &r.rounds {
            if let Some(acc) = rr.accuracy {
                csv.row(&[
                    r.label.clone(),
                    rr.round.to_string(),
                    format!("{acc:.4}"),
                    format!("{:.6}", (rr.up_bytes + rr.down_bytes) as f64 / 1e9),
                    format!("{:.4}", rr.train_loss),
                ]);
            }
        }
    }
    let _ = csv.write_to(&harness.out_dir.join(format!("{name}.csv")));
}

/// The method series Figs. 4/5/9 compare.
fn method_specs(base: &RunSpec, h_set: &[usize]) -> Vec<RunSpec> {
    let mut specs = vec![
        RunSpec { method: Method::FslMc.spec(), ..base.clone() },
        RunSpec { method: Method::FslOc.spec(), ..base.clone() },
        RunSpec { method: Method::FslAn.spec(), ..base.clone() },
    ];
    for &h in h_set {
        specs.push(RunSpec { method: Method::CseFsl.spec().with_period(h), ..base.clone() });
    }
    specs
}

/// Fig. 4: CIFAR-10, IID, full participation — top-1 accuracy vs rounds
/// for FSL_MC / FSL_OC / FSL_AN / CSE_FSL h∈{1,5,10}, at 5 and 10
/// clients. Paper trends: CSE_FSL ≥ FSL_OC everywhere; larger h converges
/// faster per round; 10 clients degrades everyone but CSE_FSL least.
pub fn fig4(harness: &mut Harness, scale: Scale) -> Result<String, String> {
    let w = cifar_workload(scale);
    let h_set: &[usize] = match scale {
        Scale::Quick => &[1, 2],
        _ => &[1, 5, 10],
    };
    let client_counts: &[usize] = match scale {
        Scale::Paper => &[5, 10],
        _ => &[5],
    };
    let mut out = String::new();
    for &n in client_counts {
        let base = RunSpec { n_clients: n, ..base_spec("cifar", "cnn27", w) };
        let mut runs = Vec::new();
        for spec in method_specs(&base, h_set) {
            runs.push(harness.run_cached(&spec)?);
        }
        let refs: Vec<&RunRecord> = runs.iter().collect();
        out.push_str(&curve_table(
            &format!("Fig 4: CIFAR-10 IID, {n} clients (accuracy vs communication rounds)"),
            &refs,
        ));
        out.push('\n');
        write_series_csv(harness, &format!("fig4_n{n}"), &refs);
    }
    Ok(out)
}

/// Fig. 5: F-EMNIST, partial participation (5 of N clients), IID and
/// non-IID (by writer). Paper trends: MC/OC poor; CSE_FSL converges fast;
/// larger h helps per-round, most visibly non-IID.
pub fn fig5(harness: &mut Harness, scale: Scale) -> Result<String, String> {
    let w = femnist_workload(scale);
    let h_set: &[usize] = match scale {
        Scale::Quick => &[1, 2],
        _ => &[1, 2, 4],
    };
    let n_clients = 10usize;
    let mut out = String::new();
    for dist in [Dist::Iid, Dist::NonIidWriter] {
        let base = RunSpec {
            n_clients,
            participation: 5,
            dist,
            ..base_spec("femnist", "cnn8", w)
        };
        let mut runs = Vec::new();
        for spec in method_specs(&base, h_set) {
            runs.push(harness.run_cached(&spec)?);
        }
        let refs: Vec<&RunRecord> = runs.iter().collect();
        let tag = if dist == Dist::Iid { "IID" } else { "non-IID (by writer)" };
        out.push_str(&curve_table(
            &format!("Fig 5: F-EMNIST {tag}, partial participation 5/{n_clients}"),
            &refs,
        ));
        out.push('\n');
        write_series_csv(harness, &format!("fig5_{}", dist.tag()), &refs);
    }
    Ok(out)
}

/// Fig. 6: asynchronous server updates — ordered vs randomly ordered
/// client arrivals. Paper claim: accuracies nearly identical on both
/// datasets.
pub fn fig6(harness: &mut Harness, scale: Scale) -> Result<String, String> {
    let mut out = String::new();
    for (dataset, aux, w, h) in [
        ("cifar", "cnn27", cifar_workload(scale), 5usize),
        ("femnist", "cnn8", femnist_workload(scale), 2),
    ] {
        let base = RunSpec {
            method: Method::CseFsl.spec().with_period(h),
            ..base_spec(dataset, aux, w)
        };
        let ordered = harness
            .run_cached(&RunSpec { arrival: ArrivalOrder::ClientIndex, ..base.clone() })?;
        let shuffled =
            harness.run_cached(&RunSpec { arrival: ArrivalOrder::Shuffled, ..base.clone() })?;
        let delta = (ordered.final_accuracy - shuffled.final_accuracy).abs();
        out.push_str(&curve_table(
            &format!("Fig 6: {dataset} — ordered vs random client update order (CSE_FSL h={h})"),
            &[&ordered, &shuffled],
        ));
        out.push_str(&format!(
            "|final(ordered) - final(random)| = {:.2} pp  (paper: nearly identical)\n\n",
            delta * 100.0
        ));
        write_series_csv(harness, &format!("fig6_{dataset}"), &[&ordered, &shuffled]);
    }
    Ok(out)
}

/// Fig. 7: CIFAR-10 auxiliary-architecture sweep (MLP vs 1x1-CNN+MLP at
/// c∈{54,27,14,7}), h∈{5,10}. Paper trend: CNN(27) matches MLP accuracy
/// at half the parameters; very small CNNs degrade.
pub fn fig7(harness: &mut Harness, scale: Scale) -> Result<String, String> {
    let w = cifar_workload(scale);
    let (h_set, archs): (&[usize], &[&str]) = match scale {
        Scale::Quick => (&[2], &["mlp", "cnn27"]),
        Scale::Ci => (&[5], &["mlp", "cnn54", "cnn27", "cnn14", "cnn7"]),
        Scale::Paper => (&[5, 10], &["mlp", "cnn54", "cnn27", "cnn14", "cnn7"]),
    };
    let mut out = String::new();
    for &h in h_set {
        let mut runs = Vec::new();
        for &arch in archs {
            let spec = RunSpec {
                aux: arch.into(),
                method: Method::CseFsl.spec().with_period(h),
                ..base_spec("cifar", arch, w)
            };
            let mut rec = harness.run_cached(&spec)?;
            let aux_params = harness.aux_params("cifar", arch)?;
            rec.label = format!("{arch} ({aux_params})");
            runs.push(rec);
        }
        let refs: Vec<&RunRecord> = runs.iter().collect();
        out.push_str(&curve_table(
            &format!("Fig 7: CIFAR-10 auxiliary architectures, CSE_FSL h={h}"),
            &refs,
        ));
        out.push('\n');
        write_series_csv(harness, &format!("fig7_h{h}"), &refs);
    }
    Ok(out)
}

/// Fig. 8: F-EMNIST auxiliary-architecture sweep, non-IID partial
/// participation, h∈{2,4}. Paper trend: CNN aux trains at client-scale
/// parameter budgets with minor accuracy loss vs the (huge) MLP aux.
pub fn fig8(harness: &mut Harness, scale: Scale) -> Result<String, String> {
    let w = femnist_workload(scale);
    let (h_set, archs): (&[usize], &[&str]) = match scale {
        Scale::Quick => (&[2], &["mlp", "cnn8"]),
        Scale::Ci => (&[2], &["mlp", "cnn64", "cnn32", "cnn8", "cnn2"]),
        Scale::Paper => (&[2, 4], &["mlp", "cnn64", "cnn32", "cnn8", "cnn2"]),
    };
    let mut out = String::new();
    for &h in h_set {
        let mut runs = Vec::new();
        for &arch in archs {
            let spec = RunSpec {
                aux: arch.into(),
                n_clients: 10,
                participation: 5,
                dist: Dist::NonIidWriter,
                method: Method::CseFsl.spec().with_period(h),
                ..base_spec("femnist", arch, w)
            };
            let mut rec = harness.run_cached(&spec)?;
            let aux_params = harness.aux_params("femnist", arch)?;
            rec.label = format!("{arch} ({aux_params})");
            runs.push(rec);
        }
        let refs: Vec<&RunRecord> = runs.iter().collect();
        out.push_str(&curve_table(
            &format!("Fig 8: F-EMNIST aux architectures, non-IID 5/10, CSE_FSL h={h}"),
            &refs,
        ));
        out.push('\n');
        write_series_csv(harness, &format!("fig8_h{h}"), &refs);
    }
    Ok(out)
}

/// Fig. 9: top-1 accuracy vs cumulative communication load (GB). Reuses
/// the Fig. 4 / Fig. 5 runs via the cache. Paper trends: (a) on CIFAR
/// larger h reaches accuracy at far lower load; (b) on F-EMNIST h=1 can
/// beat larger h per byte (big aux + few samples per client).
pub fn fig9(harness: &mut Harness, scale: Scale) -> Result<String, String> {
    let mut out = String::new();
    // (a) CIFAR IID full participation.
    let w = cifar_workload(scale);
    let h_set: &[usize] = match scale {
        Scale::Quick => &[1, 2],
        _ => &[1, 5, 10],
    };
    let base = base_spec("cifar", "cnn27", w);
    let mut runs = Vec::new();
    for spec in method_specs(&base, h_set) {
        runs.push(harness.run_cached(&spec)?);
    }
    out.push_str("== Fig 9a: CIFAR-10 — accuracy vs communication load ==\n");
    for r in &runs {
        out.push_str(&format!("{:<16}", r.label));
        for (gb, acc) in r.accuracy_vs_load() {
            out.push_str(&format!("  {:.3}GB:{:.1}%", gb, acc * 100.0));
        }
        out.push_str(&format!(
            "  [total {:.3} GB -> {:.1}%]\n",
            r.total_gb(),
            r.final_accuracy * 100.0
        ));
    }
    let refs: Vec<&RunRecord> = runs.iter().collect();
    write_series_csv(harness, "fig9_cifar", &refs);

    // (b) F-EMNIST non-IID partial.
    let w = femnist_workload(scale);
    let h_set: &[usize] = match scale {
        Scale::Quick => &[1, 2],
        _ => &[1, 2, 4],
    };
    let base = RunSpec {
        n_clients: 10,
        participation: 5,
        dist: Dist::NonIidWriter,
        ..base_spec("femnist", "cnn8", w)
    };
    let mut runs = Vec::new();
    for spec in method_specs(&base, h_set) {
        runs.push(harness.run_cached(&spec)?);
    }
    out.push_str("\n== Fig 9b: F-EMNIST non-IID — accuracy vs communication load ==\n");
    for r in &runs {
        out.push_str(&format!(
            "{:<16} total {:.4} GB -> {:.1}%\n",
            r.label,
            r.total_gb(),
            r.final_accuracy * 100.0
        ));
    }
    let refs: Vec<&RunRecord> = runs.iter().collect();
    write_series_csv(harness, "fig9_femnist", &refs);
    Ok(out)
}

/// ROADMAP figure (no paper counterpart): accuracy vs server shard
/// count k — the **staleness cost of sharding** that completes the
/// storage/staleness/throughput story. k = 1 is the paper's shared
/// copy (minimum storage, one serialized event loop); growing k buys
/// executor throughput at k·|w_s| storage while shard trajectories
/// diverge between aggregations (staleness), which is what the
/// accuracy column measures. The contiguous and balanced shard maps
/// run side by side at every k > 1 on the IID sweep, and a second arm
/// compares all three maps (contiguous / balanced / locality) on the
/// non-IID splits — Dirichlet CIFAR and by-writer F-EMNIST — where the
/// `skew` column (weighted per-shard label divergence from the global mix,
/// `RunRecord::shard_label_divergence`) shows what each placement does
/// to the gradient mix every shard copy sees. Workloads are pinned to
/// the `ci` preset even at `--scale paper` (the full paper workload is
/// hours on one box; EXPERIMENTS.md documents the protocol).
///
/// Since PR 8 this figure is two declarative [`super::sweep`] specs
/// (`staleness` + `staleness-noniid`): the grid, skip rule (k = 1 runs
/// contiguous only), CSV columns, and notes live in
/// [`sweep::builtin`]`("k", ..)`, execution goes through the
/// crash-durable trial journal, and the CSVs are byte-identical to the
/// pre-sweep hand-coded loops (pinned by `tests/sweep_resume.rs`).
pub fn fig_staleness(harness: &mut Harness, scale: Scale) -> Result<String, String> {
    sweep_figure(harness, "k", scale)
}

/// Repo figure (no paper counterpart): the **upload-period axis on the
/// per-client topology** — `AuxLocal × Period(h) × PerClient`, i.e.
/// "FSL_AN with h > 1", a point the paper never names and the old
/// closed `Method` enum could not express. Each h runs the per-client
/// arm next to its shared-topology control (the CSE_FSL preset at the
/// same h), so the table isolates the two axes: **topology** owns the
/// storage column (the per-client arm pays n·|w_s| for per-client
/// server trajectories — no cross-client mixing between aggregations —
/// while the wire bytes and the simulated schedule are
/// topology-independent), and the **upload schedule** owns the
/// communication economics — at this fixed round horizon each round
/// uploads one smashed batch whatever h is, so h· more local batches
/// ride on (almost) the same bytes: wire cost *per local batch
/// trained* falls as ~1/h (totals even tick up slightly with h because
/// epochs shorten and per-epoch aggregations come more often). h = 1
/// reduces to the FSL_AN / CSE_FSL preset pair (cached under their
/// historical keys). Workloads are pinned to the `ci` preset even at
/// `--scale paper` (like `figure k`; EXPERIMENTS.md documents the
/// protocol and quotes mock-backend numbers).
///
/// Since PR 8 this figure is declarative sweeps
/// ([`sweep::builtin`]`("h", ..)`): the preset × period composition is
/// two sweep axes (`Knob::Preset` then `Knob::H`), execution goes
/// through the trial journal, and `fig_h.csv` is byte-identical to the
/// pre-sweep loop (pinned by `tests/sweep_resume.rs`). A second sweep
/// (`h-sage`, writing `fig_h_sage.csv`) rides along: the alignment
/// period of the gradient-estimator update rule (`--update sage`),
/// whose wire traffic interpolates between the server-grad and
/// aux-local closed forms.
pub fn fig_h(harness: &mut Harness, scale: Scale) -> Result<String, String> {
    sweep_figure(harness, "h", scale)
}

/// Repo figure (no paper counterpart): **accuracy vs wire precision** —
/// the FedLite-style compression axis on the smashed-data uplink.
/// CSE_FSL at a fixed upload period h = 2 runs once uncompressed and
/// once per codec point (quantize at 8/4/2 bits, top-k keeping a
/// quarter of the entries), so the table isolates what lossy smashed
/// uploads buy and cost: the load column shrinks by the codec's
/// closed-form wire ratio (`comm::compress::Compression::wire_bytes`,
/// pinned against the ledger by `comm_properties`) while the accuracy
/// column shows the gradient-quality price of each precision. Labels,
/// model exchanges, and the simulated schedule's cost priors are
/// untouched by the codec — only the tensor bytes on the wire move.
/// Workloads are pinned to the `ci` preset even at `--scale paper`
/// (like `figure k`/`figure h`; EXPERIMENTS.md documents the protocol).
///
/// Since PR 8 this figure is the declarative `b` sweep
/// ([`sweep::builtin`]`("b", ..)`): the codec grid is one `Knob::Codec`
/// axis, execution goes through the trial journal, and `fig_b.csv` is
/// byte-identical to the pre-sweep loop (pinned by
/// `tests/sweep_resume.rs`).
pub fn fig_b(harness: &mut Harness, scale: Scale) -> Result<String, String> {
    sweep_figure(harness, "b", scale)
}

/// Repo figure (no paper counterpart): **accuracy vs churn severity** —
/// the resilience story of the method family. Each method arm (CSE_FSL
/// h=2, FSL_OC, and the sage estimator rule) runs once at full
/// availability and once per churn point of increasing severity (IID
/// dropout at p ∈ {0.9, 0.7, 0.5}), so the table isolates what an
/// unreliable fleet costs each client-update rule: the aux-local rules
/// keep training locally through dropped rounds (only uploads thin
/// out), while the server-grad rule loses the whole round for every
/// dropped client. The `dropped` column counts the cohort the
/// availability model removed (`RunRecord::clients_dropped`); accuracy
/// shows what that does to convergence at a fixed round horizon. Workloads are pinned to the `ci` preset even
/// at `--scale paper` (like `figure k`; EXPERIMENTS.md documents the
/// protocol).
///
/// Like every post-PR-8 repo figure this is a declarative sweep
/// ([`sweep::builtin`]`("r", ..)`): the churn grid is one `Knob::Churn`
/// axis over the method arms, execution goes through the crash-durable
/// trial journal, and the report derives from journal entries.
pub fn fig_churn(harness: &mut Harness, scale: Scale) -> Result<String, String> {
    sweep_figure(harness, "r", scale)
}

/// Run a figure's built-in sweeps ([`sweep::builtin`]) back to back on
/// the shared harness and concatenate their journal-derived reports.
fn sweep_figure(harness: &mut Harness, id: &str, scale: Scale) -> Result<String, String> {
    let mut out = String::new();
    for sw in sweep::builtin(id, scale)? {
        let outcome = sweep::run_sweep(harness, &sw, &SweepOptions::default())?;
        out.push_str(&outcome.report);
        out.push('\n');
    }
    Ok(out)
}

/// Fig. 3 illustration: the asynchronous-training timeline (rendered by
/// `examples/async_timeline.rs`; this driver reports the summary
/// metrics).
pub fn fig3_metrics(harness: &mut Harness, scale: Scale) -> Result<String, String> {
    let w = cifar_workload(if scale == Scale::Paper { Scale::Ci } else { scale });
    let spec = RunSpec {
        method: Method::CseFsl.spec().with_period(5),
        ..base_spec("cifar", "cnn27", w)
    };
    let rec = harness.run_cached(&spec)?;
    Ok(format!(
        "== Fig 3 metrics: CSE_FSL h=5 asynchronous schedule ==\n\
         simulated run time    : {:.2} s\n\
         server idle fraction  : {:.1}% (event-triggered updates fill arrival gaps)\n",
        rec.sim_time,
        rec.server_idle_fraction * 100.0
    ))
}
