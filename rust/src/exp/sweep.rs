//! Durable declarative sweep runner: spec → trials → journal → tables.
//!
//! A [`SweepSpec`] names the experiment grid declaratively (a base
//! [`RunSpec`] plus axes of [`Setting`]s, seeds, and repeats); it
//! expands deterministically into a [`Trial`] list, every point lowered
//! to a validated `RunSpec` *before* anything runs. Execution appends
//! one JSONL record per completed trial to a crash-durable [`Journal`]
//! (atomic line writes; recovery keeps the longest valid prefix, so a
//! line torn by `kill -9` is dropped, never misread), which lets
//! [`run_sweep`] skip journaled-complete trials on `--resume` and
//! execute only the remainder. Figure output (CSV + aligned report) is
//! derived purely from the journal — the join key between the spec
//! expansion and the journal is [`RunSpec::key`].
//!
//! The three repo figures (`figure k` / `h` / `b`) are [`builtin`]
//! sweeps; their CSVs are byte-identical to the pre-sweep hand-coded
//! drivers (pinned by `tests/sweep_resume.rs`).

use std::collections::{BTreeMap, BTreeSet};
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::coordinator::methods::{ClientUpdate, Compression, Method};
use crate::metrics::recorder::RunRecord;
use crate::util::csvio::Csv;
use crate::util::json::Json;

use super::common::{
    cifar_workload, femnist_workload, fnv64, run_from_json, run_to_json, Dist, Harness,
    RunSpec, Scale, Workload, CACHE_VERSION,
};
use super::figures::base_spec;

// ------------------------------------------------------------- knobs

/// One sweepable axis of a [`RunSpec`] — the declarative name of a
/// field (or derived field) that a [`Setting`] assigns. Lowering
/// applies base-replacing knobs ([`Knob::Dataset`] / [`Knob::Aux`] /
/// [`Knob::Preset`]) before refining ones, so e.g. `Preset=an, H=4`
/// means `Method::FslAn.spec().with_period(4)` whatever the axis order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Knob {
    /// Dataset name; also re-derives the per-dataset workload at the
    /// sweep's scale (`cifar` | `femnist`).
    Dataset,
    /// Auxiliary architecture name (manifest key).
    Aux,
    /// Method preset base (`mc` | `oc` | `an` | `cse`): replaces the
    /// whole method spec, so it applies before `H` / `Codec`.
    Preset,
    /// Upload period h ([`crate::coordinator::methods::MethodSpec::with_period`]).
    H,
    /// Server shard count k.
    Shards,
    /// Client → shard placement (`contiguous` | `balanced` | `locality`).
    Map,
    /// Data distribution (`iid` | `dir` | `writer`).
    Dist,
    /// Wire codec (`none` | `q<bits>` | `quantize<bits>` | `t<frac>` |
    /// `topk<frac>`).
    Codec,
    /// Client-update rule (`grad` | `aux` | `sage`, the
    /// [`ClientUpdate::from_str`] spellings).
    Update,
    /// Alignment period of the sage update rule (applies after
    /// [`Knob::Update`]; rejected on any other rule, like
    /// `--align-every`).
    AlignEvery,
    /// Server topology (`per-client` | `shared`).
    Topology,
    /// Number of federated clients.
    Clients,
    /// Clients sampled per round (0 = all).
    Participation,
    /// Initial learning rate.
    Lr,
    /// Availability model of the churn subsystem, in the `--churn` CLI
    /// spelling (`none` | `iid:<p>` | `diurnal:..` | `markov:..` |
    /// `correlated:..`, [`ChurnModel::parse`]).
    ///
    /// [`ChurnModel::parse`]: crate::sim::churn::ChurnModel::parse
    Churn,
    /// Experiment seed (appended automatically by the expansion).
    Seed,
}

impl Knob {
    /// Application phase: base-replacing knobs go first so refinements
    /// (`H`, `Codec`, `Topology`) compose on top of them.
    fn phase(self) -> u8 {
        match self {
            Knob::Dataset | Knob::Aux | Knob::Preset => 0,
            // Applies onto the update rule, so after `Knob::Update`.
            Knob::AlignEvery => 2,
            _ => 1,
        }
    }

    /// Assign `value` into `spec`. `scale` sizes the workload when the
    /// dataset changes.
    pub fn apply(self, spec: &mut RunSpec, value: &str, scale: Scale) -> Result<(), String> {
        match self {
            Knob::Dataset => {
                spec.workload = workload_for(value, scale)?;
                spec.dataset = value.to_string();
            }
            Knob::Aux => spec.aux = value.to_string(),
            Knob::Preset => {
                let m = Method::parse(value)
                    .ok_or_else(|| format!("unknown method preset {value:?}"))?;
                spec.method = m.spec();
            }
            Knob::H => {
                let h: usize = value
                    .parse()
                    .map_err(|_| format!("bad upload period {value:?}"))?;
                spec.method = spec.method.with_period(h);
            }
            Knob::Shards => {
                spec.server_shards =
                    value.parse().map_err(|_| format!("bad shard count {value:?}"))?;
            }
            Knob::Map => spec.shard_map = value.parse()?,
            Knob::Dist => {
                spec.dist = Dist::parse(value)
                    .ok_or_else(|| format!("unknown distribution {value:?}"))?;
            }
            Knob::Codec => {
                spec.method = spec.method.with_compression(parse_codec(value)?);
            }
            Knob::Update => spec.method.update = value.parse()?,
            Knob::AlignEvery => {
                let a: usize = value
                    .parse()
                    .map_err(|_| format!("bad alignment period {value:?}"))?;
                match &mut spec.method.update {
                    ClientUpdate::SageEstimate { align_every, .. } => *align_every = a,
                    other => {
                        return Err(format!(
                            "align-every composes with the sage update rule, not {other}"
                        ));
                    }
                }
            }
            Knob::Topology => spec.method.topology = value.parse()?,
            Knob::Clients => {
                spec.n_clients =
                    value.parse().map_err(|_| format!("bad client count {value:?}"))?;
            }
            Knob::Participation => {
                spec.participation =
                    value.parse().map_err(|_| format!("bad participation {value:?}"))?;
            }
            Knob::Lr => {
                spec.lr0 = value.parse().map_err(|_| format!("bad learning rate {value:?}"))?;
            }
            Knob::Churn => {
                spec.churn.model = crate::sim::churn::ChurnModel::parse(value)?;
            }
            Knob::Seed => {
                spec.seed = value.parse().map_err(|_| format!("bad seed {value:?}"))?;
            }
        }
        Ok(())
    }

    /// The knob's value in a lowered spec, as a CSV cell (inverse
    /// direction of [`Knob::apply`], used by journal-derived tables).
    pub fn get(self, spec: &RunSpec) -> String {
        match self {
            Knob::Dataset => spec.dataset.clone(),
            Knob::Aux => spec.aux.clone(),
            Knob::Preset => spec.method.tag(),
            Knob::H => spec.method.h_hint().to_string(),
            Knob::Shards => spec.server_shards.to_string(),
            Knob::Map => spec.shard_map.to_string(),
            Knob::Dist => spec.dist.tag().to_string(),
            Knob::Codec => spec.method.compression.to_string(),
            Knob::Update => match spec.method.update {
                ClientUpdate::ServerGrad { .. } => "grad".to_string(),
                ClientUpdate::AuxLocal => "aux".to_string(),
                ClientUpdate::SageEstimate { .. } => "sage".to_string(),
            },
            Knob::AlignEvery => match spec.method.update {
                ClientUpdate::SageEstimate { align_every, .. } => align_every.to_string(),
                _ => "-".to_string(),
            },
            Knob::Topology => spec.method.topology.to_string(),
            Knob::Clients => spec.n_clients.to_string(),
            Knob::Participation => spec.participation.to_string(),
            Knob::Lr => spec.lr0.to_string(),
            Knob::Churn => spec.churn.model.to_string(),
            Knob::Seed => spec.seed.to_string(),
        }
    }
}

/// Per-dataset workload at a scale (the [`Knob::Dataset`] derivation).
fn workload_for(dataset: &str, scale: Scale) -> Result<Workload, String> {
    match dataset {
        "cifar" => Ok(cifar_workload(scale)),
        "femnist" => Ok(femnist_workload(scale)),
        other => Err(format!("unknown dataset {other:?}")),
    }
}

/// Parse a codec axis value: `none`, `quantize<bits>` / `q<bits>`,
/// `topk<frac>` / `t<frac>`. Range validation is left to
/// [`crate::coordinator::methods::MethodSpec::validate`] so axis values
/// fail with the same messages as CLI flags.
pub fn parse_codec(s: &str) -> Result<Compression, String> {
    let low = s.to_ascii_lowercase();
    if low == "none" {
        return Ok(Compression::None);
    }
    // `topk` before the single-letter `t` prefix, and both before `q`,
    // so `topk0.25` is never read as `t` + garbage.
    for prefix in ["quantize", "q"] {
        if let Some(rest) = low.strip_prefix(prefix) {
            if let Ok(bits) = rest.parse::<u8>() {
                return Ok(Compression::Quantize { bits });
            }
        }
    }
    for prefix in ["topk", "t"] {
        if let Some(rest) = low.strip_prefix(prefix) {
            if let Ok(frac) = rest.parse::<f32>() {
                return Ok(Compression::TopK { frac });
            }
        }
    }
    Err(format!(
        "bad codec {s:?} (expected none | q<bits> | quantize<bits> | t<frac> | topk<frac>)"
    ))
}

// ----------------------------------------------------- spec expansion

/// One knob assignment of an axis point.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Setting {
    /// Which spec axis to assign.
    pub knob: Knob,
    /// The value, in the knob's CLI spelling.
    pub value: String,
}

impl Setting {
    /// A knob assignment.
    pub fn new(knob: Knob, value: &str) -> Setting {
        Setting { knob, value: value.to_string() }
    }
}

/// A named sweep axis: a list of points, each point a (usually
/// singleton) group of [`Setting`]s that vary together.
#[derive(Clone, Debug)]
pub struct Axis {
    /// Axis name (reports and error messages).
    pub name: String,
    /// The points of this axis, in sweep order.
    pub points: Vec<Vec<Setting>>,
}

impl Axis {
    /// The common case: one knob, one value per point.
    pub fn single(name: &str, knob: Knob, values: &[&str]) -> Axis {
        Axis {
            name: name.to_string(),
            points: values.iter().map(|v| vec![Setting::new(knob, v)]).collect(),
        }
    }

    /// An axis whose points assign several knobs at once (e.g. a
    /// dataset arm that moves dataset + aux + dist + lr together).
    pub fn joint(name: &str, points: Vec<Vec<Setting>>) -> Axis {
        Axis { name: name.to_string(), points }
    }
}

/// One expanded trial: its settings (for provenance) and the lowered,
/// validated [`RunSpec`].
#[derive(Clone, Debug)]
pub struct Trial {
    /// Position in the deterministic expansion order.
    pub index: usize,
    /// The settings that produced [`Trial::spec`].
    pub settings: Vec<Setting>,
    /// The fully lowered run spec (its [`RunSpec::key`] joins the
    /// journal to the expansion).
    pub spec: RunSpec,
}

/// A declarative sweep: named axes × values × seeds × repeats over a
/// base [`RunSpec`], plus the table derived from the journal.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    /// Sweep name — names the journal file (`sweeps/<backend>/<name>.jsonl`).
    pub name: String,
    /// Report title.
    pub title: String,
    /// The spec every trial starts from.
    pub base: RunSpec,
    /// Scale used when a [`Knob::Dataset`] setting re-derives the
    /// workload (the *effective* scale — figure sweeps pin `paper` to
    /// the `ci` preset, see EXPERIMENTS.md).
    pub scale: Scale,
    /// The axes, outermost first (rightmost axis varies fastest).
    pub axes: Vec<Axis>,
    /// Experiment seeds (empty = the base spec's seed).
    pub seeds: Vec<u64>,
    /// Repeats per (point, seed); repeat r runs at `seed + r`.
    pub repeats: usize,
    /// Skip rules: a point is dropped when it contains every setting of
    /// any rule (e.g. `k=1, map=balanced` — placement is moot at one shard).
    pub skip: Vec<Vec<Setting>>,
    /// The journal-derived output table.
    pub table: TableSpec,
    /// Footer appended to the report (provenance notes).
    pub notes: String,
}

impl SweepSpec {
    /// Expand the sweep deterministically into its trial list: the
    /// cartesian product of the axes (rightmost fastest) minus skip
    /// rules, times seeds × repeats; every point lowered onto the base
    /// spec and validated up front, with duplicate [`RunSpec::key`]s
    /// rejected (they would alias journal entries).
    pub fn trials(&self) -> Result<Vec<Trial>, String> {
        let mut points: Vec<Vec<Setting>> = vec![Vec::new()];
        for axis in &self.axes {
            if axis.points.is_empty() {
                return Err(format!("sweep {}: axis {:?} has no points", self.name, axis.name));
            }
            let mut next = Vec::with_capacity(points.len() * axis.points.len());
            for point in &points {
                for choice in &axis.points {
                    let mut p = point.clone();
                    p.extend(choice.iter().cloned());
                    next.push(p);
                }
            }
            points = next;
        }
        points.retain(|p| {
            !self.skip.iter().any(|rule| rule.iter().all(|s| p.contains(s)))
        });
        let seeds = if self.seeds.is_empty() { vec![self.base.seed] } else { self.seeds.clone() };
        let mut trials = Vec::new();
        let mut seen = BTreeSet::new();
        for point in &points {
            for &seed in &seeds {
                for r in 0..self.repeats.max(1) {
                    let mut settings = point.clone();
                    settings.push(Setting::new(Knob::Seed, &(seed + r as u64).to_string()));
                    let spec = self.lower(&settings)?;
                    spec.validate().map_err(|e| {
                        format!("sweep {}: invalid trial {settings:?}: {e}", self.name)
                    })?;
                    let key = spec.key();
                    if !seen.insert(key.clone()) {
                        return Err(format!(
                            "sweep {}: duplicate trial key {key} (axes overlap)",
                            self.name
                        ));
                    }
                    trials.push(Trial { index: trials.len(), settings, spec });
                }
            }
        }
        Ok(trials)
    }

    /// Lower one settings list onto the base spec (stable-sorted by
    /// `Knob::phase`, so base-replacing knobs apply first).
    fn lower(&self, settings: &[Setting]) -> Result<RunSpec, String> {
        let mut spec = self.base.clone();
        let mut ordered: Vec<&Setting> = settings.iter().collect();
        ordered.sort_by_key(|s| s.knob.phase());
        for s in ordered {
            s.knob.apply(&mut spec, &s.value, self.scale).map_err(|e| {
                format!("sweep {}: {:?}={}: {e}", self.name, s.knob, s.value)
            })?;
        }
        Ok(spec)
    }
}

// -------------------------------------------------------- table layer

/// The journal-derived output table of a sweep.
#[derive(Clone, Debug)]
pub struct TableSpec {
    /// CSV file stem (written as `<out_dir>/<file>.csv`).
    pub file: String,
    /// Columns, in order.
    pub columns: Vec<Column>,
}

/// One table column.
#[derive(Clone, Debug)]
pub struct Column {
    /// CSV header cell.
    pub header: String,
    /// Where the cell value comes from.
    pub value: ColumnValue,
}

impl Column {
    /// The run's series label (`RunRecord::label`), under the
    /// conventional `series` header.
    pub fn series() -> Column {
        Column { header: "series".to_string(), value: ColumnValue::Series }
    }

    /// A spec knob read back from the trial's lowered spec.
    pub fn knob(header: &str, knob: Knob) -> Column {
        Column { header: header.to_string(), value: ColumnValue::Knob(knob) }
    }

    /// A metric of the journaled run record.
    pub fn metric(header: &str, metric: Metric) -> Column {
        Column { header: header.to_string(), value: ColumnValue::Metric(metric) }
    }

    /// Render this column's cell for one (spec, record) pair.
    fn cell(&self, spec: &RunSpec, rec: &RunRecord) -> String {
        match &self.value {
            ColumnValue::Series => rec.label.clone(),
            ColumnValue::Knob(k) => k.get(spec),
            ColumnValue::Metric(m) => m.cell(rec),
        }
    }
}

/// What a [`Column`] cell is derived from.
#[derive(Clone, Debug)]
pub enum ColumnValue {
    /// The run record's label.
    Series,
    /// A knob of the trial's lowered spec.
    Knob(Knob),
    /// A metric of the journaled run record.
    Metric(Metric),
}

/// Run-record metrics a table can report. Formats are pinned to the
/// historical figure CSVs (byte-compatibility is a test contract).
#[derive(Clone, Copy, Debug)]
pub enum Metric {
    /// `final_accuracy`, 4 decimals.
    FinalAccuracy,
    /// Total wire load in GB (`RunRecord::total_gb`), 6 decimals.
    LoadGb,
    /// Simulated wall-clock seconds, 4 decimals.
    SimTime,
    /// `RunRecord::sched_efficiency`, 4 decimals.
    SchedEfficiency,
    /// Weighted per-shard label divergence, 4 decimals.
    ShardDivergence,
    /// Server storage in parameters (integer).
    StorageParams,
    /// Distinct clients materialized (`RunRecord::clients_activated`).
    ClientsActivated,
    /// Participants removed by the availability model (integer).
    ClientsDropped,
    /// Replacements admitted by quorum re-sampling (integer).
    ClientsReplaced,
    /// Mid-round deaths after a partial upload (integer).
    PartialFailures,
    /// Uploads dropped past the straggler window (integer).
    StragglersDropped,
}

impl Metric {
    fn cell(self, rec: &RunRecord) -> String {
        match self {
            Metric::FinalAccuracy => format!("{:.4}", rec.final_accuracy),
            Metric::LoadGb => format!("{:.6}", rec.total_gb()),
            Metric::SimTime => format!("{:.4}", rec.sim_time),
            Metric::SchedEfficiency => format!("{:.4}", rec.sched_efficiency()),
            Metric::ShardDivergence => format!("{:.4}", rec.shard_label_divergence),
            Metric::StorageParams => rec.server_storage_params.to_string(),
            Metric::ClientsActivated => rec.clients_activated.to_string(),
            Metric::ClientsDropped => rec.clients_dropped.to_string(),
            Metric::ClientsReplaced => rec.clients_replaced.to_string(),
            Metric::PartialFailures => rec.partial_failures.to_string(),
            Metric::StragglersDropped => rec.stragglers_dropped.to_string(),
        }
    }
}

// ------------------------------------------------------------ journal

/// Journal line-format version; [`TrialEntry::parse`] rejects records
/// from any other version (they fall into the invalid suffix and the
/// trials re-run from the results cache). v2 added the cohort-health
/// counters (`clients_activated` / `clients_dropped` / `clients_replaced`
/// / `partial_failures`); v1 lines lack them and re-run — cheaply, since
/// the results cache still holds their records.
pub const JOURNAL_VERSION: u32 = 2;

/// Outcome recorded for one trial.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrialStatus {
    /// The trial completed and its record was cached.
    Ok,
    /// The trial errored (journaled for forensics; never counts as
    /// complete, so a resume retries it).
    Failed,
}

impl TrialStatus {
    fn tag(self) -> &'static str {
        match self {
            TrialStatus::Ok => "ok",
            TrialStatus::Failed => "failed",
        }
    }

    fn parse(s: &str) -> Result<TrialStatus, String> {
        match s {
            "ok" => Ok(TrialStatus::Ok),
            "failed" => Ok(TrialStatus::Failed),
            other => Err(format!("bad trial status {other:?}")),
        }
    }
}

/// One journal line: the durable fact that a trial reached a terminal
/// status, plus enough to verify and locate its cached record.
#[derive(Clone, Debug, PartialEq)]
pub struct TrialEntry {
    /// The trial's [`RunSpec::key`] — the join key to the expansion.
    pub key: String,
    /// Results-cache schema version the record was written under.
    pub cache_version: u32,
    /// Terminal status.
    pub status: TrialStatus,
    /// FNV-1a digest of the cached record's bytes (0 for failures).
    pub digest: u64,
    /// Record path relative to the harness `out_dir` (empty for failures).
    pub record: String,
    /// Cohort health of the journaled run (all 0 for failures):
    /// distinct clients materialized (`RunRecord::clients_activated`) …
    pub clients_activated: u64,
    /// … participants removed by the availability model …
    pub clients_dropped: u64,
    /// … replacements admitted by quorum re-sampling …
    pub clients_replaced: u64,
    /// … and mid-round deaths after a partial upload. Journaled so
    /// sweep forensics (and `derive_table` columns) can report fleet
    /// health without re-reading every cached record.
    pub partial_failures: u64,
}

impl TrialEntry {
    /// Serialize as one compact JSON line (no trailing newline). Keys
    /// are emitted sorted (BTreeMap), so lines are byte-deterministic.
    pub fn to_line(&self) -> String {
        Json::obj(vec![
            ("cache_version", Json::num(self.cache_version as f64)),
            ("clients_activated", Json::num(self.clients_activated as f64)),
            ("clients_dropped", Json::num(self.clients_dropped as f64)),
            ("clients_replaced", Json::num(self.clients_replaced as f64)),
            ("digest", Json::str(format!("{:016x}", self.digest))),
            ("journal_version", Json::num(JOURNAL_VERSION as f64)),
            ("key", Json::str(self.key.clone())),
            ("partial_failures", Json::num(self.partial_failures as f64)),
            ("record", Json::str(self.record.clone())),
            ("status", Json::str(self.status.tag())),
        ])
        .dump()
    }

    /// Parse one journal line; any malformation (bad JSON, missing
    /// field, wrong type, unknown version) is an error, which recovery
    /// treats as the start of the invalid suffix.
    pub fn parse(line: &str) -> Result<TrialEntry, String> {
        let j = Json::parse(line).map_err(|e| e.to_string())?;
        let err = |e: crate::util::json::JsonError| e.to_string();
        let version = j.get("journal_version").map_err(err)?.as_usize().map_err(err)? as u32;
        if version != JOURNAL_VERSION {
            return Err(format!("journal_version {version} != {JOURNAL_VERSION}"));
        }
        let digest_hex = j.get("digest").map_err(err)?.as_str().map_err(err)?;
        let digest = u64::from_str_radix(digest_hex, 16)
            .map_err(|_| format!("bad digest {digest_hex:?}"))?;
        let count = |k: &str| -> Result<u64, String> {
            j.get(k).map_err(err)?.as_f64().map_err(err).map(|f| f as u64)
        };
        Ok(TrialEntry {
            key: j.get("key").map_err(err)?.as_str().map_err(err)?.to_string(),
            cache_version: j.get("cache_version").map_err(err)?.as_usize().map_err(err)?
                as u32,
            status: TrialStatus::parse(j.get("status").map_err(err)?.as_str().map_err(err)?)?,
            digest,
            record: j.get("record").map_err(err)?.as_str().map_err(err)?.to_string(),
            // v2 fields — strict, not lenient: the version gate above
            // already rejected every pre-v2 line, so a v2 line missing
            // a counter is malformed, not old.
            clients_activated: count("clients_activated")?,
            clients_dropped: count("clients_dropped")?,
            clients_replaced: count("clients_replaced")?,
            partial_failures: count("partial_failures")?,
        })
    }
}

/// Recover the longest valid prefix of a journal: entries are read off
/// newline-terminated, parseable lines until the first torn, truncated,
/// malformed, or unknown-version line; everything from that point on is
/// the invalid suffix. Returns the entries and the prefix length in
/// bytes (what [`Journal::resume`] truncates the file to).
pub fn recover(bytes: &[u8]) -> (Vec<TrialEntry>, usize) {
    let mut entries = Vec::new();
    let mut valid = 0usize;
    let mut start = 0usize;
    for (i, &b) in bytes.iter().enumerate() {
        if b != b'\n' {
            continue;
        }
        let parsed = std::str::from_utf8(&bytes[start..i])
            .map_err(|e| e.to_string())
            .and_then(TrialEntry::parse);
        match parsed {
            Ok(e) => {
                entries.push(e);
                valid = i + 1;
                start = i + 1;
            }
            Err(_) => return (entries, valid),
        }
    }
    // Bytes after the last newline are an unterminated (torn) line.
    (entries, valid)
}

/// Append-only crash-durable trial journal (JSONL). Each line is
/// written with a single `write_all` + `sync_data`, so a crash leaves
/// at most one torn line — which recovery drops.
pub struct Journal {
    path: PathBuf,
    file: std::fs::File,
    entries: Vec<TrialEntry>,
}

impl Journal {
    /// Start an empty journal, truncating any existing file.
    pub fn fresh(path: &Path) -> Result<Journal, String> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
        }
        let file = std::fs::File::create(path)
            .map_err(|e| format!("cannot create journal {}: {e}", path.display()))?;
        Ok(Journal { path: path.to_path_buf(), file, entries: Vec::new() })
    }

    /// Reopen a journal, recovering the longest valid prefix (a missing
    /// file is an empty journal). The file is truncated to the valid
    /// prefix so appends never interleave with torn bytes. Returns the
    /// journal and how many invalid-suffix bytes were dropped.
    pub fn resume(path: &Path) -> Result<(Journal, usize), String> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
        }
        let bytes = std::fs::read(path).unwrap_or_default();
        let (entries, valid) = recover(&bytes);
        let dropped = bytes.len() - valid;
        let file = std::fs::OpenOptions::new()
            .append(true)
            .create(true)
            .open(path)
            .map_err(|e| format!("cannot open journal {}: {e}", path.display()))?;
        file.set_len(valid as u64).map_err(|e| e.to_string())?;
        Ok((Journal { path: path.to_path_buf(), file, entries }, dropped))
    }

    /// Append one entry as an atomic line write (single `write_all` of
    /// `line + "\n"`, then `sync_data`).
    pub fn append(&mut self, entry: TrialEntry) -> Result<(), String> {
        let mut line = entry.to_line();
        line.push('\n');
        self.file
            .write_all(line.as_bytes())
            .map_err(|e| format!("journal write failed: {e}"))?;
        self.file.sync_data().map_err(|e| format!("journal sync failed: {e}"))?;
        self.entries.push(entry);
        Ok(())
    }

    /// All recovered + appended entries, in journal order.
    pub fn entries(&self) -> &[TrialEntry] {
        &self.entries
    }

    /// The journal file path.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// The journaled-complete trial set: last `Ok` entry per key, filtered
/// to the current [`CACHE_VERSION`] and to keys inside the sweep's own
/// expansion — so duplicate records last-win, `Failed` lines never
/// complete anything, and alien keys (another sweep's, or a stale
/// grid's) can never mark this sweep's work done.
pub fn journaled_complete<'a>(
    entries: &'a [TrialEntry],
    expansion: &BTreeSet<String>,
) -> BTreeMap<String, &'a TrialEntry> {
    let mut done = BTreeMap::new();
    for e in entries {
        if e.status == TrialStatus::Ok
            && e.cache_version == CACHE_VERSION
            && expansion.contains(&e.key)
        {
            done.insert(e.key.clone(), e);
        }
    }
    done
}

// ---------------------------------------------------------- execution

/// Execution options for [`run_sweep`].
#[derive(Clone, Copy, Debug, Default)]
pub struct SweepOptions {
    /// Reopen the journal and skip journaled-complete trials instead of
    /// starting from an empty journal.
    pub resume: bool,
    /// Fault injection (tests/CI): abort with an error before executing
    /// trial N+1, leaving N journaled trials behind.
    pub fail_after: Option<usize>,
}

/// What a completed sweep produced.
#[derive(Clone, Debug)]
pub struct SweepOutcome {
    /// Trials in the full expansion.
    pub total: usize,
    /// Trials skipped as journaled-complete.
    pub skipped: usize,
    /// Trials executed this invocation.
    pub executed: usize,
    /// Journal file path.
    pub journal: PathBuf,
    /// Derived CSV path.
    pub csv: PathBuf,
    /// Aligned-text report (title + table + notes).
    pub report: String,
}

/// Run one sweep: expand + validate the grid, skip journaled-complete
/// trials (on [`SweepOptions::resume`]), execute the remainder through
/// [`Harness::run_cached`], journal each completion, then derive the
/// CSV + report purely from the journal.
pub fn run_sweep(
    harness: &mut Harness,
    sweep: &SweepSpec,
    opts: &SweepOptions,
) -> Result<SweepOutcome, String> {
    let trials = sweep.trials()?;
    let expansion: BTreeSet<String> = trials.iter().map(|t| t.spec.key()).collect();
    let journal_path = harness
        .out_dir
        .join("sweeps")
        .join(harness.backend())
        .join(format!("{}.jsonl", sweep.name));
    let (mut journal, dropped) = if opts.resume {
        Journal::resume(&journal_path)?
    } else {
        (Journal::fresh(&journal_path)?, 0)
    };
    if dropped > 0 {
        eprintln!(
            "sweep {}: dropped {dropped} torn/invalid journal byte(s) at {}",
            sweep.name,
            journal_path.display()
        );
    }
    // A journal line only skips a trial when its cached record still
    // verifies (file present, digest matches, record parses at the
    // current cache version): a wiped or corrupted cache self-heals by
    // re-running instead of failing the table derivation later.
    let completed: BTreeSet<String> = journaled_complete(journal.entries(), &expansion)
        .into_iter()
        .filter(|(_, e)| verify_record(&harness.out_dir, e))
        .map(|(k, _)| k)
        .collect();
    let mut executed = 0usize;
    let mut skipped = 0usize;
    for trial in &trials {
        let key = trial.spec.key();
        if completed.contains(&key) {
            skipped += 1;
            continue;
        }
        if let Some(n) = opts.fail_after {
            if executed >= n {
                return Err(format!(
                    "sweep {}: injected failure after {executed} executed trial(s) \
                     ({} line(s) journaled)",
                    sweep.name,
                    journal.entries().len()
                ));
            }
        }
        eprintln!("sweep {}: [{}/{}] {key}", sweep.name, trial.index + 1, trials.len());
        match harness.run_cached(&trial.spec) {
            Ok(rec) => {
                // By the JSON round-trip stability contract (pinned in
                // exp::common tests) this digest equals the digest of
                // the cache file's bytes, whether the run was fresh or
                // replayed.
                let text = run_to_json(&rec).pretty();
                let record = rel_to(&harness.out_dir, &harness.cache_file(&trial.spec));
                journal.append(TrialEntry {
                    key,
                    cache_version: CACHE_VERSION,
                    status: TrialStatus::Ok,
                    digest: fnv64(&text),
                    record,
                    clients_activated: rec.clients_activated as u64,
                    clients_dropped: rec.clients_dropped,
                    clients_replaced: rec.clients_replaced,
                    partial_failures: rec.partial_failures,
                })?;
                executed += 1;
            }
            Err(e) => {
                let _ = journal.append(TrialEntry {
                    key: key.clone(),
                    cache_version: CACHE_VERSION,
                    status: TrialStatus::Failed,
                    digest: 0,
                    record: String::new(),
                    clients_activated: 0,
                    clients_dropped: 0,
                    clients_replaced: 0,
                    partial_failures: 0,
                });
                return Err(format!("sweep {}: trial {key} failed: {e}", sweep.name));
            }
        }
    }
    let (csv, report) = derive_table(harness, sweep, &trials, journal.entries())?;
    let csv_path = harness.out_dir.join(format!("{}.csv", sweep.table.file));
    csv.write_to(&csv_path).map_err(|e| e.to_string())?;
    Ok(SweepOutcome {
        total: trials.len(),
        skipped,
        executed,
        journal: journal_path,
        csv: csv_path,
        report,
    })
}

/// `path` relative to `base` (falls back to the absolute path when the
/// record lives outside the out dir — it never does in practice).
fn rel_to(base: &Path, path: &Path) -> String {
    path.strip_prefix(base).unwrap_or(path).to_string_lossy().into_owned()
}

/// Whether a journaled record still verifies on disk: readable, digest
/// match, parseable at the current cache version.
fn verify_record(out_dir: &Path, e: &TrialEntry) -> bool {
    if e.record.is_empty() {
        return false;
    }
    match std::fs::read_to_string(out_dir.join(&e.record)) {
        Ok(text) => fnv64(&text) == e.digest && run_from_json(&text).is_ok(),
        Err(_) => false,
    }
}

/// Derive the sweep's table purely from the journal: for every trial in
/// expansion order, look up its journaled entry by [`RunSpec::key`],
/// load + verify the cached record, and render the configured columns.
fn derive_table(
    harness: &Harness,
    sweep: &SweepSpec,
    trials: &[Trial],
    entries: &[TrialEntry],
) -> Result<(Csv, String), String> {
    let expansion: BTreeSet<String> = trials.iter().map(|t| t.spec.key()).collect();
    let done = journaled_complete(entries, &expansion);
    let headers: Vec<&str> = sweep.table.columns.iter().map(|c| c.header.as_str()).collect();
    let mut csv = Csv::new(&headers);
    let mut rows = Vec::with_capacity(trials.len());
    for trial in trials {
        let key = trial.spec.key();
        let e = done.get(&key).ok_or_else(|| {
            format!("sweep {}: journal has no completed entry for {key}", sweep.name)
        })?;
        let text = std::fs::read_to_string(harness.out_dir.join(&e.record)).map_err(|err| {
            format!("sweep {}: cannot read journaled record {}: {err}", sweep.name, e.record)
        })?;
        if fnv64(&text) != e.digest {
            return Err(format!(
                "sweep {}: record {} does not match its journaled digest",
                sweep.name, e.record
            ));
        }
        let rec = run_from_json(&text)?;
        let row: Vec<String> =
            sweep.table.columns.iter().map(|c| c.cell(&trial.spec, &rec)).collect();
        csv.row(&row);
        rows.push(row);
    }
    let report = render_report(&sweep.title, &headers, &rows, &sweep.notes);
    Ok((csv, report))
}

/// Aligned-text rendering of a derived table (first column
/// left-aligned, the rest right-aligned), with the notes footer.
fn render_report(title: &str, headers: &[&str], rows: &[Vec<String>], notes: &str) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = format!("== {title} ==\n");
    let line = |cells: &[&str], out: &mut String| {
        for (i, cell) in cells.iter().enumerate() {
            if i == 0 {
                out.push_str(&format!("{cell:<w$}", w = widths[i]));
            } else {
                out.push_str(&format!(" {cell:>w$}", w = widths[i]));
            }
        }
        out.push('\n');
    };
    line(headers, &mut out);
    for row in rows {
        let cells: Vec<&str> = row.iter().map(|c| c.as_str()).collect();
        line(&cells, &mut out);
    }
    if !notes.is_empty() {
        out.push_str(notes);
        if !notes.ends_with('\n') {
            out.push('\n');
        }
    }
    out
}

// ----------------------------------------------------- builtin sweeps

/// The figure protocol pins `--scale paper` to the `ci` workload for
/// these sweeps (EXPERIMENTS.md — the full paper workload is hours on
/// one box).
fn eff(scale: Scale) -> Scale {
    if scale == Scale::Paper {
        Scale::Ci
    } else {
        scale
    }
}

/// Resolve a figure id to its built-in sweep list: `k`/`staleness` (two
/// sweeps: IID shard axis + non-IID placement arms), `h`/`period` (two
/// sweeps: the aux-local period grid + the sage alignment-period arm),
/// `b`/`bits`, `r`/`churn`, or `all`.
pub fn builtin(id: &str, scale: Scale) -> Result<Vec<SweepSpec>, String> {
    match id {
        "k" | "staleness" => Ok(vec![staleness_sweep(scale), staleness_noniid_sweep(scale)]),
        "h" | "period" => Ok(vec![h_sweep(scale), h_sage_sweep(scale)]),
        "b" | "bits" => Ok(vec![b_sweep(scale)]),
        "r" | "churn" => Ok(vec![churn_sweep(scale)]),
        "all" => Ok(vec![
            staleness_sweep(scale),
            staleness_noniid_sweep(scale),
            h_sweep(scale),
            h_sage_sweep(scale),
            b_sweep(scale),
            churn_sweep(scale),
        ]),
        other => Err(format!(
            "no sweep {other:?} (have k|staleness, h|period, b|bits, r|churn, all)"
        )),
    }
}

/// `figure k`, IID arm: accuracy vs server shards k at contiguous and
/// balanced placements (the staleness cost of sharding).
fn staleness_sweep(scale: Scale) -> SweepSpec {
    let h = if scale == Scale::Quick { 2 } else { 5 };
    let base = RunSpec {
        method: Method::CseFsl.spec().with_period(h),
        n_clients: 8,
        ..base_spec("cifar", "cnn27", cifar_workload(eff(scale)))
    };
    SweepSpec {
        name: "staleness".to_string(),
        title: "Accuracy vs server shards k (staleness cost of sharding)".to_string(),
        base,
        scale: eff(scale),
        axes: vec![
            Axis::single("k", Knob::Shards, &["1", "2", "4", "8"]),
            Axis::single("map", Knob::Map, &["contiguous", "balanced"]),
        ],
        seeds: Vec::new(),
        repeats: 1,
        // Placement is moot at one shard: k=1 runs contiguous only.
        skip: vec![vec![Setting::new(Knob::Shards, "1"), Setting::new(Knob::Map, "balanced")]],
        table: TableSpec {
            file: "fig_staleness".to_string(),
            columns: vec![
                Column::series(),
                Column::knob("k", Knob::Shards),
                Column::knob("shard_map", Knob::Map),
                Column::metric("final_accuracy", Metric::FinalAccuracy),
                Column::metric("server_storage_params", Metric::StorageParams),
                Column::metric("sim_time", Metric::SimTime),
                Column::metric("sched_efficiency", Metric::SchedEfficiency),
                Column::metric("shard_divergence", Metric::ShardDivergence),
            ],
        },
        notes: "(k=1 = paper's shared copy; accuracy drift at larger k is the staleness \
                cost,\n storage grows as k·|w_s|, sim time falls as lanes parallelize \
                arrivals)\n"
            .to_string(),
    }
}

/// `figure k`, non-IID arm: shard placement (contiguous / balanced /
/// locality) on Dirichlet CIFAR and by-writer F-EMNIST.
fn staleness_noniid_sweep(scale: Scale) -> SweepSpec {
    let h = if scale == Scale::Quick { 2 } else { 5 };
    let base =
        RunSpec { n_clients: 8, ..base_spec("cifar", "cnn27", cifar_workload(eff(scale))) };
    SweepSpec {
        name: "staleness-noniid".to_string(),
        title: "Shard placement on non-IID splits (contiguous / balanced / locality)"
            .to_string(),
        base,
        scale: eff(scale),
        axes: vec![
            Axis::joint(
                "arm",
                vec![
                    vec![
                        Setting::new(Knob::Dataset, "cifar"),
                        Setting::new(Knob::Aux, "cnn27"),
                        Setting::new(Knob::Dist, "dir"),
                        Setting::new(Knob::H, &h.to_string()),
                        Setting::new(Knob::Lr, "0.01"),
                    ],
                    vec![
                        Setting::new(Knob::Dataset, "femnist"),
                        Setting::new(Knob::Aux, "cnn8"),
                        Setting::new(Knob::Dist, "writer"),
                        Setting::new(Knob::H, "2"),
                        Setting::new(Knob::Lr, "0.05"),
                    ],
                ],
            ),
            Axis::single("k", Knob::Shards, &["2", "4"]),
            Axis::single("map", Knob::Map, &["contiguous", "balanced", "locality"]),
        ],
        seeds: Vec::new(),
        repeats: 1,
        skip: Vec::new(),
        table: TableSpec {
            file: "fig_staleness_noniid".to_string(),
            columns: vec![
                Column::series(),
                Column::knob("dataset", Knob::Dataset),
                Column::knob("dist", Knob::Dist),
                Column::knob("k", Knob::Shards),
                Column::knob("shard_map", Knob::Map),
                Column::metric("final_accuracy", Metric::FinalAccuracy),
                Column::metric("shard_divergence", Metric::ShardDivergence),
                Column::metric("sim_time", Metric::SimTime),
            ],
        },
        notes: "(skew = weighted per-shard label divergence from the global mix, 0 = every \
                copy\n trains on the global label distribution; locality minimizes it by \
                design)\n"
            .to_string(),
    }
}

/// `figure h`: upload period × server topology on the aux-local update
/// rule (the per-client arm next to its shared-topology control).
fn h_sweep(scale: Scale) -> SweepSpec {
    let h_vals: &[&str] = if scale == Scale::Quick { &["1", "2"] } else { &["1", "2", "4", "8"] };
    SweepSpec {
        name: "h".to_string(),
        title: "Upload period h x server topology (aux-local update rule)".to_string(),
        base: base_spec("cifar", "cnn27", cifar_workload(eff(scale))),
        scale: eff(scale),
        axes: vec![
            Axis::single("h", Knob::H, h_vals),
            Axis::single("arm", Knob::Preset, &["an", "cse"]),
        ],
        seeds: Vec::new(),
        repeats: 1,
        skip: Vec::new(),
        table: TableSpec {
            file: "fig_h".to_string(),
            columns: vec![
                Column::series(),
                Column::knob("h", Knob::H),
                Column::knob("topology", Knob::Topology),
                Column::metric("final_accuracy", Metric::FinalAccuracy),
                Column::metric("load_gb", Metric::LoadGb),
                Column::metric("server_storage_params", Metric::StorageParams),
                Column::metric("sim_time", Metric::SimTime),
            ],
        },
        notes: "(h=1 rows are the FSL_AN / CSE_FSL presets; h>1 per-client rows are the\n \
                spec-only aux+p<h>+pc scenario the closed Method enum could not express.\n \
                Each round uploads one smashed batch whatever h is, so wire cost per\n \
                local batch trained falls ~1/h; the per-client arm pays n x |w_s|\n \
                storage for per-client server trajectories at identical wire/schedule\n \
                columns.)\n"
            .to_string(),
    }
}

/// `figure h`, sage arm: alignment period of the gradient-estimator
/// update rule (FSL-SAGE) on the shared topology. Wire traffic
/// interpolates between the neighbouring rules' closed forms — a=1 pays
/// the full server-grad downlink, large a approaches the aux-local
/// totals — which `tests/estimator_properties.rs` pins against the
/// measured ledger.
fn h_sage_sweep(scale: Scale) -> SweepSpec {
    let a_vals: &[&str] =
        if scale == Scale::Quick { &["1", "2"] } else { &["1", "2", "4", "8"] };
    SweepSpec {
        name: "h-sage".to_string(),
        title: "Alignment period a (sage gradient-estimator update rule)".to_string(),
        base: base_spec("cifar", "cnn27", cifar_workload(eff(scale))),
        scale: eff(scale),
        axes: vec![Axis::joint(
            "align",
            a_vals
                .iter()
                .map(|a| {
                    vec![
                        Setting::new(Knob::Update, "sage"),
                        Setting::new(Knob::AlignEvery, a),
                    ]
                })
                .collect(),
        )],
        seeds: Vec::new(),
        repeats: 1,
        skip: Vec::new(),
        table: TableSpec {
            file: "fig_h_sage".to_string(),
            columns: vec![
                Column::series(),
                Column::knob("align_every", Knob::AlignEvery),
                Column::knob("topology", Knob::Topology),
                Column::metric("final_accuracy", Metric::FinalAccuracy),
                Column::metric("load_gb", Metric::LoadGb),
                Column::metric("server_storage_params", Metric::StorageParams),
                Column::metric("sim_time", Metric::SimTime),
            ],
        },
        notes: "(sage{a} rows: aux-local rounds with a true-gradient alignment every a-th\n \
                upload. The gradient downlink pays (rounds/a)·n·smashed_wire bytes —\n \
                exactly the server-grad term at a=1, vanishing as a grows — while aux\n \
                nets ride along with aggregation like the aux-local rule; predicted and\n \
                ledgered bytes agree exactly.)\n"
            .to_string(),
    }
}

/// `figure b`: accuracy vs wire precision (FedLite-style codec axis on
/// the smashed-data uplink, CSE_FSL at h = 2).
fn b_sweep(scale: Scale) -> SweepSpec {
    let codecs: &[&str] = if scale == Scale::Quick {
        &["none", "q4"]
    } else {
        &["none", "q8", "q4", "q2", "t0.25"]
    };
    let base = RunSpec {
        method: Method::CseFsl.spec().with_period(2),
        ..base_spec("cifar", "cnn27", cifar_workload(eff(scale)))
    };
    SweepSpec {
        name: "b".to_string(),
        title: "Accuracy vs wire precision (CSE_FSL h=2, smashed-data codec)".to_string(),
        base,
        scale: eff(scale),
        axes: vec![Axis::single("codec", Knob::Codec, codecs)],
        seeds: Vec::new(),
        repeats: 1,
        skip: Vec::new(),
        table: TableSpec {
            file: "fig_b".to_string(),
            columns: vec![
                Column::series(),
                Column::knob("codec", Knob::Codec),
                Column::metric("final_accuracy", Metric::FinalAccuracy),
                Column::metric("load_gb", Metric::LoadGb),
                Column::metric("sim_time", Metric::SimTime),
            ],
        },
        notes: "(the uncompressed row is the CSE_FSL preset under its historical cache\n \
                key; codec rows pay fewer wire bytes per smashed upload at the accuracy\n \
                cost of coarser activations. Load shrinks by the codec's closed-form\n \
                ratio — ~bits/32 for quantize, ~2·frac for top-k (index+value pairs) —\n \
                while labels and model exchanges stay full precision.)\n"
            .to_string(),
    }
}

/// `figure r`: accuracy vs churn severity across the method family —
/// CSE_FSL h=2, FSL_OC, and the sage estimator arm, each at full
/// availability and at IID dropout p ∈ {0.9, 0.7, 0.5}. The aux-local
/// rules keep training locally when a round drops them (only uploads
/// thin out), the server-grad rule loses every dropped client's round
/// entirely; the `dropped` column quantifies the cohort each point
/// lost, `final_accuracy` what it cost.
fn churn_sweep(scale: Scale) -> SweepSpec {
    let churn_vals: &[&str] =
        if scale == Scale::Quick { &["none", "iid:0.7"] } else { &["none", "iid:0.9", "iid:0.7", "iid:0.5"] };
    SweepSpec {
        name: "churn".to_string(),
        title: "Accuracy vs churn severity (IID dropout, method family)".to_string(),
        base: base_spec("cifar", "cnn27", cifar_workload(eff(scale))),
        scale: eff(scale),
        axes: vec![
            Axis::joint(
                "arm",
                vec![
                    vec![
                        Setting::new(Knob::Preset, "cse"),
                        Setting::new(Knob::H, "2"),
                    ],
                    vec![Setting::new(Knob::Preset, "oc")],
                    vec![Setting::new(Knob::Update, "sage")],
                ],
            ),
            Axis::single("churn", Knob::Churn, churn_vals),
        ],
        seeds: Vec::new(),
        repeats: 1,
        skip: Vec::new(),
        table: TableSpec {
            file: "fig_r".to_string(),
            columns: vec![
                Column::series(),
                Column::knob("churn", Knob::Churn),
                Column::metric("final_accuracy", Metric::FinalAccuracy),
                Column::metric("clients_dropped", Metric::ClientsDropped),
                Column::metric("load_gb", Metric::LoadGb),
                Column::metric("sim_time", Metric::SimTime),
            ],
        },
        notes: "(churn=none rows are the presets under their historical cache keys; iid:p\n \
                drops each sampled client with probability 1-p per round via the same\n \
                split-stream draw the legacy availability knob used, so results are\n \
                bit-deterministic across parallelism and dealing policy. Aux-local rules\n \
                degrade gracefully — dropped clients still train locally — while the\n \
                server-grad rule forfeits dropped rounds outright.)\n"
            .to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::ShardMapKind;

    #[test]
    fn entry_line_roundtrip_and_version_gate() {
        let e = TrialEntry {
            key: "cifar-cnn27-CSE_FSL-h2-n8-...-s1".to_string(),
            cache_version: CACHE_VERSION,
            status: TrialStatus::Ok,
            digest: 0xDEAD_BEEF_0123_4567,
            record: "cache/mock/k.json".to_string(),
            clients_activated: 8,
            clients_dropped: 3,
            clients_replaced: 1,
            partial_failures: 2,
        };
        let line = e.to_line();
        assert!(!line.contains('\n'), "one entry = one line");
        assert_eq!(TrialEntry::parse(&line).unwrap(), e);
        // Failed entries round-trip too.
        let f = TrialEntry {
            status: TrialStatus::Failed,
            digest: 0,
            record: String::new(),
            ..e.clone()
        };
        assert_eq!(TrialEntry::parse(&f.to_line()).unwrap(), f);
        // Unknown journal versions are the invalid suffix, not data.
        // (`dump()` is compact: no space after the colon.)
        let future = line.replace(
            &format!("\"journal_version\":{JOURNAL_VERSION}"),
            "\"journal_version\":99",
        );
        assert_ne!(future, line, "replacement must hit");
        let err = TrialEntry::parse(&future).unwrap_err();
        assert!(err.contains("journal_version 99"), "{err}");
        // Pre-v2 lines (no cohort counters) fall behind the version
        // gate — the version check fires before any field parse.
        let v1 = line.replace(
            &format!("\"journal_version\":{JOURNAL_VERSION}"),
            "\"journal_version\":1",
        );
        let err = TrialEntry::parse(&v1).unwrap_err();
        assert!(err.contains("journal_version 1"), "{err}");
        // A current-version line missing a counter is malformed (the
        // counters are strict within v2).
        let gone = line.replace("\"clients_dropped\"", "\"legacy\"");
        assert_ne!(gone, line, "replacement must hit");
        assert!(TrialEntry::parse(&gone).is_err());
        // Malformed fields are errors, never defaults.
        assert!(TrialEntry::parse("{}").is_err());
        assert!(TrialEntry::parse("not json").is_err());
        let bad_status = line.replace("\"status\":\"ok\"", "\"status\":\"done\"");
        assert_ne!(bad_status, line, "replacement must hit");
        assert!(TrialEntry::parse(&bad_status).is_err());
    }

    #[test]
    fn recover_keeps_longest_valid_prefix() {
        let e1 = TrialEntry {
            key: "k1".to_string(),
            cache_version: CACHE_VERSION,
            status: TrialStatus::Ok,
            digest: 1,
            record: "cache/mock/k1.json".to_string(),
            clients_activated: 0,
            clients_dropped: 0,
            clients_replaced: 0,
            partial_failures: 0,
        };
        let e2 = TrialEntry { key: "k2".to_string(), digest: 2, ..e1.clone() };
        let l1 = e1.to_line();
        let l2 = e2.to_line();
        let full = format!("{l1}\n{l2}\n");
        let (entries, valid) = recover(full.as_bytes());
        assert_eq!(entries, vec![e1.clone(), e2.clone()]);
        assert_eq!(valid, full.len());
        // A torn final line (kill mid-write) is dropped exactly.
        let torn = format!("{l1}\n{}", &l2[..l2.len() / 2]);
        let (entries, valid) = recover(torn.as_bytes());
        assert_eq!(entries, vec![e1.clone()]);
        assert_eq!(valid, l1.len() + 1);
        // Garbage in the middle ends the prefix there — later valid
        // lines are NOT resurrected (prefix semantics, not filtering).
        let gap = format!("{l1}\nnot json\n{l2}\n");
        let (entries, valid) = recover(gap.as_bytes());
        assert_eq!(entries, vec![e1.clone()]);
        assert_eq!(valid, l1.len() + 1);
        // Empty journal.
        assert_eq!(recover(b""), (Vec::new(), 0));
    }

    #[test]
    fn journaled_complete_last_wins_and_filters() {
        let ok = |key: &str, digest: u64| TrialEntry {
            key: key.to_string(),
            cache_version: CACHE_VERSION,
            status: TrialStatus::Ok,
            digest,
            record: format!("cache/mock/{key}.json"),
            clients_activated: 0,
            clients_dropped: 0,
            clients_replaced: 0,
            partial_failures: 0,
        };
        let entries = vec![
            ok("a", 1),
            ok("a", 2),                                           // duplicate: last wins
            TrialEntry { status: TrialStatus::Failed, ..ok("b", 0) }, // failed: never complete
            TrialEntry { cache_version: CACHE_VERSION + 1, ..ok("c", 3) }, // stale schema
            ok("alien", 4),                                       // not in the expansion
        ];
        let expansion: BTreeSet<String> =
            ["a", "b", "c"].iter().map(|s| s.to_string()).collect();
        let done = journaled_complete(&entries, &expansion);
        assert_eq!(done.len(), 1);
        assert_eq!(done["a"].digest, 2);
        // Completed keys are a subset of the expansion by construction.
        assert!(done.keys().all(|k| expansion.contains(k)));
    }

    #[test]
    fn codec_axis_values_parse() {
        assert_eq!(parse_codec("none").unwrap(), Compression::None);
        assert_eq!(parse_codec("q4").unwrap(), Compression::Quantize { bits: 4 });
        assert_eq!(parse_codec("quantize8").unwrap(), Compression::Quantize { bits: 8 });
        assert_eq!(parse_codec("t0.25").unwrap(), Compression::TopK { frac: 0.25 });
        assert_eq!(parse_codec("topk0.25").unwrap(), Compression::TopK { frac: 0.25 });
        assert!(parse_codec("gzip").is_err());
        assert!(parse_codec("q").is_err());
    }

    #[test]
    fn staleness_expansion_matches_historical_loop_order() {
        let trials = staleness_sweep(Scale::Quick).trials().unwrap();
        // k=1 runs contiguous only (skip rule), then cont+bal per k.
        let got: Vec<(usize, ShardMapKind)> =
            trials.iter().map(|t| (t.spec.server_shards, t.spec.shard_map)).collect();
        let want = vec![
            (1, ShardMapKind::Contiguous),
            (2, ShardMapKind::Contiguous),
            (2, ShardMapKind::Balanced),
            (4, ShardMapKind::Contiguous),
            (4, ShardMapKind::Balanced),
            (8, ShardMapKind::Contiguous),
            (8, ShardMapKind::Balanced),
        ];
        assert_eq!(got, want);
        // Every trial is pre-validated and keys are unique.
        let keys: BTreeSet<String> = trials.iter().map(|t| t.spec.key()).collect();
        assert_eq!(keys.len(), trials.len());
        // Quick scale pins h=2 on every point.
        assert!(trials.iter().all(|t| t.spec.method.h_hint() == 2));
    }

    #[test]
    fn h_expansion_composes_preset_then_period() {
        let trials = h_sweep(Scale::Quick).trials().unwrap();
        assert_eq!(trials.len(), 4);
        // (h=1, an), (h=1, cse), (h=2, an), (h=2, cse) — rightmost
        // axis fastest, preset applied before the period refinement.
        assert_eq!(trials[0].spec.method, Method::FslAn.spec());
        assert_eq!(trials[1].spec.method, Method::CseFsl.spec());
        assert_eq!(trials[2].spec.method, Method::FslAn.spec().with_period(2));
        assert_eq!(trials[3].spec.method, Method::CseFsl.spec().with_period(2));
    }

    #[test]
    fn noniid_arms_move_dataset_workload_and_lr_together() {
        let sweep = staleness_noniid_sweep(Scale::Quick);
        let trials = sweep.trials().unwrap();
        assert_eq!(trials.len(), 2 * 2 * 3);
        let cifar = &trials[0].spec;
        assert_eq!((cifar.dataset.as_str(), cifar.aux.as_str()), ("cifar", "cnn27"));
        assert_eq!(cifar.dist, Dist::NonIidDirichlet);
        assert_eq!(cifar.lr0, 0.01);
        assert_eq!(cifar.workload.rounds, cifar_workload(Scale::Quick).rounds);
        let femnist = &trials[6].spec;
        assert_eq!((femnist.dataset.as_str(), femnist.aux.as_str()), ("femnist", "cnn8"));
        assert_eq!(femnist.dist, Dist::NonIidWriter);
        assert_eq!(femnist.lr0, 0.05);
        assert_eq!(femnist.workload.rounds, femnist_workload(Scale::Quick).rounds);
        assert_eq!(femnist.method.h_hint(), 2);
    }

    #[test]
    fn seeds_and_repeats_expand_and_duplicates_are_rejected() {
        let mut sweep = b_sweep(Scale::Quick);
        sweep.seeds = vec![1, 7];
        sweep.repeats = 2;
        let trials = sweep.trials().unwrap();
        // 2 codecs × 2 seeds × 2 repeats; repeat r runs at seed + r.
        assert_eq!(trials.len(), 8);
        let seeds: Vec<u64> = trials.iter().take(4).map(|t| t.spec.seed).collect();
        assert_eq!(seeds, vec![1, 2, 7, 8]);
        // Overlapping seed/repeat windows collide on RunSpec::key and
        // must be rejected, not silently double-journaled.
        sweep.seeds = vec![1, 2];
        let err = sweep.trials().unwrap_err();
        assert!(err.contains("duplicate trial key"), "{err}");
    }

    #[test]
    fn builtin_ids_resolve() {
        for id in ["k", "staleness", "h", "period", "b", "bits", "r", "churn", "all"] {
            assert!(builtin(id, Scale::Quick).is_ok(), "{id}");
        }
        assert_eq!(builtin("all", Scale::Quick).unwrap().len(), 6);
        assert!(builtin("z", Scale::Quick).is_err());
    }

    #[test]
    fn churn_sweep_expands_method_arms_times_severity() {
        use crate::sim::churn::ChurnModel;
        let trials = churn_sweep(Scale::Quick).trials().unwrap();
        // 3 method arms × 2 quick churn points, churn axis fastest.
        assert_eq!(trials.len(), 6);
        assert_eq!(trials[0].spec.method, Method::CseFsl.spec().with_period(2));
        assert_eq!(trials[0].spec.churn.model, ChurnModel::Iid { p: 1.0 });
        assert_eq!(trials[1].spec.churn.model, ChurnModel::Iid { p: 0.7 });
        assert_eq!(trials[2].spec.method, Method::FslOc.spec());
        assert!(matches!(
            trials[4].spec.method.update,
            ClientUpdate::SageEstimate { .. }
        ));
        // The churn=none points ARE the presets under their historical
        // cache keys (no churn suffix); severity points fork the key.
        assert!(trials[2].spec.key().ends_with("-s1"), "{}", trials[2].spec.key());
        assert!(trials[3].spec.key().ends_with("-ciid0.7"), "{}", trials[3].spec.key());
        // The churn knob reads back for the table column in the CLI
        // spelling (canonical "none" at full availability).
        assert_eq!(Knob::Churn.get(&trials[0].spec), "none");
        assert_eq!(Knob::Churn.get(&trials[1].spec), "iid:0.7");
        // Bad axis values fail at lowering, like every other knob.
        let mut bad = churn_sweep(Scale::Quick);
        bad.axes = vec![Axis::single("churn", Knob::Churn, &["weibull:1:2"])];
        assert!(bad.trials().is_err());
    }

    #[test]
    fn sage_arm_expands_update_then_alignment_period() {
        let trials = h_sage_sweep(Scale::Quick).trials().unwrap();
        assert_eq!(trials.len(), 2);
        assert_eq!(
            trials[0].spec.method.update,
            ClientUpdate::SageEstimate { align_every: 1, clip: 0.0 }
        );
        assert_eq!(
            trials[1].spec.method.update,
            ClientUpdate::SageEstimate { align_every: 2, clip: 0.0 }
        );
        // The sage segment forks the key from the aux-local grid, and
        // the knobs read back for the table columns.
        assert!(trials[0].spec.key().contains("sage1+"), "{}", trials[0].spec.key());
        assert_eq!(Knob::Update.get(&trials[0].spec), "sage");
        assert_eq!(Knob::AlignEvery.get(&trials[1].spec), "2");
        // AlignEvery on a non-sage spec is a lowering error, mirroring
        // the CLI's --align-every rejection.
        let mut bad = h_sage_sweep(Scale::Quick);
        bad.axes =
            vec![Axis::single("align", Knob::AlignEvery, &["2"])];
        let err = bad.trials().unwrap_err();
        assert!(err.contains("sage update rule"), "{err}");
        // The aux-local h grid is untouched by the sage arm: same file
        // stems as before for fig_h, a separate one for the sage table.
        assert_eq!(h_sweep(Scale::Quick).table.file, "fig_h");
        assert_eq!(h_sage_sweep(Scale::Quick).table.file, "fig_h_sage");
    }
}
