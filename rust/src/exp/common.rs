//! Shared experiment harness: scales, dataset construction, engine
//! caching, cached runs, and report formatting.
//!
//! Every figure/table driver goes through [`run_cached`]: a run is keyed
//! by its full configuration and persisted as JSON under
//! `results/cache/`, so drivers that share runs (Fig. 4 / Fig. 9 /
//! Table V) never retrain, and interrupted sweeps resume for free.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::coordinator::config::{ArrivalOrder, Parallelism, ShardMapKind, TrainConfig};
use crate::coordinator::methods::MethodSpec;
use crate::coordinator::population::{ClientSource, PopulationSetup};
use crate::sched::SchedPolicy;
use crate::coordinator::round::{Trainer, TrainerSetup};
use crate::data::partition::{by_writer, dirichlet, equalize, iid, Partition};
use crate::data::synthetic::{train_test, SyntheticSpec};
use crate::data::{femnist, Dataset};
use crate::metrics::recorder::{RoundRecord, RunRecord};
use crate::model::layout::Layout;
use crate::runtime::artifact::Manifest;
use crate::runtime::mock::MockEngine;
use crate::runtime::pjrt::{PjrtEngine, PjrtRuntime};
use crate::runtime::SplitEngine;
use crate::sim::churn::ChurnConfig;
use crate::sim::netmodel::NetModel;
use crate::util::json::Json;
use crate::util::prng::Rng;

/// Results-cache schema/semantics version. Bumped whenever a recorded
/// metric changes meaning (v2: `shard_label_divergence` switched from
/// the unweighted to the client-weighted formula); [`run_from_json`]
/// rejects any other version so stale entries re-run deterministically.
pub const CACHE_VERSION: u32 = 2;

/// Client counts at or above this run on the streaming population
/// engine ([`Trainer::new_population`]) instead of materializing one
/// `ClientState` + data shard per client: memory stays flat in the
/// fleet size, at the cost of restricting the spec to the axes the
/// population engine supports (IID pool, aux-local update, shared
/// server, contiguous map, delay-ordered arrivals, mock backend).
pub const STREAM_THRESHOLD: usize = 4096;

/// Experiment fidelity preset.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// Seconds — CI smoke (tiny data, few rounds).
    Quick,
    /// Minutes — the default for `make figures`; trends visible.
    Ci,
    /// The paper's full setting (hours on this box; documented).
    Paper,
}

impl Scale {
    /// Parse `quick` (alias `smoke`) / `ci` / `paper`.
    pub fn parse(s: &str) -> Option<Scale> {
        match s {
            "quick" | "smoke" => Some(Scale::Quick),
            "ci" => Some(Scale::Ci),
            "paper" => Some(Scale::Paper),
            _ => None,
        }
    }
}

impl std::fmt::Display for Scale {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Scale::Quick => "quick",
            Scale::Ci => "ci",
            Scale::Paper => "paper",
        };
        write!(f, "{s}")
    }
}

/// Per-dataset workload sizes at a given scale.
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    /// Training samples per client.
    pub train_per_client: usize,
    /// Test-set size.
    pub test: usize,
    /// Communication rounds.
    pub rounds: usize,
    /// Independent seeds for mean ± std reporting.
    pub seeds: usize,
    /// Evaluate accuracy every k rounds (0 = only at the end).
    pub eval_every: usize,
    /// Cap periodic eval to k batches (0 = full test set).
    pub eval_max_batches: usize,
}

/// CIFAR-like workload sizes per [`Scale`].
pub fn cifar_workload(scale: Scale) -> Workload {
    match scale {
        Scale::Quick => Workload {
            train_per_client: 100,
            test: 100,
            rounds: 4,
            seeds: 1,
            eval_every: 2,
            eval_max_batches: 2,
        },
        Scale::Ci => Workload {
            train_per_client: 400,
            test: 400,
            rounds: 12,
            seeds: 1,
            eval_every: 3,
            eval_max_batches: 4,
        },
        Scale::Paper => Workload {
            train_per_client: 10_000,
            test: 10_000,
            rounds: 400,
            seeds: 5,
            eval_every: 10,
            eval_max_batches: 0,
        },
    }
}

/// F-EMNIST-like workload sizes per [`Scale`].
pub fn femnist_workload(scale: Scale) -> Workload {
    match scale {
        Scale::Quick => Workload {
            train_per_client: 60,
            test: 120,
            rounds: 8,
            seeds: 1,
            eval_every: 4,
            eval_max_batches: 6,
        },
        Scale::Ci => Workload {
            train_per_client: 200,
            test: 600,
            rounds: 220,
            seeds: 1,
            eval_every: 20,
            eval_max_batches: 20,
        },
        Scale::Paper => Workload {
            train_per_client: 240,
            test: 4_000,
            rounds: 4_000,
            seeds: 5,
            eval_every: 100,
            eval_max_batches: 0,
        },
    }
}

/// How a dataset is distributed over clients.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dist {
    /// Shuffle-and-split evenly (the paper's IID arms).
    Iid,
    /// Dirichlet label skew (CIFAR non-IID arm of Table V).
    NonIidDirichlet,
    /// Natural writer split (F-EMNIST non-IID).
    NonIidWriter,
}

impl Dist {
    /// Short cache-key / filename tag.
    pub fn tag(self) -> &'static str {
        match self {
            Dist::Iid => "iid",
            Dist::NonIidDirichlet => "dir",
            Dist::NonIidWriter => "writer",
        }
    }

    /// Parse a distribution name — the one home of `--dist` alias
    /// handling (tags round-trip: `Dist::parse(d.tag()) == Some(d)`).
    pub fn parse(s: &str) -> Option<Dist> {
        match s.to_ascii_lowercase().as_str() {
            "iid" => Some(Dist::Iid),
            "dir" | "dirichlet" => Some(Dist::NonIidDirichlet),
            "writer" | "by-writer" => Some(Dist::NonIidWriter),
            _ => None,
        }
    }
}

/// One fully-specified run (the cache key).
#[derive(Clone, Debug)]
pub struct RunSpec {
    /// Dataset name: `"cifar"` | `"femnist"`.
    pub dataset: String,
    /// Auxiliary architecture name (manifest key).
    pub aux: String,
    /// The algorithm point to run: client-update rule × upload schedule
    /// × server topology. The paper's methods are presets
    /// (`Method::spec()`, e.g. `Method::CseFsl.spec().with_period(5)`);
    /// any other spec point runs through the same harness. Every axis
    /// changes results, so the whole spec joins [`RunSpec::key`] — with
    /// the four presets keeping their historical key strings for cache
    /// compatibility ([`MethodSpec::tag`]).
    pub method: MethodSpec,
    /// Number of federated clients.
    pub n_clients: usize,
    /// Clients sampled per round (0 = all).
    pub participation: usize,
    /// How data is distributed over clients.
    pub dist: Dist,
    /// Server consumption order of arriving uploads.
    pub arrival: ArrivalOrder,
    /// Initial learning rate.
    pub lr0: f64,
    /// Experiment seed.
    pub seed: u64,
    /// Workload sizes (rounds, dataset sizes, eval cadence).
    pub workload: Workload,
    /// Client fan-out strategy. Deliberately NOT part of the cache key:
    /// the parallel round engine is bit-deterministic (see
    /// coordinator/README.md), so sequential and threaded runs of the
    /// same spec share one cached RunRecord.
    pub parallelism: Parallelism,
    /// Server shard count k (single-copy methods). Unlike `parallelism`
    /// this **changes results** — k shard copies train on disjoint
    /// client groups between aggregations — so by the Harness contract
    /// it MUST be part of the cache key.
    pub server_shards: usize,
    /// Fan-out dealing policy. Deliberately NOT part of the cache key:
    /// like `parallelism`, every policy produces bit-identical results
    /// (the determinism contract), so all policies share one cached
    /// `RunRecord`.
    pub sched: SchedPolicy,
    /// Client → shard assignment flavor. `Balanced` regroups clients
    /// across shard copies, which **changes results** — so, like
    /// `server_shards`, it is part of the cache key and of run labels.
    pub shard_map: ShardMapKind,
    /// Churn & resilience knobs (availability model, mid-round failure
    /// rate, partial-aggregation policy). Every non-default knob
    /// changes results, so the whole config joins [`RunSpec::key`] via
    /// [`ChurnConfig::key_suffix`] — which is empty at the default, so
    /// every pre-churn cache key (and the pinned preset strings) stays
    /// byte-identical.
    pub churn: ChurnConfig,
}

impl RunSpec {
    /// The results-cache key: every field that can change the run's
    /// outcome, and nothing else (`parallelism` is excluded by the
    /// bit-determinism contract). The method segment is
    /// [`MethodSpec::tag`]: the historical preset name for the four
    /// paper methods (their key strings are **unchanged** across the
    /// spec refactor — cached preset records replay), a canonical
    /// `update+upload+topology` tag for spec-only points. The `h{}`
    /// segment is the upload period hint (redundant with the tag for
    /// custom specs, load-bearing for the preset strings).
    pub fn key(&self) -> String {
        let arr = match self.arrival {
            ArrivalOrder::ByDelay => "delay",
            ArrivalOrder::ClientIndex => "index",
            ArrivalOrder::Shuffled => "shuf",
        };
        format!(
            "{}-{}-{}-h{}-n{}-p{}-{}-{}-lr{}-r{}-d{}-t{}-k{}-m{}-s{}",
            self.dataset,
            self.aux,
            self.method.tag(),
            self.method.h_hint(),
            self.n_clients,
            self.participation,
            self.dist.tag(),
            arr,
            self.lr0,
            self.workload.rounds,
            self.workload.train_per_client,
            self.workload.test,
            self.server_shards,
            self.shard_map.tag(),
            self.seed
        ) + &self.churn.key_suffix()
    }

    /// Human-readable series label ([`MethodSpec::label`] — historical
    /// preset labels, canonical tags for spec-only points — plus the
    /// shard count when sharded and the map tag for non-default maps).
    pub fn label(&self) -> String {
        let mut l = self.method.label();
        if self.server_shards > 1 {
            l.push_str(&format!(" k={}", self.server_shards));
        }
        if self.shard_map != ShardMapKind::Contiguous {
            l.push_str(&format!(" {}", self.shard_map.tag()));
        }
        l.push_str(&self.churn.label_suffix());
        l
    }

    /// Spec-level validation for knobs `TrainConfig::validate` cannot
    /// see: axis coherence of the method spec (so incoherent specs fail
    /// before the cache is touched), and the locality shard map's
    /// non-IID requirement — locality clusters clients by label
    /// distribution, which is meaningless under IID data (every
    /// client's histogram already matches the global one). Checked by
    /// [`Harness::run_cached`] before anything runs (or is read from
    /// cache).
    pub fn validate(&self) -> Result<(), String> {
        self.method.validate()?;
        if self.shard_map == ShardMapKind::Locality && self.dist == Dist::Iid {
            return Err(
                "--shard-map locality requires a non-IID partition (--dist dir | writer): \
                 under IID data every client sees the global label mix already, so there \
                 is no locality to exploit"
                    .into(),
            );
        }
        Ok(())
    }
}

/// Which compute backend the [`Harness`] drives.
///
/// The backend changes results, so the two backends never share cached
/// records: mock runs are cached under `cache/mock/`, PJRT runs under
/// `cache/` (the historical location).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineChoice {
    /// Use the real PJRT engine when the AOT artifacts and runtime are
    /// available, otherwise fall back to the deterministic mock engine
    /// (with a note on stderr).
    Auto,
    /// Require the real PJRT engine; error out when unavailable.
    Pjrt,
    /// Force the deterministic linear-dynamics mock engine — no
    /// artifacts or Python toolchain needed, bit-reproducible runs.
    Mock,
}

impl EngineChoice {
    /// Parse `auto` / `pjrt` / `mock`.
    pub fn parse(s: &str) -> Option<EngineChoice> {
        match s {
            "auto" => Some(EngineChoice::Auto),
            "pjrt" => Some(EngineChoice::Pjrt),
            "mock" => Some(EngineChoice::Mock),
            _ => None,
        }
    }
}

/// FNV-1a over a string — stable seeds for per-(dataset, aux) mock
/// engines, and content digests for the sweep journal's cached-record
/// verification ([`super::sweep`]).
pub(crate) fn fnv64(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Engine + manifest cache shared by all drivers in one process.
pub struct Harness {
    /// The AOT artifact manifest (`None` on the mock backend).
    pub manifest: Option<Manifest>,
    /// The shared PJRT runtime (`None` on the mock backend).
    pub rt: Option<Arc<PjrtRuntime>>,
    engines: BTreeMap<(String, String), Arc<PjrtEngine>>,
    mocks: BTreeMap<(String, String), Arc<MockEngine>>,
    /// Output directory (tables, CSVs, and the `cache/` subdirectory).
    pub out_dir: PathBuf,
}

impl Harness {
    /// [`Harness::with_engine`] at [`EngineChoice::Auto`]: PJRT when the
    /// artifacts are present, the mock engine otherwise.
    pub fn new(out_dir: impl AsRef<Path>) -> Result<Self, String> {
        Harness::with_engine(out_dir, EngineChoice::Auto)
    }

    /// Resolve the compute backend, load the manifest + PJRT runtime
    /// when applicable, and prepare `out_dir` (including the
    /// backend-separated cache directories).
    pub fn with_engine(
        out_dir: impl AsRef<Path>,
        choice: EngineChoice,
    ) -> Result<Self, String> {
        let pjrt = if choice == EngineChoice::Mock {
            None
        } else {
            let dir = crate::runtime::artifacts_dir();
            let loaded = Manifest::load(&dir).map_err(|e| e.to_string()).and_then(|m| {
                PjrtRuntime::new().map(|rt| (m, rt)).map_err(|e| e.to_string())
            });
            match loaded {
                Ok(pair) => Some(pair),
                Err(e) => {
                    if choice == EngineChoice::Pjrt {
                        return Err(format!("{e}\nhint: run `make artifacts` first"));
                    }
                    eprintln!(
                        "note: PJRT backend unavailable ({e}); falling back to the \
                         deterministic mock engine (results cached under cache/mock/). \
                         Pass --engine pjrt to make this an error."
                    );
                    None
                }
            }
        };
        let (manifest, rt) = match pjrt {
            Some((m, rt)) => (Some(m), Some(rt)),
            None => (None, None),
        };
        std::fs::create_dir_all(out_dir.as_ref().join("cache").join("mock"))
            .map_err(|e| e.to_string())?;
        Ok(Harness {
            manifest,
            rt,
            engines: BTreeMap::new(),
            mocks: BTreeMap::new(),
            out_dir: out_dir.as_ref().to_path_buf(),
        })
    }

    /// Whether runs execute on the mock backend.
    pub fn mock_mode(&self) -> bool {
        self.manifest.is_none()
    }

    /// Short backend name for reports: `"pjrt"` or `"mock"`.
    pub fn backend(&self) -> &'static str {
        if self.mock_mode() {
            "mock"
        } else {
            "pjrt"
        }
    }

    /// The AOT manifest, or a clear error on the mock backend (the
    /// closed-form table drivers need the real layout sizes).
    pub fn manifest(&self) -> Result<&Manifest, String> {
        self.manifest.as_ref().ok_or_else(|| {
            "this command needs the AOT artifact manifest: run `make artifacts` \
             and retry (the mock backend has no real layouts)"
                .to_string()
        })
    }

    /// The (cached) PJRT engine for one (dataset, aux) configuration.
    pub fn engine(&mut self, dataset: &str, aux: &str) -> Result<Arc<PjrtEngine>, String> {
        let key = (dataset.to_string(), aux.to_string());
        if let Some(e) = self.engines.get(&key) {
            return Ok(e.clone());
        }
        let (manifest, rt) = match (&self.manifest, &self.rt) {
            (Some(m), Some(rt)) => (m, rt.clone()),
            _ => return Err("no PJRT backend (mock mode); use mock_engine".into()),
        };
        let e = Arc::new(
            PjrtEngine::new(rt, manifest, dataset, aux).map_err(|e| e.to_string())?,
        );
        self.engines.insert(key, e.clone());
        Ok(e)
    }

    /// The (cached) mock engine for one (dataset, aux) configuration:
    /// geometry matches the dataset (input length, class count), target
    /// dynamics are seeded from the (dataset, aux) names so different
    /// aux arms train visibly differently — a deterministic stand-in
    /// for the real engines. Model-part sizes are fixed (every aux arch
    /// gets the same small aux network), so the aux-parameter *axis* of
    /// the architecture sweeps (figs. 7/8) is degenerate on this
    /// backend; [`Harness::aux_params`] reports the true (constant)
    /// mock size rather than inventing per-arch numbers.
    pub fn mock_engine(&mut self, dataset: &str, aux: &str) -> Result<Arc<MockEngine>, String> {
        let key = (dataset.to_string(), aux.to_string());
        if let Some(e) = self.mocks.get(&key) {
            return Ok(e.clone());
        }
        let (input_len, classes) = match dataset {
            "cifar" => (32 * 32 * 3, 10),
            "femnist" => (femnist::SIDE * femnist::SIDE, femnist::CLASSES),
            other => return Err(format!("unknown dataset {other}")),
        };
        let seed = 0xC5EF5C ^ fnv64(dataset) ^ fnv64(aux).rotate_left(17);
        let e = Arc::new(MockEngine::new(20, classes, input_len, 32, 96, 24, 64, seed));
        self.mocks.insert(key, e.clone());
        Ok(e)
    }

    /// Parameter count of one auxiliary architecture: manifest-backed on
    /// the PJRT backend, the mock engine's fixed aux size otherwise.
    pub fn aux_params(&mut self, dataset: &str, aux: &str) -> Result<usize, String> {
        if let Some(m) = &self.manifest {
            return Ok(m
                .config(dataset)
                .map_err(|e| e.to_string())?
                .aux(aux)
                .map_err(|e| e.to_string())?
                .size);
        }
        Ok(self.mock_engine(dataset, aux)?.aux_size())
    }

    /// Build train/test datasets + partition for a spec (deterministic in
    /// the spec seed).
    pub fn data(&self, spec: &RunSpec) -> (Dataset, Dataset, Partition) {
        let w = &spec.workload;
        let data_seed = 10_000 + spec.seed;
        match spec.dataset.as_str() {
            "cifar" => {
                let total = w.train_per_client * spec.n_clients;
                let (train, test) =
                    train_test(&SyntheticSpec::cifar_like(), total, w.test, data_seed);
                let mut rng = Rng::new(data_seed ^ 0x77);
                let mut part = match spec.dist {
                    Dist::Iid => iid(&train, spec.n_clients, &mut rng),
                    Dist::NonIidDirichlet => {
                        let mut p = dirichlet(&train, spec.n_clients, 0.3, &mut rng);
                        equalize(&mut p);
                        p
                    }
                    Dist::NonIidWriter => {
                        panic!("writer split is a femnist concept")
                    }
                };
                equalize(&mut part);
                (train, test, part)
            }
            "femnist" => {
                // writers sized to give each client ~train_per_client.
                let spw = 40usize;
                let writers =
                    (w.train_per_client * spec.n_clients / spw).max(spec.n_clients);
                let fs = femnist::FemnistSpec {
                    writers,
                    samples_per_writer: spw,
                    ..femnist::FemnistSpec::default_like()
                };
                // Train/test share the glyph alphabet; test uses unseen
                // writers (writer split) or fresh styles (IID).
                let test_writers = (w.test / spw).max(1);
                let (train, test) = match spec.dist {
                    Dist::NonIidWriter => femnist::train_test(&fs, test_writers, data_seed),
                    _ => femnist::train_test_iid(&fs, w.test, data_seed),
                };
                let mut rng = Rng::new(data_seed ^ 0x99);
                let mut part = match spec.dist {
                    Dist::NonIidWriter => by_writer(&train, spec.n_clients, &mut rng),
                    _ => iid(&train, spec.n_clients, &mut rng),
                };
                equalize(&mut part);
                (train, test, part)
            }
            other => panic!("unknown dataset {other}"),
        }
    }

    /// Cache file of one spec — backend-separated, since the backend
    /// changes results (the `RunSpec::key` contract, applied one level
    /// up: the two backends never share a cache namespace).
    fn cache_path(&self, spec: &RunSpec) -> PathBuf {
        let dir = self.out_dir.join("cache");
        let dir = if self.mock_mode() { dir.join("mock") } else { dir };
        dir.join(format!("{}.json", spec.key()))
    }

    /// Public accessor for the cache file of one spec (the path
    /// [`Harness::run_cached`] reads and writes). The sweep journal
    /// records this path, relative to [`Harness::out_dir`], as a
    /// trial's durable output location.
    pub fn cache_file(&self, spec: &RunSpec) -> PathBuf {
        self.cache_path(spec)
    }

    /// Run (or load from cache) one spec on the resolved backend.
    pub fn run_cached(&mut self, spec: &RunSpec) -> Result<RunRecord, String> {
        spec.validate()?;
        let cache = self.cache_path(spec);
        if let Ok(text) = std::fs::read_to_string(&cache) {
            if let Ok(rec) = run_from_json(&text) {
                return Ok(rec);
            }
        }
        if spec.n_clients >= STREAM_THRESHOLD {
            let rec = self.run_streaming(spec)?;
            let _ = std::fs::write(&cache, run_to_json(&rec).pretty());
            return Ok(rec);
        }
        let (train, test, partition) = self.data(spec);
        let rec = if self.mock_mode() {
            let engine = self.mock_engine(&spec.dataset, &spec.aux)?;
            execute_spec(engine.as_ref(), spec, &train, &test, partition, None, None, None)?
        } else {
            let engine = self.engine(&spec.dataset, &spec.aux)?;
            let ds_cfg =
                self.manifest()?.config(&spec.dataset).map_err(|e| e.to_string())?;
            let aux_cfg = ds_cfg.aux(&spec.aux).map_err(|e| e.to_string())?;
            execute_spec(
                engine.as_ref(),
                spec,
                &train,
                &test,
                partition,
                Some(&ds_cfg.client_layout),
                Some(&ds_cfg.server_layout),
                Some(&aux_cfg.layout),
            )?
        };
        let _ = std::fs::write(&cache, run_to_json(&rec).pretty());
        Ok(rec)
    }

    /// Run one fleet-scale spec (`n_clients >= STREAM_THRESHOLD`) on
    /// the streaming population engine. Never materializes per-client
    /// data or state up front: clients draw cyclic windows from a
    /// small shared sample pool and are built lazily on activation, so
    /// memory is flat in the fleet size.
    fn run_streaming(&mut self, spec: &RunSpec) -> Result<RunRecord, String> {
        if !self.mock_mode() {
            return Err(format!(
                "{} clients is a streaming run (>= {STREAM_THRESHOLD}) and needs the \
                 mock backend: population runs carry no device layouts",
                spec.n_clients
            ));
        }
        if spec.dist != Dist::Iid {
            return Err(format!(
                "streaming runs draw IID pool shards; {} is not supported at \
                 fleet scale",
                spec.dist.tag()
            ));
        }
        let w = &spec.workload;
        let (train, test) = self.pool_data(spec);
        let source = ClientSource::Pool {
            n_clients: spec.n_clients,
            samples_per_client: w.train_per_client,
            pool_len: train.len(),
        };
        // An all-participate round is O(n) work per round; the resident
        // semantics of participation 0 ("everyone") auto-cap to a
        // fixed cohort at fleet scale.
        let participation = if spec.participation == 0 {
            spec.n_clients.min(1024)
        } else {
            spec.participation
        };
        let engine = self.mock_engine(&spec.dataset, &spec.aux)?;
        let cfg = build_config(spec, engine.batch(), participation);
        let setup = PopulationSetup::new(
            &train,
            &test,
            source,
            NetModel::edge_default(),
            spec.label(),
        );
        let mut trainer = Trainer::new_population(engine.as_ref(), cfg, setup)?;
        trainer.run().map_err(|e| e.to_string())
    }

    /// Train pool + test set for a streaming run: a shared sample pool
    /// sized for at most 64 disjoint client windows (beyond that,
    /// windows cycle the pool — statistically fine for IID draws, and
    /// O(1) in the fleet size) instead of `train_per_client *
    /// n_clients` materialized samples.
    fn pool_data(&self, spec: &RunSpec) -> (Dataset, Dataset) {
        let w = &spec.workload;
        let data_seed = 10_000 + spec.seed;
        let pool = w.train_per_client * spec.n_clients.min(64);
        match spec.dataset.as_str() {
            "cifar" => train_test(&SyntheticSpec::cifar_like(), pool, w.test, data_seed),
            "femnist" => {
                let spw = 40usize;
                let fs = femnist::FemnistSpec {
                    writers: (pool / spw).max(1),
                    samples_per_writer: spw,
                    ..femnist::FemnistSpec::default_like()
                };
                femnist::train_test_iid(&fs, w.test, data_seed)
            }
            other => panic!("unknown dataset {other}"),
        }
    }
}

/// Build the `TrainConfig` + `TrainerSetup` for one spec and run it over
/// any [`SplitEngine`] (PJRT or mock — the backends share every line of
/// driver logic, only layouts and the engine differ).
#[allow(clippy::too_many_arguments)]
fn execute_spec<E: SplitEngine>(
    engine: &E,
    spec: &RunSpec,
    train: &Dataset,
    test: &Dataset,
    partition: Partition,
    client_layout: Option<&Layout>,
    server_layout: Option<&Layout>,
    aux_layout: Option<&Layout>,
) -> Result<RunRecord, String> {
    let cfg = build_config(spec, engine.batch(), spec.participation);
    let setup = TrainerSetup {
        train,
        test,
        partition,
        net: NetModel::edge_default(),
        client_layout,
        server_layout,
        aux_layout,
        label: spec.label(),
    };
    let mut trainer = Trainer::new(engine, cfg, setup)?;
    trainer.run().map_err(|e| e.to_string())
}

/// The `TrainConfig` for one spec — shared by the resident and the
/// streaming engines (same driver knobs, only the client-state
/// lifecycle differs).
fn build_config(spec: &RunSpec, engine_batch: usize, participation: usize) -> TrainConfig {
    let w = &spec.workload;
    // Aggregate once per local epoch (paper setting): epoch =
    // batches_per_epoch local batches = bpe/h rounds (the upload
    // schedule's static period hint; adaptive schedules use h0).
    let bpe = (w.train_per_client / engine_batch).max(1);
    let agg_every = (bpe / spec.method.h_hint()).max(1);
    TrainConfig {
        spec: spec.method,
        rounds: w.rounds,
        agg_every,
        lr0: spec.lr0,
        lr_decay_rate: 0.99,
        lr_decay_every: 10,
        server_lr_scale: 0.25,
        participation,
        seed: spec.seed,
        eval_every: w.eval_every,
        eval_max_batches: w.eval_max_batches,
        arrival: spec.arrival,
        track_grad_norms: true,
        parallelism: spec.parallelism,
        server_shards: spec.server_shards,
        sched: spec.sched,
        shard_map: spec.shard_map,
        churn: spec.churn,
    }
}

// ------------------------------------------------ RunRecord <-> JSON

/// Serialize a [`RunRecord`] for the results cache.
pub fn run_to_json(r: &RunRecord) -> Json {
    let rounds = r
        .rounds
        .iter()
        .map(|x| {
            Json::obj(vec![
                ("round", Json::num(x.round as f64)),
                ("sim_time", Json::num(x.sim_time)),
                ("lr", Json::num(x.lr)),
                ("train_loss", Json::num(x.train_loss)),
                ("server_loss", Json::num(x.server_loss)),
                ("up_bytes", Json::num(x.up_bytes as f64)),
                ("down_bytes", Json::num(x.down_bytes as f64)),
                (
                    "accuracy",
                    x.accuracy.map(Json::num).unwrap_or(Json::Null),
                ),
                (
                    "client_grad_norm",
                    x.client_grad_norm.map(Json::num).unwrap_or(Json::Null),
                ),
                (
                    "server_grad_norm",
                    x.server_grad_norm.map(Json::num).unwrap_or(Json::Null),
                ),
            ])
        })
        .collect();
    Json::obj(vec![
        // Bump when a recorded metric changes meaning (not just shape):
        // v2 switched `shard_label_divergence` from the unweighted to
        // the client-weighted formula, so v1 records must re-run.
        ("cache_version", Json::num(CACHE_VERSION as f64)),
        ("label", Json::str(r.label.clone())),
        ("rounds", Json::Arr(rounds)),
        ("final_accuracy", Json::num(r.final_accuracy)),
        ("total_up_bytes", Json::num(r.total_up_bytes as f64)),
        ("total_down_bytes", Json::num(r.total_down_bytes as f64)),
        ("sim_time", Json::num(r.sim_time)),
        ("server_idle_fraction", Json::num(r.server_idle_fraction)),
        ("critical_path", Json::num(r.critical_path)),
        (
            "lane_busy",
            Json::Arr(r.lane_busy.iter().map(|&b| Json::num(b)).collect()),
        ),
        ("server_storage_params", Json::num(r.server_storage_params as f64)),
        (
            "server_updates_per_shard",
            Json::Arr(
                r.server_updates_per_shard.iter().map(|&u| Json::num(u as f64)).collect(),
            ),
        ),
        ("shard_label_divergence", Json::num(r.shard_label_divergence)),
        ("clients_activated", Json::num(r.clients_activated as f64)),
        ("clients_dropped", Json::num(r.clients_dropped as f64)),
        ("clients_replaced", Json::num(r.clients_replaced as f64)),
        ("partial_failures", Json::num(r.partial_failures as f64)),
        ("stragglers_dropped", Json::num(r.stragglers_dropped as f64)),
    ])
}

/// Parse a cached [`RunRecord`] back from JSON.
pub fn run_from_json(text: &str) -> Result<RunRecord, String> {
    let j = Json::parse(text).map_err(|e| e.to_string())?;
    let err = |e: crate::util::json::JsonError| e.to_string();
    // Version gate first: entries written before the weighted
    // `shard_label_divergence` switch (no version field, or an older
    // one) recorded a metric with a different meaning, so they must
    // fall through to a deterministic re-run rather than replay.
    let version = match j.opt("cache_version") {
        Some(v) => v.as_f64().map_err(err)? as u32,
        None => 0,
    };
    if version != CACHE_VERSION {
        return Err(format!(
            "cache_version {version} != {CACHE_VERSION}: stale entry, re-run"
        ));
    }
    let mut rounds = Vec::new();
    for rj in j.get("rounds").map_err(err)?.as_arr().map_err(err)? {
        let opt = |k: &str| rj.opt(k).and_then(|v| v.as_f64().ok());
        rounds.push(RoundRecord {
            round: rj.get("round").map_err(err)?.as_usize().map_err(err)?,
            sim_time: rj.get("sim_time").map_err(err)?.as_f64().map_err(err)?,
            lr: rj.get("lr").map_err(err)?.as_f64().map_err(err)?,
            train_loss: rj.get("train_loss").map_err(err)?.as_f64().map_err(err)?,
            server_loss: rj.get("server_loss").map_err(err)?.as_f64().map_err(err)?,
            up_bytes: rj.get("up_bytes").map_err(err)?.as_f64().map_err(err)? as u64,
            down_bytes: rj.get("down_bytes").map_err(err)?.as_f64().map_err(err)? as u64,
            accuracy: opt("accuracy"),
            client_grad_norm: opt("client_grad_norm"),
            server_grad_norm: opt("server_grad_norm"),
        });
    }
    Ok(RunRecord {
        label: j.get("label").map_err(err)?.as_str().map_err(err)?.to_string(),
        rounds,
        final_accuracy: j.get("final_accuracy").map_err(err)?.as_f64().map_err(err)?,
        total_up_bytes: j.get("total_up_bytes").map_err(err)?.as_f64().map_err(err)? as u64,
        total_down_bytes: j.get("total_down_bytes").map_err(err)?.as_f64().map_err(err)?
            as u64,
        sim_time: j.get("sim_time").map_err(err)?.as_f64().map_err(err)?,
        server_idle_fraction: j
            .get("server_idle_fraction")
            .map_err(err)?
            .as_f64()
            .map_err(err)?,
        // Absent in pre-scheduling cache entries; default to "unknown"
        // (but a present-yet-malformed value is an error, like every
        // other field, so corrupt cache entries fall through to a re-run).
        critical_path: match j.opt("critical_path") {
            Some(v) => v.as_f64().map_err(err)?,
            None => 0.0,
        },
        lane_busy: match j.opt("lane_busy") {
            Some(v) => v
                .as_arr()
                .map_err(err)?
                .iter()
                .map(|x| x.as_f64())
                .collect::<Result<_, _>>()
                .map_err(err)?,
            None => Vec::new(),
        },
        server_storage_params: j
            .get("server_storage_params")
            .map_err(err)?
            .as_f64()
            .map_err(err)? as usize,
        // Absent in pre-shard cache entries; default to "unknown".
        server_updates_per_shard: match j.opt("server_updates_per_shard") {
            Some(v) => v
                .as_arr()
                .map_err(err)?
                .iter()
                .map(|x| x.as_f64().map(|f| f as u64))
                .collect::<Result<_, _>>()
                .map_err(err)?,
            None => Vec::new(),
        },
        // Absent in pre-locality cache entries — treated as corrupt so
        // the entry falls through to a (deterministic) re-run. Unlike
        // the observability-only fields above, this metric feeds the
        // fig_staleness placement comparison: defaulting it to 0 would
        // report the best possible placement score for records that
        // never measured it.
        shard_label_divergence: j
            .get("shard_label_divergence")
            .map_err(err)?
            .as_f64()
            .map_err(err)?,
        clients_activated: j
            .get("clients_activated")
            .map_err(err)?
            .as_f64()
            .map_err(err)? as usize,
        // Churn counters: absent in pre-churn v2 entries, where their
        // true value IS 0 (no churn subsystem existed, so nothing was
        // ever dropped) — lenient defaults are exact here, not guesses.
        // Present-yet-malformed values still error like every field.
        clients_dropped: lenient_u64(&j, "clients_dropped").map_err(err)?,
        clients_replaced: lenient_u64(&j, "clients_replaced").map_err(err)?,
        partial_failures: lenient_u64(&j, "partial_failures").map_err(err)?,
        stragglers_dropped: lenient_u64(&j, "stragglers_dropped").map_err(err)?,
    })
}

/// Absent-means-zero u64 field parse (a present-yet-malformed value is
/// still an error): the churn counters of [`run_from_json`].
fn lenient_u64(j: &Json, field: &str) -> Result<u64, crate::util::json::JsonError> {
    match j.opt(field) {
        Some(v) => v.as_f64().map(|f| f as u64),
        None => Ok(0),
    }
}

/// Render several accuracy-vs-round curves side by side.
pub fn curve_table(title: &str, runs: &[&RunRecord]) -> String {
    let mut out = format!("== {title} ==\n");
    out.push_str(&format!("{:<8}", "round"));
    for r in runs {
        out.push_str(&format!("{:>16}", truncate(&r.label, 15)));
    }
    out.push('\n');
    // union of eval rounds from the first run's grid
    let grid: Vec<usize> = runs
        .first()
        .map(|r| r.accuracy_curve().iter().map(|&(x, _)| x).collect())
        .unwrap_or_default();
    for &round in &grid {
        out.push_str(&format!("{round:<8}"));
        for r in runs {
            let v = r
                .accuracy_curve()
                .iter()
                .find(|&&(x, _)| x == round)
                .map(|&(_, a)| format!("{:.1}%", a * 100.0))
                .unwrap_or_else(|| "-".into());
            out.push_str(&format!("{v:>16}"));
        }
        out.push('\n');
    }
    out.push_str(&format!("{:<8}", "final"));
    for r in runs {
        out.push_str(&format!("{:>16}", format!("{:.1}%", r.final_accuracy * 100.0)));
    }
    out.push('\n');
    out
}

fn truncate(s: &str, n: usize) -> String {
    if s.len() <= n {
        s.to_string()
    } else {
        format!("{}…", &s[..n - 1])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::methods::Method;

    #[test]
    fn scale_parse() {
        assert_eq!(Scale::parse("ci"), Some(Scale::Ci));
        assert_eq!(Scale::parse("smoke"), Some(Scale::Quick), "smoke aliases quick (CI job)");
        assert_eq!(Scale::parse("nope"), None);
        assert_eq!(Scale::Paper.to_string(), "paper");
    }

    #[test]
    fn dist_parse_roundtrips_tags() {
        for d in [Dist::Iid, Dist::NonIidDirichlet, Dist::NonIidWriter] {
            assert_eq!(Dist::parse(d.tag()), Some(d), "{d:?}");
        }
        assert_eq!(Dist::parse("dirichlet"), Some(Dist::NonIidDirichlet));
        assert_eq!(Dist::parse("by-writer"), Some(Dist::NonIidWriter));
        assert_eq!(Dist::parse("pareto"), None);
    }

    #[test]
    fn align_every_flag_composes_only_with_the_sage_update_rule() {
        use crate::coordinator::methods::MethodSpec;
        let cli = |method: &str, update: Option<&str>, align: Option<&str>| {
            MethodSpec::from_cli(method, update, None, None, align, None, None, None, None)
        };
        // --align-every without --update sage is a rejection, whether
        // the update axis is defaulted by the preset or set explicitly.
        for update in [None, Some("grad"), Some("aux")] {
            let err = cli("cse", update, Some("4")).unwrap_err();
            assert!(err.contains("--update sage"), "{update:?}: {err}");
        }
        // --align-every 0 parses as an integer but fails spec
        // validation (the period is 1-based).
        let err = cli("cse", Some("sage"), Some("0")).unwrap_err();
        assert!(err.contains(">= 1"), "{err}");
        // Non-integers are rejected at the flag.
        let err = cli("cse", Some("sage"), Some("x")).unwrap_err();
        assert!(err.contains("align-every"), "{err}");
        // The happy path resolves: default period 4, explicit periods
        // override it.
        let spec = cli("cse", Some("sage"), None).unwrap();
        assert_eq!(
            spec.update,
            crate::coordinator::methods::ClientUpdate::SageEstimate {
                align_every: 4,
                clip: 0.0
            }
        );
        let spec = cli("cse", Some("sage"), Some("8")).unwrap();
        assert_eq!(spec.tag(), "sage8+b+sh");
    }

    #[test]
    fn client_update_aliases_roundtrip_like_dist_parse() {
        use crate::coordinator::methods::ClientUpdate;
        // The new sage aliases round-trip through FromStr with the same
        // normalization contract as `Dist::parse`: ASCII-lowercased,
        // `_` mapped to `-`, anything else rejected.
        let sage = ClientUpdate::SageEstimate { align_every: 4, clip: 0.0 };
        for alias in ["sage", "SAGE", "Sage-Estimate", "sage_estimate", "estimator"] {
            assert_eq!(alias.parse::<ClientUpdate>(), Ok(sage), "{alias}");
        }
        for alias in ["aux", "AUX", "aux_local", "local"] {
            assert_eq!(alias.parse::<ClientUpdate>(), Ok(ClientUpdate::AuxLocal), "{alias}");
        }
        for alias in ["grad", "SERVER_GRAD", "sg"] {
            assert_eq!(
                alias.parse::<ClientUpdate>(),
                Ok(ClientUpdate::ServerGrad { clip: 0.0 }),
                "{alias}"
            );
        }
        // Tag strings are cache-key segments, not CLI aliases: they must
        // NOT parse (exactly like `Dist::parse("dir")` vs "dirichlet"
        // being the only spellings — no accidental alias space).
        for not_alias in ["sage4", "sage-4", "estimate", "sage "] {
            assert!(
                not_alias.parse::<ClientUpdate>().is_err(),
                "{not_alias:?} must not parse"
            );
        }
    }

    #[test]
    fn engine_choice_parse() {
        assert_eq!(EngineChoice::parse("auto"), Some(EngineChoice::Auto));
        assert_eq!(EngineChoice::parse("pjrt"), Some(EngineChoice::Pjrt));
        assert_eq!(EngineChoice::parse("mock"), Some(EngineChoice::Mock));
        assert_eq!(EngineChoice::parse("cuda"), None);
    }

    #[test]
    fn locality_spec_requires_non_iid() {
        let mut spec = RunSpec {
            dataset: "cifar".into(),
            aux: "cnn27".into(),
            method: Method::CseFsl.spec().with_period(5),
            n_clients: 8,
            participation: 0,
            dist: Dist::Iid,
            arrival: ArrivalOrder::ByDelay,
            lr0: 0.05,
            seed: 1,
            workload: cifar_workload(Scale::Quick),
            parallelism: Parallelism::Sequential,
            server_shards: 2,
            sched: SchedPolicy::RoundRobin,
            shard_map: ShardMapKind::Locality,
            churn: ChurnConfig::default(),
        };
        let err = spec.validate().unwrap_err();
        assert!(err.contains("non-IID"), "{err}");
        assert!(err.contains("locality"), "{err}");
        // Any non-IID distribution satisfies the requirement...
        for dist in [Dist::NonIidDirichlet, Dist::NonIidWriter] {
            spec.dist = dist;
            assert!(spec.validate().is_ok(), "{dist:?}");
        }
        // ...and the other maps never trip it.
        for map in [ShardMapKind::Contiguous, ShardMapKind::Balanced] {
            spec.shard_map = map;
            spec.dist = Dist::Iid;
            assert!(spec.validate().is_ok(), "{map:?}");
        }
    }

    #[test]
    fn mock_harness_runs_locality_end_to_end() {
        // The mock backend makes the full figure pipeline runnable with
        // no artifacts: spec → engine → trainer → cached RunRecord. This
        // is the end-to-end path for `--shard-map locality` on a real
        // non-IID split (by-writer: every client holds whole writers,
        // so no client is ever empty).
        let dir = std::env::temp_dir().join(format!(
            "cse_fsl_mock_harness_{}_{}",
            std::process::id(),
            line!()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let mut h = Harness::with_engine(&dir, EngineChoice::Mock).unwrap();
        assert!(h.mock_mode());
        assert_eq!(h.backend(), "mock");
        assert!(h.manifest().is_err(), "mock mode must not fake a manifest");
        let mut wl = femnist_workload(Scale::Quick);
        wl.rounds = 3;
        let spec = RunSpec {
            dataset: "femnist".into(),
            aux: "cnn8".into(),
            method: Method::CseFsl.spec().with_period(2),
            n_clients: 6,
            participation: 0,
            dist: Dist::NonIidWriter,
            arrival: ArrivalOrder::ByDelay,
            lr0: 0.05,
            seed: 1,
            workload: wl,
            parallelism: Parallelism::Sequential,
            server_shards: 2,
            sched: SchedPolicy::RoundRobin,
            shard_map: ShardMapKind::Locality,
            churn: ChurnConfig::default(),
        };
        let loc = h.run_cached(&spec).unwrap();
        assert_eq!(loc.rounds.len(), 3);
        assert!(loc.label.contains("loc"), "{}", loc.label);
        // The skew metric is live and well-formed (the strict
        // locality-vs-balanced ordering is pinned on a crafted partition
        // in tests/determinism_golden.rs, where it is provable).
        assert!(
            (0.0..=1.0).contains(&loc.shard_label_divergence),
            "{}",
            loc.shard_label_divergence
        );
        // Cached under the mock namespace, and the cache replays.
        assert!(dir.join("cache").join("mock").join(format!("{}.json", spec.key())).is_file());
        let replay = h.run_cached(&spec).unwrap();
        assert_eq!(run_to_json(&loc).pretty(), run_to_json(&replay).pretty());
        // The balanced map on the same spec is a distinct cached run.
        let bal = h
            .run_cached(&RunSpec { shard_map: ShardMapKind::Balanced, ..spec.clone() })
            .unwrap();
        assert!(bal.label.contains("bal"), "{}", bal.label);
        // An IID locality spec is rejected before it can run.
        let iid = RunSpec { dist: Dist::Iid, ..spec };
        assert!(h.run_cached(&iid).unwrap_err().contains("non-IID"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn runspec_keys_unique_per_field() {
        let base = RunSpec {
            dataset: "cifar".into(),
            aux: "cnn27".into(),
            method: Method::CseFsl.spec().with_period(5),
            n_clients: 5,
            participation: 0,
            dist: Dist::Iid,
            arrival: ArrivalOrder::ByDelay,
            lr0: 0.05,
            seed: 1,
            workload: cifar_workload(Scale::Quick),
            parallelism: Parallelism::Sequential,
            server_shards: 1,
            sched: SchedPolicy::RoundRobin,
            shard_map: ShardMapKind::Contiguous,
            churn: ChurnConfig::default(),
        };
        let mut other = base.clone();
        other.method = other.method.with_period(10);
        assert_ne!(base.key(), other.key());
        // Every spec axis changes the key: update rule, upload
        // schedule, and topology each move the method segment.
        let mut other = base.clone();
        other.method = Method::FslOc.spec();
        assert_ne!(base.key(), other.key());
        let mut other = base.clone();
        other.method.topology = crate::coordinator::methods::ServerTopology::PerClient;
        assert_ne!(base.key(), other.key(), "topology must join the key");
        assert!(other.key().contains("aux+p5+pc"), "{}", other.key());
        // The wire codec changes results, so it moves the method
        // segment of the key (and demotes the preset to a spec tag).
        let mut other = base.clone();
        other.method = other
            .method
            .with_compression(crate::coordinator::methods::Compression::Quantize { bits: 4 });
        assert_ne!(base.key(), other.key());
        assert!(other.key().contains("+q4"), "{}", other.key());
        // Parallelism must NOT change the key: threaded runs are
        // bit-identical to sequential ones and share the cache.
        let mut other = base.clone();
        other.parallelism = Parallelism::Threads(4);
        assert_eq!(base.key(), other.key());
        // Neither may the dealing policy (same determinism contract).
        for sched in SchedPolicy::ALL {
            let mut other = base.clone();
            other.sched = sched;
            assert_eq!(base.key(), other.key(), "{sched} must share the cache");
        }
        // Shard count MUST change the key: sharding changes results.
        let mut other = base.clone();
        other.server_shards = 2;
        assert_ne!(base.key(), other.key());
        assert!(other.label().contains("k=2"));
        assert!(!base.label().contains("k="));
        // So must the shard-map flavor (different shard cohorts).
        let mut balanced = base.clone();
        balanced.server_shards = 2;
        balanced.shard_map = ShardMapKind::Balanced;
        assert_ne!(other.key(), balanced.key());
        assert!(balanced.key().contains("-mbal-"), "{}", balanced.key());
        assert!(other.key().contains("-mcont-"), "{}", other.key());
        assert!(balanced.label().contains("bal"));
        assert!(!other.label().contains("bal"));
        // The locality map is a third cohort assignment: own key segment
        // (`-mloc`), own label tag, distinct from both other maps.
        let mut locality = balanced.clone();
        locality.shard_map = ShardMapKind::Locality;
        locality.dist = Dist::NonIidDirichlet;
        assert_ne!(locality.key(), balanced.key());
        assert!(locality.key().contains("-mloc-"), "{}", locality.key());
        assert!(locality.label().contains("loc"));
        assert!(!balanced.label().contains("loc"));
        let mut other = base.clone();
        other.dist = Dist::NonIidDirichlet;
        assert_ne!(base.key(), other.key());
        let mut other = base.clone();
        other.seed = 2;
        assert_ne!(base.key(), other.key());
        // Every non-default churn knob changes results, so each moves
        // the key (and the label); the default adds nothing, keeping
        // every pre-churn cache entry addressable.
        use crate::sim::churn::{ChurnModel, ResiliencePolicy};
        assert!(base.key().ends_with("-s1"), "default churn must not touch the key");
        let mut other = base.clone();
        other.churn.model = ChurnModel::Iid { p: 0.7 };
        assert_ne!(base.key(), other.key());
        assert!(other.key().ends_with("-ciid0.7"), "{}", other.key());
        assert!(other.label().contains("iid0.7"), "{}", other.label());
        let mut other = base.clone();
        other.churn.fail_rate = 0.05;
        assert_ne!(base.key(), other.key());
        let mut other = base.clone();
        other.churn.policy = ResiliencePolicy::Quorum { min_frac: 0.5, resample: true };
        assert_ne!(base.key(), other.key());
        assert!(other.key().ends_with("-q0.5r"), "{}", other.key());
    }

    #[test]
    fn preset_keys_match_pre_spec_refactor_strings() {
        // Cache compatibility is a hard acceptance criterion of the
        // MethodSpec refactor: the four paper presets must produce the
        // exact key strings the closed Method enum produced, so every
        // pre-refactor cache entry keeps replaying. Pinned literally.
        let base = |method: MethodSpec| RunSpec {
            dataset: "cifar".into(),
            aux: "cnn27".into(),
            method,
            n_clients: 5,
            participation: 0,
            dist: Dist::Iid,
            arrival: ArrivalOrder::ByDelay,
            lr0: 0.05,
            seed: 1,
            workload: cifar_workload(Scale::Quick),
            parallelism: Parallelism::Sequential,
            server_shards: 1,
            sched: SchedPolicy::RoundRobin,
            shard_map: ShardMapKind::Contiguous,
            churn: ChurnConfig::default(),
        };
        let tail = "n5-p0-iid-delay-lr0.05-r4-d100-t100-k1-mcont-s1";
        assert_eq!(
            base(Method::FslMc.spec()).key(),
            format!("cifar-cnn27-FSL_MC-h1-{tail}")
        );
        assert_eq!(
            base(Method::FslOc.spec()).key(),
            format!("cifar-cnn27-FSL_OC-h1-{tail}")
        );
        assert_eq!(
            base(Method::FslAn.spec()).key(),
            format!("cifar-cnn27-FSL_AN-h1-{tail}")
        );
        assert_eq!(
            base(Method::CseFsl.spec()).key(),
            format!("cifar-cnn27-CSE_FSL-h1-{tail}")
        );
        assert_eq!(
            base(Method::CseFsl.spec().with_period(5)).key(),
            format!("cifar-cnn27-CSE_FSL-h5-{tail}")
        );
        // Historical labels too (they name cached CSVs and series).
        assert_eq!(base(Method::CseFsl.spec().with_period(5)).label(), "CSE_FSL h=5");
        assert_eq!(base(Method::FslAn.spec()).label(), "FSL_AN");
        // The spec-only scenario gets its own canonical key + label and
        // can never collide with a preset entry.
        let novel = base(Method::FslAn.spec().with_period(4));
        assert_eq!(novel.key(), format!("cifar-cnn27-aux+p4+pc-h4-{tail}"));
        assert_eq!(novel.label(), "aux+p4+pc");
    }

    #[test]
    fn stream_threshold_boundary_routes_exactly_at_4096() {
        // The resident/streaming hand-off is a documented contract
        // ("at or above" STREAM_THRESHOLD) with different memory and
        // participation semantics on each side — pin the boundary at
        // 4095/4096/4097 so an off-by-one in the `>=` can never slip
        // in silently. `clients_activated` tells the engines apart:
        // the resident trainer materializes every client up front
        // (activated == n), the population engine only the sampled
        // cohorts (activated <= participation * rounds).
        let dir = std::env::temp_dir().join(format!(
            "cse_fsl_stream_boundary_{}_{}",
            std::process::id(),
            line!()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let mut h = Harness::with_engine(&dir, EngineChoice::Mock).unwrap();
        // One sample per client keeps the resident arm's materialized
        // dataset small (cifar sizes the pool as train_per_client * n;
        // femnist would floor its writer count at n_clients).
        let mut wl = cifar_workload(Scale::Quick);
        wl.train_per_client = 1;
        wl.test = 40;
        wl.rounds = 1;
        wl.eval_every = 0;
        let spec = |n: usize, participation: usize| RunSpec {
            dataset: "cifar".into(),
            aux: "cnn27".into(),
            method: Method::CseFsl.spec(),
            n_clients: n,
            participation,
            dist: Dist::Iid,
            arrival: ArrivalOrder::ByDelay,
            lr0: 0.05,
            seed: 1,
            workload: wl,
            parallelism: Parallelism::Sequential,
            server_shards: 1,
            sched: SchedPolicy::RoundRobin,
            shard_map: ShardMapKind::Contiguous,
            churn: ChurnConfig::default(),
        };
        // 4095 = STREAM_THRESHOLD - 1: resident engine, every client
        // materialized even though only 2 ever train.
        let resident = h.run_cached(&spec(STREAM_THRESHOLD - 1, 2)).unwrap();
        assert_eq!(resident.clients_activated, STREAM_THRESHOLD - 1);
        // 4096 = STREAM_THRESHOLD: first streaming count ("at or
        // above"), working set bounded by the sampled cohorts.
        let streaming = h.run_cached(&spec(STREAM_THRESHOLD, 2)).unwrap();
        assert!(
            streaming.clients_activated <= 2,
            "streaming working set {} exceeds participation * rounds",
            streaming.clients_activated
        );
        assert!(streaming.clients_activated >= 1);
        // 4097 streams too (the boundary is a threshold, not a point).
        let above = h.run_cached(&spec(STREAM_THRESHOLD + 1, 2)).unwrap();
        assert!(above.clients_activated <= 2, "{}", above.clients_activated);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn streaming_participation_zero_auto_caps_at_1024() {
        // Resident semantics of participation 0 are "everyone"; at
        // fleet scale run_streaming caps that to min(n, 1024) per
        // round. Pin the cap: one round at participation 0 must
        // materialize exactly 1024 clients, not 4096 and not 1023.
        let dir = std::env::temp_dir().join(format!(
            "cse_fsl_stream_autocap_{}_{}",
            std::process::id(),
            line!()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let mut h = Harness::with_engine(&dir, EngineChoice::Mock).unwrap();
        let mut wl = cifar_workload(Scale::Quick);
        wl.train_per_client = 1;
        wl.test = 40;
        wl.rounds = 1;
        wl.eval_every = 0;
        let spec = RunSpec {
            dataset: "cifar".into(),
            aux: "cnn27".into(),
            method: Method::CseFsl.spec(),
            n_clients: STREAM_THRESHOLD,
            participation: 0,
            dist: Dist::Iid,
            arrival: ArrivalOrder::ByDelay,
            lr0: 0.05,
            seed: 1,
            workload: wl,
            parallelism: Parallelism::Sequential,
            server_shards: 1,
            sched: SchedPolicy::RoundRobin,
            shard_map: ShardMapKind::Contiguous,
            churn: ChurnConfig::default(),
        };
        let rec = h.run_cached(&spec).unwrap();
        assert_eq!(rec.clients_activated, 1024, "participation-0 auto-cap");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn run_json_roundtrip() {
        let rec = RunRecord {
            label: "x".into(),
            rounds: vec![RoundRecord {
                round: 1,
                sim_time: 0.25,
                lr: 0.05,
                train_loss: 2.0,
                server_loss: 1.0,
                up_bytes: 10,
                down_bytes: 20,
                accuracy: Some(0.5),
                client_grad_norm: None,
                server_grad_norm: Some(1.5),
            }],
            final_accuracy: 0.5,
            total_up_bytes: 10,
            total_down_bytes: 20,
            sim_time: 0.25,
            server_idle_fraction: 0.9,
            critical_path: 0.2,
            lane_busy: vec![0.1, 0.2],
            server_storage_params: 123,
            server_updates_per_shard: vec![4, 6],
            shard_label_divergence: 0.125,
            clients_activated: 4,
            clients_dropped: 7,
            clients_replaced: 2,
            partial_failures: 3,
            stragglers_dropped: 5,
        };
        let rt = run_from_json(&run_to_json(&rec).pretty()).unwrap();
        assert_eq!(rt.label, "x");
        assert_eq!(rt.rounds.len(), 1);
        assert_eq!(rt.rounds[0].accuracy, Some(0.5));
        assert_eq!(rt.rounds[0].client_grad_norm, None);
        assert_eq!(rt.server_storage_params, 123);
        assert_eq!(rt.server_updates_per_shard, vec![4, 6]);
        assert_eq!(rt.critical_path, 0.2);
        assert_eq!(rt.lane_busy, vec![0.1, 0.2]);
        assert_eq!(rt.shard_label_divergence, 0.125);
        assert_eq!(rt.clients_activated, 4);
        assert_eq!(
            (rt.clients_dropped, rt.clients_replaced, rt.partial_failures, rt.stragglers_dropped),
            (7, 2, 3, 5),
            "churn counters round-trip"
        );
        // Unversioned (pre-v2) cache entries must NOT parse: they
        // recorded the unweighted shard-divergence formula, so every
        // one of them falls through to a deterministic re-run.
        let legacy = run_to_json(&rec)
            .pretty()
            .replace("\"cache_version\"", "\"legacy_version\"");
        let err = run_from_json(&legacy).unwrap_err();
        assert!(err.contains("cache_version 0"), "{err}");
        // Wrong (future or past) versions re-run too.
        let legacy = run_to_json(&rec)
            .pretty()
            .replace("\"cache_version\": 2", "\"cache_version\": 1");
        assert!(run_from_json(&legacy).is_err(), "v1 entry must re-run");
        // A v2 entry missing the skew field must NOT parse either: the
        // skew metric feeds a comparison figure, so a record that
        // never measured it falls through to a re-run instead of
        // claiming the perfect score 0.
        let legacy = run_to_json(&rec)
            .pretty()
            .replace("\"shard_label_divergence\"", "\"legacy_skew\"");
        assert!(run_from_json(&legacy).is_err(), "skew-less entry must re-run");
        // Observability-only fields keep their lenient defaults within
        // v2 (a present-yet-malformed value is still an error).
        let legacy = run_to_json(&rec)
            .pretty()
            .replace("\"critical_path\"", "\"legacy_cp\"")
            .replace("\"lane_busy\"", "\"legacy_lb\"");
        let rt = run_from_json(&legacy).unwrap();
        assert_eq!(rt.critical_path, 0.0);
        assert!(rt.lane_busy.is_empty());
        let legacy = run_to_json(&rec).pretty().replace(
            "\"server_updates_per_shard\"",
            "\"legacy_ignored\"",
        );
        let rt = run_from_json(&legacy).unwrap();
        assert!(rt.server_updates_per_shard.is_empty());
        // Pre-churn v2 entries have no churn counters; their true value
        // is 0 (nothing could be dropped before the subsystem existed),
        // so the lenient default replays them without a re-run...
        let legacy = run_to_json(&rec)
            .pretty()
            .replace("\"clients_dropped\"", "\"legacy_cd\"")
            .replace("\"partial_failures\"", "\"legacy_pf\"");
        let rt = run_from_json(&legacy).unwrap();
        assert_eq!(rt.clients_dropped, 0);
        assert_eq!(rt.partial_failures, 0);
        assert_eq!(rt.stragglers_dropped, 5, "present counters still parse");
        // ...while a present-yet-malformed counter is an error.
        let broken = run_to_json(&rec)
            .pretty()
            .replace("\"clients_dropped\": 7", "\"clients_dropped\": \"many\"");
        assert!(run_from_json(&broken).is_err(), "malformed counter must reject");
    }

    #[test]
    fn curve_table_renders() {
        let rec = RunRecord {
            label: "CSE_FSL h=5".into(),
            rounds: vec![RoundRecord {
                round: 2,
                sim_time: 0.0,
                lr: 0.0,
                train_loss: 0.0,
                server_loss: 0.0,
                up_bytes: 0,
                down_bytes: 0,
                accuracy: Some(0.42),
                client_grad_norm: None,
                server_grad_norm: None,
            }],
            final_accuracy: 0.42,
            total_up_bytes: 0,
            total_down_bytes: 0,
            sim_time: 0.0,
            server_idle_fraction: 0.0,
            critical_path: 0.0,
            lane_busy: Vec::new(),
            server_storage_params: 0,
            server_updates_per_shard: Vec::new(),
            shard_label_divergence: 0.0,
            clients_activated: 0,
            clients_dropped: 0,
            clients_replaced: 0,
            partial_failures: 0,
            stragglers_dropped: 0,
        };
        let t = curve_table("fig", &[&rec]);
        assert!(t.contains("42.0%"));
        assert!(t.contains("CSE_FSL h=5"));
    }

    #[test]
    fn scale_aliases_roundtrip_exhaustively() {
        // Every alias → variant pair, and Display round-trips.
        for (alias, want) in [
            ("quick", Scale::Quick),
            ("smoke", Scale::Quick),
            ("ci", Scale::Ci),
            ("paper", Scale::Paper),
        ] {
            assert_eq!(Scale::parse(alias), Some(want), "{alias}");
            assert_eq!(Scale::parse(&want.to_string()), Some(want));
        }
        // Scale::parse is case-SENSITIVE (CLI values are lowercase by
        // contract) — pin that so a lowercasing change is deliberate.
        for bad in ["QUICK", "Quick", "Ci", "PAPER", "fast", ""] {
            assert_eq!(Scale::parse(bad), None, "{bad:?}");
        }
    }

    #[test]
    fn dist_aliases_roundtrip_exhaustively() {
        for (alias, want) in [
            ("iid", Dist::Iid),
            ("dir", Dist::NonIidDirichlet),
            ("dirichlet", Dist::NonIidDirichlet),
            ("writer", Dist::NonIidWriter),
            ("by-writer", Dist::NonIidWriter),
        ] {
            assert_eq!(Dist::parse(alias), Some(want), "{alias}");
        }
        // Dist::parse lowercases its input (unlike Scale::parse).
        for (alias, want) in [
            ("DIR", Dist::NonIidDirichlet),
            ("Writer", Dist::NonIidWriter),
            ("IID", Dist::Iid),
        ] {
            assert_eq!(Dist::parse(alias), Some(want), "{alias}");
        }
        for bad in ["niid", "by_writer", "dirichlet(0.5)", ""] {
            assert_eq!(Dist::parse(bad), None, "{bad:?}");
        }
        // Tags round-trip (the documented contract).
        for d in [Dist::Iid, Dist::NonIidDirichlet, Dist::NonIidWriter] {
            assert_eq!(Dist::parse(d.tag()), Some(d));
        }
    }

    #[test]
    fn run_from_json_rejects_malformed_input() {
        // Malformed JSON and non-object roots are parse errors, never
        // defaulted records.
        assert!(run_from_json("").is_err());
        assert!(run_from_json("not json").is_err());
        assert!(run_from_json("{\"cache_version\": 2").is_err(), "truncated object");
        assert!(run_from_json("[1, 2, 3]").is_err(), "non-object root");
        assert!(run_from_json("42").is_err(), "scalar root");
    }

    #[test]
    fn run_from_json_rejects_each_missing_strict_field() {
        let rec = RunRecord {
            label: "x".into(),
            rounds: Vec::new(),
            final_accuracy: 0.5,
            total_up_bytes: 10,
            total_down_bytes: 20,
            sim_time: 0.25,
            server_idle_fraction: 0.9,
            critical_path: 0.2,
            lane_busy: Vec::new(),
            server_storage_params: 123,
            server_updates_per_shard: Vec::new(),
            shard_label_divergence: 0.125,
            clients_activated: 4,
            clients_dropped: 0,
            clients_replaced: 0,
            partial_failures: 0,
            stragglers_dropped: 0,
        };
        let good = run_to_json(&rec).pretty();
        assert!(run_from_json(&good).is_ok());
        // Each strict field, removed in isolation, must fail the parse
        // (the lenient observability fields are pinned separately in
        // run_json_roundtrip).
        for field in [
            "label",
            "rounds",
            "final_accuracy",
            "total_up_bytes",
            "total_down_bytes",
            "sim_time",
            "server_idle_fraction",
            "server_storage_params",
            "shard_label_divergence",
            "clients_activated",
        ] {
            let broken = good.replace(&format!("\"{field}\""), "\"gone\"");
            assert_ne!(broken, good, "field {field} present in serialization");
            assert!(run_from_json(&broken).is_err(), "missing {field} must be rejected");
        }
        // Wrong-typed values are rejected too, not coerced.
        let broken = good.replace("\"final_accuracy\": 0.5", "\"final_accuracy\": \"high\"");
        assert_ne!(broken, good);
        assert!(run_from_json(&broken).is_err(), "string accuracy must be rejected");
        let broken = good.replace("\"label\": \"x\"", "\"label\": 7");
        assert_ne!(broken, good);
        assert!(run_from_json(&broken).is_err(), "numeric label must be rejected");
    }
}
