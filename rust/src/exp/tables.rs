//! Drivers for the paper's tables (II, III, IV, V).

use crate::comm::accounting::{table2, WireSizes};
use crate::coordinator::config::{ArrivalOrder, Parallelism, ShardMapKind};
use crate::coordinator::methods::{Method, MethodSpec};
use crate::sched::SchedPolicy;
use crate::storage::{server_storage_m, ModelSizes};

use super::common::{cifar_workload, femnist_workload, Dist, Harness, RunSpec, Scale};

/// Table II: closed-form total communication per global epoch + server
/// storage, evaluated at the paper's CIFAR-10 operating point
/// (n=5, |D_i|=10k, q=6·6·64·4 B) — plus the n-scaling the paper argues.
pub fn table2_report(harness: &mut Harness) -> Result<String, String> {
    let cfg = harness.manifest()?.config("cifar").map_err(|e| e.to_string())?;
    let aux = cfg.aux("mlp").map_err(|e| e.to_string())?;
    let w = WireSizes::new(cfg.smashed_size, cfg.client_layout.total, aux.size);
    let sizes = ModelSizes {
        client: cfg.client_layout.total,
        server: cfg.server_layout.total,
        aux: aux.size,
    };
    let d_i = 10_000u64;
    let mut out = String::from(
        "== Table II: per-epoch communication (GB) and server storage (M params) ==\n",
    );
    out.push_str(&format!(
        "{:<14} {:>12} {:>12} {:>12} {:>14}\n",
        "method", "n=5", "n=10", "n=50", "storage(n=50)"
    ));
    let rows: Vec<(&str, Box<dyn Fn(u64) -> u64>, Method)> = vec![
        ("FSL_MC", Box::new(move |n| table2::fsl_mc(n, d_i, &w)), Method::FslMc),
        ("FSL_OC", Box::new(move |n| table2::fsl_oc(n, d_i, &w)), Method::FslOc),
        ("FSL_AN", Box::new(move |n| table2::fsl_an(n, d_i, &w)), Method::FslAn),
        ("CSE_FSL_h=5", Box::new(move |n| table2::cse_fsl(n, d_i, 5, &w)), Method::CseFsl),
        ("CSE_FSL_h=50", Box::new(move |n| table2::cse_fsl(n, d_i, 50, &w)), Method::CseFsl),
    ];
    for (name, f, method) in rows {
        out.push_str(&format!(
            "{:<14} {:>12.3} {:>12.3} {:>12.3} {:>14.2}\n",
            name,
            f(5) as f64 / 1e9,
            f(10) as f64 / 1e9,
            f(50) as f64 / 1e9,
            server_storage_m(&method.spec(), 50, &sizes),
        ));
    }
    out.push_str(
        "\n(The measured ledger is cross-checked against these closed forms in\n\
         rust/tests/coordinator_mock.rs::measured_bytes_match_table2_closed_form.)\n",
    );
    Ok(out)
}

/// Tables III & IV: auxiliary-network parameter counts, read from the
/// manifest layouts and checked against the paper's printed numbers.
pub fn table34_report(harness: &mut Harness) -> Result<String, String> {
    let mut out = String::new();
    for (ds, title, order) in [
        ("cifar", "Table III: CIFAR-10 auxiliary networks",
         vec!["mlp", "cnn54", "cnn27", "cnn14", "cnn7"]),
        ("femnist", "Table IV: F-EMNIST auxiliary networks",
         vec!["mlp", "cnn64", "cnn32", "cnn8", "cnn2"]),
    ] {
        let cfg = harness.manifest()?.config(ds).map_err(|e| e.to_string())?;
        let whole = cfg.client_layout.total + cfg.server_layout.total;
        out.push_str(&format!("== {title} ==\n"));
        out.push_str(&format!(
            "{:<10} {:>12} {:>22}\n",
            "arch", "parameters", "% of whole model"
        ));
        for arch in order {
            let aux = cfg.aux(arch).map_err(|e| e.to_string())?;
            out.push_str(&format!(
                "{:<10} {:>12} {:>21.2}%\n",
                arch,
                aux.size,
                100.0 * aux.size as f64 / whole as f64
            ));
        }
        out.push('\n');
    }
    out.push_str("(Counts are asserted to equal the paper's Tables III/IV exactly, at\nAOT time and in python/tests/test_models.py.)\n");
    Ok(out)
}

/// Table V: accuracy / communication load / storage for every method on
/// both datasets (IID + non-IID). Reuses the cached Fig.-4/5-style runs.
/// Paper trends: CSE_FSL dominates the acc/load/storage trade-off; load
/// falls ~1/h; storage is n-independent.
pub fn table5_report(harness: &mut Harness, scale: Scale) -> Result<String, String> {
    let mut out =
        String::from("== Table V: accuracy / communication load / server storage ==\n");
    for (ds, aux, wl, h_set, dists) in [
        (
            "cifar",
            "cnn27",
            cifar_workload(scale),
            match scale {
                Scale::Quick => vec![1usize, 2],
                _ => vec![1, 5, 10],
            },
            vec![Dist::Iid, Dist::NonIidDirichlet],
        ),
        (
            "femnist",
            "cnn8",
            femnist_workload(scale),
            match scale {
                Scale::Quick => vec![1, 2],
                _ => vec![1, 2, 4],
            },
            vec![Dist::Iid, Dist::NonIidWriter],
        ),
    ] {
        out.push_str(&format!("\n--- {ds} ---\n"));
        out.push_str(&format!(
            "{:<16} {:>12} {:>12} {:>10} {:>12}\n",
            "method", "acc(IID)", "acc(nonIID)", "load(GB)", "storage(M)"
        ));
        let specs: Vec<(String, MethodSpec)> = {
            let mut v = vec![
                ("FSL_MC".to_string(), Method::FslMc.spec()),
                ("FSL_OC".to_string(), Method::FslOc.spec()),
                ("FSL_AN".to_string(), Method::FslAn.spec()),
            ];
            for &h in &h_set {
                v.push((format!("CSE_FSL h={h}"), Method::CseFsl.spec().with_period(h)));
            }
            v
        };
        for (name, method) in specs {
            let mut accs = Vec::new();
            let mut load_gb = 0.0;
            let mut storage_m = 0.0;
            for &dist in &dists {
                let base = if ds == "femnist" {
                    RunSpec {
                        n_clients: 10,
                        participation: 5,
                        ..fig_base(ds, aux, wl)
                    }
                } else {
                    fig_base(ds, aux, wl)
                };
                let spec = RunSpec { method, dist, ..base };
                let rec = harness.run_cached(&spec)?;
                accs.push(rec.final_accuracy);
                load_gb = rec.total_gb();
                storage_m = rec.server_storage_params as f64 / 1e6;
            }
            out.push_str(&format!(
                "{:<16} {:>11.1}% {:>11.1}% {:>10.4} {:>12.2}\n",
                name,
                accs[0] * 100.0,
                accs.get(1).copied().unwrap_or(f64::NAN) * 100.0,
                load_gb,
                storage_m
            ));
        }
    }
    Ok(out)
}

fn fig_base(dataset: &str, aux: &str, w: super::common::Workload) -> RunSpec {
    RunSpec {
        dataset: dataset.into(),
        aux: aux.into(),
        method: Method::CseFsl.spec(),
        n_clients: 5,
        participation: 0,
        dist: Dist::Iid,
        arrival: ArrivalOrder::ByDelay,
        lr0: if dataset == "cifar" { 0.01 } else { 0.05 },
        seed: 1,
        workload: w,
        parallelism: Parallelism::auto(),
        server_shards: 1,
        sched: SchedPolicy::WorkStealing,
        shard_map: ShardMapKind::Contiguous,
    }
}
