//! Experiment drivers: one per paper figure/table (DESIGN.md §3 index).

pub mod common;
pub mod figures;
pub mod tables;
