//! Experiment drivers: one per paper figure/table (DESIGN.md §3 index),
//! plus the declarative sweep runner they execute through.

pub mod common;
pub mod figures;
pub mod sweep;
pub mod tables;
