//! Deterministic per-client mini-batch iteration.
//!
//! Each client walks its local shard in a reshuffled order every epoch
//! (standard SGD protocol; Algorithm 1 line 5 "for each mini-batch").
//! Batches are exactly `batch_size` — the tail is carried into the next
//! epoch's order so no sample is dropped and the AOT-fixed batch shape is
//! always honored.

use crate::util::prng::Rng;

/// Infinite batch stream over a fixed index shard.
#[derive(Clone, Debug)]
pub struct Batcher {
    shard: Vec<usize>,
    order: Vec<usize>,
    cursor: usize,
    batch_size: usize,
    rng: Rng,
    epoch: u64,
    carried: Vec<usize>,
}

impl Batcher {
    /// Build a batch stream over `shard` (non-empty) with the given
    /// batch size; `rng` drives the per-epoch reshuffles.
    pub fn new(shard: Vec<usize>, batch_size: usize, rng: Rng) -> Self {
        assert!(batch_size > 0);
        assert!(!shard.is_empty(), "empty shard");
        let mut b = Batcher {
            shard,
            order: Vec::new(),
            cursor: 0,
            batch_size,
            rng,
            epoch: 0,
            carried: Vec::new(),
        };
        b.reshuffle();
        b
    }

    fn reshuffle(&mut self) {
        self.order = self.shard.clone();
        self.rng.shuffle(&mut self.order);
        self.cursor = 0;
        self.epoch += 1;
    }

    /// Number of full batches per epoch (used for h/C scheduling).
    pub fn batches_per_epoch(&self) -> usize {
        self.shard.len() / self.batch_size
    }

    /// Number of reshuffles so far (1 after construction).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Next mini-batch of exactly `batch_size` indices.
    pub fn next_batch(&mut self, out: &mut Vec<usize>) {
        out.clear();
        out.extend_from_slice(&self.carried);
        self.carried.clear();
        while out.len() < self.batch_size {
            if self.cursor >= self.order.len() {
                self.reshuffle();
            }
            let take = (self.batch_size - out.len()).min(self.order.len() - self.cursor);
            out.extend_from_slice(&self.order[self.cursor..self.cursor + take]);
            self.cursor += take;
        }
    }
}

/// Chunked evaluation iterator: walks 0..n in fixed-size chunks, padding
/// the last chunk by repeating the final index (the evaluator masks the
/// padding out of the accuracy count).
pub struct EvalChunks {
    n: usize,
    chunk: usize,
    pos: usize,
}

impl EvalChunks {
    /// Walk `0..n` in chunks of `chunk` (> 0), padding the tail.
    pub fn new(n: usize, chunk: usize) -> Self {
        assert!(chunk > 0);
        EvalChunks { n, chunk, pos: 0 }
    }
}

impl Iterator for EvalChunks {
    /// (indices, number of real — unpadded — entries)
    type Item = (Vec<usize>, usize);

    fn next(&mut self) -> Option<Self::Item> {
        if self.pos >= self.n {
            return None;
        }
        let real = (self.n - self.pos).min(self.chunk);
        let mut idx: Vec<usize> = (self.pos..self.pos + real).collect();
        while idx.len() < self.chunk {
            idx.push(self.n - 1);
        }
        self.pos += real;
        Some((idx, real))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_cover_epoch_exactly() {
        let mut b = Batcher::new((0..10).collect(), 5, Rng::new(1));
        let mut got = Vec::new();
        let mut buf = Vec::new();
        for _ in 0..2 {
            b.next_batch(&mut buf);
            assert_eq!(buf.len(), 5);
            got.extend_from_slice(&buf);
        }
        got.sort_unstable();
        assert_eq!(got, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn tail_carries_across_epochs() {
        // shard of 7, batch of 5: batch1 = 5 items, batch2 = 2 carried + 3
        // from the next epoch; nothing dropped, nothing duplicated within
        // a window of 2 epochs minus the in-flight batch.
        let mut b = Batcher::new((0..7).collect(), 5, Rng::new(2));
        let mut buf = Vec::new();
        let mut counts = vec![0usize; 7];
        for _ in 0..14 {
            // 14 batches * 5 = 70 = 10 epochs
            b.next_batch(&mut buf);
            for &i in &buf {
                counts[i] += 1;
            }
        }
        assert_eq!(counts.iter().sum::<usize>(), 70);
        for (i, &c) in counts.iter().enumerate() {
            assert_eq!(c, 10, "sample {i} seen {c} times");
        }
    }

    #[test]
    fn deterministic_given_rng() {
        let mut a = Batcher::new((0..20).collect(), 4, Rng::new(3));
        let mut b = Batcher::new((0..20).collect(), 4, Rng::new(3));
        let (mut ba, mut bb) = (Vec::new(), Vec::new());
        for _ in 0..10 {
            a.next_batch(&mut ba);
            b.next_batch(&mut bb);
            assert_eq!(ba, bb);
        }
    }

    #[test]
    fn batches_per_epoch_math() {
        let b = Batcher::new((0..53).collect(), 10, Rng::new(4));
        assert_eq!(b.batches_per_epoch(), 5);
    }

    #[test]
    fn eval_chunks_pad_and_mask() {
        let chunks: Vec<_> = EvalChunks::new(7, 3).collect();
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks[0], ((0..3).collect(), 3));
        assert_eq!(chunks[2].0, vec![6, 6, 6]);
        assert_eq!(chunks[2].1, 1);
        let total: usize = chunks.iter().map(|c| c.1).sum();
        assert_eq!(total, 7);
    }
}
