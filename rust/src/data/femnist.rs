//! Synthetic F-EMNIST: writer-structured 62-class handwriting substitute.
//!
//! Real F-EMNIST partitions digit/letter images *by author*, which makes
//! the federated split naturally non-IID ("the writing style varies from
//! person to person"). We reproduce that structure: every class has a
//! global glyph template, every *writer* has a persistent style (shear,
//! stroke gain, offset, contrast), and a sample is
//! `style(writer) ∘ glyph(class) + noise`. Partitioning by writer then
//! yields exactly the kind of covariate-shift non-IID the paper's Fig. 5b
//! and Fig. 8 stress.

use crate::util::prng::Rng;

use super::Dataset;

/// F-EMNIST class count (digits + upper/lowercase letters).
pub const CLASSES: usize = 62;
/// Glyph canvas side length in pixels.
pub const SIDE: usize = 28;

/// Writer style: a persistent transform applied to every glyph rendered
/// by that writer.
#[derive(Clone, Debug)]
pub struct WriterStyle {
    /// Horizontal shear (slant), in pixels per row.
    pub shear: f64,
    /// Multiplicative stroke gain ("pen pressure").
    pub gain: f64,
    /// Horizontal offset in pixels.
    pub dx: i64,
    /// Vertical offset in pixels.
    pub dy: i64,
    /// Additive background bias.
    pub bias: f64,
}

/// Generation parameters of the synthetic F-EMNIST.
#[derive(Clone, Debug)]
pub struct FemnistSpec {
    /// Number of writers.
    pub writers: usize,
    /// Samples rendered per writer.
    pub samples_per_writer: usize,
    /// Per-writer label skew: each writer draws labels from a Dirichlet
    /// over classes with this concentration (smaller = more skew). Real
    /// authors also have label skew (people write some characters more).
    pub label_alpha: f64,
    /// Pixel noise sigma.
    pub noise: f64,
}

impl FemnistSpec {
    /// A default sized like the CI workloads.
    pub fn default_like() -> Self {
        FemnistSpec { writers: 50, samples_per_writer: 40, label_alpha: 0.5, noise: 0.3 }
    }
}

/// Global glyph templates (one 28x28 field per class).
pub struct Glyphs {
    /// [CLASSES][SIDE*SIDE] stroke fields.
    pub fields: Vec<Vec<f32>>,
}

/// Draw the per-class glyph templates (random soft strokes).
pub fn make_glyphs(rng: &mut Rng) -> Glyphs {
    // Glyph = a handful of random "strokes" (soft line segments) on the
    // canvas — close enough to character structure for a conv net, and
    // far more class-distinctive than raw noise.
    let mut fields = Vec::with_capacity(CLASSES);
    for _ in 0..CLASSES {
        let mut f = vec![0f32; SIDE * SIDE];
        let strokes = 3 + rng.below(3) as usize;
        for _ in 0..strokes {
            let x0 = rng.uniform_in(4.0, 24.0);
            let y0 = rng.uniform_in(4.0, 24.0);
            let ang = rng.uniform_in(0.0, std::f64::consts::TAU);
            let len = rng.uniform_in(6.0, 16.0);
            let width = rng.uniform_in(1.0, 2.2);
            let (dx, dy) = (ang.cos(), ang.sin());
            // Soft line: intensity = exp(-d^2 / width^2) along the segment
            for y in 0..SIDE {
                for x in 0..SIDE {
                    let px = x as f64 - x0;
                    let py = y as f64 - y0;
                    let t = (px * dx + py * dy).clamp(0.0, len);
                    let qx = px - t * dx;
                    let qy = py - t * dy;
                    let d2 = qx * qx + qy * qy;
                    f[y * SIDE + x] += (-d2 / (width * width)).exp() as f32;
                }
            }
        }
        // Normalize energy.
        let norm: f32 = f.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-6);
        for v in &mut f {
            *v = *v / norm * 10.0;
        }
        fields.push(f);
    }
    Glyphs { fields }
}

/// Draw one writer's persistent style transform.
pub fn make_writer_style(rng: &mut Rng) -> WriterStyle {
    WriterStyle {
        shear: rng.uniform_in(-0.25, 0.25),
        gain: rng.uniform_in(0.7, 1.3),
        dx: rng.uniform_in(-3.0, 4.0).floor() as i64,
        dy: rng.uniform_in(-3.0, 4.0).floor() as i64,
        bias: rng.uniform_in(-0.1, 0.1),
    }
}

/// Render one glyph under a writer style.
pub fn render(
    glyphs: &Glyphs,
    class: usize,
    style: &WriterStyle,
    noise: f64,
    rng: &mut Rng,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), SIDE * SIDE);
    let field = &glyphs.fields[class];
    for y in 0..SIDE {
        // shear: horizontal source offset grows with row
        let shear_px = (style.shear * (y as f64 - SIDE as f64 / 2.0)).round() as i64;
        for x in 0..SIDE {
            let sx = (x as i64 + style.dx + shear_px).rem_euclid(SIDE as i64) as usize;
            let sy = (y as i64 + style.dy).rem_euclid(SIDE as i64) as usize;
            let v = field[sy * SIDE + sx] as f64 * style.gain
                + style.bias
                + rng.normal() * noise;
            out[y * SIDE + x] = v as f32;
        }
    }
}

/// Generate a writer-structured dataset from existing glyphs, with
/// writer RNG streams offset by `writer_base` (so train and test draw
/// DISJOINT writer populations over the SAME glyph alphabet).
pub fn generate_writers(
    glyphs: &Glyphs,
    spec: &FemnistSpec,
    root: &Rng,
    writer_base: u64,
) -> Dataset {
    let n = spec.writers * spec.samples_per_writer;
    let sz = SIDE * SIDE;
    let mut images = vec![0f32; n * sz];
    let mut labels = Vec::with_capacity(n);
    let mut writers = Vec::with_capacity(n);
    let mut i = 0usize;
    for w in 0..spec.writers {
        let mut wrng = root.split(w as u64 + writer_base);
        let style = make_writer_style(&mut wrng);
        // Writer-specific label distribution (label skew).
        let probs = wrng.dirichlet(spec.label_alpha, CLASSES);
        for _ in 0..spec.samples_per_writer {
            let class = wrng.categorical(&probs);
            render(glyphs, class, &style, spec.noise, &mut wrng, &mut images[i * sz..(i + 1) * sz]);
            labels.push(class as i32);
            writers.push(w as u32);
            i += 1;
        }
    }
    Dataset { images, labels, shape: [SIDE, SIDE, 1], classes: CLASSES, writers }
}

/// Generate the full writer-structured dataset. Samples are grouped by
/// writer (writer ids recorded in `Dataset::writers`).
pub fn generate(spec: &FemnistSpec, seed: u64) -> Dataset {
    let root = Rng::new(seed);
    let mut grng = root.split_str("glyphs");
    let glyphs = make_glyphs(&mut grng);
    generate_writers(&glyphs, spec, &root, 1_000)
}

/// Train/test pair: SAME glyph alphabet (classes mean the same thing),
/// DISJOINT writer populations (test measures generalization to unseen
/// styles, like holding out authors in real F-EMNIST).
pub fn train_test(spec: &FemnistSpec, test_writers: usize, seed: u64) -> (Dataset, Dataset) {
    let root = Rng::new(seed);
    let mut grng = root.split_str("glyphs");
    let glyphs = make_glyphs(&mut grng);
    let train = generate_writers(&glyphs, spec, &root, 1_000);
    let test_spec = FemnistSpec { writers: test_writers, ..spec.clone() };
    let test = generate_writers(&glyphs, &test_spec, &root, 5_000_000);
    (train, test)
}

/// IID variant: same glyphs and styles, but every sample draws a uniform
/// class and a *random* writer style — destroying the writer structure
/// (used for the Fig. 5a IID arm).
pub fn generate_iid(spec: &FemnistSpec, seed: u64) -> Dataset {
    let root = Rng::new(seed);
    let mut grng = root.split_str("glyphs");
    let glyphs = make_glyphs(&mut grng);
    generate_iid_from(&glyphs, spec, &root, "iid-samples")
}

/// IID train/test pair over a shared glyph alphabet.
pub fn train_test_iid(spec: &FemnistSpec, test_samples: usize, seed: u64) -> (Dataset, Dataset) {
    let root = Rng::new(seed);
    let mut grng = root.split_str("glyphs");
    let glyphs = make_glyphs(&mut grng);
    let train = generate_iid_from(&glyphs, spec, &root, "iid-train");
    let spw = spec.samples_per_writer.max(1);
    let test_spec = FemnistSpec { writers: (test_samples / spw).max(1), ..spec.clone() };
    let test = generate_iid_from(&glyphs, &test_spec, &root, "iid-test");
    (train, test)
}

fn generate_iid_from(glyphs: &Glyphs, spec: &FemnistSpec, root: &Rng, stream: &str) -> Dataset {
    let n = spec.writers * spec.samples_per_writer;
    let sz = SIDE * SIDE;
    let mut images = vec![0f32; n * sz];
    let mut labels = Vec::with_capacity(n);
    let mut srng = root.split_str(stream);
    for i in 0..n {
        let class = srng.below(CLASSES as u64) as usize;
        let style = make_writer_style(&mut srng);
        render(glyphs, class, &style, spec.noise, &mut srng, &mut images[i * sz..(i + 1) * sz]);
        labels.push(class as i32);
    }
    Dataset {
        images,
        labels,
        shape: [SIDE, SIDE, 1],
        classes: CLASSES,
        writers: (0..n).map(|i| (i % spec.writers) as u32).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_writers() {
        let spec = FemnistSpec { writers: 5, samples_per_writer: 8, ..FemnistSpec::default_like() };
        let d = generate(&spec, 1);
        assert_eq!(d.len(), 40);
        assert_eq!(d.shape, [28, 28, 1]);
        assert_eq!(d.classes, 62);
        assert_eq!(d.writers[0..8], [0; 8]);
        assert_eq!(d.writers[8], 1);
    }

    #[test]
    fn deterministic() {
        let spec = FemnistSpec { writers: 3, samples_per_writer: 4, ..FemnistSpec::default_like() };
        let a = generate(&spec, 9);
        let b = generate(&spec, 9);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn writers_have_label_skew() {
        // With alpha=0.5 over 62 classes, each writer should concentrate
        // on a small subset of classes — unlike the IID variant.
        let spec = FemnistSpec { writers: 8, samples_per_writer: 50, label_alpha: 0.3, ..FemnistSpec::default_like() };
        let d = generate(&spec, 2);
        let mut max_share = 0f64;
        for w in 0..spec.writers {
            let mut hist = vec![0usize; CLASSES];
            for i in 0..d.len() {
                if d.writers[i] == w as u32 {
                    hist[d.labels[i] as usize] += 1;
                }
            }
            let top = *hist.iter().max().unwrap() as f64 / spec.samples_per_writer as f64;
            max_share = max_share.max(top);
        }
        assert!(max_share > 0.2, "expected label concentration, got {max_share}");

        let iid = generate_iid(&spec, 2);
        let hist = iid.class_histogram();
        let top = *hist.iter().max().unwrap() as f64 / iid.len() as f64;
        assert!(top < 0.12, "iid should be flat, got {top}");
    }

    #[test]
    fn glyph_classes_distinct() {
        let mut rng = Rng::new(4);
        let g = make_glyphs(&mut rng);
        // distinct templates: normalized correlation below 0.9 for all pairs
        for i in 0..8 {
            for j in 0..i {
                let (a, b) = (&g.fields[i], &g.fields[j]);
                let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
                let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
                let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
                assert!(dot / (na * nb) < 0.9, "glyphs {i},{j} too similar");
            }
        }
    }
}
