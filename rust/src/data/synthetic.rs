//! Class-template synthetic image generator (CIFAR-10 substitute).
//!
//! Each class is a smooth random field (a sum of low-frequency cosine
//! waves per channel); a sample is its class template under a random
//! cyclic shift + brightness/contrast jitter + pixel noise. The task is
//! genuinely learnable (templates are well separated at the default SNR)
//! but not trivial (jitter moves class evidence around spatially, so the
//! conv stack has to earn its keep), and train/test splits generalize.

use crate::util::prng::Rng;

use super::Dataset;

/// Generation parameters; defaults approximate a "CIFAR-difficulty" task
/// at the paper's tensor shapes.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    /// Number of classes.
    pub classes: usize,
    /// Image height in pixels.
    pub height: usize,
    /// Image width in pixels.
    pub width: usize,
    /// Channels per pixel.
    pub channels: usize,
    /// Number of cosine components per class template.
    pub waves: usize,
    /// Max spatial frequency (cycles per image side).
    pub max_freq: f64,
    /// Pixel noise sigma added to each sample.
    pub noise: f64,
    /// Max absolute cyclic shift in pixels per axis.
    pub max_shift: usize,
    /// Brightness scale jitter range (low, high).
    pub scale_jitter: (f64, f64),
}

impl SyntheticSpec {
    /// Calibrated so the paper's CIFAR CNN lands mid-range (not ceiling)
    /// at the CI workload — method orderings need dynamic range.
    pub fn cifar_like() -> Self {
        SyntheticSpec {
            classes: 10,
            height: 32,
            width: 32,
            channels: 3,
            waves: 6,
            max_freq: 3.0,
            noise: 1.0,
            max_shift: 6,
            scale_jitter: (0.7, 1.3),
        }
    }
}

/// The per-class smooth templates. Kept public so tests can assert
/// separation properties.
pub struct Templates {
    /// The generation parameters the templates were built from.
    pub spec: SyntheticSpec,
    /// [classes][h*w*c]
    pub fields: Vec<Vec<f32>>,
}

/// Draw the per-class smooth random fields (unit-normalized).
pub fn make_templates(spec: &SyntheticSpec, rng: &mut Rng) -> Templates {
    let (h, w, c) = (spec.height, spec.width, spec.channels);
    let mut fields = Vec::with_capacity(spec.classes);
    for _ in 0..spec.classes {
        let mut field = vec![0f32; h * w * c];
        for ch in 0..c {
            for _ in 0..spec.waves {
                let fu = rng.uniform_in(0.3, spec.max_freq) / w as f64;
                let fv = rng.uniform_in(0.3, spec.max_freq) / h as f64;
                let phase = rng.uniform_in(0.0, std::f64::consts::TAU);
                let amp = rng.uniform_in(0.4, 1.0);
                let su = if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
                for y in 0..h {
                    for x in 0..w {
                        let arg = std::f64::consts::TAU
                            * (fu * x as f64 * su + fv * y as f64)
                            + phase;
                        field[(y * w + x) * c + ch] += (amp * arg.cos()) as f32;
                    }
                }
            }
        }
        // Normalize template to zero mean / unit std so every class has
        // the same energy and the only class signal is *structure*.
        let n = field.len() as f32;
        let mean = field.iter().sum::<f32>() / n;
        let var = field.iter().map(|v| (v - mean).powi(2)).sum::<f32>() / n;
        let inv = 1.0 / var.sqrt().max(1e-6);
        for v in &mut field {
            *v = (*v - mean) * inv;
        }
        fields.push(field);
    }
    Templates { spec: spec.clone(), fields }
}

impl Templates {
    /// Render one sample of class `label` into `out` (len h*w*c).
    pub fn render(&self, label: usize, rng: &mut Rng, out: &mut [f32]) {
        let spec = &self.spec;
        let (h, w, c) = (spec.height, spec.width, spec.channels);
        debug_assert_eq!(out.len(), h * w * c);
        let field = &self.fields[label];
        let sh = spec.max_shift as i64;
        let dy = rng.uniform_in(-(sh as f64), sh as f64 + 1.0).floor() as i64;
        let dx = rng.uniform_in(-(sh as f64), sh as f64 + 1.0).floor() as i64;
        let scale = rng.uniform_in(spec.scale_jitter.0, spec.scale_jitter.1) as f32;
        for y in 0..h {
            // cyclic shift keeps all class energy in-frame
            let sy = ((y as i64 + dy).rem_euclid(h as i64)) as usize;
            for x in 0..w {
                let sx = ((x as i64 + dx).rem_euclid(w as i64)) as usize;
                for ch in 0..c {
                    let v = field[(sy * w + sx) * c + ch] * scale
                        + (rng.normal() as f32) * spec.noise as f32;
                    out[(y * w + x) * c + ch] = v;
                }
            }
        }
    }
}

/// Generate a dataset of `n` samples with a balanced label distribution.
pub fn generate(spec: &SyntheticSpec, n: usize, seed: u64) -> Dataset {
    let root = Rng::new(seed);
    let mut trng = root.split_str("templates");
    let templates = make_templates(spec, &mut trng);
    generate_from(&templates, n, &mut root.split_str("samples"))
}

/// Generate from existing templates (train/test splits share templates
/// but use disjoint sample RNG streams).
pub fn generate_from(templates: &Templates, n: usize, rng: &mut Rng) -> Dataset {
    let spec = &templates.spec;
    let sz = spec.height * spec.width * spec.channels;
    let mut images = vec![0f32; n * sz];
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        // Balanced, interleaved labels; deterministic given n.
        let label = i % spec.classes;
        templates.render(label, rng, &mut images[i * sz..(i + 1) * sz]);
        labels.push(label as i32);
    }
    Dataset {
        images,
        labels,
        shape: [spec.height, spec.width, spec.channels],
        classes: spec.classes,
        writers: vec![0; n],
    }
}

/// Train/test pair sharing templates but with independent sample noise.
pub fn train_test(spec: &SyntheticSpec, n_train: usize, n_test: usize, seed: u64) -> (Dataset, Dataset) {
    let root = Rng::new(seed);
    let mut trng = root.split_str("templates");
    let templates = make_templates(spec, &mut trng);
    let train = generate_from(&templates, n_train, &mut root.split_str("train"));
    let test = generate_from(&templates, n_test, &mut root.split_str("test"));
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> SyntheticSpec {
        SyntheticSpec { height: 8, width: 8, channels: 2, classes: 4, ..SyntheticSpec::cifar_like() }
    }

    #[test]
    fn shapes_and_balance() {
        let d = generate(&small_spec(), 40, 1);
        assert_eq!(d.len(), 40);
        assert_eq!(d.sample_size(), 128);
        assert_eq!(d.class_histogram(), vec![10, 10, 10, 10]);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate(&small_spec(), 10, 7);
        let b = generate(&small_spec(), 10, 7);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
        let c = generate(&small_spec(), 10, 8);
        assert_ne!(a.images, c.images);
    }

    #[test]
    fn templates_are_separated() {
        // Different class templates must be nearly orthogonal (low |cos|);
        // same-class samples must correlate with their template.
        let spec = small_spec();
        let mut rng = Rng::new(3);
        let t = make_templates(&spec, &mut rng);
        for i in 0..spec.classes {
            for j in 0..i {
                let dot: f32 = t.fields[i]
                    .iter()
                    .zip(&t.fields[j])
                    .map(|(a, b)| a * b)
                    .sum();
                let cos = dot / t.fields[i].len() as f32; // unit-std fields
                assert!(cos.abs() < 0.5, "classes {i},{j} cos {cos}");
            }
        }
    }

    #[test]
    fn samples_correlate_with_their_template() {
        let spec = small_spec();
        let (train, _) = train_test(&spec, 40, 0, 5);
        let mut rng = Rng::new(5).split_str("templates");
        let t = make_templates(&spec, &mut rng);
        let mut correct = 0;
        for i in 0..train.len() {
            let img = train.image(i);
            let mut best = (f32::MIN, 0usize);
            for (cls, field) in t.fields.iter().enumerate() {
                // max correlation over the shift range used by render
                let mut best_corr = f32::MIN;
                for dy in -4i64..=4 {
                    for dx in -4i64..=4 {
                        let mut dot = 0f32;
                        for y in 0..spec.height {
                            let sy = ((y as i64 + dy).rem_euclid(spec.height as i64)) as usize;
                            for x in 0..spec.width {
                                let sx = ((x as i64 + dx).rem_euclid(spec.width as i64)) as usize;
                                for ch in 0..spec.channels {
                                    dot += img[(y * spec.width + x) * spec.channels + ch]
                                        * field[(sy * spec.width + sx) * spec.channels + ch];
                                }
                            }
                        }
                        best_corr = best_corr.max(dot);
                    }
                }
                if best_corr > best.0 {
                    best = (best_corr, cls);
                }
            }
            if best.1 as i32 == train.labels[i] {
                correct += 1;
            }
        }
        // A matched-filter oracle should decode most labels — if not, the
        // task is unlearnable and every accuracy figure is noise.
        assert!(correct * 10 >= train.len() * 7, "{correct}/{}", train.len());
    }

    #[test]
    fn train_test_share_templates_but_not_noise() {
        let spec = small_spec();
        let (tr, te) = train_test(&spec, 8, 8, 11);
        assert_ne!(tr.images, te.images);
        assert_eq!(tr.labels[..4], te.labels[..4]); // same balanced labeling
    }
}
