//! Federated dataset partitioners.
//!
//! Produces per-client index sets over a [`Dataset`]:
//! * [`iid`] — shuffle and split evenly (paper: "training sets are evenly
//!   distributed over N clients", CIFAR experiments);
//! * [`dirichlet`] — label-skew non-IID with concentration `alpha`
//!   (standard FL benchmark protocol);
//! * [`by_writer`] — assign whole writers to clients (the natural
//!   F-EMNIST non-IID split the paper uses).

use crate::util::prng::Rng;

use super::Dataset;

/// Per-client sample indices.
#[derive(Clone, Debug)]
pub struct Partition {
    /// One index shard per client.
    pub clients: Vec<Vec<usize>>,
}

impl Partition {
    /// Number of clients.
    pub fn n_clients(&self) -> usize {
        self.clients.len()
    }

    /// Total samples across all shards.
    pub fn total(&self) -> usize {
        self.clients.iter().map(|c| c.len()).sum()
    }

    /// Verify the partition is disjoint and within bounds.
    pub fn validate(&self, dataset_len: usize) -> Result<(), String> {
        let mut seen = vec![false; dataset_len];
        for (ci, idx) in self.clients.iter().enumerate() {
            for &i in idx {
                if i >= dataset_len {
                    return Err(format!("client {ci}: index {i} out of bounds"));
                }
                if seen[i] {
                    return Err(format!("client {ci}: index {i} duplicated"));
                }
                seen[i] = true;
            }
        }
        Ok(())
    }

    /// Label histogram per client (for non-IID diagnostics).
    pub fn label_histograms(&self, ds: &Dataset) -> Vec<Vec<usize>> {
        self.clients
            .iter()
            .map(|idx| {
                let mut h = vec![0usize; ds.classes];
                for &i in idx {
                    h[ds.labels[i] as usize] += 1;
                }
                h
            })
            .collect()
    }
}

/// IID: shuffle indices and deal them out evenly. Trailing remainder
/// samples (fewer than n_clients) are dropped so all clients hold equally
/// sized datasets, matching the paper's |D_i| = |D_j| assumption.
pub fn iid(ds: &Dataset, n_clients: usize, rng: &mut Rng) -> Partition {
    assert!(n_clients > 0);
    let mut idx: Vec<usize> = (0..ds.len()).collect();
    rng.shuffle(&mut idx);
    let per = ds.len() / n_clients;
    let clients = (0..n_clients)
        .map(|c| idx[c * per..(c + 1) * per].to_vec())
        .collect();
    Partition { clients }
}

/// Dirichlet label-skew: for each class, split its samples across clients
/// with proportions ~ Dir(alpha). Smaller alpha = more skew.
pub fn dirichlet(ds: &Dataset, n_clients: usize, alpha: f64, rng: &mut Rng) -> Partition {
    assert!(n_clients > 0);
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); ds.classes];
    for (i, &l) in ds.labels.iter().enumerate() {
        by_class[l as usize].push(i);
    }
    let mut clients: Vec<Vec<usize>> = vec![Vec::new(); n_clients];
    for mut class_idx in by_class {
        rng.shuffle(&mut class_idx);
        let props = rng.dirichlet(alpha, n_clients);
        // Convert proportions to contiguous cut points.
        let n = class_idx.len();
        let mut start = 0usize;
        let mut acc = 0f64;
        for (c, p) in props.iter().enumerate() {
            acc += p;
            let end = if c + 1 == n_clients { n } else { (acc * n as f64).round() as usize };
            let end = end.clamp(start, n);
            clients[c].extend_from_slice(&class_idx[start..end]);
            start = end;
        }
    }
    for c in &mut clients {
        rng.shuffle(c);
    }
    Partition { clients }
}

/// By-writer: whole writers are dealt to clients round-robin after a
/// shuffle; every sample of a writer lands on the same client.
pub fn by_writer(ds: &Dataset, n_clients: usize, rng: &mut Rng) -> Partition {
    assert!(n_clients > 0);
    let max_writer = ds.writers.iter().copied().max().unwrap_or(0) as usize;
    let mut writer_order: Vec<usize> = (0..=max_writer).collect();
    rng.shuffle(&mut writer_order);
    let mut writer_to_client = vec![0usize; max_writer + 1];
    for (pos, &w) in writer_order.iter().enumerate() {
        writer_to_client[w] = pos % n_clients;
    }
    let mut clients: Vec<Vec<usize>> = vec![Vec::new(); n_clients];
    for (i, &w) in ds.writers.iter().enumerate() {
        clients[writer_to_client[w as usize]].push(i);
    }
    Partition { clients }
}

/// Trim every client's shard to the same length (the paper's equal-|D_i|
/// assumption); useful after dirichlet/by_writer which produce skewed
/// shard sizes.
pub fn equalize(p: &mut Partition) {
    if let Some(min) = p.clients.iter().map(|c| c.len()).min() {
        for c in &mut p.clients {
            c.truncate(min);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::femnist::{generate, FemnistSpec};
    use crate::data::synthetic::{generate as gen_syn, SyntheticSpec};

    fn ds() -> Dataset {
        let spec = SyntheticSpec { height: 4, width: 4, channels: 1, classes: 5, ..SyntheticSpec::cifar_like() };
        gen_syn(&spec, 100, 1)
    }

    #[test]
    fn iid_even_and_disjoint() {
        let d = ds();
        let mut rng = Rng::new(2);
        let p = iid(&d, 5, &mut rng);
        assert_eq!(p.n_clients(), 5);
        assert!(p.clients.iter().all(|c| c.len() == 20));
        p.validate(d.len()).unwrap();
    }

    #[test]
    fn iid_drops_remainder() {
        let d = ds();
        let mut rng = Rng::new(2);
        let p = iid(&d, 3, &mut rng); // 100/3 = 33
        assert!(p.clients.iter().all(|c| c.len() == 33));
        assert_eq!(p.total(), 99);
    }

    #[test]
    fn dirichlet_disjoint_and_skewed() {
        let d = ds();
        let mut rng = Rng::new(3);
        let p = dirichlet(&d, 4, 0.2, &mut rng);
        p.validate(d.len()).unwrap();
        assert_eq!(p.total(), d.len());
        // With small alpha, at least one client must be visibly skewed:
        // top class share > 2x the uniform share.
        let hists = p.label_histograms(&d);
        let skewed = hists.iter().any(|h| {
            let tot: usize = h.iter().sum();
            tot > 0 && *h.iter().max().unwrap() as f64 / tot as f64 > 2.0 / 5.0
        });
        assert!(skewed, "{hists:?}");
    }

    #[test]
    fn dirichlet_large_alpha_approaches_iid() {
        let d = ds();
        let mut rng = Rng::new(4);
        let p = dirichlet(&d, 4, 1000.0, &mut rng);
        p.validate(d.len()).unwrap();
        for h in p.label_histograms(&d) {
            let tot: usize = h.iter().sum();
            let top = *h.iter().max().unwrap() as f64 / tot as f64;
            assert!(top < 0.35, "{h:?}");
        }
    }

    #[test]
    fn by_writer_keeps_writers_whole() {
        let spec = FemnistSpec { writers: 9, samples_per_writer: 10, ..FemnistSpec::default_like() };
        let d = generate(&spec, 5);
        let mut rng = Rng::new(6);
        let p = by_writer(&d, 3, &mut rng);
        p.validate(d.len()).unwrap();
        assert_eq!(p.total(), d.len());
        // each writer's samples all on one client
        for (ci, idx) in p.clients.iter().enumerate() {
            for &i in idx {
                let w = d.writers[i];
                for (cj, idx2) in p.clients.iter().enumerate() {
                    if ci != cj {
                        assert!(idx2.iter().all(|&k| d.writers[k] != w));
                    }
                }
            }
        }
    }

    #[test]
    fn equalize_trims() {
        let mut p = Partition { clients: vec![vec![0, 1, 2], vec![3], vec![4, 5]] };
        equalize(&mut p);
        assert!(p.clients.iter().all(|c| c.len() == 1));
    }
}
