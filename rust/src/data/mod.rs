//! Data substrate: synthetic datasets + federated partitioners.
//!
//! The paper evaluates on CIFAR-10 and F-EMNIST. Neither is downloadable
//! in this environment (repro band 0/5), so per DESIGN.md §Substitutions
//! we synthesize structurally-equivalent datasets:
//!
//! * [`synthetic`] — class-template image generator (CIFAR-10-like:
//!   10 classes, 32x32x3). Learnable, with a real generalization gap.
//! * [`femnist`] — synthetic *writers* with persistent styles (62
//!   classes, 28x28x1); partitioning by writer reproduces the natural
//!   non-IID structure of the real F-EMNIST ("writing style varies from
//!   person to person").
//! * [`partition`] — IID, Dirichlet non-IID, and by-writer partitioners.
//! * [`batcher`] — deterministic per-client mini-batch iteration.

pub mod batcher;
pub mod femnist;
pub mod partition;
pub mod synthetic;

/// A dataset of dense NHWC f32 images + integer labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Flattened images, row-major [n, h, w, c].
    pub images: Vec<f32>,
    /// Class labels in [0, classes).
    pub labels: Vec<i32>,
    /// Per-sample shape [h, w, c].
    pub shape: [usize; 3],
    /// Number of classes.
    pub classes: usize,
    /// Writer/author id per sample (used by the by-writer partitioner);
    /// all zeros for datasets without writer structure.
    pub writers: Vec<u32>,
}

impl Dataset {
    /// Number of samples.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// Whether the dataset holds no samples.
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Elements per sample (h·w·c).
    pub fn sample_size(&self) -> usize {
        self.shape[0] * self.shape[1] * self.shape[2]
    }

    /// Borrow the pixels of sample `i`.
    pub fn image(&self, i: usize) -> &[f32] {
        let n = self.sample_size();
        &self.images[i * n..(i + 1) * n]
    }

    /// Gather samples at `idx` into a contiguous batch buffer.
    pub fn gather(&self, idx: &[usize], images_out: &mut Vec<f32>, labels_out: &mut Vec<i32>) {
        let n = self.sample_size();
        images_out.clear();
        labels_out.clear();
        images_out.reserve(idx.len() * n);
        labels_out.reserve(idx.len());
        for &i in idx {
            images_out.extend_from_slice(self.image(i));
            labels_out.push(self.labels[i]);
        }
    }

    /// Per-class sample counts (sanity metric for partition skew).
    pub fn class_histogram(&self) -> Vec<usize> {
        let mut h = vec![0usize; self.classes];
        for &l in &self.labels {
            h[l as usize] += 1;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset {
            images: (0..2 * 2 * 2 * 1).map(|x| x as f32).collect(),
            labels: vec![0, 1],
            shape: [2, 2, 1],
            classes: 2,
            writers: vec![0, 0],
        }
    }

    #[test]
    fn image_slicing() {
        let d = tiny();
        assert_eq!(d.len(), 2);
        assert_eq!(d.sample_size(), 4);
        assert_eq!(d.image(1), &[4.0, 5.0, 6.0, 7.0]);
    }

    #[test]
    fn gather_batches() {
        let d = tiny();
        let mut imgs = Vec::new();
        let mut labs = Vec::new();
        d.gather(&[1, 0, 1], &mut imgs, &mut labs);
        assert_eq!(labs, vec![1, 0, 1]);
        assert_eq!(imgs.len(), 12);
        assert_eq!(&imgs[0..4], d.image(1));
    }

    #[test]
    fn histogram() {
        let d = tiny();
        assert_eq!(d.class_histogram(), vec![1, 1]);
    }
}
