//! `cse-fsl` — launcher for the CSE-FSL reproduction.
//!
//! Subcommands:
//!   run      one training run (any method-spec point: preset --method,
//!            or composed --update/--upload-every/--clip/--topology),
//!            prints the round table and summary
//!   figure   regenerate a figure (3|4|5|6|7|8|9|k|h|b|r|all; `k` is the
//!            repo's accuracy-vs-shards staleness figure, `h` the
//!            upload-period x topology figure, `b` the accuracy-vs-bits
//!            compression figure, `r` the accuracy-vs-churn-severity
//!            reliability figure)
//!   table    regenerate a paper table (2|3|4|5|all)
//!   sweep    run a declarative sweep (k|h|b|r|all) with a crash-durable
//!            trial journal; `--resume` skips journaled-complete trials
//!            and `--fail-after N` injects a mid-sweep abort (CI/tests)
//!   inspect  show the AOT artifact manifest
//!
//! Everything requires `make artifacts` to have produced `artifacts/`.

use cse_fsl::coordinator::config::{ArrivalOrder, Parallelism};
use cse_fsl::coordinator::methods::{Compression, MethodSpec};
use cse_fsl::sim::churn::{ChurnConfig, ChurnModel, ResiliencePolicy};
use cse_fsl::exp::common::{
    cifar_workload, femnist_workload, Dist, EngineChoice, Harness, RunSpec, Scale,
    STREAM_THRESHOLD,
};
use cse_fsl::exp::sweep::{self, SweepOptions};
use cse_fsl::exp::{figures, tables};
use cse_fsl::util::cli::Command;
use cse_fsl::util::logging;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Ok(level) = std::env::var("CSE_FSL_LOG") {
        logging::set_level(logging::level_from_str(&level));
    }
    let code = match argv.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&argv[1..]),
        Some("figure") => cmd_figure(&argv[1..]),
        Some("table") => cmd_table(&argv[1..]),
        Some("sweep") => cmd_sweep(&argv[1..]),
        Some("inspect") => cmd_inspect(&argv[1..]),
        Some("--help") | Some("-h") | None => {
            eprintln!(
                "cse-fsl — Communication and Storage Efficient Federated Split Learning\n\n\
                 USAGE:\n  cse-fsl <run|figure|table|sweep|inspect> [args]\n\n\
                 EXAMPLES:\n  cse-fsl run --dataset femnist --method cse --h 2 --rounds 20\n  \
                 cse-fsl figure 4 --scale ci\n  cse-fsl table all\n  \
                 cse-fsl sweep h --scale paper --engine mock --resume\n  cse-fsl inspect"
            );
            0
        }
        Some(other) => {
            eprintln!("unknown subcommand {other:?}; try --help");
            2
        }
    };
    std::process::exit(code);
}

fn fail(e: impl std::fmt::Display) -> i32 {
    eprintln!("error: {e}");
    1
}

/// Parse a client count, accepting `_` digit separators the way Rust
/// literals do (`--clients 1_000_000`).
fn parse_clients(s: &str) -> Result<usize, String> {
    if s.is_empty() || s.starts_with('_') || s.ends_with('_') {
        return Err(format!("bad --clients {s:?}"));
    }
    let compact: String = s.chars().filter(|&c| c != '_').collect();
    compact.parse().map_err(|e| format!("bad --clients {s:?}: {e}"))
}

fn cmd_run(argv: &[String]) -> i32 {
    let cmd = Command::new("cse-fsl run", "run one federated-split-learning training job")
        .opt("dataset", "femnist", "cifar | femnist")
        .opt("aux", "", "auxiliary arch (default: cnn27 for cifar, cnn8 for femnist)")
        .opt(
            "method",
            "cse",
            "preset base spec: mc | oc | an | cse (axis flags below override \
             individual axes of the preset)",
        )
        .opt_nodefault(
            "h",
            "local batches per smashed upload (alias of --upload-every; the \
             aux-local update rule only for h>1; absent = keep the --method \
             preset's schedule, i.e. h=1 for every preset)",
        )
        .opt_nodefault(
            "update",
            "client-update axis: grad (server-grad downlink) | aux (aux-local) | \
             sage (gradient estimator, FSL-SAGE: aux-local rounds with a \
             true-gradient alignment every --align-every rounds); overrides the \
             --method preset's axis",
        )
        .opt_nodefault(
            "upload-every",
            "upload-schedule axis: <h> | adaptive:<h0>:<h_max>:<double_every>; \
             takes precedence over --h",
        )
        .opt_nodefault(
            "clip",
            "gradient-norm clip of the server-grad update rule (composes with \
             --update grad / the mc|oc presets, and with --update sage on its \
             alignment round trip; 0 = off)",
        )
        .opt_nodefault(
            "align-every",
            "alignment period of --update sage: every Nth upload triggers the \
             true-gradient downlink + estimator re-fit (>= 1; default 4)",
        )
        .opt_nodefault(
            "topology",
            "server-topology axis: per-client | shared; overrides the --method \
             preset's axis",
        )
        .opt_nodefault(
            "compress",
            "wire-compression axis: none | quantize | topk (FedLite-style lossy \
             codec on smashed uploads, and on grad downlinks for the server-grad \
             rule; absent = none, full precision)",
        )
        .opt_nodefault(
            "bits",
            "bits per element of --compress quantize (1..=16; default 8)",
        )
        .opt_nodefault(
            "topk",
            "kept fraction of --compress topk (in (0, 1]; default 0.25)",
        )
        .opt(
            "clients",
            "5",
            "number of clients; `_` separators allowed (1_000_000). Counts >= \
             4096 run on the streaming population engine (mock backend, IID \
             pool): memory stays flat in the fleet size",
        )
        .opt("participation", "0", "clients sampled per round (0 = all)")
        .opt("dist", "iid", "iid | dir | writer")
        .opt("rounds", "20", "communication rounds")
        .opt("lr", "0.02", "initial learning rate")
        .opt("seed", "1", "experiment seed")
        .opt("scale", "ci", "workload preset: quick (alias smoke) | ci | paper")
        .opt("out", "results", "output directory")
        .opt(
            "parallelism",
            "auto",
            "client fan-out: seq | auto | <threads> (bit-identical results either way)",
        )
        .opt(
            "server-shards",
            "1",
            "server shard count k (OC/CSE only): k copies + k event loops, \
             cross-shard FedAvg every aggregation; changes results (cached per k)",
        )
        .opt(
            "sched",
            "rr",
            "fan-out dealing policy: rr | cost | steal \
             (bit-identical results for every policy; wall-clock only)",
        )
        .opt(
            "shard-map",
            "contiguous",
            "client -> shard assignment: contiguous | balanced | locality \
             (balanced/locality need --server-shards >= 2; locality also needs a \
             non-IID --dist; both change results, cached per map)",
        )
        .opt(
            "engine",
            "auto",
            "compute backend: auto | pjrt | mock (mock = deterministic \
             linear-dynamics engine, no AOT artifacts needed; cached under cache/mock/)",
        )
        .opt(
            "churn",
            "none",
            "availability model: none | iid:<p> | diurnal:<amp>:<period>[:<phase>] | \
             markov:<p_up>:<p_down> | correlated:<clusters>:<p_outage> \
             (per-(round,client) split-stream draws; bit-deterministic)",
        )
        .opt(
            "fail-rate",
            "0",
            "mid-round failure probability per sampled participant in [0, 1): a \
             failed client uploads a prefix of its h batches (half wire cost, no \
             labels) and contributes nothing to this round's updates",
        )
        .opt_nodefault(
            "cutoff",
            "straggler window in simulated seconds: drop smashed uploads arriving \
             more than this long after the round's first arrival (>= 0; mutually \
             exclusive with --quorum)",
        )
        .opt_nodefault(
            "quorum",
            "minimum surviving cohort fraction in (0, 1]: below it the round \
             proceeds partially, or re-samples replacements with --resample \
             (mutually exclusive with --cutoff)",
        )
        .flag("resample", "re-sample deterministic replacements below --quorum")
        .flag("shuffled-arrivals", "randomize server consumption order (Fig. 6)");
    let args = match cmd.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n\n{}", cmd.usage());
            return 2;
        }
    };
    let run = || -> Result<(), String> {
        let dataset = args.get("dataset").unwrap().to_string();
        let scale = Scale::parse(args.get("scale").unwrap()).ok_or("bad --scale")?;
        let mut workload = match dataset.as_str() {
            "cifar" => cifar_workload(scale),
            "femnist" => femnist_workload(scale),
            other => return Err(format!("unknown dataset {other}")),
        };
        workload.rounds = args.parse_as("rounds").map_err(|e| e.to_string())?;
        let aux = match args.get("aux").unwrap() {
            "" => if dataset == "cifar" { "cnn27" } else { "cnn8" }.to_string(),
            a => a.to_string(),
        };
        let dist = args
            .get("dist")
            .and_then(Dist::parse)
            .ok_or_else(|| format!("unknown dist {:?}", args.get("dist").unwrap_or("")))?;
        // Method-spec resolution is centralized in MethodSpec::from_cli
        // (--method preset base, axis flags override; --upload-every
        // wins over the historical --h alias when both are given).
        let method = MethodSpec::from_cli(
            args.get("method").unwrap(),
            args.get("update"),
            args.get("upload-every").or_else(|| args.get("h")),
            args.get("clip"),
            args.get("align-every"),
            args.get("topology"),
            args.get("compress"),
            args.get("bits"),
            args.get("topk"),
        )?;
        let policy = match (args.get("cutoff"), args.get("quorum")) {
            (Some(_), Some(_)) => {
                return Err("--cutoff and --quorum are mutually exclusive".into());
            }
            (Some(_), None) => ResiliencePolicy::Cutoff {
                secs: args.parse_as("cutoff").map_err(|e| e.to_string())?,
            },
            (None, Some(_)) => ResiliencePolicy::Quorum {
                min_frac: args.parse_as("quorum").map_err(|e| e.to_string())?,
                resample: args.flag("resample"),
            },
            (None, None) => {
                if args.flag("resample") {
                    return Err("--resample needs --quorum".into());
                }
                ResiliencePolicy::WaitAll
            }
        };
        let churn = ChurnConfig {
            model: ChurnModel::parse(args.get("churn").unwrap())?,
            fail_rate: args.parse_as("fail-rate").map_err(|e| e.to_string())?,
            policy,
        };
        churn.validate()?;
        let spec = RunSpec {
            dataset,
            aux,
            method,
            n_clients: parse_clients(args.get("clients").unwrap())?,
            participation: args.parse_as("participation").map_err(|e| e.to_string())?,
            dist,
            arrival: if args.flag("shuffled-arrivals") {
                ArrivalOrder::Shuffled
            } else {
                ArrivalOrder::ByDelay
            },
            lr0: args.parse_as("lr").map_err(|e| e.to_string())?,
            seed: args.parse_as("seed").map_err(|e| e.to_string())?,
            workload,
            parallelism: args
                .parse_as::<Parallelism>("parallelism")
                .map_err(|e| e.to_string())?,
            server_shards: args.parse_as("server-shards").map_err(|e| e.to_string())?,
            sched: args.parse_as("sched").map_err(|e| e.to_string())?,
            shard_map: args.parse_as("shard-map").map_err(|e| e.to_string())?,
            churn,
        };
        let engine =
            EngineChoice::parse(args.get("engine").unwrap()).ok_or("bad --engine")?;
        let mut harness = Harness::with_engine(args.get("out").unwrap(), engine)?;
        let rec = harness.run_cached(&spec)?;
        println!("== {} [engine: {}] ==", rec.label, harness.backend());
        if spec.method.compression != Compression::None {
            println!("wire compression: {}", spec.method.compression);
        }
        println!("round  train_loss  server_loss  acc");
        for r in &rec.rounds {
            println!(
                "{:>5}  {:>10.4}  {:>11.4}  {}",
                r.round,
                r.train_loss,
                r.server_loss,
                r.accuracy.map(|a| format!("{:.1}%", a * 100.0)).unwrap_or_else(|| "-".into())
            );
        }
        println!(
            "final accuracy {:.2}%   load {:.4} GB   storage {:.2} M params   sim {:.2}s (idle {:.0}%)",
            rec.final_accuracy * 100.0,
            rec.total_gb(),
            rec.server_storage_params as f64 / 1e6,
            rec.sim_time,
            rec.server_idle_fraction * 100.0,
        );
        println!(
            "sched: critical path {:.2}s / makespan {:.2}s -> efficiency {:.0}%",
            rec.critical_path,
            rec.sim_time,
            rec.sched_efficiency() * 100.0,
        );
        if !spec.churn.is_default() {
            println!(
                "churn [{} fail-rate {} policy {}]: {} dropped, {} replaced, \
                 {} partial failures, {} stragglers cut",
                spec.churn.model,
                spec.churn.fail_rate,
                spec.churn.policy,
                rec.clients_dropped,
                rec.clients_replaced,
                rec.partial_failures,
                rec.stragglers_dropped,
            );
        }
        if spec.n_clients >= STREAM_THRESHOLD {
            println!(
                "fleet: {} clients, {} ever materialized (streaming population engine)",
                spec.n_clients, rec.clients_activated,
            );
        }
        if spec.server_shards > 1 {
            println!(
                "server updates per shard: {:?} (total {})",
                rec.server_updates_per_shard,
                rec.server_updates(),
            );
            let lanes: Vec<String> =
                rec.lane_busy.iter().map(|b| format!("{b:.2}")).collect();
            println!("lane busy (s): [{}]", lanes.join(", "));
            println!(
                "shard label divergence: {:.4} (0 = every shard copy trains on \
                 the global label mix)",
                rec.shard_label_divergence,
            );
        }
        let csv = harness.out_dir.join(format!("run_{}.csv", rec.label.replace([' ', '='], "_")));
        rec.write_csv(&csv).map_err(|e| e.to_string())?;
        println!("per-round CSV: {}", csv.display());
        Ok(())
    };
    run().map(|_| 0).unwrap_or_else(fail)
}

fn figure_table_args(
    argv: &[String],
    what: &str,
) -> Result<(String, Scale, String, EngineChoice), String> {
    let cmd =
        Command::new(&format!("cse-fsl {what}"), &format!("regenerate a paper {what}"))
            .positional("id", "which one (or 'all')")
            .opt("scale", "ci", "quick (alias smoke) | ci | paper")
            .opt("out", "results", "output directory")
            .opt("engine", "auto", "compute backend: auto | pjrt | mock");
    let args = cmd.parse(argv).map_err(|e| format!("{e}\n\n{}", cmd.usage()))?;
    let id = args.positional("id").unwrap().to_string();
    let scale = Scale::parse(args.get("scale").unwrap()).ok_or("bad --scale")?;
    let engine = EngineChoice::parse(args.get("engine").unwrap()).ok_or("bad --engine")?;
    Ok((id, scale, args.get("out").unwrap().to_string(), engine))
}

fn cmd_figure(argv: &[String]) -> i32 {
    let run = || -> Result<(), String> {
        let (id, scale, out, engine) = figure_table_args(argv, "figure")?;
        let mut harness = Harness::with_engine(&out, engine)?;
        println!("(engine backend: {})", harness.backend());
        let ids: Vec<&str> = if id == "all" {
            vec!["3", "4", "5", "6", "7", "8", "9", "k", "h", "b", "r"]
        } else {
            vec![id.as_str()]
        };
        for id in ids {
            let report = match id {
                "3" => figures::fig3_metrics(&mut harness, scale)?,
                "4" => figures::fig4(&mut harness, scale)?,
                "5" => figures::fig5(&mut harness, scale)?,
                "6" => figures::fig6(&mut harness, scale)?,
                "7" => figures::fig7(&mut harness, scale)?,
                "8" => figures::fig8(&mut harness, scale)?,
                "9" => figures::fig9(&mut harness, scale)?,
                "k" | "staleness" => figures::fig_staleness(&mut harness, scale)?,
                "h" | "period" => figures::fig_h(&mut harness, scale)?,
                "b" | "bits" => figures::fig_b(&mut harness, scale)?,
                "r" | "churn" => figures::fig_churn(&mut harness, scale)?,
                other => return Err(format!("no figure {other} (have 3-9, k, h, b, r)")),
            };
            println!("{report}");
        }
        println!("(series CSVs under {out}/)");
        Ok(())
    };
    run().map(|_| 0).unwrap_or_else(fail)
}

fn cmd_table(argv: &[String]) -> i32 {
    let run = || -> Result<(), String> {
        let (id, scale, out, engine) = figure_table_args(argv, "table")?;
        let mut harness = Harness::with_engine(&out, engine)?;
        let ids: Vec<&str> =
            if id == "all" { vec!["2", "3", "4", "5"] } else { vec![id.as_str()] };
        for id in ids {
            let report = match id {
                "2" => tables::table2_report(&mut harness)?,
                "3" | "4" => tables::table34_report(&mut harness)?,
                "5" => tables::table5_report(&mut harness, scale)?,
                other => return Err(format!("no table {other} (have 2-5)")),
            };
            println!("{report}");
        }
        Ok(())
    };
    run().map(|_| 0).unwrap_or_else(fail)
}

fn cmd_sweep(argv: &[String]) -> i32 {
    let cmd = Command::new(
        "cse-fsl sweep",
        "run a declarative sweep with a crash-durable trial journal",
    )
    .positional("spec", "which sweep: k|staleness, h|period, b|bits, r|churn, all")
    .opt("scale", "ci", "quick (alias smoke) | ci | paper")
    .opt("out", "results", "output directory")
    .opt("engine", "auto", "compute backend: auto | pjrt | mock")
    .flag(
        "resume",
        "reopen the trial journal (tolerating a torn final line) and skip \
         journaled-complete trials instead of starting fresh",
    )
    .opt_nodefault(
        "fail-after",
        "fault injection: abort after N executed trials, leaving the journal \
         behind for --resume (tests/CI)",
    );
    let run = || -> Result<(), String> {
        let args = cmd.parse(argv).map_err(|e| format!("{e}\n\n{}", cmd.usage()))?;
        let id = args.positional("spec").unwrap().to_string();
        let scale = Scale::parse(args.get("scale").unwrap()).ok_or("bad --scale")?;
        let engine =
            EngineChoice::parse(args.get("engine").unwrap()).ok_or("bad --engine")?;
        let fail_after = match args.get("fail-after") {
            Some(_) => Some(args.parse_as::<usize>("fail-after").map_err(|e| e.to_string())?),
            None => None,
        };
        let opts = SweepOptions { resume: args.flag("resume"), fail_after };
        let mut harness = Harness::with_engine(args.get("out").unwrap(), engine)?;
        println!("(engine backend: {})", harness.backend());
        for sw in sweep::builtin(&id, scale)? {
            let outcome = sweep::run_sweep(&mut harness, &sw, &opts)?;
            println!("{}", outcome.report);
            println!(
                "sweep {}: {} trials, {} journaled-complete (skipped), {} executed",
                sw.name, outcome.total, outcome.skipped, outcome.executed
            );
            println!("journal: {}", outcome.journal.display());
            println!("csv:     {}\n", outcome.csv.display());
        }
        Ok(())
    };
    run().map(|_| 0).unwrap_or_else(fail)
}

fn cmd_inspect(_argv: &[String]) -> i32 {
    let run = || -> Result<(), String> {
        let dir = cse_fsl::runtime::artifacts_dir();
        let manifest = cse_fsl::runtime::artifact::Manifest::load(&dir)
            .map_err(|e| format!("{e}\nhint: run `make artifacts`"))?;
        println!("artifacts: {}", dir.display());
        for (name, cfg) in &manifest.configs {
            println!(
                "\n[{name}] batch={} input={:?} classes={} smashed={:?}",
                cfg.batch, cfg.input, cfg.classes, cfg.smashed
            );
            println!(
                "  client params {:>9}   server params {:>9}",
                cfg.client_layout.total, cfg.server_layout.total
            );
            for (arch, aux) in &cfg.aux {
                println!("  aux {arch:<6} params {:>9}", aux.size);
            }
            println!(
                "  entries: {}",
                cfg.entries.keys().cloned().collect::<Vec<_>>().join(", ")
            );
        }
        Ok(())
    };
    run().map(|_| 0).unwrap_or_else(fail)
}
