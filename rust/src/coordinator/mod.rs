//! The L3 coordinator — the paper's system contribution.
//!
//! [`methods`] defines the four compared FSL variants; [`config`] the run
//! configuration; [`client`]/[`server`] the per-party state (including
//! the event-triggered `dataQueue` of Algorithm 2); [`round`] the trainer
//! that drives communication rounds, asynchronous server updates,
//! aggregation, and all accounting.

pub mod client;
pub mod config;
pub mod methods;
pub mod round;
pub mod server;
