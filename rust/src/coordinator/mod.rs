//! The L3 coordinator — the paper's system contribution.
//!
//! [`methods`] defines the composable `MethodSpec` API (client-update
//! rule × upload schedule × server topology, with the paper's four
//! methods as presets); [`config`] the run configuration; [`client`]/
//! [`server`] the per-party state (including the event-triggered
//! `dataQueue` of Algorithm 2); [`round`] the trainer that drives
//! communication rounds, asynchronous server updates, aggregation, and
//! all accounting — branching only on the spec's axes.

pub mod client;
pub mod config;
pub mod methods;
pub mod round;
pub mod server;
