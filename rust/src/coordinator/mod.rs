//! The L3 coordinator — the paper's system contribution.
//!
//! [`methods`] defines the composable `MethodSpec` API (client-update
//! rule × upload schedule × server topology, with the paper's four
//! methods as presets); [`config`] the run configuration; [`client`]/
//! [`server`] the per-party state (including the event-triggered
//! `dataQueue` of Algorithm 2); [`round`] the trainer that drives
//! communication rounds, asynchronous server updates, aggregation, and
//! all accounting — branching only on the spec's axes; [`population`]
//! the streaming client-population engine behind `Trainer::
//! new_population` — clients sampled per round from a `ClientSource`
//! distribution, materialized lazily on activation, and retired after
//! their aggregation upload, so fleet-scale runs (`--clients 1_000_000`)
//! hold only the sampled working set in memory.

pub mod client;
pub mod config;
pub mod methods;
pub mod population;
pub mod round;
pub mod server;
