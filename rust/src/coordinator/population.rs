//! Streaming client *population* for fleet-scale runs.
//!
//! The resident engine ([`Trainer::new`]) materializes every
//! [`ClientState`] up front — O(n) models, batchers, and profiles —
//! which caps it at a few thousand clients. The population engine
//! ([`Trainer::new_population`]) instead treats clients as a
//! *distribution*: a [`ClientSource`] describes where any client's data
//! shard comes from, [`NetModel::profile_for`] derives any client's
//! persistent delay profile per id, and full [`ClientState`]s are built
//! **lazily on first activation** (sampled into a round's cohort) and
//! **retired after their aggregation upload** (model buffers dropped,
//! private RNG/batcher state carried). Peak memory is bounded by the
//! working set — the clients activated at least once — independent of
//! the population size n (`--clients 1_000_000` on the mock engine).
//!
//! # Bit-determinism contract
//!
//! A population run over a [`ClientSource::Partition`] source produces a
//! `RunRecord` **bit-identical** to the resident engine over the same
//! partition and config (enforced by `tests/population_equivalence.rs`),
//! because every random stream is derived per id from non-mutated roots
//! (never positionally), every merge happens in canonical client-id
//! order, and every floating-point accumulation the record depends on
//! replays the resident operation order exactly:
//!
//! * arrivals drain through [`EventQueue`] — min-order with FIFO ties —
//!   which reproduces the resident engine's stable sort by arrival time
//!   when messages are enqueued in participant order;
//! * the O(n) aggregation broadcast is replayed as a streaming sweep
//!   (running `dl_end_max`, per-client busy folds in span-record order)
//!   instead of O(n) recorded `Download` spans;
//! * the evaluation FedAvg iterates ids `0..n`, substituting the carried
//!   diverged model where one exists and the post-aggregation global
//!   model everywhere else — the identical `+= v * inv` f32 reduction.
//!
//! [`Trainer::new`]: super::round::Trainer::new
//! [`Trainer::new_population`]: super::round::Trainer::new_population
//! [`ClientState`]: super::client::ClientState
//! [`NetModel::profile_for`]: crate::sim::netmodel::NetModel::profile_for
//! [`EventQueue`]: crate::sim::event::EventQueue

use std::collections::{BTreeMap, BTreeSet};

use crate::data::partition::Partition;
use crate::data::Dataset;
use crate::sched::cost::EWMA_ALPHA;
use crate::sim::netmodel::NetModel;
use crate::util::prng::Rng;

use super::client::ClientState;
use super::server::ShardMap;

/// Where a population client's data shard comes from.
pub enum ClientSource {
    /// An explicit per-client index partition — the resident engine's
    /// input, offered so small-n population runs can be checked
    /// bit-identical against [`Trainer::new`]. O(total samples) memory,
    /// so only viable at resident scale.
    ///
    /// [`Trainer::new`]: super::round::Trainer::new
    Partition(Partition),
    /// A synthetic fleet over a shared sample pool: client `i` holds the
    /// `samples_per_client` indices `(i * spc + j) % pool_len`. Shards
    /// are computed on activation (O(spc) each, nothing global), so the
    /// source itself is O(1) in n — the fleet-scale mode.
    Pool {
        /// Population size n.
        n_clients: usize,
        /// Samples per client shard.
        samples_per_client: usize,
        /// Shared pool size (indices cycle modulo this; must not exceed
        /// the dataset length).
        pool_len: usize,
    },
}

impl ClientSource {
    /// Population size n.
    pub fn n_clients(&self) -> usize {
        match self {
            ClientSource::Partition(p) => p.n_clients(),
            ClientSource::Pool { n_clients, .. } => *n_clients,
        }
    }

    /// Materialize client `id`'s sample-index shard (called once per
    /// activation).
    pub fn shard_of(&self, id: usize) -> Vec<usize> {
        match self {
            ClientSource::Partition(p) => p.clients[id].clone(),
            ClientSource::Pool { samples_per_client, pool_len, .. } => (0..*samples_per_client)
                .map(|j| (id * samples_per_client + j) % pool_len)
                .collect(),
        }
    }

    /// Check the source against the backing dataset.
    pub fn validate(&self, dataset_len: usize) -> Result<(), String> {
        match self {
            ClientSource::Partition(p) => p.validate(dataset_len),
            ClientSource::Pool { n_clients, samples_per_client, pool_len } => {
                if *n_clients == 0 {
                    return Err("pool source: zero clients".into());
                }
                if *samples_per_client == 0 {
                    return Err("pool source: zero samples per client".into());
                }
                if *pool_len == 0 || *pool_len > dataset_len {
                    return Err(format!(
                        "pool source: pool_len {pool_len} outside 1..={dataset_len}"
                    ));
                }
                Ok(())
            }
        }
    }

    /// The shard-skew metric the resident engine records
    /// ([`ShardMap::label_divergence_weighted`]), computed without
    /// materializing per-client histograms: one streaming pass over the
    /// population accumulates the k × classes shard mixes directly, so
    /// memory is O(shards · classes) at any n. For a `Partition` source
    /// this defers to the resident metric verbatim (the bit-determinism
    /// contract covers the recorded value).
    pub fn label_divergence_weighted(&self, map: &ShardMap, ds: &Dataset) -> f64 {
        match self {
            ClientSource::Partition(p) => {
                map.label_divergence_weighted(&p.label_histograms(ds))
            }
            ClientSource::Pool { n_clients, samples_per_client, pool_len } => {
                let classes = ds.classes;
                if classes == 0 || map.shards() == 0 || *n_clients == 0 {
                    return 0.0;
                }
                let mut global = vec![0f64; classes];
                let mut shard_h = vec![vec![0f64; classes]; map.shards()];
                for c in 0..*n_clients {
                    let s = map.shard_of(c);
                    for j in 0..*samples_per_client {
                        let idx = (c * samples_per_client + j) % pool_len;
                        let k = ds.labels[idx] as usize;
                        global[k] += 1.0;
                        shard_h[s][k] += 1.0;
                    }
                }
                let g_tot: f64 = global.iter().sum();
                if g_tot == 0.0 {
                    return 0.0;
                }
                let mut acc = 0.0;
                for sh in &shard_h {
                    let s_tot: f64 = sh.iter().sum();
                    if s_tot == 0.0 {
                        continue;
                    }
                    let tv: f64 = sh
                        .iter()
                        .zip(&global)
                        .map(|(&s, &g)| (s / s_tot - g / g_tot).abs())
                        .sum();
                    acc += (s_tot / g_tot) * 0.5 * tv;
                }
                acc
            }
        }
    }
}

/// Everything needed to build a population trainer
/// ([`Trainer::new_population`]).
///
/// [`Trainer::new_population`]: super::round::Trainer::new_population
pub struct PopulationSetup<'a> {
    /// Training dataset the source's shard indices point into.
    pub train: &'a Dataset,
    /// Held-out evaluation dataset.
    pub test: &'a Dataset,
    /// The client population distribution.
    pub source: ClientSource,
    /// Client heterogeneity / network delay model.
    pub net: NetModel,
    /// Human-readable run label carried into the `RunRecord`.
    pub label: String,
}

impl<'a> PopulationSetup<'a> {
    /// A setup over the given source and delay model. Availability,
    /// mid-round failures, and straggler handling are no longer setup
    /// knobs: they live in `TrainConfig::churn`
    /// ([`crate::sim::churn::ChurnConfig`]), shared with the resident
    /// engine.
    pub fn new(
        train: &'a Dataset,
        test: &'a Dataset,
        source: ClientSource,
        net: NetModel,
        label: impl Into<String>,
    ) -> Self {
        PopulationSetup { train, test, source, net, label: label.into() }
    }
}

/// One aggregation barrier's broadcast, recorded so never-yet-activated
/// clients can replay it lazily: a client first activated at round t
/// folds every earlier broadcast's download delay into its busy total
/// and ready time, exactly as if it had been resident all along.
pub struct AggEvent {
    /// Barrier end time (downloads start here).
    pub agg_done: f64,
    /// Trainer-stream snapshot at the barrier (`split` is non-mutating
    /// and aggregation never advances the stream, so
    /// `rng.split(id ^ 0xD7)` reproduces the resident per-id download
    /// jitter stream for *any* id, at any later time).
    pub rng: Rng,
    /// Broadcast payload per client (client model + aux riders).
    pub bytes: u64,
}

/// Sparse per-client cost estimates for the cost-aware dealing policies
/// — the population-engine counterpart of [`CostTracker`], keyed by id
/// instead of indexed by a dense Vec, seeded on activation. Same prior,
/// same EWMA; like the resident tracker, estimates steer dealing only
/// and can never change results.
///
/// [`CostTracker`]: crate::sched::CostTracker
#[derive(Clone, Debug, Default)]
pub struct SparseCosts {
    est: BTreeMap<usize, f64>,
}

impl SparseCosts {
    /// An empty tracker (estimates are seeded per activation).
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of clients with an estimate (== clients activated).
    pub fn len(&self) -> usize {
        self.est.len()
    }

    /// Whether no client has an estimate yet.
    pub fn is_empty(&self) -> bool {
        self.est.is_empty()
    }

    /// Install `prior` for `id` unless an estimate already exists.
    pub fn seed(&mut self, id: usize, prior: f64) {
        self.est.entry(id).or_insert(prior);
    }

    /// Current estimate for `id`; panics when the client was never
    /// seeded (mirrors [`CostTracker::estimate`]'s out-of-bounds panic).
    ///
    /// [`CostTracker::estimate`]: crate::sched::CostTracker::estimate
    pub fn estimate(&self, id: usize) -> f64 {
        self.est[&id]
    }

    /// Fold one measured round cost into `id`'s estimate — the same
    /// EWMA (and the same non-finite/negative guard) as
    /// [`CostTracker::observe`].
    ///
    /// [`CostTracker::observe`]: crate::sched::CostTracker::observe
    pub fn observe(&mut self, id: usize, measured: f64) {
        if measured.is_finite() && measured >= 0.0 {
            if let Some(e) = self.est.get_mut(&id) {
                *e = (1.0 - EWMA_ALPHA) * *e + EWMA_ALPHA * measured;
            }
        }
    }
}

/// The population engine's streaming state: the carried working set plus
/// the O(1)-per-client aggregates that replace the resident engine's
/// O(n) structures.
pub struct PopulationState {
    /// Population size n.
    pub n: usize,
    /// The client distribution (shards per id).
    pub source: ClientSource,
    /// Delay model (profiles per id via [`NetModel::profile_for`]).
    ///
    /// [`NetModel::profile_for`]: crate::sim::netmodel::NetModel::profile_for
    pub net: NetModel,
    /// Profile root stream (`root.split_str("profiles")`, never
    /// advanced).
    pub prof_root: Rng,
    /// Client private-stream root (`Rng::new(seed)`; activation derives
    /// `client_root.split(1_000 + id)` — the resident constructor arg).
    pub client_root: Rng,
    /// The model every not-currently-diverged client holds (x_c after
    /// the last aggregation; x_c^0 before the first).
    pub global_xc: Vec<f32>,
    /// Aux-network counterpart of `global_xc`.
    pub global_ac: Vec<f32>,
    /// Ever-activated clients, by id. Entries persist for the run (their
    /// private batcher/seed streams must survive retirement) but carry
    /// empty model buffers between divergence windows.
    pub carry: BTreeMap<usize, ClientState>,
    /// Clients that trained since the last aggregation (always a subset
    /// of `carry`'s keys). Ascending iteration = the resident
    /// contributor order.
    pub dirty: BTreeSet<usize>,
    /// Sparse cost estimates for the dealing policies.
    pub costs: SparseCosts,
    /// Every aggregation broadcast so far (O(rounds / agg_every)).
    pub aggs: Vec<AggEvent>,
    /// Latest broadcast download end over all n clients — the streaming
    /// stand-in for the resident engine's O(n) `Download` spans in
    /// `Timeline::end_time`.
    pub dl_end_max: f64,
    /// Per-client busy totals for ever-activated clients, accumulated in
    /// the resident span-record order (the `Timeline::critical_path`
    /// BTreeMap fold, replayed).
    pub busy: BTreeMap<usize, f64>,
    /// Smashed arrivals processed through the event queue.
    pub arrivals: u64,
}

impl PopulationState {
    /// Clients materialized at least once (the working-set size reported
    /// as `RunRecord::clients_activated`).
    pub fn activated(&self) -> usize {
        self.carry.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::CostTracker;

    fn pool_ds(len: usize, classes: usize) -> Dataset {
        Dataset {
            images: vec![0.0; len * 4],
            labels: (0..len).map(|i| (i % classes) as i32).collect(),
            shape: [2, 2, 1],
            classes,
            writers: vec![0; len],
        }
    }

    #[test]
    fn pool_shards_cycle_the_pool() {
        let src = ClientSource::Pool { n_clients: 10, samples_per_client: 3, pool_len: 7 };
        assert_eq!(src.n_clients(), 10);
        assert_eq!(src.shard_of(0), vec![0, 1, 2]);
        assert_eq!(src.shard_of(2), vec![6, 0, 1]);
        // Every index stays inside the pool.
        for id in 0..10 {
            assert!(src.shard_of(id).iter().all(|&i| i < 7));
        }
        assert!(src.validate(7).is_ok());
        assert!(src.validate(6).is_err(), "pool larger than dataset");
        let degenerate =
            ClientSource::Pool { n_clients: 0, samples_per_client: 3, pool_len: 7 };
        assert!(degenerate.validate(7).is_err());
    }

    #[test]
    fn partition_source_mirrors_partition() {
        let p = Partition { clients: vec![vec![0, 1], vec![2, 3]] };
        let src = ClientSource::Partition(p);
        assert_eq!(src.n_clients(), 2);
        assert_eq!(src.shard_of(1), vec![2, 3]);
        assert!(src.validate(4).is_ok());
        assert!(src.validate(3).is_err());
    }

    #[test]
    fn pool_divergence_matches_materialized_histograms() {
        // Build the same population both ways: streaming vs explicit
        // per-client histograms through the resident metric.
        let ds = pool_ds(12, 3);
        let (n, spc, pool) = (8usize, 3usize, 12usize);
        let src = ClientSource::Pool { n_clients: n, samples_per_client: spc, pool_len: pool };
        let map = ShardMap::contiguous(n, 3);
        let streamed = src.label_divergence_weighted(&map, &ds);
        let hists: Vec<Vec<usize>> = (0..n)
            .map(|c| {
                let mut h = vec![0usize; ds.classes];
                for j in 0..spc {
                    h[ds.labels[(c * spc + j) % pool] as usize] += 1;
                }
                h
            })
            .collect();
        let materialized = map.label_divergence_weighted(&hists);
        assert!(
            (streamed - materialized).abs() < 1e-12,
            "streamed {streamed} vs materialized {materialized}"
        );
        // A cycled pool spreads labels near-evenly: low but finite skew.
        assert!((0.0..=1.0).contains(&streamed));
    }

    #[test]
    fn sparse_costs_track_like_the_dense_tracker() {
        let mut dense = CostTracker::new(vec![2.0, 4.0, 8.0]);
        let mut sparse = SparseCosts::new();
        for (id, prior) in [(0usize, 2.0), (1, 4.0), (2, 8.0)] {
            sparse.seed(id, prior);
        }
        // Re-seeding never clobbers a live estimate.
        sparse.seed(1, 999.0);
        for (id, obs) in [(1usize, 1.0), (0, 3.5), (1, 2.0), (2, f64::NAN), (2, -1.0)] {
            dense.observe(id, obs);
            sparse.observe(id, obs);
        }
        for id in 0..3 {
            assert_eq!(dense.estimate(id), sparse.estimate(id), "client {id}");
        }
        assert_eq!(sparse.len(), 3);
    }

    #[test]
    #[should_panic]
    fn sparse_costs_panic_on_unseeded_client() {
        let sparse = SparseCosts::new();
        let _ = sparse.estimate(5);
    }
}
