//! Training-run configuration + validation.

use crate::sched::SchedPolicy;
use crate::sim::churn::ChurnConfig;

use super::methods::{Compression, Method, MethodSpec, ServerTopology};

/// Client fan-out strategy for the local-training phase of a round.
///
/// The paper's clients are fire-and-forget — they never wait for server
/// gradients — so their local work is embarrassingly parallel. `Threads`
/// runs it on a scoped thread pool; results are merged in canonical
/// order (client id, then time) so a parallel run's `RunRecord` is
/// **bit-identical** to the sequential one (enforced by
/// `tests/determinism_golden.rs`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Parallelism {
    /// One client at a time (the reference schedule).
    #[default]
    Sequential,
    /// Fan client work out over `n` worker threads (n >= 1).
    Threads(usize),
}

impl Parallelism {
    /// One worker per hardware core (what `--parallelism auto` means).
    pub fn auto() -> Self {
        Parallelism::Threads(
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        )
    }

    /// Worker threads actually used for `items` units of work.
    pub fn worker_count(self, items: usize) -> usize {
        match self {
            Parallelism::Sequential => 1,
            Parallelism::Threads(n) => n.clamp(1, items.max(1)),
        }
    }
}

impl std::fmt::Display for Parallelism {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Parallelism::Sequential => write!(f, "seq"),
            Parallelism::Threads(n) => write!(f, "threads{n}"),
        }
    }
}

impl std::str::FromStr for Parallelism {
    type Err = String;

    /// `seq` / `sequential` / `0` => Sequential; `auto` => one thread per
    /// hardware core; any integer n >= 1 => Threads(n).
    fn from_str(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "seq" | "sequential" => Ok(Parallelism::Sequential),
            "auto" => Ok(Parallelism::auto()),
            other => match other.parse::<usize>() {
                Ok(0) => Ok(Parallelism::Sequential),
                Ok(n) => Ok(Parallelism::Threads(n)),
                Err(_) => Err(format!(
                    "bad parallelism {s:?} (expected seq | auto | <threads>)"
                )),
            },
        }
    }
}

/// Client → shard assignment flavor for the sharded server phase.
///
/// Unlike [`SchedPolicy`] (pure scheduling, bit-identical results) the
/// shard map decides *which clients share a server copy* between
/// aggregations — that changes results, so the kind is part of
/// `RunSpec::key` and of run labels.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ShardMapKind {
    /// Contiguous equal-count groups in canonical client-id order (the
    /// historical assignment; a pure function of `(n_clients, k)`).
    #[default]
    Contiguous,
    /// LPT bin packing on estimated per-client costs
    /// (`ShardMap::balanced`): balances shard executor load under
    /// heterogeneous clients. Requires `server_shards >= 2`.
    Balanced,
    /// Label-distribution stratification (`ShardMap::locality`): each
    /// shard's aggregate label histogram approximates the global one,
    /// cost-balanced within each dealing wave. Built for the non-IID
    /// arms — requires `server_shards >= 2` **and** a non-IID partition
    /// (enforced where the data distribution is known:
    /// `exp::common::RunSpec::validate`).
    Locality,
}

impl ShardMapKind {
    /// Short cache-key tag (the `-m` segment of `RunSpec::key`).
    pub fn tag(self) -> &'static str {
        match self {
            ShardMapKind::Contiguous => "cont",
            ShardMapKind::Balanced => "bal",
            ShardMapKind::Locality => "loc",
        }
    }

    /// Whether this map reassigns clients across shard copies (anything
    /// but the historical contiguous grouping). Such maps need a sharded
    /// server (`server_shards >= 2`) to have anything to reassign.
    pub fn regroups_clients(self) -> bool {
        !matches!(self, ShardMapKind::Contiguous)
    }
}

impl std::fmt::Display for ShardMapKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            ShardMapKind::Contiguous => "contiguous",
            ShardMapKind::Balanced => "balanced",
            ShardMapKind::Locality => "locality",
        };
        write!(f, "{s}")
    }
}

impl std::str::FromStr for ShardMapKind {
    type Err = String;

    /// `contiguous` / `cont`; `balanced` / `bal`; `locality` / `loc`.
    fn from_str(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "contiguous" | "cont" => Ok(ShardMapKind::Contiguous),
            "balanced" | "bal" => Ok(ShardMapKind::Balanced),
            "locality" | "loc" => Ok(ShardMapKind::Locality),
            other => Err(format!(
                "bad shard map {other:?} (expected contiguous | balanced | locality)"
            )),
        }
    }
}

/// Order in which the server consumes arriving smashed-data uploads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArrivalOrder {
    /// By simulated arrival time (heterogeneous delays — the realistic
    /// asynchronous schedule of Fig. 3).
    ByDelay,
    /// Client index order (the "ordered" arm of Fig. 6).
    ClientIndex,
    /// A fresh random permutation every round (the "random" arm of
    /// Fig. 6).
    Shuffled,
}

/// Full configuration of one training run (any [`MethodSpec`] point).
///
/// Built with [`TrainConfig::new`] (preset defaults) or
/// [`TrainConfig::from_spec`] (any spec point), adjusted via the
/// `with_*` builders or struct update syntax, and checked by
/// [`TrainConfig::validate`] before any training happens.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// The algorithm point to run: client-update rule × upload schedule
    /// × server topology. The paper's four methods are the preset
    /// points ([`Method::spec`]); everything the trainer branches on
    /// comes from these axes — there is no separate method identity.
    pub spec: MethodSpec,
    /// Communication rounds to run (one round = one upload wave).
    pub rounds: usize,
    /// Aggregate every k rounds (paper: once per epoch).
    pub agg_every: usize,
    /// Initial learning rate of the schedule
    /// `lr(t) = lr0 * decay_rate^(t / decay_every)`.
    pub lr0: f64,
    /// Multiplicative decay factor of the learning-rate schedule.
    pub lr_decay_rate: f64,
    /// Rounds between learning-rate decay steps (0 disables decay).
    pub lr_decay_every: usize,
    /// Server-side learning-rate multiplier (the server head sees much
    /// larger fan-in than the client stack; the paper uses one eta, but
    /// stability on the synthetic tasks wants a cooler server step).
    pub server_lr_scale: f64,
    /// Clients sampled per round (k of n; n = partition size).
    pub participation: usize,
    /// Experiment seed: every random stream in the run derives from it.
    pub seed: u64,
    /// Evaluate accuracy every k rounds (0 = only at the end).
    pub eval_every: usize,
    /// Cap eval to k batches (0 = full test set).
    pub eval_max_batches: usize,
    /// Order in which the server consumes this round's uploads.
    pub arrival: ArrivalOrder,
    /// Record gradient norms (Props 1-2 traces).
    pub track_grad_norms: bool,
    /// Client fan-out strategy (bit-deterministic either way).
    pub parallelism: Parallelism,
    /// Server shard count k for the shared topology: k server-side
    /// copies, each serving a client group on its own event-loop
    /// executor, FedAvg'd together every `agg_every` rounds. k = 1 (the
    /// default) is the paper's shared copy; k = n matches the
    /// per-client topology's storage. Rejected (> 1) for
    /// [`ServerTopology::PerClient`], which fixes its own copy count.
    /// Unlike `parallelism`, shard count **changes results** and is part
    /// of the experiment cache key.
    pub server_shards: usize,
    /// Work-dealing policy of the parallel fan-out. Like `parallelism`
    /// this is a wall-clock-only knob: results are bit-identical for
    /// every policy (merged in canonical order), so it is excluded from
    /// the experiment cache key.
    pub sched: SchedPolicy,
    /// Client → shard assignment for the sharded server phase.
    /// `Balanced` regroups clients across shard copies by estimated
    /// cost, `Locality` by label distribution (non-IID arms) — either
    /// **changes results** (like `server_shards`, unlike `sched`) and
    /// requires `server_shards >= 2`. `Locality` additionally requires a
    /// non-IID partition, enforced where the distribution is known
    /// (`exp::common::RunSpec::validate`).
    pub shard_map: ShardMapKind,
    /// Client churn & reliability: availability model × mid-round
    /// failure rate × server resilience policy
    /// ([`crate::sim::churn`]). The default is full availability with
    /// no failures and `WaitAll` — the contract point, under which no
    /// churn draw ever happens. Any non-default knob **changes
    /// results** and rides into `RunSpec::key` / run labels.
    pub churn: ChurnConfig,
}

impl TrainConfig {
    /// Preset defaults (paper Section VI-A operating points):
    /// [`TrainConfig::from_spec`] at the preset's spec point.
    pub fn new(method: Method) -> Self {
        Self::from_spec(method.spec())
    }

    /// Defaults for any spec point (the open-API constructor — this is
    /// how spec-only scenarios like `AuxLocal × Period(h) × PerClient`
    /// get a config).
    pub fn from_spec(spec: MethodSpec) -> Self {
        TrainConfig {
            spec,
            rounds: 40,
            agg_every: 10,
            lr0: 0.05,
            lr_decay_rate: 0.99,
            lr_decay_every: 10,
            server_lr_scale: 0.25,
            participation: 0, // 0 = all clients
            seed: 1,
            eval_every: 5,
            eval_max_batches: 0,
            arrival: ArrivalOrder::ByDelay,
            track_grad_norms: false,
            parallelism: Parallelism::Sequential,
            server_shards: 1,
            sched: SchedPolicy::RoundRobin,
            shard_map: ShardMapKind::Contiguous,
            churn: ChurnConfig::default(),
        }
    }

    /// Builder: set the client fan-out strategy.
    pub fn with_parallelism(mut self, parallelism: Parallelism) -> Self {
        self.parallelism = parallelism;
        self
    }

    /// Builder: set the upload period to a fixed `h` batches per upload
    /// ([`MethodSpec::with_period`]; validation decides whether the
    /// update rule can amortize it).
    pub fn with_h(mut self, h: usize) -> Self {
        self.spec = self.spec.with_period(h);
        self
    }

    /// Builder: set the spec's wire-compression codec
    /// ([`MethodSpec::with_compression`]).
    pub fn with_compression(mut self, compression: Compression) -> Self {
        self.spec = self.spec.with_compression(compression);
        self
    }

    /// Builder: set the communication-round count.
    pub fn with_rounds(mut self, rounds: usize) -> Self {
        self.rounds = rounds;
        self
    }

    /// Builder: set the experiment seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Builder: set the server shard count k.
    pub fn with_server_shards(mut self, server_shards: usize) -> Self {
        self.server_shards = server_shards;
        self
    }

    /// Builder: set the fan-out dealing policy.
    pub fn with_sched(mut self, sched: SchedPolicy) -> Self {
        self.sched = sched;
        self
    }

    /// Builder: set the client → shard assignment flavor.
    pub fn with_shard_map(mut self, shard_map: ShardMapKind) -> Self {
        self.shard_map = shard_map;
        self
    }

    /// Builder: set the churn & reliability configuration.
    pub fn with_churn(mut self, churn: ChurnConfig) -> Self {
        self.churn = churn;
        self
    }

    /// The learning rate in effect at (0-based) `round`.
    pub fn lr_at(&self, round: usize) -> f64 {
        let steps = if self.lr_decay_every == 0 { 0 } else { round / self.lr_decay_every };
        self.lr0 * self.lr_decay_rate.powi(steps as i32)
    }

    /// Check the configuration against the client count; returns a
    /// human-readable reason when it cannot run. Axis coherence is
    /// [`MethodSpec::validate`]; the cross-cutting checks here are the
    /// ones that need the rest of the config (shards vs topology, maps
    /// vs shards, participation vs n).
    pub fn validate(&self, n_clients: usize) -> Result<(), String> {
        self.spec.validate()?;
        if self.rounds == 0 {
            return Err("rounds must be >= 1".into());
        }
        if self.agg_every == 0 {
            return Err("agg_every must be >= 1".into());
        }
        if self.participation > n_clients {
            return Err(format!(
                "participation {} exceeds client count {n_clients}",
                self.participation
            ));
        }
        if self.server_shards == 0 {
            return Err("server-shards must be >= 1".into());
        }
        if self.server_shards > n_clients {
            return Err(format!(
                "server-shards {} exceeds client count {n_clients}",
                self.server_shards
            ));
        }
        if self.server_shards > 1 && self.spec.topology == ServerTopology::PerClient {
            return Err(format!(
                "the per-client topology ({}) already keeps one server copy per \
                 client; --server-shards applies to the shared topology \
                 (FSL_OC / CSE_FSL, or --topology shared)",
                self.spec
            ));
        }
        if self.shard_map.regroups_clients() && self.server_shards < 2 {
            return Err(format!(
                "--shard-map {} requires --server-shards >= 2 \
                 (it reassigns clients across shard copies)",
                self.shard_map
            ));
        }
        if self.lr0 <= 0.0 || self.lr_decay_rate <= 0.0 || self.lr_decay_rate > 1.0 {
            return Err("bad learning-rate schedule".into());
        }
        self.churn.validate()?;
        Ok(())
    }

    /// Number of clients active each round.
    pub fn active_clients(&self, n_clients: usize) -> usize {
        if self.participation == 0 {
            n_clients
        } else {
            self.participation
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::methods::{ClientUpdate, UploadSchedule};

    #[test]
    fn lr_schedule_decays() {
        let c = TrainConfig::new(Method::CseFsl);
        assert_eq!(c.lr_at(0), 0.05);
        assert!(c.lr_at(10) < c.lr_at(9));
        assert!((c.lr_at(10) - 0.05 * 0.99).abs() < 1e-12);
        assert!((c.lr_at(25) - 0.05 * 0.99f64.powi(2)).abs() < 1e-12);
    }

    #[test]
    fn validation_rules() {
        let c = TrainConfig::new(Method::FslMc);
        assert!(c.validate(5).is_ok());
        assert!(
            c.clone().with_h(5).validate(5).is_err(),
            "server-grad updates must reject a period"
        );
        let mut c = TrainConfig::new(Method::CseFsl).with_h(5);
        assert!(c.validate(5).is_ok());
        assert!(c.clone().with_h(0).validate(5).is_err(), "h = 0 must be rejected");
        c.participation = 9;
        assert!(c.validate(5).is_err());
        c.participation = 3;
        assert!(c.validate(5).is_ok());
        assert_eq!(c.active_clients(5), 3);
        c.participation = 0;
        assert_eq!(c.active_clients(5), 5);
    }

    #[test]
    fn spec_only_scenarios_validate() {
        // The point the paper never names: aux-local updates with a
        // period on the per-client topology ("FSL_AN with h > 1").
        let c = TrainConfig::new(Method::FslAn).with_h(4);
        assert!(c.validate(5).is_ok(), "AuxLocal x Period x PerClient must run");
        assert_eq!(c.spec.preset(), None);
        // An adaptive schedule on the shared topology.
        let c = TrainConfig::from_spec(MethodSpec {
            upload: UploadSchedule::AdaptivePeriod { h0: 1, h_max: 8, double_every: 5 },
            ..Method::CseFsl.spec()
        });
        assert!(c.validate(5).is_ok());
    }

    #[test]
    fn server_shard_validation() {
        // Default is the paper's single copy.
        assert_eq!(TrainConfig::new(Method::CseFsl).server_shards, 1);
        // Any k in 1..=n works for the shared-topology presets.
        for method in [Method::CseFsl, Method::FslOc] {
            for k in 1..=5usize {
                let c = TrainConfig::new(method).with_server_shards(k);
                assert!(c.validate(5).is_ok(), "{method} k={k}");
            }
            assert!(TrainConfig::new(method).with_server_shards(6).validate(5).is_err());
            assert!(TrainConfig::new(method).with_server_shards(0).validate(5).is_err());
        }
        // The per-client topology fixes its own copy count.
        for method in [Method::FslMc, Method::FslAn] {
            assert!(TrainConfig::new(method).with_server_shards(1).validate(5).is_ok());
            assert!(
                TrainConfig::new(method).with_server_shards(2).validate(5).is_err(),
                "{method} must reject explicit sharding"
            );
        }
    }

    #[test]
    fn parallelism_parse_display_and_workers() {
        use std::str::FromStr;
        assert_eq!(Parallelism::from_str("seq"), Ok(Parallelism::Sequential));
        assert_eq!(Parallelism::from_str("sequential"), Ok(Parallelism::Sequential));
        assert_eq!(Parallelism::from_str("0"), Ok(Parallelism::Sequential));
        assert_eq!(Parallelism::from_str("4"), Ok(Parallelism::Threads(4)));
        assert!(Parallelism::from_str("sideways").is_err());
        if let Ok(Parallelism::Threads(n)) = Parallelism::from_str("auto") {
            assert!(n >= 1);
        } else {
            panic!("auto must map to Threads");
        }
        assert_eq!(Parallelism::from_str("auto").unwrap(), Parallelism::auto());
        assert_eq!(Parallelism::Sequential.to_string(), "seq");
        assert_eq!(Parallelism::Threads(4).to_string(), "threads4");
        assert_eq!(Parallelism::Sequential.worker_count(8), 1);
        assert_eq!(Parallelism::Threads(4).worker_count(8), 4);
        assert_eq!(Parallelism::Threads(4).worker_count(2), 2, "never more workers than work");
        assert_eq!(Parallelism::Threads(4).worker_count(0), 1);
        assert_eq!(Parallelism::Threads(0).worker_count(8), 1);
        assert_eq!(TrainConfig::new(Method::CseFsl).parallelism, Parallelism::Sequential);
        let c = TrainConfig::new(Method::CseFsl).with_parallelism(Parallelism::Threads(2));
        assert_eq!(c.parallelism, Parallelism::Threads(2));
    }

    #[test]
    fn sched_and_shard_map_knobs() {
        use std::str::FromStr;
        // Defaults are the historical behavior.
        let c = TrainConfig::new(Method::CseFsl);
        assert_eq!(c.sched, SchedPolicy::RoundRobin);
        assert_eq!(c.shard_map, ShardMapKind::Contiguous);
        // Builders.
        let c = c.with_sched(SchedPolicy::WorkStealing).with_shard_map(ShardMapKind::Balanced);
        assert_eq!(c.sched, SchedPolicy::WorkStealing);
        assert_eq!(c.shard_map, ShardMapKind::Balanced);
        // Balanced needs a sharded server...
        assert!(c.clone().with_server_shards(1).validate(5).is_err());
        assert!(c.clone().with_server_shards(2).validate(5).is_ok());
        // ...and any sched policy is valid anywhere (wall-clock only).
        for p in SchedPolicy::ALL {
            assert!(TrainConfig::new(Method::FslMc).with_sched(p).validate(5).is_ok());
        }
        // Parse / display / tag.
        assert_eq!(ShardMapKind::from_str("balanced"), Ok(ShardMapKind::Balanced));
        assert_eq!(ShardMapKind::from_str("cont"), Ok(ShardMapKind::Contiguous));
        assert_eq!(ShardMapKind::from_str("locality"), Ok(ShardMapKind::Locality));
        assert_eq!(ShardMapKind::from_str("loc"), Ok(ShardMapKind::Locality));
        assert!(ShardMapKind::from_str("diagonal").is_err());
        assert_eq!(ShardMapKind::Balanced.to_string(), "balanced");
        assert_eq!(ShardMapKind::Balanced.tag(), "bal");
        assert_eq!(ShardMapKind::Locality.to_string(), "locality");
        assert_eq!(ShardMapKind::Locality.tag(), "loc");
        assert_eq!(ShardMapKind::default(), ShardMapKind::Contiguous);
    }

    #[test]
    fn shard_map_validation_messages_consistent() {
        // Every regrouping map needs a sharded server, with one message
        // shape naming the offending map; contiguous never does.
        assert!(!ShardMapKind::Contiguous.regroups_clients());
        for (map, name) in
            [(ShardMapKind::Balanced, "balanced"), (ShardMapKind::Locality, "locality")]
        {
            assert!(map.regroups_clients());
            let err = TrainConfig::new(Method::CseFsl)
                .with_shard_map(map)
                .with_server_shards(1)
                .validate(5)
                .unwrap_err();
            assert!(
                err.contains(&format!("--shard-map {name} requires --server-shards >= 2")),
                "{map}: {err}"
            );
            assert!(TrainConfig::new(Method::CseFsl)
                .with_shard_map(map)
                .with_server_shards(0)
                .validate(5)
                .is_err());
            // With k >= 2 the config-level check passes (the locality
            // map's non-IID requirement lives at the RunSpec level,
            // where the data distribution is known).
            assert!(TrainConfig::new(Method::CseFsl)
                .with_shard_map(map)
                .with_server_shards(2)
                .validate(5)
                .is_ok());
            // ...but never on the per-client topology (sharding itself
            // is rejected there).
            assert!(TrainConfig::new(Method::FslMc)
                .with_shard_map(map)
                .with_server_shards(2)
                .validate(5)
                .is_err());
        }
    }

    #[test]
    fn compression_rides_the_spec() {
        // Presets default to the uncompressed wire.
        for m in [Method::FslMc, Method::FslOc, Method::FslAn, Method::CseFsl] {
            assert_eq!(TrainConfig::new(m).spec.compression, Compression::None, "{m}");
        }
        // The builder delegates to the spec and composes with the rest.
        let c = TrainConfig::new(Method::CseFsl)
            .with_h(2)
            .with_compression(Compression::Quantize { bits: 4 });
        assert_eq!(c.spec.compression, Compression::Quantize { bits: 4 });
        assert!(c.validate(5).is_ok());
        assert_eq!(c.spec.preset(), None, "compressed specs are spec-only points");
        // Spec-level codec validation surfaces through the config.
        assert!(TrainConfig::new(Method::CseFsl)
            .with_compression(Compression::Quantize { bits: 0 })
            .validate(5)
            .is_err());
        assert!(TrainConfig::new(Method::CseFsl)
            .with_compression(Compression::TopK { frac: 0.0 })
            .validate(5)
            .is_err());
        // Server-grad presets accept a codec too (symmetric downlink).
        assert!(TrainConfig::new(Method::FslOc)
            .with_compression(Compression::TopK { frac: 0.25 })
            .validate(5)
            .is_ok());
    }

    #[test]
    fn churn_rides_the_config_and_is_validated_at_build_time() {
        use crate::sim::churn::{ChurnModel, ResiliencePolicy};
        // The default is the contract point: no churn anywhere.
        let c = TrainConfig::new(Method::CseFsl);
        assert!(c.churn.is_default());
        assert!(c.validate(5).is_ok());
        // A full non-default stack validates...
        let churned = c.clone().with_churn(ChurnConfig {
            model: ChurnModel::Correlated { clusters: 4, p_outage: 0.2 },
            fail_rate: 0.1,
            policy: ResiliencePolicy::Quorum { min_frac: 0.5, resample: true },
        });
        assert!(churned.validate(5).is_ok());
        // ...and every bad parameter is rejected at config build time
        // instead of flowing into the engines (one test per path).
        let reject = |churn: ChurnConfig| {
            TrainConfig::new(Method::CseFsl).with_churn(churn).validate(5)
        };
        assert!(
            reject(ChurnConfig {
                model: ChurnModel::Iid { p: 0.0 },
                ..ChurnConfig::default()
            })
            .is_err(),
            "availability 0 must be rejected"
        );
        assert!(
            reject(ChurnConfig {
                model: ChurnModel::Iid { p: 1.5 },
                ..ChurnConfig::default()
            })
            .is_err(),
            "availability > 1 must be rejected"
        );
        assert!(
            reject(ChurnConfig {
                model: ChurnModel::Iid { p: f64::NAN },
                ..ChurnConfig::default()
            })
            .is_err(),
            "NaN availability must be rejected"
        );
        assert!(
            reject(ChurnConfig {
                policy: ResiliencePolicy::Cutoff { secs: -1.0 },
                ..ChurnConfig::default()
            })
            .is_err(),
            "negative straggler cutoff must be rejected"
        );
        assert!(
            reject(ChurnConfig {
                policy: ResiliencePolicy::Cutoff { secs: f64::NAN },
                ..ChurnConfig::default()
            })
            .is_err(),
            "NaN straggler cutoff must be rejected"
        );
        assert!(
            reject(ChurnConfig { fail_rate: 1.0, ..ChurnConfig::default() }).is_err(),
            "fail rate 1 must be rejected"
        );
        assert!(
            reject(ChurnConfig {
                policy: ResiliencePolicy::Quorum { min_frac: 0.0, resample: false },
                ..ChurnConfig::default()
            })
            .is_err(),
            "zero quorum must be rejected"
        );
    }

    #[test]
    fn clip_rides_the_update_axis() {
        // The paper's clip lives in the spec now: FSL_OC's preset point
        // carries clip = 1, everything else 0.
        assert!(TrainConfig::new(Method::FslOc).spec.clip() > 0.0);
        assert_eq!(TrainConfig::new(Method::CseFsl).spec.clip(), 0.0);
        assert_eq!(TrainConfig::new(Method::FslMc).spec.clip(), 0.0);
        // A custom clip is a new spec point, not a preset.
        let custom = TrainConfig::from_spec(MethodSpec {
            update: ClientUpdate::ServerGrad { clip: 0.25 },
            ..Method::FslOc.spec()
        });
        assert!(custom.validate(5).is_ok());
        assert_eq!(custom.spec.preset(), None);
    }
}
