//! Server state: sharded server-side model copies, per-shard executor
//! clocks, and aggregation accumulators. The paper's event-triggered
//! `dataQueue` (Algorithm 2) is materialized by the round engine as
//! per-executor-lane arrival queues each round
//! (`coordinator::round::Trainer::drain_data_queue`).
//!
//! The paper's methods pin two points of a storage/throughput curve: one
//! shared copy behind one event loop (FSL_OC / CSE_FSL) or one copy per
//! client behind one event loop (FSL_MC / FSL_AN). [`Topology`]
//! generalizes the single-copy side to `k` **shards**: `k` server-side
//! copies, each serving a contiguous group of clients on its own
//! event-loop executor, FedAvg'd back together at every aggregation
//! (cross-shard FedAvg). `k = 1` reproduces the paper's single-copy
//! server bit-for-bit; `k = n` holds as many copies as FSL_MC.

use crate::model::aggregate::{fedavg, fedavg_weighted, Accumulator};

/// One smashed-data upload in flight / queued at the server.
#[derive(Clone, Debug)]
pub struct SmashedMsg {
    /// Originating client id.
    pub client: usize,
    /// Flattened smashed activations for one batch.
    pub smashed: Vec<f32>,
    /// Labels accompanying the smashed batch.
    pub labels: Vec<i32>,
    /// Simulated arrival time at the server.
    pub arrival: f64,
    /// Dropout seed the client used for this forward (server replays it
    /// for its own dropout stream).
    pub seed: i32,
}

/// Deterministic client → shard assignment.
///
/// Three constructors: [`ShardMap::contiguous`] (equal-count groups in
/// canonical client-id order), [`ShardMap::balanced`] (LPT bin
/// packing on per-client cost estimates), and [`ShardMap::locality`]
/// (label-distribution stratification for non-IID arms, cost-balanced
/// within each dealing wave). Either way the assignment is
/// a pure function of its inputs — never of arrival order or thread
/// scheduling — which is what lets the sharded server phase keep the
/// bit-determinism contract (see `coordinator/README.md`). Changing the
/// *map* (like changing the shard count) legitimately changes results,
/// which is why the map kind is part of `RunSpec::key`.
///
/// Representation: the contiguous map is stored in **closed form**
/// (O(1), independent of client count — a million-client population run
/// must not materialize an 8 MB assignment vector per server), while the
/// cost- and data-driven maps store their per-client assignment
/// explicitly. Equality is semantic — two maps are equal iff they assign
/// every client to the same shard — so `balanced(n, 1, ..)` still equals
/// `contiguous(n, 1)` whatever the representations.
#[derive(Clone, Debug)]
pub struct ShardMap {
    assign: ShardAssign,
    shards: usize,
}

/// Storage behind a [`ShardMap`]: closed-form or materialized.
#[derive(Clone, Debug)]
enum ShardAssign {
    /// Equal-as-possible contiguous groups in client-id order, computed
    /// on lookup — the only representation the streaming population
    /// engine accepts (its memory must not grow with n).
    Contiguous {
        /// Number of clients mapped.
        n_clients: usize,
    },
    /// One entry per client ([`ShardMap::balanced`] /
    /// [`ShardMap::locality`]).
    Explicit(Vec<usize>),
}

impl PartialEq for ShardMap {
    fn eq(&self, other: &Self) -> bool {
        self.shards == other.shards
            && self.n_clients() == other.n_clients()
            && (0..self.n_clients()).all(|c| self.shard_of(c) == other.shard_of(c))
    }
}

impl Eq for ShardMap {}

impl ShardMap {
    /// Contiguous equal-as-possible groups of `n_clients` over `shards`.
    ///
    /// `shards` must be in `1..=n_clients`; `contiguous(n, 1)` maps every
    /// client to shard 0 (the paper's shared copy) and `contiguous(n, n)`
    /// is the identity (one copy per client, FSL_MC-style). Stored in
    /// closed form: building this map is O(1) in `n_clients`.
    pub fn contiguous(n_clients: usize, shards: usize) -> Self {
        assert!(shards >= 1, "at least one shard required");
        assert!(
            shards <= n_clients.max(1),
            "more shards ({shards}) than clients ({n_clients})"
        );
        ShardMap { assign: ShardAssign::Contiguous { n_clients }, shards }
    }

    /// Whether this map is the closed-form contiguous assignment (the
    /// representation the streaming population engine requires).
    pub fn is_contiguous_repr(&self) -> bool {
        matches!(self.assign, ShardAssign::Contiguous { .. })
    }

    /// Load-balanced client → shard assignment: LPT
    /// (longest-processing-time) bin packing of the per-client cost
    /// estimates over `shards` bins (`sched::lpt`) — heaviest client
    /// first into the least-loaded shard, deterministic tie-breaks.
    ///
    /// Groups are generally **non-contiguous**, and which clients share
    /// a copy changes the training trajectory — so the map kind joins
    /// `RunSpec::key`, unlike the dealing policy. Non-finite or
    /// non-positive costs are replaced by the mean positive cost
    /// (`sched::sanitize_costs`), so every shard is guaranteed at least
    /// one client whenever `shards <= n_clients`.
    pub fn balanced(n_clients: usize, shards: usize, costs: &[f64]) -> Self {
        assert!(shards >= 1, "at least one shard required");
        assert!(
            shards <= n_clients.max(1),
            "more shards ({shards}) than clients ({n_clients})"
        );
        assert_eq!(costs.len(), n_clients, "one cost estimate per client");
        let sane = crate::sched::sanitize_costs(costs);
        let bins = crate::sched::lpt(&sane, shards);
        let mut shard_of = vec![0usize; n_clients];
        for (s, bin) in bins.iter().enumerate() {
            for &c in bin {
                shard_of[c] = s;
            }
        }
        ShardMap { assign: ShardAssign::Explicit(shard_of), shards }
    }

    /// Locality-aware client → shard assignment for non-IID data:
    /// stratify clients over shards by **label distribution** so every
    /// shard's aggregate label histogram approximates the global one,
    /// while staying cost-balanced.
    ///
    /// Under label-skew non-IID data (Dirichlet / by-writer splits) a
    /// cost-only map can pack statistically identical clients onto one
    /// shard copy and starve it of label diversity; this constructor
    /// co-locates clients *by data distribution*. Algorithm
    /// (deterministic — a pure function of `(histograms, costs, shards)`,
    /// with client ids only breaking ties between data-identical
    /// clients, so the grouping is invariant to input permutation up to
    /// shard relabeling):
    ///
    /// 1. order clients by similarity: dominant label, then the full
    ///    histogram (descending lexicographic), then sanitized cost
    ///    (descending), then client id;
    /// 2. deal the ordering in **waves** of `shards` consecutive
    ///    clients: within a wave, clients go heaviest-cost-first to the
    ///    least-loaded shard not yet used in that wave (`sched::lpt`'s
    ///    greedy rule, restricted to one client per shard per wave).
    ///
    /// Statistically similar clients sit adjacent in the ordering, and a
    /// wave never puts two of its clients on one shard — so each shard
    /// receives a cross-section of the similarity spectrum (for one-hot
    /// clients, each shard gets between `⌊m/k⌋` and `⌈m/k⌉` clients of a
    /// label held by `m` clients — the minimum achievable skew). Shard
    /// client counts differ by at most one, every shard is non-empty,
    /// and per-shard cost stays near the [`crate::sched::greedy_bound`]
    /// the balanced map obeys (cost-greedy within each wave). Costs are
    /// sanitized exactly as in [`ShardMap::balanced`]
    /// ([`crate::sched::sanitize_costs`]).
    ///
    /// # Example
    ///
    /// Four clients, two labels: clients 0 and 1 hold only label 0,
    /// clients 2 and 3 only label 1. The contiguous map packs the two
    /// label-0 clients onto one shard (maximal skew); the locality map
    /// pairs opposite-skew clients so each shard sees both labels:
    ///
    /// ```
    /// use cse_fsl::coordinator::server::ShardMap;
    ///
    /// let hists = vec![vec![8, 0], vec![8, 0], vec![0, 8], vec![0, 8]];
    /// let costs = vec![1.0; 4];
    /// let loc = ShardMap::locality(4, 2, &hists, &costs);
    /// assert_ne!(loc.shard_of(0), loc.shard_of(1), "same-skew clients split");
    /// assert_ne!(loc.shard_of(2), loc.shard_of(3));
    /// // Each shard's label mix now matches the global mix exactly...
    /// assert_eq!(loc.label_divergence(&hists), 0.0);
    /// // ...where the contiguous grouping is maximally skewed.
    /// assert_eq!(ShardMap::contiguous(4, 2).label_divergence(&hists), 0.5);
    /// ```
    pub fn locality(
        n_clients: usize,
        shards: usize,
        histograms: &[Vec<usize>],
        costs: &[f64],
    ) -> Self {
        assert!(shards >= 1, "at least one shard required");
        assert!(
            shards <= n_clients.max(1),
            "more shards ({shards}) than clients ({n_clients})"
        );
        assert_eq!(histograms.len(), n_clients, "one label histogram per client");
        assert_eq!(costs.len(), n_clients, "one cost estimate per client");
        let sane = crate::sched::sanitize_costs(costs);
        fn dominant(h: &[usize]) -> usize {
            let mut best = 0usize;
            for (c, &v) in h.iter().enumerate() {
                if v > h[best] {
                    best = c;
                }
            }
            best
        }
        // Similarity ordering: every key component before the final
        // client-id tie-break is derived from the client's *data*, so
        // permuting the input permutes only data-identical clients.
        let mut order: Vec<usize> = (0..n_clients).collect();
        order.sort_by(|&a, &b| {
            dominant(&histograms[a])
                .cmp(&dominant(&histograms[b]))
                .then_with(|| histograms[b].cmp(&histograms[a]))
                .then_with(|| sane[b].total_cmp(&sane[a]))
                .then_with(|| a.cmp(&b))
        });
        let mut shard_of = vec![0usize; n_clients];
        let mut loads = vec![0f64; shards];
        for wave in order.chunks(shards) {
            // Cost-descending within the wave (LPT's greedy rule), each
            // client to the least-loaded shard not yet used this wave.
            let mut wave_items: Vec<usize> = wave.to_vec();
            wave_items.sort_by(|&a, &b| {
                sane[b]
                    .total_cmp(&sane[a])
                    .then_with(|| histograms[b].cmp(&histograms[a]))
                    .then_with(|| a.cmp(&b))
            });
            let mut used = vec![false; shards];
            for c in wave_items {
                let mut best = usize::MAX;
                for s in 0..shards {
                    if !used[s] && (best == usize::MAX || loads[s] < loads[best]) {
                        best = s;
                    }
                }
                used[best] = true;
                loads[best] += sane[c];
                shard_of[c] = best;
            }
        }
        ShardMap { assign: ShardAssign::Explicit(shard_of), shards }
    }

    /// Shard-skew metric: mean over shards of the total-variation
    /// distance between the shard's aggregate label distribution and the
    /// global one, in `[0, 1]`.
    ///
    /// `0` means every shard sees exactly the global label mix (a single
    /// shard always scores 0); `1` is maximal skew. A shard with no
    /// samples counts the full distance 1 (it is maximally
    /// unrepresentative of the global mix). The recorded
    /// `shard_label_divergence` in `RunRecord` / summary JSON is the
    /// sample-mass-weighted variant
    /// ([`ShardMap::label_divergence_weighted`]); this unweighted mean
    /// remains for diagnostics where a pathological small shard *should*
    /// dominate the score.
    pub fn label_divergence(&self, histograms: &[Vec<usize>]) -> f64 {
        let Some((global, shard_h, g_tot)) = self.label_mix(histograms) else {
            return 0.0;
        };
        let mut acc = 0.0;
        for sh in &shard_h {
            let s_tot: f64 = sh.iter().sum();
            if s_tot == 0.0 {
                acc += 1.0;
                continue;
            }
            acc += 0.5 * Self::tv_distance(sh, s_tot, &global, g_tot);
        }
        acc / self.shards as f64
    }

    /// Shared accumulation behind both skew metrics: the global and
    /// per-shard label mixes, plus the global sample total. `None` when
    /// there is nothing to measure (no classes, no shards, or no
    /// samples) — both metrics define that as zero skew.
    fn label_mix(&self, histograms: &[Vec<usize>]) -> Option<(Vec<f64>, Vec<Vec<f64>>, f64)> {
        assert_eq!(
            histograms.len(),
            self.n_clients(),
            "one label histogram per client"
        );
        let classes = histograms.first().map(|h| h.len()).unwrap_or(0);
        if classes == 0 || self.shards == 0 {
            return None;
        }
        let mut global = vec![0f64; classes];
        let mut shard_h = vec![vec![0f64; classes]; self.shards];
        for (c, h) in histograms.iter().enumerate() {
            assert_eq!(h.len(), classes, "ragged label histograms");
            let s = self.shard_of(c);
            for (k, &v) in h.iter().enumerate() {
                global[k] += v as f64;
                shard_h[s][k] += v as f64;
            }
        }
        let g_tot: f64 = global.iter().sum();
        if g_tot == 0.0 {
            return None;
        }
        Some((global, shard_h, g_tot))
    }

    /// Total-variation distance between one shard's label mix and the
    /// global one (callers multiply by ½ and weight as their metric
    /// defines).
    fn tv_distance(sh: &[f64], s_tot: f64, global: &[f64], g_tot: f64) -> f64 {
        sh.iter().zip(global).map(|(&s, &g)| (s / s_tot - g / g_tot).abs()).sum()
    }

    /// Sample-mass-weighted shard-skew: each shard's TV distance from
    /// the global label mix, weighted by the fraction of all samples
    /// the shard serves — `Σ_s (|D_s| / |D|) · TV_s` — instead of the
    /// per-shard mean [`ShardMap::label_divergence`] takes.
    ///
    /// The two metrics agree when shard sample masses are equal and
    /// diverge when they are not: the unweighted mean lets a tiny
    /// pathological shard dominate the score (it counts as much as a
    /// shard serving half the data), while the weighted form scores
    /// what a *sample-weighted* cross-shard FedAvg actually mixes.
    /// An empty shard carries zero mass and therefore zero weighted
    /// contribution (the unweighted metric charges it the full
    /// distance 1). Since the ROADMAP-carried follow-up landed, **this
    /// is the recorded `RunRecord::shard_label_divergence`** (the cache
    /// version was bumped so stale unweighted records re-run); the
    /// unweighted mean stays available via
    /// [`ShardMap::label_divergence`].
    pub fn label_divergence_weighted(&self, histograms: &[Vec<usize>]) -> f64 {
        let Some((global, shard_h, g_tot)) = self.label_mix(histograms) else {
            return 0.0;
        };
        let mut acc = 0.0;
        for sh in &shard_h {
            let s_tot: f64 = sh.iter().sum();
            if s_tot == 0.0 {
                continue; // zero mass, zero weighted contribution
            }
            acc += (s_tot / g_tot) * 0.5 * Self::tv_distance(sh, s_tot, &global, g_tot);
        }
        acc
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Number of clients mapped.
    pub fn n_clients(&self) -> usize {
        match &self.assign {
            ShardAssign::Contiguous { n_clients } => *n_clients,
            ShardAssign::Explicit(v) => v.len(),
        }
    }

    /// The shard serving `client`.
    pub fn shard_of(&self, client: usize) -> usize {
        match &self.assign {
            ShardAssign::Explicit(v) => v[client],
            ShardAssign::Contiguous { n_clients } => {
                assert!(
                    client < *n_clients,
                    "client {client} out of range ({n_clients} mapped)"
                );
                // Closed form of the original materialized fill: the
                // first `extra` shards hold `base + 1` clients, the rest
                // `base`. `base` can only be 0 with zero clients (the
                // constructor rejects shards > n_clients), and then the
                // range assert above already fired.
                let base = n_clients / self.shards;
                let extra = n_clients % self.shards;
                let wide = extra * (base + 1);
                if client < wide {
                    client / (base + 1)
                } else {
                    extra + (client - wide) / base
                }
            }
        }
    }

    /// Client ids of one shard, ascending (contiguous for
    /// [`ShardMap::contiguous`]; generally scattered for
    /// [`ShardMap::balanced`]).
    pub fn clients_of(&self, shard: usize) -> Vec<usize> {
        (0..self.n_clients()).filter(|&c| self.shard_of(c) == shard).collect()
    }
}

/// How server-side model copies map to event-loop executors.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// One copy per client behind a **single** executor — FSL_MC / FSL_AN
    /// exactly as the paper describes them (the server is one machine
    /// holding n models).
    PerClient,
    /// `k` shard copies, each with its own event-loop executor; clients
    /// map to shards via [`ShardMap::contiguous`]. `Sharded(1)` is the
    /// paper's single-copy server (FSL_OC / CSE_FSL).
    Sharded(usize),
}

/// Algorithm 2 state, generalized to sharded copies.
pub struct ServerState {
    /// Server-side model copies: `n` ([`Topology::PerClient`]) or `k`
    /// ([`Topology::Sharded`]).
    pub copies: Vec<Vec<f32>>,
    /// Client → copy routing (identity for `PerClient`).
    pub shard_map: ShardMap,
    /// Per-executor clocks: when each event-loop lane finishes its
    /// current work. Length 1 for `PerClient` (n copies share one
    /// executor) and `k` for `Sharded(k)` (one executor per shard copy).
    pub free_at: Vec<f64>,
    /// Aggregation accumulator for client-side models.
    pub client_acc: Accumulator,
    /// Aggregation accumulator for auxiliary networks.
    pub aux_acc: Accumulator,
    /// Total event-triggered updates performed (observability).
    pub updates: u64,
    /// Event-triggered updates applied to each copy (per-shard counts;
    /// sums to [`ServerState::updates`]).
    pub shard_updates: Vec<u64>,
}

impl ServerState {
    /// Build the server from the initial server-side model `xs`, the
    /// client count, and the copy/executor [`Topology`], with the
    /// default contiguous [`ShardMap`].
    pub fn new(
        xs: Vec<f32>,
        n_clients: usize,
        topology: Topology,
        client_size: usize,
        aux_size: usize,
    ) -> Self {
        let shard_map = match topology {
            Topology::PerClient => ShardMap::contiguous(n_clients, n_clients.max(1)),
            Topology::Sharded(k) => ShardMap::contiguous(n_clients, k),
        };
        Self::with_map(xs, topology, shard_map, client_size, aux_size)
    }

    /// Build the server with an explicit client → copy [`ShardMap`]
    /// (contiguous or balanced). The map's shard count must match the
    /// topology's copy count: `k` for [`Topology::Sharded`], one copy
    /// per client for [`Topology::PerClient`].
    pub fn with_map(
        xs: Vec<f32>,
        topology: Topology,
        shard_map: ShardMap,
        client_size: usize,
        aux_size: usize,
    ) -> Self {
        let lanes = match topology {
            Topology::PerClient => {
                assert_eq!(
                    shard_map.shards(),
                    shard_map.n_clients().max(1),
                    "per-client topology needs the identity shard map"
                );
                1
            }
            Topology::Sharded(k) => {
                assert_eq!(shard_map.shards(), k, "shard map does not match topology");
                k
            }
        };
        let copies = shard_map.shards();
        ServerState {
            copies: vec![xs; copies],
            shard_map,
            free_at: vec![0.0; lanes],
            client_acc: Accumulator::new(client_size),
            aux_acc: Accumulator::new(aux_size),
            updates: 0,
            shard_updates: vec![0; copies],
        }
    }

    /// Number of executor lanes (independent server event loops).
    pub fn lanes(&self) -> usize {
        self.free_at.len()
    }

    /// The copy index serving `client`.
    pub fn copy_for(&self, client: usize) -> usize {
        self.shard_map.shard_of(client)
    }

    /// The executor lane serving `client` (0 when all copies share one
    /// event loop).
    pub fn lane_for(&self, client: usize) -> usize {
        if self.free_at.len() == 1 {
            0
        } else {
            self.shard_map.shard_of(client)
        }
    }

    /// Latest time any executor lane is busy until (the global "server
    /// free" time — used as the aggregation barrier baseline).
    pub fn free_at_max(&self) -> f64 {
        self.free_at.iter().copied().fold(0.0, f64::max)
    }

    /// Synchronize every executor lane to `t` (aggregation is a global
    /// barrier across shards).
    pub fn sync_free_at(&mut self, t: f64) {
        self.free_at.iter_mut().for_each(|f| *f = t);
    }

    /// Count one event-triggered update against `copy`.
    pub fn record_update(&mut self, copy: usize) {
        self.updates += 1;
        self.shard_updates[copy] += 1;
    }

    /// Clients served by each copy (the FedAvg weights of the copies:
    /// a shard copy speaks for its whole client group, so copies must
    /// be weighted per client — Eq. (14) — not per copy).
    fn copy_weights(&self) -> Vec<f64> {
        let mut w = vec![0f64; self.copies.len()];
        for c in 0..self.shard_map.n_clients() {
            w[self.shard_map.shard_of(c)] += 1.0;
        }
        w
    }

    /// Client-count-weighted mean of the copies. Uses the exact uniform
    /// path when every copy serves equally many clients (the per-client
    /// topologies and evenly divisible shards), so historical results
    /// stay bit-identical there.
    fn copies_mean(&self) -> Vec<f32> {
        let refs: Vec<&[f32]> = self.copies.iter().map(|c| c.as_slice()).collect();
        let w = self.copy_weights();
        if w.windows(2).all(|p| p[0] == p[1]) {
            fedavg(&refs)
        } else {
            fedavg_weighted(&refs, &w)
        }
    }

    /// FedAvg all server copies into a single model and reset every copy
    /// to it — SplitFed's server-side aggregation for the per-client
    /// copies, and the **cross-shard FedAvg** of the sharded server
    /// phase. Copies are weighted by the number of clients they serve
    /// (uneven contiguous shards must not down-weight the larger
    /// groups). No-op with a single copy.
    pub fn aggregate_copies(&mut self) {
        if self.copies.len() <= 1 {
            return;
        }
        let mean = self.copies_mean();
        for c in &mut self.copies {
            c.copy_from_slice(&mean);
        }
    }

    /// Client-weighted mean of the server copies (evaluation probe).
    pub fn eval_model(&self) -> Vec<f32> {
        if self.copies.len() == 1 {
            self.copies[0].clone()
        } else {
            self.copies_mean()
        }
    }

    /// Resident server-side parameter count (live storage check): the
    /// measured counterpart of `comm::accounting::storage`'s closed form.
    pub fn resident_params(&self) -> usize {
        self.copies.iter().map(|c| c.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn copy_routing() {
        let single = ServerState::new(vec![0.0; 4], 4, Topology::Sharded(1), 2, 2);
        assert_eq!(single.copy_for(0), 0);
        assert_eq!(single.copy_for(3), 0);
        assert_eq!(single.lanes(), 1);
        let multi = ServerState::new(vec![0.0; 4], 5, Topology::PerClient, 2, 2);
        assert_eq!(multi.copy_for(3), 3);
        assert_eq!(multi.lanes(), 1, "per-client copies share one executor");
        assert_eq!(multi.lane_for(3), 0);
        assert_eq!(multi.resident_params(), 20);
        assert_eq!(single.resident_params(), 4);
    }

    #[test]
    fn shard_map_contiguous_and_balanced() {
        // 7 clients over 3 shards: sizes 3, 2, 2 in canonical order.
        let m = ShardMap::contiguous(7, 3);
        assert_eq!(m.shards(), 3);
        assert_eq!(m.n_clients(), 7);
        let of: Vec<usize> = (0..7).map(|c| m.shard_of(c)).collect();
        assert_eq!(of, vec![0, 0, 0, 1, 1, 2, 2]);
        assert_eq!(m.clients_of(0), vec![0, 1, 2]);
        assert_eq!(m.clients_of(2), vec![5, 6]);
        // The two paper endpoints.
        let one = ShardMap::contiguous(5, 1);
        assert!((0..5).all(|c| one.shard_of(c) == 0));
        let per = ShardMap::contiguous(5, 5);
        assert!((0..5).all(|c| per.shard_of(c) == c));
    }

    #[test]
    #[should_panic(expected = "more shards")]
    fn shard_map_rejects_oversharding() {
        ShardMap::contiguous(3, 4);
    }

    #[test]
    fn contiguous_closed_form_matches_materialized_fill() {
        // The O(1) closed form must agree with the historical
        // materialized fill (first n%k shards get one extra client) for
        // every (n, k), and semantic equality must hold across
        // representations.
        for n in 0..40usize {
            for k in 1..=n.max(1) {
                let m = ShardMap::contiguous(n, k);
                assert!(m.is_contiguous_repr());
                let base = n / k;
                let extra = n % k;
                let mut expect = Vec::with_capacity(n);
                for s in 0..k {
                    let len = base + usize::from(s < extra);
                    expect.resize(expect.len() + len, s);
                }
                let got: Vec<usize> = (0..n).map(|c| m.shard_of(c)).collect();
                assert_eq!(got, expect, "n={n} k={k}");
            }
        }
        // Million-scale spot check: no allocation proportional to n.
        let big = ShardMap::contiguous(1_000_000, 3);
        assert_eq!(big.shard_of(0), 0);
        assert_eq!(big.shard_of(333_333), 0);
        assert_eq!(big.shard_of(333_334), 1);
        assert_eq!(big.shard_of(999_999), 2);
        // Cross-representation equality: a balanced map that happens to
        // produce the contiguous grouping compares equal to it.
        let bal = ShardMap::balanced(4, 1, &[1.0; 4]);
        assert!(!bal.is_contiguous_repr());
        assert_eq!(bal, ShardMap::contiguous(4, 1));
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn contiguous_closed_form_rejects_out_of_range_lookup() {
        ShardMap::contiguous(5, 2).shard_of(5);
    }

    #[test]
    fn balanced_map_spreads_heavy_clients() {
        // Contiguous over 5 clients / 2 shards is {0,1,2} | {3,4}; with
        // clients 0 and 4 heavy, LPT must split the heavy pair instead.
        let costs = [10.0, 1.0, 1.0, 1.0, 9.0];
        let bal = ShardMap::balanced(5, 2, &costs);
        assert_eq!(bal.shards(), 2);
        assert_eq!(bal.n_clients(), 5);
        assert_ne!(bal.shard_of(0), bal.shard_of(4), "heavy clients must not share a shard");
        assert_ne!(bal, ShardMap::contiguous(5, 2));
        // The partition is a permutation of the clients: every client in
        // exactly one shard, every shard non-empty.
        let mut all: Vec<usize> = (0..2).flat_map(|s| bal.clients_of(s)).collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3, 4]);
        assert!((0..2).all(|s| !bal.clients_of(s).is_empty()));
        // Max shard load respects the greedy LPT bound.
        let load = |s: usize| bal.clients_of(s).iter().map(|&c| costs[c]).sum::<f64>();
        let max_load = (0..2).map(load).fold(0.0f64, f64::max);
        assert!(max_load <= crate::sched::greedy_bound(&costs, 2) + 1e-12, "{max_load}");
    }

    #[test]
    fn balanced_map_degenerate_inputs() {
        // k = 1 collapses to the single shared copy, like contiguous.
        let one = ShardMap::balanced(4, 1, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(one, ShardMap::contiguous(4, 1));
        // All-zero costs sanitize to uniform: every shard still serves
        // at least one client.
        let z = ShardMap::balanced(4, 2, &[0.0; 4]);
        assert!((0..2).all(|s| !z.clients_of(s).is_empty()));
        // Empty map.
        let empty = ShardMap::balanced(0, 1, &[]);
        assert_eq!(empty.n_clients(), 0);
    }

    #[test]
    #[should_panic(expected = "one cost estimate per client")]
    fn balanced_map_rejects_cost_mismatch() {
        ShardMap::balanced(3, 2, &[1.0]);
    }

    #[test]
    fn locality_map_stratifies_label_sorted_clients() {
        // Five clients whose shards were filled label-by-label (the
        // pathological non-IID grouping): contiguous packs same-label
        // neighbours onto one shard; locality deals each similarity
        // block across shards so both shard mixes match the global one.
        let h = vec![
            vec![24, 0, 0],
            vec![16, 8, 0],
            vec![0, 24, 0],
            vec![0, 8, 16],
            vec![0, 0, 24],
        ];
        let costs = [1.0; 5];
        let loc = ShardMap::locality(5, 2, &h, &costs);
        // Deterministic stratification: shard 0 = {0, 2, 4}, shard 1 =
        // {1, 3} — each shard's aggregate is exactly the global mix.
        assert_eq!(loc.clients_of(0), vec![0, 2, 4]);
        assert_eq!(loc.clients_of(1), vec![1, 3]);
        assert!(loc.label_divergence(&h) < 1e-12, "{}", loc.label_divergence(&h));
        let cont = ShardMap::contiguous(5, 2);
        let cd = cont.label_divergence(&h);
        assert!((cd - 0.41666).abs() < 1e-3, "{cd}");
        assert!(loc.label_divergence(&h) < cd);
    }

    #[test]
    fn locality_beats_balanced_on_skewed_arms() {
        // Two label-0 clients (0, 2) carry the heavy costs, two label-1
        // clients (1, 3) the light ones. Cost-only LPT isolates client 0
        // on its own shard (pure label 0 — maximal skew); the locality
        // map pairs opposite-skew clients on both shards while staying
        // within the greedy cost bound.
        let h = vec![vec![8, 0], vec![0, 8], vec![8, 0], vec![0, 8]];
        let costs = [10.0, 0.6, 9.0, 0.5];
        let bal = ShardMap::balanced(4, 2, &costs);
        let loc = ShardMap::locality(4, 2, &h, &costs);
        let bd = bal.label_divergence(&h);
        let ld = loc.label_divergence(&h);
        assert!((bd - 1.0 / 3.0).abs() < 1e-9, "balanced divergence {bd}");
        assert!(ld < 1e-12, "locality divergence {ld}");
        assert!(ld < bd, "locality must beat cost-only packing on skewed arms");
        // Opposite-skew pairing on both shards.
        assert_ne!(loc.shard_of(0), loc.shard_of(2));
        assert_ne!(loc.shard_of(1), loc.shard_of(3));
        // Cost balance: within the greedy list-scheduling bound.
        let load = |s: usize| loc.clients_of(s).iter().map(|&c| costs[c]).sum::<f64>();
        let max_load = (0..2).map(load).fold(0.0f64, f64::max);
        assert!(max_load <= crate::sched::greedy_bound(&costs, 2) + 1e-12, "{max_load}");
    }

    #[test]
    fn locality_counts_balanced_and_all_shards_covered() {
        // Shard client counts differ by at most one (each dealing wave
        // uses every shard at most once), so no shard is ever empty.
        let h: Vec<Vec<usize>> =
            (0..7).map(|c| vec![c, 7 - c, (c * 3) % 5]).collect();
        let costs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0];
        let m = ShardMap::locality(7, 3, &h, &costs);
        let counts: Vec<usize> = (0..3).map(|s| m.clients_of(s).len()).collect();
        assert_eq!(counts.iter().sum::<usize>(), 7);
        assert!(counts.iter().all(|&c| c == 2 || c == 3), "{counts:?}");
        let mut all: Vec<usize> = (0..3).flat_map(|s| m.clients_of(s)).collect();
        all.sort_unstable();
        assert_eq!(all, (0..7).collect::<Vec<_>>());
    }

    #[test]
    fn locality_degenerate_inputs() {
        // k = 1 collapses to the single shared copy with zero skew.
        let h = vec![vec![4, 0], vec![0, 4]];
        let one = ShardMap::locality(2, 1, &h, &[1.0, 2.0]);
        assert_eq!(one, ShardMap::contiguous(2, 1));
        assert_eq!(one.label_divergence(&h), 0.0);
        // Degenerate costs sanitize exactly like the balanced map.
        let z = ShardMap::locality(2, 2, &h, &[0.0, f64::NAN]);
        assert_ne!(z.shard_of(0), z.shard_of(1));
        // All-empty histograms: defined (no labels, no skew).
        let empty_h = vec![vec![0usize; 3]; 2];
        let m = ShardMap::locality(2, 2, &empty_h, &[1.0, 1.0]);
        assert_eq!(m.label_divergence(&empty_h), 0.0);
        // Empty map.
        let none = ShardMap::locality(0, 1, &[], &[]);
        assert_eq!(none.n_clients(), 0);
        assert_eq!(none.label_divergence(&[]), 0.0);
    }

    #[test]
    #[should_panic(expected = "one label histogram per client")]
    fn locality_rejects_histogram_mismatch() {
        ShardMap::locality(3, 2, &[vec![1, 2]], &[1.0, 1.0, 1.0]);
    }

    #[test]
    fn weighted_divergence_diverges_from_mean_on_unbalanced_shards() {
        // Deliberately unbalanced shard masses: contiguous(3, 2) puts
        // clients {0, 1} (32 well-mixed samples) on shard 0 and the
        // tiny pure-label client {2} (2 samples) on shard 1. Global
        // mix: (18, 16)/34.
        //   TV_0 = ½(|16/32 − 18/34| + |16/32 − 16/34|) ≈ 0.0294
        //   TV_1 = ½(|1 − 18/34| + |0 − 16/34|)        ≈ 0.4706
        // Unweighted mean = (TV_0 + TV_1)/2 = 0.25 — the 2-sample shard
        // dominates. Weighted = (32/34)·TV_0 + (2/34)·TV_1 ≈ 0.0554 —
        // proportional to what a sample-weighted FedAvg actually mixes.
        let h = vec![vec![8, 8], vec![8, 8], vec![2, 0]];
        let m = ShardMap::contiguous(3, 2);
        let mean = m.label_divergence(&h);
        let weighted = m.label_divergence_weighted(&h);
        assert!((mean - 0.25).abs() < 1e-9, "mean {mean}");
        assert!((weighted - 0.0554).abs() < 1e-3, "weighted {weighted}");
        assert!(
            weighted < mean / 4.0,
            "the metrics must diverge on unbalanced masses: {weighted} vs {mean}"
        );
        // On equal (non-zero) shard masses the two metrics agree
        // exactly: contiguous(4, 2) packs the pure-label pairs, both
        // shards score TV = 0.5, and the weights are uniform.
        let h_eq = vec![vec![8, 0], vec![8, 0], vec![0, 8], vec![0, 8]];
        let m_eq = ShardMap::contiguous(4, 2);
        assert_eq!(m_eq.label_divergence(&h_eq), 0.5);
        assert!(
            (m_eq.label_divergence(&h_eq) - m_eq.label_divergence_weighted(&h_eq)).abs()
                < 1e-12
        );
        // Empty-shard semantics differ by design: the unweighted form
        // charges the full distance, the weighted form zero mass. A
        // 1-client, 2-shard map cannot be built via the constructors
        // (shards <= clients), so exercise degenerate masses instead:
        // an all-empty histogram shard.
        let h_zero = vec![vec![4, 4], vec![0, 0]];
        let m2 = ShardMap::contiguous(2, 2);
        assert_eq!(m2.label_divergence(&h_zero), 0.5, "mean charges the empty shard");
        assert_eq!(
            m2.label_divergence_weighted(&h_zero),
            0.0,
            "weighted gives the empty shard zero mass"
        );
    }

    #[test]
    fn with_map_routes_through_custom_assignment() {
        let map = ShardMap::balanced(5, 2, &[10.0, 1.0, 1.0, 1.0, 9.0]);
        let s = ServerState::with_map(vec![0.0; 4], Topology::Sharded(2), map.clone(), 2, 2);
        assert_eq!(s.lanes(), 2);
        assert_eq!(s.copies.len(), 2);
        for c in 0..5 {
            assert_eq!(s.copy_for(c), map.shard_of(c));
            assert_eq!(s.lane_for(c), map.shard_of(c));
        }
    }

    #[test]
    #[should_panic(expected = "does not match topology")]
    fn with_map_rejects_mismatched_shards() {
        let map = ShardMap::contiguous(4, 2);
        ServerState::with_map(vec![0.0; 4], Topology::Sharded(3), map, 2, 2);
    }

    #[test]
    fn sharded_topology_lanes_and_counts() {
        let mut s = ServerState::new(vec![0.0; 4], 6, Topology::Sharded(3), 2, 2);
        assert_eq!(s.lanes(), 3);
        assert_eq!(s.copies.len(), 3);
        assert_eq!(s.lane_for(0), 0);
        assert_eq!(s.lane_for(5), 2);
        assert_eq!(s.resident_params(), 12);
        s.record_update(2);
        s.record_update(2);
        s.record_update(0);
        assert_eq!(s.updates, 3);
        assert_eq!(s.shard_updates, vec![1, 0, 2]);
        s.free_at[1] = 4.0;
        assert_eq!(s.free_at_max(), 4.0);
        s.sync_free_at(7.0);
        assert_eq!(s.free_at, vec![7.0; 3]);
    }

    #[test]
    fn smashed_msg_is_send() {
        // The parallel round engine produces SmashedMsgs on worker
        // threads and ships them back over a channel.
        fn assert_send<T: Send>() {}
        assert_send::<SmashedMsg>();
    }

    #[test]
    fn aggregate_copies_means() {
        let mut s = ServerState::new(vec![0.0; 2], 2, Topology::Sharded(2), 1, 1);
        s.copies[0] = vec![1.0, 3.0];
        s.copies[1] = vec![3.0, 1.0];
        s.aggregate_copies();
        assert_eq!(s.copies[0], vec![2.0, 2.0]);
        assert_eq!(s.copies[1], vec![2.0, 2.0]);
        assert_eq!(s.eval_model(), vec![2.0, 2.0]);
    }

    #[test]
    fn uneven_shards_weight_copies_per_client() {
        // 3 clients over 2 shards: groups of 2 and 1. The cross-shard
        // FedAvg must weight per CLIENT (Eq. (14)): (2*a + 1*b) / 3,
        // not the per-copy mean (a + b) / 2.
        let mut s = ServerState::new(vec![0.0; 1], 3, Topology::Sharded(2), 1, 1);
        s.copies[0] = vec![3.0]; // serves clients 0, 1
        s.copies[1] = vec![9.0]; // serves client 2
        let m = s.eval_model();
        assert!((m[0] - 5.0).abs() < 1e-5, "(2*3 + 1*9) / 3 = 5, got {}", m[0]);
        s.aggregate_copies();
        assert!((s.copies[0][0] - 5.0).abs() < 1e-5, "{}", s.copies[0][0]);
        assert_eq!(s.copies[0], s.copies[1]);
    }
}
