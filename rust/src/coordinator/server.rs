//! Server state: sharded server-side model copies, per-shard executor
//! clocks, and aggregation accumulators. The paper's event-triggered
//! `dataQueue` (Algorithm 2) is materialized by the round engine as
//! per-executor-lane arrival queues each round
//! (`coordinator::round::Trainer::drain_data_queue`).
//!
//! The paper's methods pin two points of a storage/throughput curve: one
//! shared copy behind one event loop (FSL_OC / CSE_FSL) or one copy per
//! client behind one event loop (FSL_MC / FSL_AN). [`Topology`]
//! generalizes the single-copy side to `k` **shards**: `k` server-side
//! copies, each serving a contiguous group of clients on its own
//! event-loop executor, FedAvg'd back together at every aggregation
//! (cross-shard FedAvg). `k = 1` reproduces the paper's single-copy
//! server bit-for-bit; `k = n` holds as many copies as FSL_MC.

use crate::model::aggregate::{fedavg, fedavg_weighted, Accumulator};

/// One smashed-data upload in flight / queued at the server.
#[derive(Clone, Debug)]
pub struct SmashedMsg {
    /// Originating client id.
    pub client: usize,
    /// Flattened smashed activations for one batch.
    pub smashed: Vec<f32>,
    /// Labels accompanying the smashed batch.
    pub labels: Vec<i32>,
    /// Simulated arrival time at the server.
    pub arrival: f64,
    /// Dropout seed the client used for this forward (server replays it
    /// for its own dropout stream).
    pub seed: i32,
}

/// Deterministic client → shard assignment: canonical client-id order,
/// contiguous groups, sizes as equal as possible (the first
/// `n mod k` shards hold one extra client).
///
/// The assignment is a pure function of `(n_clients, shards)` — never of
/// arrival order or scheduling — which is what lets the sharded server
/// phase keep the bit-determinism contract (see `coordinator/README.md`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardMap {
    shard_of: Vec<usize>,
    shards: usize,
}

impl ShardMap {
    /// Contiguous equal-as-possible groups of `n_clients` over `shards`.
    ///
    /// `shards` must be in `1..=n_clients`; `contiguous(n, 1)` maps every
    /// client to shard 0 (the paper's shared copy) and `contiguous(n, n)`
    /// is the identity (one copy per client, FSL_MC-style).
    pub fn contiguous(n_clients: usize, shards: usize) -> Self {
        assert!(shards >= 1, "at least one shard required");
        assert!(
            shards <= n_clients.max(1),
            "more shards ({shards}) than clients ({n_clients})"
        );
        let base = n_clients / shards;
        let extra = n_clients % shards;
        let mut shard_of = Vec::with_capacity(n_clients);
        for s in 0..shards {
            let len = base + usize::from(s < extra);
            shard_of.resize(shard_of.len() + len, s);
        }
        debug_assert_eq!(shard_of.len(), n_clients);
        ShardMap { shard_of, shards }
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Number of clients mapped.
    pub fn n_clients(&self) -> usize {
        self.shard_of.len()
    }

    /// The shard serving `client`.
    pub fn shard_of(&self, client: usize) -> usize {
        self.shard_of[client]
    }

    /// Client ids of one shard, ascending (contiguous by construction).
    pub fn clients_of(&self, shard: usize) -> Vec<usize> {
        (0..self.shard_of.len()).filter(|&c| self.shard_of[c] == shard).collect()
    }
}

/// How server-side model copies map to event-loop executors.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// One copy per client behind a **single** executor — FSL_MC / FSL_AN
    /// exactly as the paper describes them (the server is one machine
    /// holding n models).
    PerClient,
    /// `k` shard copies, each with its own event-loop executor; clients
    /// map to shards via [`ShardMap::contiguous`]. `Sharded(1)` is the
    /// paper's single-copy server (FSL_OC / CSE_FSL).
    Sharded(usize),
}

/// Algorithm 2 state, generalized to sharded copies.
pub struct ServerState {
    /// Server-side model copies: `n` ([`Topology::PerClient`]) or `k`
    /// ([`Topology::Sharded`]).
    pub copies: Vec<Vec<f32>>,
    /// Client → copy routing (identity for `PerClient`).
    pub shard_map: ShardMap,
    /// Per-executor clocks: when each event-loop lane finishes its
    /// current work. Length 1 for `PerClient` (n copies share one
    /// executor) and `k` for `Sharded(k)` (one executor per shard copy).
    pub free_at: Vec<f64>,
    /// Aggregation accumulator for client-side models.
    pub client_acc: Accumulator,
    /// Aggregation accumulator for auxiliary networks.
    pub aux_acc: Accumulator,
    /// Total event-triggered updates performed (observability).
    pub updates: u64,
    /// Event-triggered updates applied to each copy (per-shard counts;
    /// sums to [`ServerState::updates`]).
    pub shard_updates: Vec<u64>,
}

impl ServerState {
    /// Build the server from the initial server-side model `xs`, the
    /// client count, and the copy/executor [`Topology`].
    pub fn new(
        xs: Vec<f32>,
        n_clients: usize,
        topology: Topology,
        client_size: usize,
        aux_size: usize,
    ) -> Self {
        let (shard_map, lanes) = match topology {
            Topology::PerClient => (ShardMap::contiguous(n_clients, n_clients.max(1)), 1),
            Topology::Sharded(k) => (ShardMap::contiguous(n_clients, k), k),
        };
        let copies = shard_map.shards();
        ServerState {
            copies: vec![xs; copies],
            shard_map,
            free_at: vec![0.0; lanes],
            client_acc: Accumulator::new(client_size),
            aux_acc: Accumulator::new(aux_size),
            updates: 0,
            shard_updates: vec![0; copies],
        }
    }

    /// Number of executor lanes (independent server event loops).
    pub fn lanes(&self) -> usize {
        self.free_at.len()
    }

    /// The copy index serving `client`.
    pub fn copy_for(&self, client: usize) -> usize {
        self.shard_map.shard_of(client)
    }

    /// The executor lane serving `client` (0 when all copies share one
    /// event loop).
    pub fn lane_for(&self, client: usize) -> usize {
        if self.free_at.len() == 1 {
            0
        } else {
            self.shard_map.shard_of(client)
        }
    }

    /// Latest time any executor lane is busy until (the global "server
    /// free" time — used as the aggregation barrier baseline).
    pub fn free_at_max(&self) -> f64 {
        self.free_at.iter().copied().fold(0.0, f64::max)
    }

    /// Synchronize every executor lane to `t` (aggregation is a global
    /// barrier across shards).
    pub fn sync_free_at(&mut self, t: f64) {
        self.free_at.iter_mut().for_each(|f| *f = t);
    }

    /// Count one event-triggered update against `copy`.
    pub fn record_update(&mut self, copy: usize) {
        self.updates += 1;
        self.shard_updates[copy] += 1;
    }

    /// Clients served by each copy (the FedAvg weights of the copies:
    /// a shard copy speaks for its whole client group, so copies must
    /// be weighted per client — Eq. (14) — not per copy).
    fn copy_weights(&self) -> Vec<f64> {
        let mut w = vec![0f64; self.copies.len()];
        for c in 0..self.shard_map.n_clients() {
            w[self.shard_map.shard_of(c)] += 1.0;
        }
        w
    }

    /// Client-count-weighted mean of the copies. Uses the exact uniform
    /// path when every copy serves equally many clients (the per-client
    /// topologies and evenly divisible shards), so historical results
    /// stay bit-identical there.
    fn copies_mean(&self) -> Vec<f32> {
        let refs: Vec<&[f32]> = self.copies.iter().map(|c| c.as_slice()).collect();
        let w = self.copy_weights();
        if w.windows(2).all(|p| p[0] == p[1]) {
            fedavg(&refs)
        } else {
            fedavg_weighted(&refs, &w)
        }
    }

    /// FedAvg all server copies into a single model and reset every copy
    /// to it — SplitFed's server-side aggregation for the per-client
    /// copies, and the **cross-shard FedAvg** of the sharded server
    /// phase. Copies are weighted by the number of clients they serve
    /// (uneven contiguous shards must not down-weight the larger
    /// groups). No-op with a single copy.
    pub fn aggregate_copies(&mut self) {
        if self.copies.len() <= 1 {
            return;
        }
        let mean = self.copies_mean();
        for c in &mut self.copies {
            c.copy_from_slice(&mean);
        }
    }

    /// Client-weighted mean of the server copies (evaluation probe).
    pub fn eval_model(&self) -> Vec<f32> {
        if self.copies.len() == 1 {
            self.copies[0].clone()
        } else {
            self.copies_mean()
        }
    }

    /// Resident server-side parameter count (live storage check): the
    /// measured counterpart of `comm::accounting::storage`'s closed form.
    pub fn resident_params(&self) -> usize {
        self.copies.iter().map(|c| c.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn copy_routing() {
        let single = ServerState::new(vec![0.0; 4], 4, Topology::Sharded(1), 2, 2);
        assert_eq!(single.copy_for(0), 0);
        assert_eq!(single.copy_for(3), 0);
        assert_eq!(single.lanes(), 1);
        let multi = ServerState::new(vec![0.0; 4], 5, Topology::PerClient, 2, 2);
        assert_eq!(multi.copy_for(3), 3);
        assert_eq!(multi.lanes(), 1, "per-client copies share one executor");
        assert_eq!(multi.lane_for(3), 0);
        assert_eq!(multi.resident_params(), 20);
        assert_eq!(single.resident_params(), 4);
    }

    #[test]
    fn shard_map_contiguous_and_balanced() {
        // 7 clients over 3 shards: sizes 3, 2, 2 in canonical order.
        let m = ShardMap::contiguous(7, 3);
        assert_eq!(m.shards(), 3);
        assert_eq!(m.n_clients(), 7);
        let of: Vec<usize> = (0..7).map(|c| m.shard_of(c)).collect();
        assert_eq!(of, vec![0, 0, 0, 1, 1, 2, 2]);
        assert_eq!(m.clients_of(0), vec![0, 1, 2]);
        assert_eq!(m.clients_of(2), vec![5, 6]);
        // The two paper endpoints.
        let one = ShardMap::contiguous(5, 1);
        assert!((0..5).all(|c| one.shard_of(c) == 0));
        let per = ShardMap::contiguous(5, 5);
        assert!((0..5).all(|c| per.shard_of(c) == c));
    }

    #[test]
    #[should_panic(expected = "more shards")]
    fn shard_map_rejects_oversharding() {
        ShardMap::contiguous(3, 4);
    }

    #[test]
    fn sharded_topology_lanes_and_counts() {
        let mut s = ServerState::new(vec![0.0; 4], 6, Topology::Sharded(3), 2, 2);
        assert_eq!(s.lanes(), 3);
        assert_eq!(s.copies.len(), 3);
        assert_eq!(s.lane_for(0), 0);
        assert_eq!(s.lane_for(5), 2);
        assert_eq!(s.resident_params(), 12);
        s.record_update(2);
        s.record_update(2);
        s.record_update(0);
        assert_eq!(s.updates, 3);
        assert_eq!(s.shard_updates, vec![1, 0, 2]);
        s.free_at[1] = 4.0;
        assert_eq!(s.free_at_max(), 4.0);
        s.sync_free_at(7.0);
        assert_eq!(s.free_at, vec![7.0; 3]);
    }

    #[test]
    fn smashed_msg_is_send() {
        // The parallel round engine produces SmashedMsgs on worker
        // threads and ships them back over a channel.
        fn assert_send<T: Send>() {}
        assert_send::<SmashedMsg>();
    }

    #[test]
    fn aggregate_copies_means() {
        let mut s = ServerState::new(vec![0.0; 2], 2, Topology::Sharded(2), 1, 1);
        s.copies[0] = vec![1.0, 3.0];
        s.copies[1] = vec![3.0, 1.0];
        s.aggregate_copies();
        assert_eq!(s.copies[0], vec![2.0, 2.0]);
        assert_eq!(s.copies[1], vec![2.0, 2.0]);
        assert_eq!(s.eval_model(), vec![2.0, 2.0]);
    }

    #[test]
    fn uneven_shards_weight_copies_per_client() {
        // 3 clients over 2 shards: groups of 2 and 1. The cross-shard
        // FedAvg must weight per CLIENT (Eq. (14)): (2*a + 1*b) / 3,
        // not the per-copy mean (a + b) / 2.
        let mut s = ServerState::new(vec![0.0; 1], 3, Topology::Sharded(2), 1, 1);
        s.copies[0] = vec![3.0]; // serves clients 0, 1
        s.copies[1] = vec![9.0]; // serves client 2
        let m = s.eval_model();
        assert!((m[0] - 5.0).abs() < 1e-5, "(2*3 + 1*9) / 3 = 5, got {}", m[0]);
        s.aggregate_copies();
        assert!((s.copies[0][0] - 5.0).abs() < 1e-5, "{}", s.copies[0][0]);
        assert_eq!(s.copies[0], s.copies[1]);
    }
}
