//! Server state: server-side model copies, the event-triggered
//! `dataQueue` (Algorithm 2), and aggregation accumulators.

use std::collections::VecDeque;

use crate::model::aggregate::{fedavg, Accumulator};

/// One smashed-data upload in flight / queued at the server.
#[derive(Clone, Debug)]
pub struct SmashedMsg {
    pub client: usize,
    pub smashed: Vec<f32>,
    pub labels: Vec<i32>,
    /// Simulated arrival time at the server.
    pub arrival: f64,
    /// Dropout seed the client used for this forward (server replays it
    /// for its own dropout stream).
    pub seed: i32,
}

/// Algorithm 2 state.
pub struct ServerState {
    /// Server-side model copies: len 1 (FSL_OC / CSE_FSL) or n (FSL_MC /
    /// FSL_AN, one per client).
    pub copies: Vec<Vec<f32>>,
    /// The paper's dataQueue: arrived smashed data waiting for the
    /// event-triggered update loop.
    pub data_queue: VecDeque<SmashedMsg>,
    /// Simulated time at which the server finishes its current work.
    pub free_at: f64,
    /// Aggregation accumulators (client models / aux nets).
    pub client_acc: Accumulator,
    pub aux_acc: Accumulator,
    /// Total event-triggered updates performed (observability).
    pub updates: u64,
}

impl ServerState {
    pub fn new(xs: Vec<f32>, copies: usize, client_size: usize, aux_size: usize) -> Self {
        assert!(copies >= 1);
        ServerState {
            copies: vec![xs; copies],
            data_queue: VecDeque::new(),
            free_at: 0.0,
            client_acc: Accumulator::new(client_size),
            aux_acc: Accumulator::new(aux_size),
            updates: 0,
        }
    }

    /// The copy index serving `client` (0 when a single copy is shared).
    pub fn copy_for(&self, client: usize) -> usize {
        if self.copies.len() == 1 {
            0
        } else {
            client
        }
    }

    pub fn enqueue(&mut self, msg: SmashedMsg) {
        self.data_queue.push_back(msg);
    }

    /// Enqueue a whole upload wave, preserving the given order (the
    /// round engine pre-sorts by the configured [`ArrivalOrder`]).
    ///
    /// [`ArrivalOrder`]: super::config::ArrivalOrder
    pub fn enqueue_all(&mut self, msgs: impl IntoIterator<Item = SmashedMsg>) {
        for m in msgs {
            self.enqueue(m);
        }
    }

    /// FedAvg the per-client server copies into a single model and reset
    /// every copy to it (SplitFed's server-side aggregation). No-op with
    /// a single copy.
    pub fn aggregate_copies(&mut self) {
        if self.copies.len() <= 1 {
            return;
        }
        let refs: Vec<&[f32]> = self.copies.iter().map(|c| c.as_slice()).collect();
        let mean = fedavg(&refs);
        for c in &mut self.copies {
            c.copy_from_slice(&mean);
        }
    }

    /// Mean of the server copies (evaluation probe).
    pub fn eval_model(&self) -> Vec<f32> {
        if self.copies.len() == 1 {
            self.copies[0].clone()
        } else {
            let refs: Vec<&[f32]> = self.copies.iter().map(|c| c.as_slice()).collect();
            fedavg(&refs)
        }
    }

    /// Resident server-side parameter count (live storage check).
    pub fn resident_params(&self) -> usize {
        self.copies.iter().map(|c| c.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn copy_routing() {
        let single = ServerState::new(vec![0.0; 4], 1, 2, 2);
        assert_eq!(single.copy_for(0), 0);
        assert_eq!(single.copy_for(3), 0);
        let multi = ServerState::new(vec![0.0; 4], 5, 2, 2);
        assert_eq!(multi.copy_for(3), 3);
        assert_eq!(multi.resident_params(), 20);
        assert_eq!(single.resident_params(), 4);
    }

    #[test]
    fn queue_fifo() {
        let mut s = ServerState::new(vec![0.0; 2], 1, 1, 1);
        s.enqueue_all((0..3).map(|i| SmashedMsg {
            client: i,
            smashed: vec![],
            labels: vec![],
            arrival: i as f64,
            seed: 0,
        }));
        assert_eq!(s.data_queue.pop_front().unwrap().client, 0);
        assert_eq!(s.data_queue.pop_front().unwrap().client, 1);
    }

    #[test]
    fn smashed_msg_is_send() {
        // The parallel round engine produces SmashedMsgs on worker
        // threads and ships them back over a channel.
        fn assert_send<T: Send>() {}
        assert_send::<SmashedMsg>();
    }

    #[test]
    fn aggregate_copies_means() {
        let mut s = ServerState::new(vec![0.0; 2], 2, 1, 1);
        s.copies[0] = vec![1.0, 3.0];
        s.copies[1] = vec![3.0, 1.0];
        s.aggregate_copies();
        assert_eq!(s.copies[0], vec![2.0, 2.0]);
        assert_eq!(s.copies[1], vec![2.0, 2.0]);
        assert_eq!(s.eval_model(), vec![2.0, 2.0]);
    }
}
