//! Per-client state: model shards, local data stream, delay profile.

use crate::data::batcher::Batcher;
use crate::data::Dataset;
use crate::sim::netmodel::ClientProfile;
use crate::util::prng::Rng;

/// One federated client (Algorithm 1 state).
///
/// Must stay `Send`: the parallel round engine hands each participating
/// client's `&mut ClientState` to a scoped worker thread. All randomness
/// a client consumes (its batcher stream, its dropout-seed stream) is
/// owned here, derived from `root.split(1000 + id)` at construction — so
/// client trajectories are independent of both scheduling and the fan-out
/// strategy.
pub struct ClientState {
    /// Client id (canonical merge order of the parallel engine).
    pub id: usize,
    /// Client-side model x_{c,i}.
    pub xc: Vec<f32>,
    /// Auxiliary network a_{c,i} (empty when the method has none).
    pub ac: Vec<f32>,
    /// Mini-batch stream over this client's data shard.
    pub batcher: Batcher,
    /// Persistent compute/network delay profile.
    pub profile: ClientProfile,
    /// Simulated time at which this client is free to start local work.
    pub ready_at: f64,
    rng: Rng,
    seed_counter: i64,
    /// Reusable batch index buffer (no allocation in the round loop).
    pub idx_buf: Vec<usize>,
    /// Reusable batch image buffer.
    pub images: Vec<f32>,
    /// Reusable batch label buffer.
    pub labels: Vec<i32>,
}

impl ClientState {
    /// Build one client from its initial models, data shard, and delay
    /// profile; `rng` seeds all of this client's private random streams.
    pub fn new(
        id: usize,
        xc: Vec<f32>,
        ac: Vec<f32>,
        shard: Vec<usize>,
        batch_size: usize,
        profile: ClientProfile,
        rng: Rng,
    ) -> Self {
        let batcher_rng = rng.split_str("batches");
        ClientState {
            id,
            xc,
            ac,
            batcher: Batcher::new(shard, batch_size, batcher_rng),
            profile,
            ready_at: 0.0,
            rng,
            seed_counter: 0,
            idx_buf: Vec::new(),
            images: Vec::new(),
            labels: Vec::new(),
        }
    }

    /// Deterministic per-step dropout seed, never repeated for this
    /// client (paired client_fwd/client_bwd calls reuse one value).
    pub fn next_seed(&mut self) -> i32 {
        self.seed_counter += 1;
        // Mix with a client-specific stream so clients never share seeds.
        let mixed = self.rng.split(self.seed_counter as u64).next_u64();
        (mixed & 0x7FFF_FFFF) as i32
    }

    /// Load the next mini-batch into the internal buffers.
    pub fn load_batch(&mut self, ds: &Dataset) {
        self.batcher.next_batch(&mut self.idx_buf);
        ds.gather(&self.idx_buf, &mut self.images, &mut self.labels);
    }

    /// Full mini-batches per local epoch (h/C scheduling).
    pub fn shard_len(&self) -> usize {
        self.batcher.batches_per_epoch()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticSpec};
    use crate::sim::netmodel::NetModel;

    fn mk() -> (ClientState, Dataset) {
        let spec =
            SyntheticSpec { height: 4, width: 4, channels: 1, classes: 2, ..SyntheticSpec::cifar_like() };
        let ds = generate(&spec, 20, 1);
        let mut rng = Rng::new(2);
        let profile = NetModel::homogeneous().sample_profile(&mut rng);
        let c = ClientState::new(
            0,
            vec![0.0; 8],
            vec![0.0; 4],
            (0..20).collect(),
            5,
            profile,
            Rng::new(3),
        );
        (c, ds)
    }

    #[test]
    fn seeds_unique_and_deterministic() {
        let (mut c, _) = mk();
        let s: Vec<i32> = (0..100).map(|_| c.next_seed()).collect();
        let mut dedup = s.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 100, "seed collision");
        let (mut c2, _) = mk();
        let s2: Vec<i32> = (0..100).map(|_| c2.next_seed()).collect();
        assert_eq!(s, s2);
        assert!(s.iter().all(|&x| x >= 0));
    }

    #[test]
    fn client_state_is_send() {
        fn assert_send<T: Send>() {}
        assert_send::<ClientState>();
    }

    #[test]
    fn batch_loading_fills_buffers() {
        let (mut c, ds) = mk();
        c.load_batch(&ds);
        assert_eq!(c.idx_buf.len(), 5);
        assert_eq!(c.images.len(), 5 * 16);
        assert_eq!(c.labels.len(), 5);
    }
}
