//! The composable method-spec API: every federated-split-learning
//! variant is a point in a four-axis design space, and the paper's four
//! compared methods (Section VI-A) are named presets in it.
//!
//! # The four axes
//!
//! | axis | variants | decides |
//! |---|---|---|
//! | [`ClientUpdate`] | `ServerGrad { clip }` / `AuxLocal` / `SageEstimate { align_every, clip }` | where the client-side gradient comes from (server downlink per batch, a local auxiliary-network loss, or an aux-network *estimate* of the server gradient re-aligned against the true gradient every `align_every`-th upload — FSL-SAGE) |
//! | [`UploadSchedule`] | `EveryBatch` / `Period(h)` / `AdaptivePeriod { .. }` | how many local batches each smashed upload amortizes |
//! | [`ServerTopology`] | `PerClient` / `Shared` | whether the server keeps one model copy per client or shared copies (`TrainConfig::server_shards` refines `Shared` into k shard copies) |
//! | [`Compression`] | `None` / `Quantize { bits }` / `TopK { frac }` | how many bits each smashed upload (and server-grad downlink) costs on the wire (FedLite-style lossy codecs) |
//!
//! # The paper's presets
//!
//! | preset | update | upload | topology |
//! |---------|----------------------|------------|-----------|
//! | FSL_MC  | `ServerGrad{clip:0}` | every batch| per-client|
//! | FSL_OC  | `ServerGrad{clip:1}` | every batch| shared    |
//! | FSL_AN  | `AuxLocal`           | every batch| per-client|
//! | CSE_FSL | `AuxLocal`           | every h    | shared    |
//!
//! Every preset sits at `Compression::None` (the paper transmits
//! full-precision smashed data); any compressed point is spec-only and
//! gets the canonical axis tag.
//!
//! Any other combination is a scenario the paper never names — e.g.
//! `AuxLocal × Period(h) × PerClient` ("FSL_AN with h > 1", the `figure
//! h` arm) or `CSE_FSL × Quantize{4}` (the `figure b` arm) — and runs
//! through exactly the same trainer. The only incoherent region is
//! `ServerGrad` with a non-every-batch schedule: the SplitFed client
//! *blocks* on the per-batch gradient round trip, so there is nothing
//! for a period to amortize ([`MethodSpec::validate`]).
//!
//! This module is the single home of method parsing / display / alias
//! handling: the CLI resolves `--method` (preset alias) and the
//! `--update` / `--upload-every` / `--clip` / `--topology` /
//! `--compress`+`--bits`+`--topk` axis flags through
//! [`MethodSpec::from_cli`], and every axis type implements `FromStr`
//! here (compression composes from two flags, so it parses in
//! `from_cli` directly).
//!
//! ```
//! use cse_fsl::coordinator::methods::{
//!     ClientUpdate, Compression, Method, MethodSpec, ServerTopology, UploadSchedule,
//! };
//!
//! // The paper's method is just one point of the space...
//! assert_eq!(Method::CseFsl.spec().with_period(5).preset(), Some(Method::CseFsl));
//! // ...and the axes compose into points the paper never names:
//! let an_h4 = MethodSpec {
//!     update: ClientUpdate::AuxLocal,
//!     upload: UploadSchedule::period(4),
//!     topology: ServerTopology::PerClient,
//!     compression: Compression::None,
//! };
//! assert_eq!(an_h4, Method::FslAn.spec().with_period(4));
//! assert_eq!(an_h4.preset(), None); // spec-only scenario ("FSL_AN with h>1")
//! assert!(an_h4.validate().is_ok());
//! // Compressed CSE-FSL: quantized smashed uploads every 2 batches.
//! let q4 = Method::CseFsl
//!     .spec()
//!     .with_period(2)
//!     .with_compression(Compression::Quantize { bits: 4 });
//! assert_eq!(q4.preset(), None);
//! assert_eq!(q4.tag(), "aux+p2+sh+q4");
//! ```

use crate::comm::accounting::predict::TrafficProfile;
pub use crate::comm::compress::Compression;

/// Where the client-side model's gradient comes from (axis 1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ClientUpdate {
    /// The server runs the forward/backward over the smashed data and
    /// sends the cut-layer gradient back every batch; the client blocks
    /// on the round trip (the SplitFed rule). `clip` caps the gradient
    /// norm on both sides of the cut (0 disables — the paper adds
    /// clipping to FSL_OC to fix its gradient-explosion instability).
    ServerGrad {
        /// Gradient-norm clip applied server- and client-side (0 = off).
        clip: f32,
    },
    /// The client trains against a local auxiliary-network loss and
    /// never waits for server gradients (fire-and-forget — the CSE-FSL
    /// rule). The aux networks join the model exchange at aggregation.
    AuxLocal,
    /// The auxiliary network *estimates* the server's smashed-gradient
    /// and the client trains against the estimate locally — between
    /// alignments the round is fire-and-forget with AuxLocal-shaped
    /// traffic. Every `align_every`-th upload the server returns its
    /// true cut-layer gradient, used both for the client step and an
    /// estimator-alignment update of the aux net — ServerGrad-shaped
    /// traffic on that round only (the FSL-SAGE rule). `clip` caps the
    /// gradient norm on both sides of the alignment round trip (0 =
    /// off).
    SageEstimate {
        /// Alignment period in rounds: every `align_every`-th upload
        /// triggers the true-gradient downlink. `1` aligns every round
        /// (the ServerGrad traffic shape); large values approach the
        /// purely local AuxLocal profile.
        align_every: usize,
        /// Gradient-norm clip on the alignment round trip (0 = off).
        clip: f32,
    },
}

impl ClientUpdate {
    /// Does this rule train (and aggregate) an auxiliary network?
    pub fn uses_aux(self) -> bool {
        matches!(self, ClientUpdate::AuxLocal | ClientUpdate::SageEstimate { .. })
    }

    /// The gradient clip in effect (0 for the aux-local rule, which
    /// never touches the server-grad path).
    pub fn clip(self) -> f32 {
        match self {
            ClientUpdate::ServerGrad { clip } => clip,
            ClientUpdate::AuxLocal => 0.0,
            ClientUpdate::SageEstimate { clip, .. } => clip,
        }
    }

    /// Short cache-key tag (`sg{clip}` / `aux` / `sage{a}`; a non-zero
    /// sage clip joins the segment as `sage{a}c{clip}` — the clip
    /// changes results, so it must fork the key).
    pub fn tag(self) -> String {
        match self {
            ClientUpdate::ServerGrad { clip } => format!("sg{clip}"),
            ClientUpdate::AuxLocal => "aux".to_string(),
            ClientUpdate::SageEstimate { align_every, clip } => {
                if clip == 0.0 {
                    format!("sage{align_every}")
                } else {
                    format!("sage{align_every}c{clip}")
                }
            }
        }
    }
}

impl std::fmt::Display for ClientUpdate {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientUpdate::ServerGrad { clip } => write!(f, "server-grad(clip={clip})"),
            ClientUpdate::AuxLocal => write!(f, "aux-local"),
            ClientUpdate::SageEstimate { align_every, clip } => {
                write!(f, "sage-estimate(align={align_every}, clip={clip})")
            }
        }
    }
}

impl std::str::FromStr for ClientUpdate {
    type Err = String;

    /// `grad` / `server-grad` / `sg` (clip 0 until `--clip` composes);
    /// `aux` / `aux-local` / `local`; `sage` / `sage-estimate` /
    /// `estimator` (alignment period 4 until `--align-every` composes,
    /// clip 0 until `--clip` does). Parsing lowercases and maps `_` to
    /// `-`, exactly like `Dist::parse`.
    fn from_str(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().replace('_', "-").as_str() {
            "grad" | "server-grad" | "sg" => Ok(ClientUpdate::ServerGrad { clip: 0.0 }),
            "aux" | "aux-local" | "local" => Ok(ClientUpdate::AuxLocal),
            "sage" | "sage-estimate" | "estimator" => {
                Ok(ClientUpdate::SageEstimate { align_every: 4, clip: 0.0 })
            }
            other => Err(format!(
                "bad client update {other:?} (expected grad | server-grad | aux | \
                 aux-local | sage)"
            )),
        }
    }
}

/// How many local batches each smashed upload amortizes (axis 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UploadSchedule {
    /// One local batch per upload (h = 1 — every baseline preset).
    EveryBatch,
    /// A fixed `h >= 2` local batches per upload (CSE_FSL's h).
    /// Build via [`UploadSchedule::period`], which canonicalizes
    /// `Period(1)` to [`UploadSchedule::EveryBatch`]; hand-built
    /// `Period(0)` / `Period(1)` values are rejected by
    /// [`MethodSpec::validate`] (one canonical representation per
    /// behavior, so cache keys can never fork).
    Period(usize),
    /// A deterministic schedule that starts at `h0` batches per upload
    /// and doubles every `double_every` rounds up to `h_max` — chatty
    /// early (fresh server model while training is volatile), cheap
    /// late, mirroring the lr decay. A pure function of the round
    /// index, so the bit-determinism contract is untouched.
    AdaptivePeriod {
        /// Batches per upload in round 1.
        h0: usize,
        /// Upper bound on the period.
        h_max: usize,
        /// Rounds between doublings.
        double_every: usize,
    },
}

impl UploadSchedule {
    /// The canonical fixed-period constructor: `h = 1` is
    /// [`UploadSchedule::EveryBatch`] (so `Period(1)` never aliases it),
    /// any other `h` is `Period(h)` (`h = 0` is rejected by
    /// [`MethodSpec::validate`]).
    pub fn period(h: usize) -> UploadSchedule {
        if h == 1 {
            UploadSchedule::EveryBatch
        } else {
            UploadSchedule::Period(h)
        }
    }

    /// Local batches trained before the upload of (1-based) `round`.
    pub fn batches_at(self, round: usize) -> usize {
        match self {
            UploadSchedule::EveryBatch => 1,
            UploadSchedule::Period(h) => h,
            UploadSchedule::AdaptivePeriod { h0, h_max, double_every } => {
                let steps = (round.saturating_sub(1) / double_every.max(1)).min(64);
                let mut h = h0;
                for _ in 0..steps {
                    if h >= h_max {
                        break;
                    }
                    h = h.saturating_mul(2).min(h_max);
                }
                h.min(h_max)
            }
        }
    }

    /// Static period estimate: the exact h for the fixed schedules, the
    /// initial h0 for the adaptive one. Feeds scheduling cost priors,
    /// the per-epoch aggregation cadence, and the `h{}` key segment.
    pub fn h_hint(self) -> usize {
        match self {
            UploadSchedule::EveryBatch => 1,
            UploadSchedule::Period(h) => h,
            UploadSchedule::AdaptivePeriod { h0, .. } => h0,
        }
    }

    /// Short cache-key tag (`b` / `p{h}` / `ap{h0}x{h_max}e{k}`).
    pub fn tag(self) -> String {
        match self {
            UploadSchedule::EveryBatch => "b".to_string(),
            UploadSchedule::Period(h) => format!("p{h}"),
            UploadSchedule::AdaptivePeriod { h0, h_max, double_every } => {
                format!("ap{h0}x{h_max}e{double_every}")
            }
        }
    }
}

impl std::fmt::Display for UploadSchedule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            UploadSchedule::EveryBatch => write!(f, "every-batch"),
            UploadSchedule::Period(h) => write!(f, "every {h} batches"),
            UploadSchedule::AdaptivePeriod { h0, h_max, double_every } => {
                write!(f, "adaptive ({h0}..{h_max}, x2 every {double_every} rounds)")
            }
        }
    }
}

impl std::str::FromStr for UploadSchedule {
    type Err = String;

    /// An integer `h` (`1` = every batch), or
    /// `adaptive:<h0>:<h_max>:<double_every>`.
    fn from_str(s: &str) -> Result<Self, String> {
        let low = s.to_ascii_lowercase();
        if let Some(rest) = low.strip_prefix("adaptive:") {
            let parts: Vec<&str> = rest.split(':').collect();
            if parts.len() != 3 {
                return Err(format!(
                    "bad adaptive schedule {s:?} (expected adaptive:<h0>:<h_max>:<double_every>)"
                ));
            }
            let num = |p: &str| {
                p.parse::<usize>()
                    .map_err(|_| format!("bad adaptive schedule component {p:?} in {s:?}"))
            };
            return Ok(UploadSchedule::AdaptivePeriod {
                h0: num(parts[0])?,
                h_max: num(parts[1])?,
                double_every: num(parts[2])?,
            });
        }
        match low.as_str() {
            "batch" | "every-batch" => Ok(UploadSchedule::EveryBatch),
            other => match other.parse::<usize>() {
                Ok(h) => Ok(UploadSchedule::period(h)),
                Err(_) => Err(format!(
                    "bad upload schedule {s:?} (expected <h> | adaptive:<h0>:<h_max>:<k>)"
                )),
            },
        }
    }
}

/// How server-side model copies map to clients (axis 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServerTopology {
    /// One server-side copy per client behind a single executor (the
    /// FSL_MC / FSL_AN storage point). Incompatible with
    /// `--server-shards > 1`, which refines the shared topology.
    PerClient,
    /// Shared server-side copies: 1 by default (the paper's FSL_OC /
    /// CSE_FSL server), or k shard copies with their own executors via
    /// `TrainConfig::server_shards` and a `ShardMapKind` placement.
    Shared,
}

impl ServerTopology {
    /// Short cache-key tag (`pc` / `sh`).
    pub fn tag(self) -> &'static str {
        match self {
            ServerTopology::PerClient => "pc",
            ServerTopology::Shared => "sh",
        }
    }
}

impl std::fmt::Display for ServerTopology {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServerTopology::PerClient => write!(f, "per-client"),
            ServerTopology::Shared => write!(f, "shared"),
        }
    }
}

impl std::str::FromStr for ServerTopology {
    type Err = String;

    /// `per-client` / `pc`; `shared` / `sh`.
    fn from_str(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().replace('_', "-").as_str() {
            "per-client" | "pc" => Ok(ServerTopology::PerClient),
            "shared" | "sh" => Ok(ServerTopology::Shared),
            other => Err(format!(
                "bad server topology {other:?} (expected per-client | shared)"
            )),
        }
    }
}

/// One fully-specified algorithm point: update rule × upload schedule ×
/// server topology × wire compression. The four paper methods are
/// presets ([`Method::spec`]); everything else is a spec-only scenario
/// served by the same trainer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MethodSpec {
    /// Where the client-side gradient comes from.
    pub update: ClientUpdate,
    /// How many local batches each smashed upload amortizes.
    pub upload: UploadSchedule,
    /// Server-side copy layout.
    pub topology: ServerTopology,
    /// Lossy codec on the smashed-activation uplink (and, under the
    /// server-grad rule, the gradient downlink). Presets sit at
    /// [`Compression::None`].
    pub compression: Compression,
}

impl MethodSpec {
    /// Axis-coherence validation; returns a human-readable reason when
    /// the point is not runnable.
    pub fn validate(&self) -> Result<(), String> {
        match self.update {
            ClientUpdate::ServerGrad { clip } => {
                if !clip.is_finite() || clip < 0.0 {
                    return Err(format!("clip must be finite and >= 0 (got {clip})"));
                }
                if self.upload != UploadSchedule::EveryBatch {
                    return Err(format!(
                        "the server-grad update rule requires every-batch uploads \
                         (got {}): the client blocks on the per-batch gradient \
                         round trip, so there is no local period to amortize",
                        self.upload
                    ));
                }
            }
            ClientUpdate::AuxLocal => {}
            ClientUpdate::SageEstimate { align_every, clip } => {
                if align_every == 0 {
                    return Err(
                        "sage alignment period must be >= 1 (--align-every)".into()
                    );
                }
                if !clip.is_finite() || clip < 0.0 {
                    return Err(format!("clip must be finite and >= 0 (got {clip})"));
                }
                // Between alignments the client is as fire-and-forget as
                // AuxLocal, so any upload schedule composes.
            }
        }
        match self.upload {
            UploadSchedule::EveryBatch => {}
            UploadSchedule::Period(h) => {
                if h == 0 {
                    return Err("h must be >= 1".into());
                }
                if h == 1 {
                    // One canonical representation per behavior, so cache
                    // keys and preset detection can never fork: h = 1 IS
                    // EveryBatch (the period() constructor maps it there).
                    return Err(
                        "Period(1) is not canonical: build schedules via \
                         UploadSchedule::period(h), which maps h = 1 to EveryBatch"
                            .into(),
                    );
                }
            }
            UploadSchedule::AdaptivePeriod { h0, h_max, double_every } => {
                if h0 == 0 || double_every == 0 {
                    return Err("adaptive schedule needs h0 >= 1 and double_every >= 1".into());
                }
                if h_max < h0 {
                    return Err(format!(
                        "adaptive schedule needs h_max >= h0 (got h0={h0}, h_max={h_max})"
                    ));
                }
            }
        }
        self.compression.validate()?;
        Ok(())
    }

    /// The preset this spec is a point of, if any — the exact inverse of
    /// [`Method::spec`] (CSE_FSL absorbs every fixed period on the
    /// shared topology; non-preset clips, the adaptive schedule, and any
    /// compression are spec-only).
    pub fn preset(&self) -> Option<Method> {
        if self.compression != Compression::None {
            // Compressed points always carry the canonical axis tag —
            // the paper's presets transmit full precision.
            return None;
        }
        match (self.update, self.upload, self.topology) {
            (
                ClientUpdate::ServerGrad { clip },
                UploadSchedule::EveryBatch,
                ServerTopology::PerClient,
            ) if clip == 0.0 => Some(Method::FslMc),
            (
                ClientUpdate::ServerGrad { clip },
                UploadSchedule::EveryBatch,
                ServerTopology::Shared,
            ) if clip == 1.0 => Some(Method::FslOc),
            (ClientUpdate::AuxLocal, UploadSchedule::EveryBatch, ServerTopology::PerClient) => {
                Some(Method::FslAn)
            }
            (
                ClientUpdate::AuxLocal,
                UploadSchedule::EveryBatch | UploadSchedule::Period(_),
                ServerTopology::Shared,
            ) => Some(Method::CseFsl),
            _ => None,
        }
    }

    /// The cache-key segment: the preset's historical name when the spec
    /// is a preset point (cache compatibility — `RunSpec::key` strings
    /// are unchanged for the four paper methods), a canonical
    /// `{update}+{upload}+{topology}` tag otherwise, with a trailing
    /// `+{compression}` segment when a codec is on (e.g. `aux+p2+sh+q4`;
    /// `Compression::None` is deliberately unrepresented so every
    /// pre-axis key string survives byte-identically).
    pub fn tag(&self) -> String {
        match self.preset() {
            Some(m) => m.to_string(),
            None => {
                let mut t = format!(
                    "{}+{}+{}",
                    self.update.tag(),
                    self.upload.tag(),
                    self.topology.tag()
                );
                if self.compression != Compression::None {
                    t.push('+');
                    t.push_str(&self.compression.tag());
                }
                t
            }
        }
    }

    /// Human-readable series label: historical preset labels
    /// (`CSE_FSL h=5`), the canonical tag for spec-only points.
    pub fn label(&self) -> String {
        match self.preset() {
            Some(Method::CseFsl) => format!("{} h={}", Method::CseFsl, self.h_hint()),
            Some(m) => m.to_string(),
            None => self.tag(),
        }
    }

    /// Static upload-period estimate ([`UploadSchedule::h_hint`]).
    pub fn h_hint(&self) -> usize {
        self.upload.h_hint()
    }

    /// The gradient clip in effect ([`ClientUpdate::clip`]).
    pub fn clip(&self) -> f32 {
        self.update.clip()
    }

    /// The wire-relevant projection of this spec
    /// (`comm::accounting::predict` closed forms): only the update axis
    /// moves bytes — the upload schedule changes rounds per epoch, not
    /// bytes per round, and the topology moves storage only.
    pub fn traffic(&self) -> TrafficProfile {
        match self.update {
            ClientUpdate::ServerGrad { .. } => TrafficProfile::ServerGrad,
            ClientUpdate::AuxLocal => TrafficProfile::AuxLocal,
            ClientUpdate::SageEstimate { align_every, .. } => {
                TrafficProfile::SageEstimate { align_every: align_every as u64 }
            }
        }
    }

    /// Builder: replace the upload schedule with a fixed period
    /// ([`UploadSchedule::period`] canonicalization applies).
    pub fn with_period(mut self, h: usize) -> Self {
        self.upload = UploadSchedule::period(h);
        self
    }

    /// Builder: set the wire-compression codec.
    pub fn with_compression(mut self, compression: Compression) -> Self {
        self.compression = compression;
        self
    }

    /// Resolve a spec from CLI flags — THE one home of method/axis flag
    /// handling. `method` names the preset base (`--method`, historical
    /// aliases preserved); each `Some` axis flag then overrides that
    /// axis (`--update`, `--upload-every`, `--clip`, `--align-every`,
    /// `--topology`, and the compression trio `--compress` / `--bits` /
    /// `--topk`). The result is validated.
    ///
    /// `--align-every` composes with the gradient-estimator rule only
    /// (`--update sage`); passing it with any other update rule — or
    /// passing a non-integer or zero period — is rejected rather than
    /// silently ignored.
    ///
    /// Compression resolution: `--compress quantize` takes `--bits`
    /// (default 8), `--compress topk` takes `--topk` (default 0.25);
    /// `--bits` / `--topk` without the matching codec — or with the
    /// other one — are rejected rather than silently ignored.
    #[allow(clippy::too_many_arguments)]
    pub fn from_cli(
        method: &str,
        update: Option<&str>,
        upload: Option<&str>,
        clip: Option<&str>,
        align_every: Option<&str>,
        topology: Option<&str>,
        compress: Option<&str>,
        bits: Option<&str>,
        topk: Option<&str>,
    ) -> Result<MethodSpec, String> {
        let mut spec = Method::parse(method)
            .ok_or_else(|| format!("bad method {method:?} (expected mc | oc | an | cse)"))?
            .spec();
        if let Some(u) = update {
            spec.update = u.parse()?;
        }
        if let Some(u) = upload {
            spec.upload = u.parse()?;
        }
        if let Some(c) = clip {
            let v: f32 = c
                .parse()
                .map_err(|_| format!("bad clip {c:?} (expected a number)"))?;
            match &mut spec.update {
                ClientUpdate::ServerGrad { clip } => *clip = v,
                ClientUpdate::SageEstimate { clip, .. } => *clip = v,
                ClientUpdate::AuxLocal => {
                    if v != 0.0 {
                        return Err(
                            "--clip composes with the server-grad update rule \
                             (--update grad); the aux-local rule never touches \
                             the server-grad path"
                                .into(),
                        );
                    }
                }
            }
        }
        if let Some(a) = align_every {
            let v: usize = a.parse().map_err(|_| {
                format!("bad --align-every {a:?} (expected an integer >= 1)")
            })?;
            match &mut spec.update {
                ClientUpdate::SageEstimate { align_every, .. } => *align_every = v,
                _ => {
                    return Err(format!(
                        "--align-every {a} composes with the gradient-estimator \
                         update rule (--update sage)"
                    ));
                }
            }
        }
        if let Some(t) = topology {
            spec.topology = t.parse()?;
        }
        spec.compression = match compress.map(|c| c.to_ascii_lowercase()).as_deref() {
            None | Some("none") => {
                if let Some(b) = bits {
                    return Err(format!(
                        "--bits {b} composes with --compress quantize"
                    ));
                }
                if let Some(k) = topk {
                    return Err(format!(
                        "--topk {k} composes with --compress topk"
                    ));
                }
                Compression::None
            }
            Some("quantize") | Some("q") => {
                if let Some(k) = topk {
                    return Err(format!(
                        "--topk {k} composes with --compress topk, not quantize"
                    ));
                }
                let b: u8 = match bits {
                    Some(b) => b
                        .parse()
                        .map_err(|_| format!("bad --bits {b:?} (expected 1..=16)"))?,
                    None => 8,
                };
                Compression::Quantize { bits: b }
            }
            Some("topk") | Some("top-k") | Some("t") => {
                if let Some(b) = bits {
                    return Err(format!(
                        "--bits {b} composes with --compress quantize, not topk"
                    ));
                }
                let f: f32 = match topk {
                    Some(k) => k
                        .parse()
                        .map_err(|_| format!("bad --topk {k:?} (expected a fraction)"))?,
                    None => 0.25,
                };
                Compression::TopK { frac: f }
            }
            Some(other) => {
                return Err(format!(
                    "bad compression {other:?} (expected none | quantize | topk)"
                ));
            }
        };
        spec.validate()?;
        Ok(spec)
    }
}

impl std::fmt::Display for MethodSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.label())
    }
}

/// The four compared paper methods, as named preset points of the spec
/// space ([`Method::spec`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Method {
    /// SplitFed baseline with one server-side copy per client.
    FslMc,
    /// SplitFed with one shared server-side copy (clipped gradients).
    FslOc,
    /// Auxiliary-network local updates, per-client server copies.
    FslAn,
    /// The paper's method: auxiliary networks, one shared server copy,
    /// smashed uploads every h batches.
    CseFsl,
}

impl Method {
    /// Every preset, in the paper's comparison order.
    pub const ALL: [Method; 4] = [Method::FslMc, Method::FslOc, Method::FslAn, Method::CseFsl];

    /// The preset's spec point. CSE_FSL starts at h = 1
    /// ([`UploadSchedule::EveryBatch`]); compose
    /// [`MethodSpec::with_period`] for h > 1.
    pub fn spec(self) -> MethodSpec {
        match self {
            Method::FslMc => MethodSpec {
                update: ClientUpdate::ServerGrad { clip: 0.0 },
                upload: UploadSchedule::EveryBatch,
                topology: ServerTopology::PerClient,
                compression: Compression::None,
            },
            Method::FslOc => MethodSpec {
                // The paper adds clipping to FSL_OC to fix its
                // gradient-explosion instability.
                update: ClientUpdate::ServerGrad { clip: 1.0 },
                upload: UploadSchedule::EveryBatch,
                topology: ServerTopology::Shared,
                compression: Compression::None,
            },
            Method::FslAn => MethodSpec {
                update: ClientUpdate::AuxLocal,
                upload: UploadSchedule::EveryBatch,
                topology: ServerTopology::PerClient,
                compression: Compression::None,
            },
            Method::CseFsl => MethodSpec {
                update: ClientUpdate::AuxLocal,
                upload: UploadSchedule::EveryBatch,
                topology: ServerTopology::Shared,
                compression: Compression::None,
            },
        }
    }

    /// Parse a preset name (`fsl_mc`/`mc`, …, `cse_fsl`/`cse`).
    pub fn parse(s: &str) -> Option<Method> {
        match s.to_ascii_lowercase().replace('-', "_").as_str() {
            "fsl_mc" | "mc" => Some(Method::FslMc),
            "fsl_oc" | "oc" => Some(Method::FslOc),
            "fsl_an" | "an" => Some(Method::FslAn),
            "cse_fsl" | "cse" => Some(Method::CseFsl),
            _ => None,
        }
    }
}

impl std::fmt::Display for Method {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Method::FslMc => "FSL_MC",
            Method::FslOc => "FSL_OC",
            Method::FslAn => "FSL_AN",
            Method::CseFsl => "CSE_FSL",
        };
        write!(f, "{s}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_specs_match_paper_matrix() {
        // The pre-refactor capability matrix, verbatim: (per-client
        // server model, uses aux, grad downlink, supports h>1, clip).
        // "Supports h" maps onto the open API as *h > 1 stays the same
        // preset point*: only CSE_FSL absorbs a period — the SplitFed
        // presets reject it outright, and FSL_AN × Period(h) is a valid
        // but spec-only scenario (the point the paper never names).
        let matrix = [
            (Method::FslMc, true, false, true, false, 0.0f32),
            (Method::FslOc, false, false, true, false, 1.0),
            (Method::FslAn, true, true, false, false, 0.0),
            (Method::CseFsl, false, true, false, true, 0.0),
        ];
        for (m, per_client, aux, grad, h_stays_preset, clip) in matrix {
            let s = m.spec();
            assert_eq!(s.topology == ServerTopology::PerClient, per_client, "{m}");
            assert_eq!(s.update.uses_aux(), aux, "{m}");
            assert_eq!(
                matches!(s.update, ClientUpdate::ServerGrad { .. }),
                grad,
                "{m}"
            );
            assert_eq!(s.with_period(3).preset() == Some(m), h_stays_preset, "{m} h=3");
            // Exactly the old supports_h + uses_aux semantics: a period
            // is *runnable* iff the update rule is aux-local.
            assert_eq!(s.with_period(3).validate().is_ok(), aux, "{m} h=3 validity");
            assert_eq!(s.clip(), clip, "{m}");
            assert_eq!(s.preset(), Some(m), "{m} must round-trip through preset()");
        }
    }

    #[test]
    fn parse_and_display_roundtrip() {
        for m in Method::ALL {
            assert_eq!(Method::parse(&m.to_string()), Some(m));
        }
        assert_eq!(Method::parse("cse"), Some(Method::CseFsl));
        assert_eq!(Method::parse("fsl-an"), Some(Method::FslAn));
        assert_eq!(Method::parse("bogus"), None);
    }

    #[test]
    fn period_canonicalizes_and_schedules() {
        assert_eq!(UploadSchedule::period(1), UploadSchedule::EveryBatch);
        assert_eq!(UploadSchedule::period(5), UploadSchedule::Period(5));
        assert_eq!(UploadSchedule::period(5).batches_at(1), 5);
        assert_eq!(UploadSchedule::period(5).batches_at(99), 5);
        assert_eq!(UploadSchedule::EveryBatch.batches_at(7), 1);
        assert_eq!(UploadSchedule::period(5).h_hint(), 5);
        // Adaptive: h0=2, doubling every 3 rounds, capped at 8.
        let a = UploadSchedule::AdaptivePeriod { h0: 2, h_max: 8, double_every: 3 };
        assert_eq!(a.batches_at(1), 2);
        assert_eq!(a.batches_at(3), 2);
        assert_eq!(a.batches_at(4), 4);
        assert_eq!(a.batches_at(7), 8);
        assert_eq!(a.batches_at(1000), 8, "cap must hold far out");
        assert_eq!(a.h_hint(), 2);
    }

    #[test]
    fn spec_validation_rules() {
        // ServerGrad requires every-batch uploads...
        assert!(Method::FslMc.spec().with_period(2).validate().is_err());
        assert!(Method::FslOc.spec().with_period(2).validate().is_err());
        // ...AuxLocal composes with any schedule and either topology.
        assert!(Method::FslAn.spec().with_period(4).validate().is_ok());
        assert!(Method::CseFsl.spec().with_period(4).validate().is_ok());
        let adaptive = MethodSpec {
            upload: UploadSchedule::AdaptivePeriod { h0: 1, h_max: 8, double_every: 4 },
            ..Method::CseFsl.spec()
        };
        assert!(adaptive.validate().is_ok());
        // Degenerate parameters are rejected.
        assert!(MethodSpec {
            upload: UploadSchedule::Period(0),
            ..Method::CseFsl.spec()
        }
        .validate()
        .is_err());
        // Non-canonical Period(1) is rejected too (it would fork the
        // cache key / preset identity of an EveryBatch-identical run).
        let err = MethodSpec { upload: UploadSchedule::Period(1), ..Method::CseFsl.spec() }
            .validate()
            .unwrap_err();
        assert!(err.contains("not canonical"), "{err}");
        assert!(Method::CseFsl.spec().with_period(1).validate().is_ok(), "period(1) canonicalizes");
        assert!(MethodSpec {
            upload: UploadSchedule::AdaptivePeriod { h0: 0, h_max: 4, double_every: 2 },
            ..Method::CseFsl.spec()
        }
        .validate()
        .is_err());
        assert!(MethodSpec {
            upload: UploadSchedule::AdaptivePeriod { h0: 4, h_max: 2, double_every: 2 },
            ..Method::CseFsl.spec()
        }
        .validate()
        .is_err());
        assert!(MethodSpec {
            update: ClientUpdate::ServerGrad { clip: -1.0 },
            ..Method::FslMc.spec()
        }
        .validate()
        .is_err());
    }

    #[test]
    fn preset_detection_edges() {
        // CSE_FSL absorbs every fixed period on the shared topology.
        assert_eq!(Method::CseFsl.spec().with_period(10).preset(), Some(Method::CseFsl));
        // The spec-only scenarios the paper never names:
        assert_eq!(Method::FslAn.spec().with_period(2).preset(), None);
        let oc_no_clip = MethodSpec {
            update: ClientUpdate::ServerGrad { clip: 0.0 },
            ..Method::FslOc.spec()
        };
        assert_eq!(oc_no_clip.preset(), None, "non-default clip is its own point");
        let adaptive = MethodSpec {
            upload: UploadSchedule::AdaptivePeriod { h0: 1, h_max: 8, double_every: 4 },
            ..Method::CseFsl.spec()
        };
        assert_eq!(adaptive.preset(), None);
    }

    #[test]
    fn tags_and_labels() {
        // Presets keep their historical names (cache-key compatibility).
        assert_eq!(Method::CseFsl.spec().with_period(5).tag(), "CSE_FSL");
        assert_eq!(Method::FslMc.spec().tag(), "FSL_MC");
        assert_eq!(Method::CseFsl.spec().with_period(5).label(), "CSE_FSL h=5");
        assert_eq!(Method::FslAn.spec().label(), "FSL_AN");
        // Spec-only points get the canonical axis tag.
        assert_eq!(Method::FslAn.spec().with_period(4).tag(), "aux+p4+pc");
        assert_eq!(Method::FslAn.spec().with_period(4).label(), "aux+p4+pc");
        let oc_custom = MethodSpec {
            update: ClientUpdate::ServerGrad { clip: 0.5 },
            ..Method::FslOc.spec()
        };
        assert_eq!(oc_custom.tag(), "sg0.5+b+sh");
        let adaptive = MethodSpec {
            upload: UploadSchedule::AdaptivePeriod { h0: 2, h_max: 8, double_every: 5 },
            ..Method::CseFsl.spec()
        };
        assert_eq!(adaptive.tag(), "aux+ap2x8e5+sh");
        // Compression is a trailing tag segment; None is unrepresented.
        let q4 = Method::CseFsl
            .spec()
            .with_period(2)
            .with_compression(Compression::Quantize { bits: 4 });
        assert_eq!(q4.tag(), "aux+p2+sh+q4");
        assert_eq!(q4.label(), "aux+p2+sh+q4");
        assert_eq!(
            Method::FslMc.spec().with_compression(Compression::Quantize { bits: 8 }).tag(),
            "sg0+b+pc+q8"
        );
        assert_eq!(
            Method::CseFsl.spec().with_compression(Compression::TopK { frac: 0.25 }).tag(),
            "aux+b+sh+t0.25"
        );
        assert_eq!(
            Method::CseFsl.spec().with_compression(Compression::None).tag(),
            "CSE_FSL",
            "explicit None must keep the historical preset tag"
        );
    }

    #[test]
    fn compression_leaves_presets_and_validates() {
        // Any codec moves the spec off the preset points...
        for m in Method::ALL {
            let q = m.spec().with_compression(Compression::Quantize { bits: 8 });
            assert_eq!(q.preset(), None, "{m}");
            assert!(q.validate().is_ok(), "{m}");
            // ...and with_compression(None) round-trips back.
            assert_eq!(q.with_compression(Compression::None).preset(), Some(m), "{m}");
        }
        // Bad codec parameters are caught by spec validation.
        assert!(Method::CseFsl
            .spec()
            .with_compression(Compression::Quantize { bits: 0 })
            .validate()
            .is_err());
        assert!(Method::CseFsl
            .spec()
            .with_compression(Compression::Quantize { bits: 17 })
            .validate()
            .is_err());
        assert!(Method::CseFsl
            .spec()
            .with_compression(Compression::TopK { frac: 0.0 })
            .validate()
            .is_err());
        assert!(Method::CseFsl
            .spec()
            .with_compression(Compression::TopK { frac: 2.0 })
            .validate()
            .is_err());
        // Compression composes with the server-grad rule too (the grad
        // downlink is compressed symmetrically).
        assert!(Method::FslOc
            .spec()
            .with_compression(Compression::Quantize { bits: 4 })
            .validate()
            .is_ok());
    }

    #[test]
    fn axis_parsing() {
        assert_eq!("aux".parse::<ClientUpdate>(), Ok(ClientUpdate::AuxLocal));
        assert_eq!(
            "server-grad".parse::<ClientUpdate>(),
            Ok(ClientUpdate::ServerGrad { clip: 0.0 })
        );
        assert!("sideways".parse::<ClientUpdate>().is_err());
        assert_eq!("1".parse::<UploadSchedule>(), Ok(UploadSchedule::EveryBatch));
        assert_eq!("4".parse::<UploadSchedule>(), Ok(UploadSchedule::Period(4)));
        assert_eq!(
            "adaptive:2:8:5".parse::<UploadSchedule>(),
            Ok(UploadSchedule::AdaptivePeriod { h0: 2, h_max: 8, double_every: 5 })
        );
        assert!("adaptive:2:8".parse::<UploadSchedule>().is_err());
        assert!("x".parse::<UploadSchedule>().is_err());
        assert_eq!("per-client".parse::<ServerTopology>(), Ok(ServerTopology::PerClient));
        assert_eq!("sh".parse::<ServerTopology>(), Ok(ServerTopology::Shared));
        assert!("ring".parse::<ServerTopology>().is_err());
    }

    #[test]
    fn cli_resolution_composes() {
        // --method alone is the historical preset path.
        assert_eq!(
            MethodSpec::from_cli("cse", None, None, None, None, None, None, None, None).unwrap(),
            Method::CseFsl.spec()
        );
        assert_eq!(
            MethodSpec::from_cli("mc", None, None, None, None, None, None, None, None).unwrap(),
            Method::FslMc.spec()
        );
        // --upload-every composes onto the preset base...
        assert_eq!(
            MethodSpec::from_cli("cse", None, Some("5"), None, None, None, None, None, None)
                .unwrap(),
            Method::CseFsl.spec().with_period(5)
        );
        // ...including the spec-only "FSL_AN with h>1" point.
        assert_eq!(
            MethodSpec::from_cli("an", None, Some("4"), None, None, None, None, None, None)
                .unwrap(),
            Method::FslAn.spec().with_period(4)
        );
        // Axis flags compose without any preset semantics.
        assert_eq!(
            MethodSpec::from_cli(
                "cse",
                Some("aux"),
                Some("4"),
                None,
                None,
                Some("per-client"),
                None,
                None,
                None
            )
            .unwrap(),
            Method::FslAn.spec().with_period(4)
        );
        // --clip composes with the server-grad rule only.
        let oc =
            MethodSpec::from_cli("oc", None, None, Some("2.5"), None, None, None, None, None)
                .unwrap();
        assert_eq!(oc.clip(), 2.5);
        assert_eq!(oc.preset(), None, "non-default clip leaves the preset");
        assert!(
            MethodSpec::from_cli("cse", None, None, Some("1.0"), None, None, None, None, None)
                .is_err()
        );
        assert!(
            MethodSpec::from_cli("cse", None, None, Some("0"), None, None, None, None, None)
                .is_ok()
        );
        // Incoherent compositions are rejected at resolution time.
        assert!(
            MethodSpec::from_cli("mc", None, Some("2"), None, None, None, None, None, None)
                .is_err()
        );
        assert!(
            MethodSpec::from_cli("warp", None, None, None, None, None, None, None, None).is_err()
        );
        assert!(
            MethodSpec::from_cli("cse", None, Some("bogus"), None, None, None, None, None, None)
                .is_err()
        );
    }

    #[test]
    fn cli_compression_resolution() {
        let cli = |compress: Option<&str>, bits: Option<&str>, topk: Option<&str>| {
            MethodSpec::from_cli(
                "cse",
                None,
                Some("2"),
                None,
                None,
                None,
                compress,
                bits,
                topk,
            )
        };
        // Defaults: quantize -> 8 bits, topk -> 25%.
        assert_eq!(
            cli(Some("quantize"), None, None).unwrap().compression,
            Compression::Quantize { bits: 8 }
        );
        assert_eq!(
            cli(Some("topk"), None, None).unwrap().compression,
            Compression::TopK { frac: 0.25 }
        );
        // Explicit parameters.
        assert_eq!(
            cli(Some("quantize"), Some("4"), None).unwrap().compression,
            Compression::Quantize { bits: 4 }
        );
        assert_eq!(
            cli(Some("topk"), None, Some("0.1")).unwrap().compression,
            Compression::TopK { frac: 0.1 }
        );
        // Aliases and the explicit none.
        assert_eq!(
            cli(Some("q"), Some("2"), None).unwrap().compression,
            Compression::Quantize { bits: 2 }
        );
        assert_eq!(
            cli(Some("top-k"), None, None).unwrap().compression,
            Compression::TopK { frac: 0.25 }
        );
        assert_eq!(cli(Some("none"), None, None).unwrap().compression, Compression::None);
        assert_eq!(cli(None, None, None).unwrap().compression, Compression::None);
        // Mismatched parameter flags are rejected, not ignored.
        assert!(cli(None, Some("4"), None).is_err(), "--bits without --compress");
        assert!(cli(None, None, Some("0.5")).is_err(), "--topk without --compress");
        assert!(cli(Some("quantize"), None, Some("0.5")).is_err());
        assert!(cli(Some("topk"), Some("4"), None).is_err());
        assert!(cli(Some("none"), Some("4"), None).is_err());
        // Bad values are rejected by parse or validation.
        assert!(cli(Some("zip"), None, None).is_err());
        assert!(cli(Some("quantize"), Some("0"), None).is_err());
        assert!(cli(Some("quantize"), Some("99"), None).is_err());
        assert!(cli(Some("topk"), None, Some("1.5")).is_err());
        assert!(cli(Some("topk"), None, Some("x")).is_err());
    }

    #[test]
    fn traffic_projection_follows_update_axis() {
        assert_eq!(Method::FslMc.spec().traffic(), TrafficProfile::ServerGrad);
        assert_eq!(Method::FslOc.spec().traffic(), TrafficProfile::ServerGrad);
        assert_eq!(Method::FslAn.spec().traffic(), TrafficProfile::AuxLocal);
        assert_eq!(Method::CseFsl.spec().traffic(), TrafficProfile::AuxLocal);
        let sage = MethodSpec {
            update: ClientUpdate::SageEstimate { align_every: 3, clip: 0.0 },
            ..Method::CseFsl.spec()
        };
        assert_eq!(sage.traffic(), TrafficProfile::SageEstimate { align_every: 3 });
    }

    fn sage_spec(align_every: usize) -> MethodSpec {
        MethodSpec {
            update: ClientUpdate::SageEstimate { align_every, clip: 0.0 },
            ..Method::CseFsl.spec()
        }
    }

    #[test]
    fn sage_axis_semantics() {
        let s = sage_spec(4);
        // The estimator rule trains (and aggregates) an aux network...
        assert!(s.update.uses_aux());
        // ...composes with any upload schedule, either topology, and any
        // codec (the downlink codec applies to the alignment rounds)...
        assert!(s.validate().is_ok());
        assert!(s.with_period(5).validate().is_ok());
        assert!(
            MethodSpec { topology: ServerTopology::PerClient, ..s }.validate().is_ok()
        );
        assert!(s
            .with_compression(Compression::Quantize { bits: 4 })
            .validate()
            .is_ok());
        let adaptive = MethodSpec {
            upload: UploadSchedule::AdaptivePeriod { h0: 1, h_max: 8, double_every: 4 },
            ..sage_spec(2)
        };
        assert!(adaptive.validate().is_ok());
        // ...and never detects as a preset point.
        assert_eq!(s.preset(), None);
        assert_eq!(sage_spec(1).preset(), None);
        // Degenerate parameters are rejected.
        assert!(sage_spec(0).validate().is_err());
        assert!(MethodSpec {
            update: ClientUpdate::SageEstimate { align_every: 4, clip: -1.0 },
            ..Method::CseFsl.spec()
        }
        .validate()
        .is_err());
        assert!(MethodSpec {
            update: ClientUpdate::SageEstimate { align_every: 4, clip: f32::NAN },
            ..Method::CseFsl.spec()
        }
        .validate()
        .is_err());
        // Clip composes (the alignment round trip is clippable).
        assert_eq!(
            MethodSpec {
                update: ClientUpdate::SageEstimate { align_every: 4, clip: 1.5 },
                ..Method::CseFsl.spec()
            }
            .clip(),
            1.5
        );
    }

    #[test]
    fn sage_tags_and_labels() {
        // The canonical `sage{a}` segment composes with the other axis
        // tags exactly like any spec-only point.
        assert_eq!(sage_spec(4).tag(), "sage4+b+sh");
        assert_eq!(sage_spec(4).with_period(3).tag(), "sage4+p3+sh");
        assert_eq!(sage_spec(4).with_period(3).label(), "sage4+p3+sh");
        assert_eq!(
            MethodSpec { topology: ServerTopology::PerClient, ..sage_spec(2) }.tag(),
            "sage2+b+pc"
        );
        assert_eq!(
            sage_spec(8).with_compression(Compression::Quantize { bits: 4 }).tag(),
            "sage8+b+sh+q4"
        );
        // A non-zero clip changes results, so it forks the key segment.
        assert_eq!(
            MethodSpec {
                update: ClientUpdate::SageEstimate { align_every: 4, clip: 0.5 },
                ..Method::CseFsl.spec()
            }
            .tag(),
            "sage4c0.5+b+sh"
        );
        assert_eq!(
            format!("{}", ClientUpdate::SageEstimate { align_every: 4, clip: 0.0 }),
            "sage-estimate(align=4, clip=0)"
        );
    }

    #[test]
    fn sage_axis_parsing() {
        // Aliases, lowercasing, and `_` → `-` pinned like Dist::parse.
        let d = ClientUpdate::SageEstimate { align_every: 4, clip: 0.0 };
        assert_eq!("sage".parse::<ClientUpdate>(), Ok(d));
        assert_eq!("SAGE".parse::<ClientUpdate>(), Ok(d));
        assert_eq!("sage-estimate".parse::<ClientUpdate>(), Ok(d));
        assert_eq!("sage_estimate".parse::<ClientUpdate>(), Ok(d));
        assert_eq!("Sage_Estimate".parse::<ClientUpdate>(), Ok(d));
        assert_eq!("estimator".parse::<ClientUpdate>(), Ok(d));
        assert!("sage4".parse::<ClientUpdate>().is_err(), "period composes via --align-every");
    }

    #[test]
    fn sage_cli_resolution() {
        // --update sage alone: the documented default alignment period.
        let s = MethodSpec::from_cli(
            "cse", Some("sage"), None, None, None, None, None, None, None,
        )
        .unwrap();
        assert_eq!(s.update, ClientUpdate::SageEstimate { align_every: 4, clip: 0.0 });
        // --align-every composes onto it...
        let s = MethodSpec::from_cli(
            "cse", Some("sage"), Some("2"), None, Some("8"), None, None, None, None,
        )
        .unwrap();
        assert_eq!(s.update, ClientUpdate::SageEstimate { align_every: 8, clip: 0.0 });
        assert_eq!(s.upload, UploadSchedule::Period(2));
        assert_eq!(s.tag(), "sage8+p2+sh");
        // ...as does --clip (the alignment round trip is clippable).
        let s = MethodSpec::from_cli(
            "cse", Some("sage"), None, Some("1.5"), Some("3"), None, None, None, None,
        )
        .unwrap();
        assert_eq!(s.update, ClientUpdate::SageEstimate { align_every: 3, clip: 1.5 });
        // --align-every without --update sage is rejected, not ignored.
        assert!(MethodSpec::from_cli(
            "cse", None, None, None, Some("4"), None, None, None, None,
        )
        .is_err());
        assert!(MethodSpec::from_cli(
            "mc", Some("grad"), None, None, Some("4"), None, None, None, None,
        )
        .is_err());
        // Zero and garbage periods are rejected.
        assert!(MethodSpec::from_cli(
            "cse", Some("sage"), None, None, Some("0"), None, None, None, None,
        )
        .is_err());
        assert!(MethodSpec::from_cli(
            "cse", Some("sage"), None, None, Some("x"), None, None, None, None,
        )
        .is_err());
    }
}
