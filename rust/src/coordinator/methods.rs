//! The four FSL methods the paper compares (Section VI-A).
//!
//! | method  | server copies | aux net | client update source   | uploads    |
//! |---------|---------------|---------|------------------------|------------|
//! | FSL_MC  | n             | no      | server grad downlink   | every batch|
//! | FSL_OC  | 1             | no      | server grad (clipped)  | every batch|
//! | FSL_AN  | n             | yes     | local auxiliary loss   | every batch|
//! | CSE_FSL | 1             | yes     | local auxiliary loss   | every h    |

/// One of the four compared federated-split-learning methods.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Method {
    /// SplitFed baseline with one server-side copy per client.
    FslMc,
    /// SplitFed with one shared server-side copy (clipped gradients).
    FslOc,
    /// Auxiliary-network local updates, per-client server copies.
    FslAn,
    /// The paper's method: auxiliary networks, one shared server copy,
    /// smashed uploads every h batches.
    CseFsl,
}

impl Method {
    /// Every method, in the paper's comparison order.
    pub const ALL: [Method; 4] = [Method::FslMc, Method::FslOc, Method::FslAn, Method::CseFsl];

    /// Does the server keep one model copy per client?
    pub fn per_client_server_model(self) -> bool {
        matches!(self, Method::FslMc | Method::FslAn)
    }

    /// Does the client train an auxiliary network and update locally?
    pub fn uses_aux(self) -> bool {
        matches!(self, Method::FslAn | Method::CseFsl)
    }

    /// Does the server send cut-layer gradients back per batch?
    pub fn grad_downlink(self) -> bool {
        matches!(self, Method::FslMc | Method::FslOc)
    }

    /// Can h exceed 1 (periodic smashed upload)?
    pub fn supports_h(self) -> bool {
        matches!(self, Method::CseFsl)
    }

    /// Default gradient clip (the paper adds clipping to FSL_OC to fix
    /// its gradient-explosion instability; 0 disables elsewhere).
    pub fn default_clip(self) -> f32 {
        if self == Method::FslOc {
            1.0
        } else {
            0.0
        }
    }

    /// Parse a method name (`fsl_mc`/`mc`, …, `cse_fsl`/`cse`).
    pub fn parse(s: &str) -> Option<Method> {
        match s.to_ascii_lowercase().replace('-', "_").as_str() {
            "fsl_mc" | "mc" => Some(Method::FslMc),
            "fsl_oc" | "oc" => Some(Method::FslOc),
            "fsl_an" | "an" => Some(Method::FslAn),
            "cse_fsl" | "cse" => Some(Method::CseFsl),
            _ => None,
        }
    }
}

impl std::fmt::Display for Method {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Method::FslMc => "FSL_MC",
            Method::FslOc => "FSL_OC",
            Method::FslAn => "FSL_AN",
            Method::CseFsl => "CSE_FSL",
        };
        write!(f, "{s}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capability_matrix_matches_paper() {
        assert!(Method::FslMc.per_client_server_model());
        assert!(!Method::FslOc.per_client_server_model());
        assert!(Method::FslAn.per_client_server_model());
        assert!(!Method::CseFsl.per_client_server_model());

        assert!(!Method::FslMc.uses_aux());
        assert!(!Method::FslOc.uses_aux());
        assert!(Method::FslAn.uses_aux());
        assert!(Method::CseFsl.uses_aux());

        assert!(Method::FslMc.grad_downlink());
        assert!(Method::FslOc.grad_downlink());
        assert!(!Method::FslAn.grad_downlink());
        assert!(!Method::CseFsl.grad_downlink());

        assert!(Method::CseFsl.supports_h());
        assert!(!Method::FslAn.supports_h());
    }

    #[test]
    fn parse_and_display_roundtrip() {
        for m in Method::ALL {
            assert_eq!(Method::parse(&m.to_string()), Some(m));
        }
        assert_eq!(Method::parse("cse"), Some(Method::CseFsl));
        assert_eq!(Method::parse("bogus"), None);
    }

    #[test]
    fn only_oc_clips_by_default() {
        assert!(Method::FslOc.default_clip() > 0.0);
        assert_eq!(Method::FslMc.default_clip(), 0.0);
        assert_eq!(Method::CseFsl.default_clip(), 0.0);
    }
}
