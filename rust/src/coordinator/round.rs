//! The training loop: rounds, event-triggered server updates, and
//! aggregation — Algorithms 1 & 2 of the paper, for **any**
//! [`MethodSpec`] point (the four paper methods are presets of it).
//!
//! The trainer branches exclusively on the spec's four axes:
//!
//! - [`ClientUpdate`] picks the round shape — `AuxLocal` runs the
//!   fire-and-forget local round (Algorithm 1), `ServerGrad { clip }`
//!   the blocking SplitFed round trip, and `SageEstimate { align_every,
//!   clip }` runs the aux-local body every round plus, on every
//!   `align_every`-th round, a true-gradient **alignment pass**: the
//!   server's drain loop returns real cut-layer gradients, each client
//!   takes a backward step on its own and re-fits its estimator against
//!   it (ServerGrad-shaped downlink traffic on those rounds only);
//! - [`UploadSchedule`] decides how many local batches each round's
//!   upload amortizes (`batches_at(t)` — h per round, possibly
//!   adaptive);
//! - [`ServerTopology`] (refined by `TrainConfig::server_shards`)
//!   decides the server-side copy layout;
//! - [`Compression`] decides what each smashed upload (and, for the
//!   server-grad rule, each gradient download) costs on the wire. The
//!   codec runs sender-side as a compress → decompress round trip: the
//!   receiver trains on the dequantized tensor, the ledger records the
//!   compressed wire size, and the stochastic-rounding rng is split off
//!   the round snapshot so the transform is schedule-independent.
//!
//! [`Compression`]: super::methods::Compression
//!
//! One **communication round** = one upload wave: each participating
//! client trains its scheduled local batches and uploads its smashed
//! data once ("when client i sends the smashed data to the server, it
//! completes one communication round"). The server consumes arrivals
//! from the dataQueue in arrival order (configurable for the Fig. 6
//! ablation) and updates its server-side model(s) event-triggered,
//! never waiting for a barrier. Every `agg_every` rounds the clients
//! upload their client-side models (+ aux for the aux-local rule) for
//! FedAvg (Eq. (14)) and download the aggregate.
//!
//! [`MethodSpec`]: super::methods::MethodSpec
//! [`ClientUpdate`]: super::methods::ClientUpdate
//! [`UploadSchedule`]: super::methods::UploadSchedule
//! [`ServerTopology`]: super::methods::ServerTopology
//!
//! Timing is simulated deterministically (sim/netmodel): client compute,
//! uplink/downlink transmission, and server update costs all advance the
//! clock, the timeline records every span, and the ledger records every
//! byte — those feed Figs. 3/9 and Tables II/V.
//!
//! # The parallel round engine
//!
//! CSE-FSL clients are fire-and-forget — they never wait for server
//! gradients — so the client phase of a round is embarrassingly
//! parallel. With [`Parallelism::Threads`], client local training (and
//! the phase-1 forwards of the SplitFed methods) fans out across a
//! scoped thread pool ([`std::thread::scope`]): each worker drives its
//! own [`ClientState`] with its already-independent per-client RNG
//! streams, recording spans and wire bytes into worker-local
//! [`Timeline`]/[`CommLedger`]s.
//!
//! *Which worker runs which client when* is decided by the pluggable
//! [`SchedPolicy`] (`crate::sched`): round-robin (the historical
//! dealing), cost-weighted LPT on per-client cost estimates, or
//! work-stealing over a shared atomic-index queue. Cost estimates come
//! from each client's persistent [`ClientProfile`] prior blended with
//! an EWMA of the simulated spans it produced in earlier rounds
//! ([`CostTracker`]); they steer dealing only and can never change
//! results, so — like `Parallelism` — the policy is excluded from the
//! experiment cache key.
//!
//! [`ClientProfile`]: crate::sim::netmodel::ClientProfile
//!
//! # The sharded server phase
//!
//! With `TrainConfig::server_shards = k` (single-copy methods only), the
//! server holds `k` model copies, each serving a client group
//! ([`ShardMap`]: contiguous canonical-id ranges, cost-balanced LPT, or
//! locality-stratified by label distribution) on its **own event-loop
//! executor** with its own simulated clock. The event-triggered drain loop runs once per shard —
//! fanned over the same scoped-thread machinery as the client phase —
//! and shard results (losses, spans, clocks, per-shard update counts)
//! are merged in canonical shard order. Every `agg_every` rounds the
//! shard copies are FedAvg'd back together (cross-shard FedAvg), which
//! doubles as a global clock barrier. `k = 1` reproduces the historical
//! single-copy schedule bit-for-bit; the per-client-copy methods
//! (FSL_MC / FSL_AN) keep their n copies behind a single executor,
//! exactly as the paper describes them.
//!
//! **Determinism is a hard contract**: per-client results are merged in
//! canonical order (client id, then time) and per-shard results in
//! canonical shard order, so a parallel run's `RunRecord`, timeline,
//! ledger, and model states are bit-identical to the sequential
//! schedule's — enforced by `tests/determinism_golden.rs` for every
//! method and shard count. See `coordinator/README.md` for the argument.
//!
//! [`ShardMap`]: super::server::ShardMap

use std::collections::{BTreeMap, BTreeSet};

use crate::comm::accounting::{CommLedger, MsgKind, WireSizes};
use crate::data::partition::Partition;
use crate::data::Dataset;
use crate::metrics::eval::accuracy;
use crate::metrics::recorder::{RoundRecord, RunRecord};
use crate::model::aggregate::fedavg;
use crate::model::init::init_flat;
use crate::model::layout::Layout;
use crate::runtime::{EngineError, SplitEngine};
use crate::sched::{self, CostTracker, SchedPolicy};
use crate::sim::churn::{ChurnState, ChurnStats, ResiliencePolicy};
use crate::sim::event::EventQueue;
use crate::sim::netmodel::NetModel;
use crate::sim::timeline::{SpanKind, Timeline};
use crate::storage;
use crate::util::prng::Rng;

use super::client::ClientState;
use super::config::{ArrivalOrder, Parallelism, ShardMapKind, TrainConfig};
use super::methods::{ClientUpdate, Compression, ServerTopology};
use super::population::{AggEvent, PopulationSetup, PopulationState, SparseCosts};

use super::server::{ServerState, ShardMap, SmashedMsg, Topology};

/// Drives one full training run over an engine: owns the clients, the
/// (possibly sharded) server, the wire ledger, and the timeline.
pub struct Trainer<'a, E: SplitEngine> {
    /// The compute engine shared by every client and server step.
    pub engine: &'a E,
    /// The validated run configuration.
    pub cfg: TrainConfig,
    train: &'a Dataset,
    test: &'a Dataset,
    /// Per-client state (models, batcher, delay profile). Holds every
    /// client for the resident engine ([`Trainer::new`]); **empty** for
    /// the streaming population engine ([`Trainer::new_population`]),
    /// whose working set lives in `population`.
    pub clients: Vec<ClientState>,
    /// Streaming-population state (`Some` iff built by
    /// [`Trainer::new_population`]): the lazily-materialized working
    /// set plus the streaming aggregates replacing the resident O(n)
    /// structures.
    pub population: Option<PopulationState>,
    /// Server-side state (shard copies, executor clocks, dataQueue).
    pub server: ServerState,
    /// Measured wire traffic.
    pub ledger: CommLedger,
    /// Recorded simulated schedule.
    pub timeline: Timeline,
    wires: WireSizes,
    rng: Rng,
    /// Per-client cost estimates steering the cost-aware dealing
    /// policies (profile priors + EWMA of observed round spans).
    cost_tracker: CostTracker,
    /// Shard-skew metric of the configured shard map: sample-mass-
    /// weighted per-shard label-histogram divergence from the global
    /// mix (`ShardMap::label_divergence_weighted`), fixed at
    /// construction.
    shard_divergence: f64,
    records: Vec<RoundRecord>,
    /// Clients that contributed training since the last aggregation.
    dirty: Vec<bool>,
    /// Churn evaluator: the availability/resample draw streams plus the
    /// Markov models' carried per-client session state
    /// (`cfg.churn` decides what, if anything, it is asked).
    churn: ChurnState,
    /// Reliability counters (dropped / replaced / failed / straggling),
    /// accumulated across the run and surfaced through the `RunRecord`.
    pub churn_stats: ChurnStats,
    label: String,
}

/// Everything needed to build a Trainer over real or mock engines.
pub struct TrainerSetup<'a> {
    /// Training dataset (clients batch from their partition shards).
    pub train: &'a Dataset,
    /// Held-out evaluation dataset.
    pub test: &'a Dataset,
    /// Per-client sample-index partition of `train`.
    pub partition: Partition,
    /// Client heterogeneity / network delay model.
    pub net: NetModel,
    /// Layouts drive initialization; pass `None` to zero-init (mock).
    pub client_layout: Option<&'a Layout>,
    /// Server-side model layout (`None` = zero-init).
    pub server_layout: Option<&'a Layout>,
    /// Auxiliary-network layout (`None` = zero-init).
    pub aux_layout: Option<&'a Layout>,
    /// Human-readable run label carried into the `RunRecord`.
    pub label: String,
}

/// Run `work(position, item)` once per owned work item, fanned out
/// according to `parallelism` and dealt to workers according to the
/// scheduling `policy` (`sched::fanout`), and return the results **in
/// item order** (the canonical merge order of the deterministic
/// parallel engine).
///
/// `costs` are per-item estimates for the cost-aware policies (empty =
/// uniform); they steer dealing only and can never change results. The
/// first error in canonical order wins, matching sequential error
/// reporting (see `sched::fanout` for the exact contract under work
/// stealing).
fn fanout_owned<I, T, F>(
    parallelism: Parallelism,
    policy: SchedPolicy,
    costs: &[f64],
    items: Vec<I>,
    work: F,
) -> Result<Vec<T>, EngineError>
where
    I: Send,
    T: Send,
    F: Fn(usize, I) -> Result<T, EngineError> + Sync,
{
    let workers = parallelism.worker_count(items.len());
    sched::fanout(policy, workers, items, costs, work).map_err(|f| match f {
        sched::FanoutFailure::Work(e) => e,
        // Defensive: unreachable with the shipped policies.
        sched::FanoutFailure::Lost => {
            EngineError::Parallel("worker dropped a result".into())
        }
    })
}

/// Run `work(position, client_id, client)` once per participant, fanned
/// out according to `parallelism` / `policy`, and return the results
/// **in participant order** (ascending client id — the canonical merge
/// order of the deterministic parallel engine).
///
/// `participants` must be sorted and duplicate-free (guaranteed by
/// `select_participants`); `costs` holds one estimate per participant,
/// in participant order. Each worker owns disjoint `&mut ClientState`s,
/// so no client state is ever shared.
fn fanout_clients<T, F>(
    parallelism: Parallelism,
    policy: SchedPolicy,
    costs: &[f64],
    clients: &mut [ClientState],
    participants: &[usize],
    work: F,
) -> Result<Vec<T>, EngineError>
where
    T: Send,
    F: Fn(usize, usize, &mut ClientState) -> Result<T, EngineError> + Sync,
{
    debug_assert!(
        participants.windows(2).all(|w| w[0] < w[1]),
        "participants must be sorted and distinct"
    );
    // Disjoint mutable borrows for the participant set, ascending.
    let mut refs: Vec<&mut ClientState> = Vec::with_capacity(participants.len());
    {
        let mut want = participants.iter().copied().peekable();
        for (i, c) in clients.iter_mut().enumerate() {
            if want.peek() == Some(&i) {
                want.next();
                refs.push(c);
            }
        }
        assert!(want.peek().is_none(), "participant id out of range");
    }
    fanout_owned(parallelism, policy, costs, refs, |pos, c| work(pos, participants[pos], c))
}

/// One true cut-layer gradient produced by an aligning drain pass
/// (`ClientUpdate::SageEstimate`, on an `align_every`-th round): the
/// lane's `server_fwd_bwd` output for one arrival, tagged with the
/// client, its batch seed, and the server-update completion time the
/// downlink departs at. Collected worker-locally in the lane loop and
/// consumed by [`Trainer::align_estimators`] in canonical client order.
struct AlignGrad {
    client: usize,
    seed: i32,
    grad: Vec<f32>,
    done: f64,
}

/// Worker-local artifacts of one client's aux-local round (losses,
/// spans, wire bytes, the smashed message) — produced by
/// [`run_local_client`], merged in canonical participant order.
struct LocalOutcome {
    losses: Vec<f32>,
    gnorms: Vec<f32>,
    timeline: Timeline,
    ledger: CommLedger,
    /// The smashed upload; `None` when the client died mid-round (a
    /// partial upload's wire bytes are ledgered, but nothing reaches
    /// the server's dataQueue and the client's own state is untouched).
    msg: Option<SmashedMsg>,
}

/// One client's aux-local round (Algorithm 1): `h` local batches, one
/// smashed upload. This is THE round body for **both** engines — the
/// resident trainer fans it over `Trainer::clients`, the population
/// trainer over the activated cohort — so their per-client arithmetic
/// (engine steps, delay draws, span endpoints, byte records) is shared
/// code, not merely equivalent code. `round_rng` is the trainer-stream
/// snapshot for this round; `i` the canonical client id.
/// `smashed_bytes` is the **wire** size of one upload under
/// `compression` (the trainer's `smashed_bytes()`), and the uploaded
/// tensor is the codec's compress → decompress round trip of the
/// forward output — the server trains on what actually arrived.
///
/// With `fail_rate > 0` the client first takes a per-(round, id) death
/// draw off a throwaway split (`0xFA`): a dying client crashes after
/// computing a prefix of its `h` batches and half its upload — the
/// partial wire bytes ARE ledgered (the server really received them),
/// the spans ARE recorded, but no message is produced and the client's
/// own state (model, batcher, private stream) is untouched, so it
/// resumes from its checkpoint whenever it next participates.
#[allow(clippy::too_many_arguments)]
fn run_local_client<E: SplitEngine>(
    engine: &E,
    train: &Dataset,
    h: usize,
    lr: f32,
    compression: Compression,
    fail_rate: f64,
    smashed_bytes: u64,
    label_bytes: u64,
    round_rng: &Rng,
    i: usize,
    c: &mut ClientState,
) -> Result<LocalOutcome, EngineError> {
    let payload = smashed_bytes + label_bytes;
    let start = c.ready_at;
    if fail_rate > 0.0 {
        let mut frng = round_rng.split(i as u64 ^ 0xFA);
        if frng.uniform() < fail_rate {
            // Crash after `done` of the `h` batches (uniform prefix)
            // plus half the upload. No engine step runs: the partial
            // round's model updates die with the process.
            let done = frng.below(h as u64) as usize;
            let mut drng = round_rng.split(i as u64);
            let frac = (done as f64 + 0.5) / h as f64;
            let t_compute = c.profile.compute_delay(h, &mut drng) * frac;
            let t_up = c.profile.upload_delay(payload, &mut drng) * 0.5;
            let mut timeline = Timeline::default();
            timeline.record(
                SpanKind::ClientCompute,
                Some(i),
                start,
                start + t_compute,
                format!("train {done}/{h} (died)"),
            );
            timeline.record(
                SpanKind::Upload,
                Some(i),
                start + t_compute,
                start + t_compute + t_up,
                "smashed (partial)",
            );
            let mut ledger = CommLedger::new();
            ledger.record(i, MsgKind::SmashedUpload, smashed_bytes / 2);
            c.ready_at = start + t_compute + t_up;
            return Ok(LocalOutcome {
                losses: Vec::new(),
                gnorms: Vec::new(),
                timeline,
                ledger,
                msg: None,
            });
        }
    }
    let mut losses = Vec::with_capacity(h);
    let mut gnorms = Vec::with_capacity(h);
    let mut last_seed = 0;
    for _ in 0..h {
        c.load_batch(train);
        last_seed = c.next_seed();
        let out =
            engine.client_train_step(&c.xc, &c.ac, &c.images, &c.labels, lr, last_seed)?;
        c.xc = out.new_client;
        c.ac = out.new_aux;
        losses.push(out.loss);
        gnorms.push(out.grad_norm);
    }
    // Smashed data of the *updated* model on the last batch
    // (Algorithm 1 line 9: g_{x^{t,h}}(z)).
    let mut smashed = engine.client_fwd(&c.xc, &c.images, last_seed)?;
    if compression != Compression::None {
        // Lossy wire round trip, seeded off the round snapshot per
        // client id (non-mutating split) — schedule-independent.
        smashed = compression.apply(&smashed, &round_rng.split(i as u64 ^ 0xB6));
    }
    let mut drng = round_rng.split(i as u64);
    let t_compute = c.profile.compute_delay(h, &mut drng);
    let t_up = c.profile.upload_delay(payload, &mut drng);
    let mut timeline = Timeline::default();
    timeline.record(
        SpanKind::ClientCompute,
        Some(i),
        start,
        start + t_compute,
        format!("train h={h}"),
    );
    timeline.record(
        SpanKind::Upload,
        Some(i),
        start + t_compute,
        start + t_compute + t_up,
        "smashed",
    );
    let mut ledger = CommLedger::new();
    ledger.record(i, MsgKind::SmashedUpload, smashed_bytes);
    ledger.record(i, MsgKind::LabelUpload, label_bytes);
    let msg = SmashedMsg {
        client: i,
        smashed,
        labels: c.labels.clone(),
        arrival: start + t_compute + t_up,
        seed: last_seed,
    };
    // Fire-and-forget: the client is free as soon as the upload leaves —
    // it never waits for server gradients.
    c.ready_at = start + t_compute + t_up;
    Ok(LocalOutcome { losses, gnorms, timeline, ledger, msg: Some(msg) })
}

/// One client's estimator-alignment step (`ClientUpdate::SageEstimate`,
/// alignment rounds only): the true-gradient downlink (codec round trip
/// + wire record + download span), a client backward on the true
/// gradient, and an estimator re-fit on the same batch — the aux net is
/// trained to regress what the server actually returned. This is THE
/// alignment body for **both** engines, exactly like
/// [`run_local_client`] is the round body for both. `round_rng` is the
/// trainer-stream snapshot; the alignment splits use fresh tags
/// (`0xEB` downlink codec, `0xA7` delays) so no same-round stream is
/// shared. Returns the client backward's gradient norm plus the
/// worker-local timeline/ledger to merge.
#[allow(clippy::too_many_arguments)]
fn align_one_client<E: SplitEngine>(
    engine: &E,
    lr: f32,
    clip: f32,
    compression: Compression,
    grad_bytes: u64,
    round_rng: &Rng,
    g: AlignGrad,
    c: &mut ClientState,
) -> Result<(f32, Timeline, CommLedger), EngineError> {
    let i = g.client;
    // The alignment downlink crosses the same lossy codec as the
    // uplink; the client consumes what actually arrived.
    let grad = if compression == Compression::None {
        g.grad
    } else {
        compression.apply(&g.grad, &round_rng.split(i as u64 ^ 0xEB))
    };
    let mut ledger = CommLedger::new();
    ledger.record(i, MsgKind::GradDownload, grad_bytes);
    let mut drng = round_rng.split(i as u64 ^ 0xA7);
    let t_down = c.profile.download_delay(grad_bytes, &mut drng);
    // True-gradient client step (the SplitFed backward, norm-clipped by
    // `clip`; 0 = off)...
    let (new_xc, gnorm) = engine.client_bwd(&c.xc, &c.images, &grad, lr, g.seed, clip)?;
    c.xc = new_xc;
    // ...then the estimator re-fit: one aux training step on the same
    // batch, keeping ONLY the aux update (the client model already took
    // its true-gradient step above).
    let out = engine.client_train_step(&c.xc, &c.ac, &c.images, &c.labels, lr, g.seed)?;
    c.ac = out.new_aux;
    let t_align = c.profile.compute_delay(1, &mut drng) * 0.5;
    let mut timeline = Timeline::default();
    timeline.record(SpanKind::Download, Some(i), g.done, g.done + t_down, "align grads");
    timeline.record(
        SpanKind::ClientCompute,
        Some(i),
        g.done + t_down,
        g.done + t_down + t_align,
        "align",
    );
    // Alignment rounds block on the round trip, unlike the
    // fire-and-forget base round.
    c.ready_at = g.done + t_down + t_align;
    Ok((gnorm, timeline, ledger))
}

impl<'a, E: SplitEngine> Trainer<'a, E> {
    /// Validate `cfg` against the setup and build the initial state:
    /// globally-initialized models (Step 1), per-client profiles and RNG
    /// streams, and the server topology implied by the spec's topology
    /// axis and `cfg.server_shards`.
    pub fn new(engine: &'a E, cfg: TrainConfig, setup: TrainerSetup<'a>) -> Result<Self, String> {
        let n = setup.partition.n_clients();
        cfg.validate(n)?;
        setup.partition.validate(setup.train.len()).map_err(|e| format!("partition: {e}"))?;
        let root = Rng::new(cfg.seed);

        // Global init: every client starts from the same x_c^0, a_c^0
        // (Step 1: model download), server from x_s^0.
        let irng = root.split_str("init");
        let xc0 = match setup.client_layout {
            Some(l) => init_flat(l, &mut irng.split_str("client")),
            None => vec![0.0; engine.client_size()],
        };
        let ac0 = match setup.aux_layout {
            Some(l) => init_flat(l, &mut irng.split_str("aux")),
            None => vec![0.0; engine.aux_size()],
        };
        let xs0 = match setup.server_layout {
            Some(l) => init_flat(l, &mut irng.split_str("server")),
            None => vec![0.0; engine.server_size()],
        };

        // Profiles derive *per id* from the non-mutated profile root
        // (`NetModel::profile_for`), not from one sequential stream —
        // so the population engine, materializing clients lazily and
        // out of order, reconstructs the identical draws.
        let prng = root.split_str("profiles");
        let clients: Vec<ClientState> = setup
            .partition
            .clients
            .iter()
            .enumerate()
            .map(|(i, shard)| {
                let profile = setup.net.profile_for(&prng, i as u64);
                ClientState::new(
                    i,
                    xc0.clone(),
                    ac0.clone(),
                    shard.clone(),
                    engine.batch(),
                    profile,
                    root.split(1_000 + i as u64),
                )
            })
            .collect();

        let wires =
            WireSizes::new(engine.smashed_len(), engine.client_size(), engine.aux_size());
        // Cost priors: predicted simulated seconds of one client round
        // (h local batches + the smashed upload). They steer the
        // cost-aware dealing policies and the balanced shard map and
        // never touch results.
        let payload = engine.batch() as u64 * (wires.smashed_per_sample + wires.label);
        let costs: Vec<f64> = clients
            .iter()
            .map(|c| sched::profile_cost(&c.profile, cfg.spec.h_hint(), payload))
            .collect();
        let topology = match cfg.spec.topology {
            ServerTopology::PerClient => Topology::PerClient,
            ServerTopology::Shared => Topology::Sharded(cfg.server_shards),
        };
        // Per-client label histograms: the locality map clusters on
        // them, and every map reports its label-skew metric over them.
        let hists = setup.partition.label_histograms(setup.train);
        let shard_map = match topology {
            Topology::PerClient => ShardMap::contiguous(n, n.max(1)),
            Topology::Sharded(k) => match cfg.shard_map {
                ShardMapKind::Contiguous => ShardMap::contiguous(n, k),
                ShardMapKind::Balanced => ShardMap::balanced(n, k, &costs),
                ShardMapKind::Locality => ShardMap::locality(n, k, &hists, &costs),
            },
        };
        // Recorded skew is the sample-mass-weighted variant (the
        // ROADMAP-carried fix; the experiment cache version was bumped
        // so records carrying the old unweighted metric re-run).
        let shard_divergence = shard_map.label_divergence_weighted(&hists);
        let server = ServerState::with_map(
            xs0,
            topology,
            shard_map,
            engine.client_size(),
            engine.aux_size(),
        );
        Ok(Trainer {
            engine,
            cfg,
            train: setup.train,
            test: setup.test,
            clients,
            population: None,
            server,
            ledger: CommLedger::new(),
            timeline: Timeline::default(),
            wires,
            rng: root.split_str("trainer"),
            cost_tracker: CostTracker::new(costs),
            shard_divergence,
            records: Vec::new(),
            dirty: vec![false; n],
            churn: ChurnState::new(&root),
            churn_stats: ChurnStats::default(),
            label: setup.label,
        })
    }

    /// Build a **streaming population** trainer: no per-client state is
    /// materialized here — clients are sampled per round, activated
    /// lazily, and retired after their aggregation upload (see the
    /// `coordinator::population` module docs for the memory and
    /// bit-determinism arguments). Restricted to the config points
    /// whose round shape needs no resident global state: the
    /// aux-training update rules (aux-local, and the sage estimator —
    /// its alignment pass only touches the carried cohort), the shared server
    /// topology, the contiguous shard map (O(1) closed form at any n),
    /// and by-delay arrival ordering (the event queue's native order).
    pub fn new_population(
        engine: &'a E,
        cfg: TrainConfig,
        setup: PopulationSetup<'a>,
    ) -> Result<Self, String> {
        let n = setup.source.n_clients();
        cfg.validate(n)?;
        setup.source.validate(setup.train.len()).map_err(|e| format!("source: {e}"))?;
        if !matches!(
            cfg.spec.update,
            ClientUpdate::AuxLocal | ClientUpdate::SageEstimate { .. }
        ) {
            return Err(
                "population engine: only the aux-training update rules stream \
                 (server-grad clients block on per-batch round trips)"
                    .into(),
            );
        }
        if !matches!(cfg.spec.topology, ServerTopology::Shared) {
            return Err(
                "population engine: per-client server copies are O(n) resident state".into()
            );
        }
        if !matches!(cfg.shard_map, ShardMapKind::Contiguous) {
            return Err(
                "population engine: only the contiguous shard map has an O(1) closed form"
                    .into(),
            );
        }
        if !matches!(cfg.arrival, ArrivalOrder::ByDelay) {
            return Err(
                "population engine: arrivals drain through the event queue in time \
                 order (ArrivalOrder::ByDelay)"
                    .into(),
            );
        }
        let root = Rng::new(cfg.seed);
        // Global zero-init, matching `Trainer::new` with no layouts (the
        // population engine drives layout-free mock runs; every client
        // starts from the same x_c^0 / a_c^0 either way).
        let xc0 = vec![0.0; engine.client_size()];
        let ac0 = vec![0.0; engine.aux_size()];
        let xs0 = vec![0.0; engine.server_size()];
        let shard_map = ShardMap::contiguous(n, cfg.server_shards);
        // The recorded skew metric, streamed (O(shards · classes)
        // memory) instead of materializing n client histograms.
        let shard_divergence =
            setup.source.label_divergence_weighted(&shard_map, setup.train);
        let server = ServerState::with_map(
            xs0,
            Topology::Sharded(cfg.server_shards),
            shard_map,
            engine.client_size(),
            engine.aux_size(),
        );
        let pop = PopulationState {
            n,
            source: setup.source,
            net: setup.net,
            prof_root: root.split_str("profiles"),
            client_root: root.clone(),
            global_xc: xc0,
            global_ac: ac0,
            carry: BTreeMap::new(),
            dirty: BTreeSet::new(),
            costs: SparseCosts::new(),
            aggs: Vec::new(),
            dl_end_max: 0.0,
            busy: BTreeMap::new(),
            arrivals: 0,
        };
        Ok(Trainer {
            engine,
            cfg,
            train: setup.train,
            test: setup.test,
            clients: Vec::new(),
            population: Some(pop),
            server,
            ledger: CommLedger::new(),
            timeline: Timeline::default(),
            wires: WireSizes::new(
                engine.smashed_len(),
                engine.client_size(),
                engine.aux_size(),
            ),
            rng: root.split_str("trainer"),
            cost_tracker: CostTracker::new(Vec::new()),
            shard_divergence,
            records: Vec::new(),
            dirty: Vec::new(),
            churn: ChurnState::new(&root),
            churn_stats: ChurnStats::default(),
            label: setup.label,
        })
    }

    /// Number of clients in the run's population (resident or
    /// streaming).
    pub fn n_clients(&self) -> usize {
        self.population.as_ref().map_or(self.clients.len(), |p| p.n)
    }

    /// Clients whose state was materialized at least once — the
    /// streaming engine's working-set size (= n for resident runs).
    pub fn clients_activated(&self) -> usize {
        self.population.as_ref().map_or(self.clients.len(), |p| p.activated())
    }

    /// Wire bytes of one smashed upload (and of one gradient downlink,
    /// which carries the same tensor shape): the spec's compression
    /// codec applied to the batch's element count. At
    /// `Compression::None` this is exactly the historical
    /// `batch × smashed_per_sample` bytes.
    fn smashed_bytes(&self) -> u64 {
        let elems = self.engine.batch() as u64 * (self.wires.smashed_per_sample / 4);
        self.cfg.spec.compression.wire_bytes(elems)
    }

    fn label_bytes(&self) -> u64 {
        self.engine.batch() as u64 * self.wires.label
    }

    /// Select this round's participants (k of n, or all when k = 0).
    /// `Rng::choose` is sparse (O(k) memory), so sampling a cohort out
    /// of a million-client population never materializes the id range.
    fn select_participants(&mut self) -> Vec<usize> {
        let n = self.n_clients();
        let k = self.cfg.active_clients(n);
        if k == n {
            (0..n).collect()
        } else {
            let mut v = self.rng.choose(n, k);
            v.sort_unstable();
            v
        }
    }

    /// Apply the availability model and the quorum guard to this
    /// round's sampled participants (both engines, pre-fanout).
    ///
    /// Availability draws are per-(round, id) non-mutating splits
    /// ([`ChurnState::is_available`]) — the filter perturbs no other
    /// stream, and the default full-availability model never draws, so
    /// the bit-determinism contract's covered point is untouched byte
    /// for byte. When the surviving cohort falls below a resampling
    /// quorum, deterministic replacements are drawn from the still-
    /// available population (bounded rejection sampling off a per-round
    /// stream) and merged back in canonical id order.
    fn apply_churn(&mut self, t: usize, participants: &mut Vec<usize>) {
        let model = self.cfg.churn.model;
        if model.is_full() {
            // No model can drop anyone, so every quorum is met: nothing
            // to do (and nothing may be drawn — `Quorum { 1.0, false }`
            // must stay byte-identical to `WaitAll`).
            return;
        }
        let planned = participants.len();
        participants.retain(|&i| self.churn.is_available(&model, t, i));
        self.churn_stats.clients_dropped += (planned - participants.len()) as u64;
        if let ResiliencePolicy::Quorum { min_frac, resample } = self.cfg.churn.policy {
            let quorum = (min_frac * planned as f64).ceil() as usize;
            if resample && participants.len() < quorum {
                let n = self.n_clients();
                let mut have: BTreeSet<usize> = participants.iter().copied().collect();
                let mut rng = self.churn.resample_stream(t);
                let need = quorum - have.len();
                // Bounded rejection sampling: candidates already in the
                // cohort or themselves unavailable are skipped; under a
                // heavy blackout the budget runs out and the round
                // proceeds below quorum with whoever there is.
                let budget = 4 * need + 64;
                let mut accepted = 0usize;
                for _ in 0..budget {
                    if accepted >= need || have.len() >= n {
                        break;
                    }
                    let cand = rng.below(n as u64) as usize;
                    if have.contains(&cand) || !self.churn.is_available(&model, t, cand) {
                        continue;
                    }
                    have.insert(cand);
                    accepted += 1;
                }
                self.churn_stats.clients_replaced += accepted as u64;
                *participants = have.into_iter().collect();
            }
        }
    }

    /// The `Cutoff` resilience policy over an upload wave: drop every
    /// message arriving more than the window past the wave's *first*
    /// arrival (the resident counterpart of the population engine's
    /// event-queue filter in [`Trainer::order_arrivals`] — same window,
    /// same first-arrival anchor, same strict inequality).
    fn apply_cutoff(&mut self, msgs: &mut Vec<SmashedMsg>) {
        if let ResiliencePolicy::Cutoff { secs } = self.cfg.churn.policy {
            if let Some(first) =
                msgs.iter().map(|m| m.arrival).reduce(f64::min)
            {
                let before = msgs.len();
                msgs.retain(|m| m.arrival <= first + secs);
                self.churn_stats.stragglers_dropped += (before - msgs.len()) as u64;
            }
        }
    }

    /// Run all configured rounds; returns the run record.
    pub fn run(&mut self) -> Result<RunRecord, EngineError> {
        for t in 1..=self.cfg.rounds {
            self.run_round(t)?;
        }
        // Final aggregation + full eval.
        let final_acc = self.eval_probe(0)?;
        if let Some(last) = self.records.last_mut() {
            last.accuracy = Some(final_acc);
        }
        let sizes = storage::ModelSizes {
            client: self.engine.client_size(),
            server: self.engine.server_size(),
            aux: self.engine.aux_size(),
        };
        let lanes = self.server.lanes();
        // Timeline-derived whole-run stats. A population run's timeline
        // holds no broadcast `Download` spans (they are streamed into
        // `dl_end_max` and the busy folds), so the resident formulas
        // are replayed over the streaming aggregates instead.
        let (sim_time, server_idle_fraction, critical_path) = match &self.population {
            Some(pop) => {
                let end = self.timeline.end_time().max(pop.dl_end_max);
                let idle = if end <= 0.0 {
                    0.0
                } else {
                    (1.0 - self.timeline.server_busy() / end).clamp(0.0, 1.0)
                };
                (end, idle, self.population_critical_path(lanes))
            }
            None => (
                self.timeline.end_time(),
                self.timeline.server_idle_fraction(),
                self.timeline.critical_path(lanes),
            ),
        };
        Ok(RunRecord {
            label: self.label.clone(),
            rounds: self.records.clone(),
            final_accuracy: final_acc,
            total_up_bytes: self.ledger.up_bytes(),
            total_down_bytes: self.ledger.down_bytes(),
            sim_time,
            server_idle_fraction,
            critical_path,
            lane_busy: self.timeline.lane_busy(lanes),
            server_storage_params: storage::server_storage_params_sharded(
                &self.cfg.spec,
                self.n_clients(),
                self.cfg.server_shards,
                &sizes,
            ),
            server_updates_per_shard: self.server.shard_updates.clone(),
            shard_label_divergence: self.shard_divergence,
            clients_activated: self.clients_activated(),
            clients_dropped: self.churn_stats.clients_dropped,
            clients_replaced: self.churn_stats.clients_replaced,
            partial_failures: self.churn_stats.partial_failures,
            stragglers_dropped: self.churn_stats.stragglers_dropped,
        })
    }

    /// Critical path of a population run: the resident
    /// [`Timeline::critical_path`] replayed over streaming state. Busy
    /// totals of ever-activated clients are folded incrementally in
    /// span-record order (`PopulationState::busy`); never-activated
    /// clients only ever accrue broadcast download spans, replayed here
    /// per recorded aggregation — O(n · aggs) time, O(1) extra memory.
    fn population_critical_path(&self, lanes: usize) -> f64 {
        let pop = self.population.as_ref().expect("population run");
        let mut client_max = pop.busy.values().fold(0.0f64, |a, &b| a.max(b));
        if !pop.aggs.is_empty() {
            for id in 0..pop.n {
                if pop.busy.contains_key(&id) {
                    continue;
                }
                let profile = pop.net.profile_for(&pop.prof_root, id as u64);
                let mut b = 0.0;
                for ev in &pop.aggs {
                    let mut drng = ev.rng.split(id as u64 ^ 0xD7);
                    b += profile.download_delay(ev.bytes, &mut drng);
                }
                client_max = client_max.max(b);
            }
        }
        let lane_max = self.timeline.lane_busy(lanes).into_iter().fold(0.0f64, f64::max);
        client_max.max(lane_max)
    }

    fn run_round(&mut self, t: usize) -> Result<(), EngineError> {
        if self.population.is_some() {
            return self.run_round_population(t);
        }
        let lr = self.cfg.lr_at(t - 1) as f32;
        let server_lr = (self.cfg.lr_at(t - 1) * self.cfg.server_lr_scale) as f32;
        let mut participants = self.select_participants();
        self.apply_churn(t, &mut participants);
        let mut train_losses = Vec::new();
        let mut client_gnorms = Vec::new();
        let mut msgs: Vec<SmashedMsg> = Vec::new();

        // The update axis picks the round shape; the upload axis the
        // local batch count this round's upload amortizes. `align` is
        // the sage rule's alignment trigger: Some(clip) on every
        // `align_every`-th round, when the drain returns true gradients.
        let mut align: Option<f32> = None;
        match self.cfg.spec.update {
            ClientUpdate::ServerGrad { clip } => self.splitfed_round(
                &participants,
                lr,
                server_lr,
                clip,
                &mut train_losses,
                &mut client_gnorms,
            )?,
            ClientUpdate::AuxLocal => {
                let h = self.cfg.spec.upload.batches_at(t);
                self.local_round(
                    &participants,
                    h,
                    lr,
                    &mut train_losses,
                    &mut client_gnorms,
                    &mut msgs,
                )?
            }
            ClientUpdate::SageEstimate { align_every, clip } => {
                // Between alignments the sage round IS the aux-local
                // round: the estimator stands in for the server.
                let h = self.cfg.spec.upload.batches_at(t);
                self.local_round(
                    &participants,
                    h,
                    lr,
                    &mut train_losses,
                    &mut client_gnorms,
                    &mut msgs,
                )?;
                if t % align_every == 0 {
                    align = Some(clip);
                }
            }
        }

        // Clients that actually trained go dirty: a mid-round failure
        // never touched its model (no message), while a straggler cut
        // below *did* train — only its upload is dropped.
        for m in &msgs {
            self.dirty[m.client] = true;
        }
        self.apply_cutoff(&mut msgs);

        // Event-triggered server updates over the arrival queue.
        let (server_losses, server_gnorms, grads) =
            self.drain_data_queue(server_lr, msgs, align)?;
        if let Some(clip) = align {
            self.align_estimators(lr, clip, grads, &mut client_gnorms)?;
        }

        if t % self.cfg.agg_every == 0 {
            self.aggregate(t)?;
        }

        let do_eval = self.cfg.eval_every > 0 && t % self.cfg.eval_every == 0;
        let acc = if do_eval { Some(self.eval_probe(self.cfg.eval_max_batches)?) } else { None };

        let mean = |v: &[f32]| {
            if v.is_empty() {
                0.0
            } else {
                v.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64
            }
        };
        self.records.push(RoundRecord {
            round: t,
            sim_time: self.timeline.end_time(),
            lr: lr as f64,
            train_loss: mean(&train_losses),
            server_loss: mean(&server_losses),
            up_bytes: self.ledger.up_bytes(),
            down_bytes: self.ledger.down_bytes(),
            accuracy: acc,
            client_grad_norm: self.cfg.track_grad_norms.then(|| mean(&client_gnorms)),
            server_grad_norm: self.cfg.track_grad_norms.then(|| mean(&server_gnorms)),
        });
        Ok(())
    }

    /// The aux-local round (`ClientUpdate::AuxLocal` — FSL_AN / CSE_FSL
    /// and every spec-only point on that axis): `h` local
    /// auxiliary-loss batches per client (the upload schedule's batch
    /// count for this round), then one smashed upload (Algorithm 1).
    /// Client work fans out according to `cfg.parallelism`; every
    /// per-client artifact (spans, wire bytes, the smashed message) is
    /// produced worker-locally and merged back in canonical client-id
    /// order, so the fan-out is invisible in the run record.
    fn local_round(
        &mut self,
        participants: &[usize],
        h: usize,
        lr: f32,
        train_losses: &mut Vec<f32>,
        client_gnorms: &mut Vec<f32>,
        msgs: &mut Vec<SmashedMsg>,
    ) -> Result<(), EngineError> {
        let engine = self.engine;
        let train = self.train;
        let compression = self.cfg.spec.compression;
        let fail_rate = self.cfg.churn.fail_rate;
        let smashed_bytes = self.smashed_bytes();
        let label_bytes = self.label_bytes();
        // Snapshot of the trainer stream: `split` derives child streams
        // without mutating, so every worker sees exactly the state the
        // sequential loop would.
        let round_rng = self.rng.clone();
        let costs: Vec<f64> =
            participants.iter().map(|&i| self.cost_tracker.estimate(i)).collect();
        let outcomes = fanout_clients(
            self.cfg.parallelism,
            self.cfg.sched,
            &costs,
            &mut self.clients,
            participants,
            |_pos, i, c: &mut ClientState| {
                run_local_client(
                    engine,
                    train,
                    h,
                    lr,
                    compression,
                    fail_rate,
                    smashed_bytes,
                    label_bytes,
                    &round_rng,
                    i,
                    c,
                )
            },
        )?;
        for (pos, o) in outcomes.into_iter().enumerate() {
            // Feed the measured span total (compute + upload, simulated
            // seconds) back into the cost tracker — in canonical order,
            // so the tracker state is as deterministic as the results.
            let observed: f64 = o.timeline.spans.iter().map(|s| s.end - s.start).sum();
            self.cost_tracker.observe(participants[pos], observed);
            train_losses.extend_from_slice(&o.losses);
            client_gnorms.extend_from_slice(&o.gnorms);
            self.timeline.append(o.timeline);
            self.ledger.merge(&o.ledger);
            match o.msg {
                Some(m) => msgs.push(m),
                None => self.churn_stats.partial_failures += 1,
            }
        }
        Ok(())
    }

    /// The server-grad round (`ClientUpdate::ServerGrad` — FSL_MC /
    /// FSL_OC): one interactive split batch per client — forward,
    /// smashed upload, server fwd/bwd, gradient downlink (norm-clipped
    /// by `clip`; 0 = off), client backward. The client *blocks* on the
    /// server round trip, so only phase 1 (forward + upload) fans out;
    /// phase 2 is the serialized server loop — one global loop for the
    /// per-client topology, or one loop per shard executor when the
    /// shared topology is sharded.
    fn splitfed_round(
        &mut self,
        participants: &[usize],
        lr: f32,
        server_lr: f32,
        clip: f32,
        train_losses: &mut Vec<f32>,
        client_gnorms: &mut Vec<f32>,
    ) -> Result<(), EngineError> {
        struct Pending {
            client: usize,
            smashed: Vec<f32>,
            seed: i32,
            arrival: f64,
        }
        struct FwdOutcome {
            timeline: Timeline,
            ledger: CommLedger,
            /// `None` when the client died mid-upload (partial wire
            /// bytes ledgered, nothing reaches the server).
            pend: Option<Pending>,
        }
        // Phase 1: forwards + uploads (parallel across clients).
        let engine = self.engine;
        let train = self.train;
        let compression = self.cfg.spec.compression;
        let smashed_bytes = self.smashed_bytes();
        let label_bytes = self.label_bytes();
        let payload = smashed_bytes + label_bytes;
        let fail_rate = self.cfg.churn.fail_rate;
        let round_rng = self.rng.clone();
        let costs: Vec<f64> =
            participants.iter().map(|&i| self.cost_tracker.estimate(i)).collect();
        let outcomes = fanout_clients(
            self.cfg.parallelism,
            self.cfg.sched,
            &costs,
            &mut self.clients,
            participants,
            |_pos, i, c: &mut ClientState| {
                let start = c.ready_at;
                if fail_rate > 0.0
                    && round_rng.split(i as u64 ^ 0xFA).uniform() < fail_rate
                {
                    // Mid-round death: the client crashes partway
                    // through its forward + upload. Half the compute
                    // and half the wire bytes are spent (and ledgered
                    // — the server really received a partial smashed
                    // upload), but nothing reaches the dataQueue and
                    // the client's own state (model, batcher, private
                    // stream) is untouched: it restarts this round's
                    // work from its checkpoint whenever it returns.
                    let mut drng = round_rng.split(i as u64 ^ 0x5F);
                    let t_fwd = c.profile.compute_delay(1, &mut drng) * 0.5 * 0.5;
                    let t_up = c.profile.upload_delay(payload, &mut drng) * 0.5;
                    let mut timeline = Timeline::default();
                    timeline.record(
                        SpanKind::ClientCompute,
                        Some(i),
                        start,
                        start + t_fwd,
                        "fwd (died)",
                    );
                    timeline.record(
                        SpanKind::Upload,
                        Some(i),
                        start + t_fwd,
                        start + t_fwd + t_up,
                        "smashed (partial)",
                    );
                    let mut ledger = CommLedger::new();
                    ledger.record(i, MsgKind::SmashedUpload, smashed_bytes / 2);
                    c.ready_at = start + t_fwd + t_up;
                    return Ok(FwdOutcome { timeline, ledger, pend: None });
                }
                c.load_batch(train);
                let seed = c.next_seed();
                let mut smashed = engine.client_fwd(&c.xc, &c.images, seed)?;
                if compression != Compression::None {
                    // Same uplink codec + rng tag as the aux-local round.
                    smashed =
                        compression.apply(&smashed, &round_rng.split(i as u64 ^ 0xB6));
                }
                let mut drng = round_rng.split(i as u64 ^ 0x5F);
                let t_fwd = c.profile.compute_delay(1, &mut drng) * 0.5;
                let t_up = c.profile.upload_delay(payload, &mut drng);
                let mut timeline = Timeline::default();
                timeline.record(SpanKind::ClientCompute, Some(i), start, start + t_fwd, "fwd");
                timeline.record(
                    SpanKind::Upload,
                    Some(i),
                    start + t_fwd,
                    start + t_fwd + t_up,
                    "smashed",
                );
                let mut ledger = CommLedger::new();
                ledger.record(i, MsgKind::SmashedUpload, smashed_bytes);
                ledger.record(i, MsgKind::LabelUpload, label_bytes);
                let pend =
                    Pending { client: i, smashed, seed, arrival: start + t_fwd + t_up };
                Ok(FwdOutcome { timeline, ledger, pend: Some(pend) })
            },
        )?;
        let mut pend: Vec<Pending> = Vec::with_capacity(outcomes.len());
        for (pos, o) in outcomes.into_iter().enumerate() {
            // Only phase 1 fans out, so only its spans feed the tracker.
            let observed: f64 = o.timeline.spans.iter().map(|s| s.end - s.start).sum();
            self.cost_tracker.observe(participants[pos], observed);
            self.timeline.append(o.timeline);
            self.ledger.merge(&o.ledger);
            match o.pend {
                Some(p) => pend.push(p),
                None => self.churn_stats.partial_failures += 1,
            }
        }
        // Stable sort: equal arrivals keep canonical client-id order.
        pend.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        // The straggler window applies to the SplitFed dataQueue too: a
        // cut client's upload never reaches the server, so it takes no
        // round trip, no gradient, no step — and stays clean.
        if let ResiliencePolicy::Cutoff { secs } = self.cfg.churn.policy {
            if let Some(first) = pend.first().map(|p| p.arrival) {
                let before = pend.len();
                pend.retain(|p| p.arrival <= first + secs);
                self.churn_stats.stragglers_dropped += (before - pend.len()) as u64;
            }
        }

        // Phase 2: the server round trip, then client backward after the
        // gradient downlink. Arrivals are grouped by executor lane
        // (stable within the global arrival order) and lanes run in
        // canonical lane order; with a single lane this is exactly the
        // historical global loop. Lanes stay sequential here — the loop
        // interleaves client mutation with the shared timeline/ledger —
        // only the event-triggered drain loop fans out over threads.
        let net_server = NetModel::edge_default().server_update_time;
        let lanes = self.server.lanes();
        let mut by_lane: Vec<Vec<Pending>> = (0..lanes).map(|_| Vec::new()).collect();
        for p in pend {
            by_lane[self.server.lane_for(p.client)].push(p);
        }
        for (lane, lane_pend) in by_lane.into_iter().enumerate() {
            for p in lane_pend {
                let i = p.client;
                // A SplitFed client trains iff its upload is served:
                // dirty is decided here, not at sampling time.
                self.dirty[i] = true;
                let start = self.server.free_at[lane].max(p.arrival);
                let copy = self.server.copy_for(i);
                let labels = self.clients[i].labels.clone();
                let out = self.engine.server_fwd_bwd(
                    &self.server.copies[copy],
                    &p.smashed,
                    &labels,
                    server_lr,
                    p.seed,
                    clip,
                )?;
                self.server.copies[copy] = out.new_server;
                self.server.record_update(copy);
                train_losses.push(out.loss);
                let done = start + net_server;
                self.server.free_at[lane] = done;
                let label = if lanes == 1 {
                    "fwd/bwd".to_string()
                } else {
                    format!("fwd/bwd s{lane}")
                };
                self.timeline
                    .record_in_lane(SpanKind::ServerUpdate, None, lane, start, done, label);

                let mut drng = self.rng.split(i as u64 ^ 0xA3);
                let grad_bytes = self.smashed_bytes();
                let c = &mut self.clients[i];
                let t_down = c.profile.download_delay(grad_bytes, &mut drng);
                self.timeline.record(SpanKind::Download, Some(i), done, done + t_down, "grads");
                self.ledger.record(i, MsgKind::GradDownload, grad_bytes);

                // The gradient downlink crosses the same lossy codec as
                // the uplink; the client backward consumes what actually
                // arrived. Phase 2 is sequential, but the split is
                // non-mutating anyway — a fresh per-(round, client) tag
                // off the trainer stream.
                let grad = if compression == Compression::None {
                    out.grad_smashed
                } else {
                    compression.apply(&out.grad_smashed, &self.rng.split(i as u64 ^ 0xE9))
                };
                let (new_xc, gnorm) =
                    self.engine.client_bwd(&c.xc, &c.images, &grad, lr, p.seed, clip)?;
                c.xc = new_xc;
                client_gnorms.push(gnorm);
                let t_bwd = c.profile.compute_delay(1, &mut drng) * 0.5;
                self.timeline.record(
                    SpanKind::ClientCompute,
                    Some(i),
                    done + t_down,
                    done + t_down + t_bwd,
                    "bwd",
                );
                c.ready_at = done + t_down + t_bwd;
            }
        }
        Ok(())
    }

    /// The event-triggered update loop (Algorithm 2): order arrivals,
    /// route them to their executor lane, and run each lane's update
    /// loop — fanned over the `Parallelism` thread machinery, merged in
    /// canonical lane order.
    ///
    /// Each lane owns a contiguous range of server copies: all of them
    /// behind the single executor of the per-client-copy methods, or
    /// exactly one each for the sharded server phase. On error the
    /// trainer is left with its copies taken and must be discarded
    /// (matching the documented error contract of the parallel engine).
    fn drain_data_queue(
        &mut self,
        lr: f32,
        mut msgs: Vec<SmashedMsg>,
        align: Option<f32>,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<AlignGrad>), EngineError> {
        if msgs.is_empty() {
            return Ok((Vec::new(), Vec::new(), Vec::new()));
        }
        match self.cfg.arrival {
            ArrivalOrder::ByDelay => {
                msgs.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
            }
            ArrivalOrder::ClientIndex => msgs.sort_by_key(|m| m.client),
            ArrivalOrder::Shuffled => self.rng.shuffle(&mut msgs),
        }
        self.drain_ordered(lr, msgs, align)
    }

    /// The lane-routing + fan-out body of the drain loop, over
    /// **already-ordered** arrivals. The resident path orders them by
    /// `cfg.arrival` above; the population path pops them off the
    /// [`EventQueue`] (time order, FIFO ties — the same sequence as the
    /// resident stable sort) before calling in here.
    ///
    /// `align` is the sage rule's alignment trigger: `Some(clip)` makes
    /// every lane update run the full `server_fwd_bwd` (instead of the
    /// forward-only `server_train_step`) and return the true cut-layer
    /// gradient as an [`AlignGrad`] for the post-drain alignment pass.
    fn drain_ordered(
        &mut self,
        lr: f32,
        msgs: Vec<SmashedMsg>,
        align: Option<f32>,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<AlignGrad>), EngineError> {
        if msgs.is_empty() {
            return Ok((Vec::new(), Vec::new(), Vec::new()));
        }
        let lanes = self.server.lanes();
        // The paper's dataQueue, materialized per executor lane: route
        // the globally-ordered arrivals to their lanes (stable: within
        // a lane, the global order is preserved).
        let mut lane_msgs: Vec<Vec<SmashedMsg>> = (0..lanes).map(|_| Vec::new()).collect();
        for m in msgs {
            lane_msgs[self.server.lane_for(m.client)].push(m);
        }
        // Each lane takes ownership of its contiguous copy range.
        let all_copies = std::mem::take(&mut self.server.copies);
        let lane_copies: Vec<(usize, Vec<Vec<f32>>)> = if lanes == 1 {
            vec![(0, all_copies)]
        } else {
            all_copies.into_iter().enumerate().map(|(l, c)| (l, vec![c])).collect()
        };
        struct LaneOutcome {
            copies: Vec<Vec<f32>>,
            free_at: f64,
            /// Updates applied to each owned copy (parallel to `copies`).
            updates: Vec<u64>,
            losses: Vec<f32>,
            gnorms: Vec<f32>,
            timeline: Timeline,
            /// True gradients for the alignment pass (aligning drains
            /// only), in lane arrival order.
            grads: Vec<AlignGrad>,
        }
        let engine = self.engine;
        let net_server = NetModel::edge_default().server_update_time;
        let shard_map = self.server.shard_map.clone();
        // Lane cost = queued work on that executor (message count times
        // the per-update cost) — exact, so even CostWeighted dealing is
        // as balanced as the lane loads allow.
        let lane_costs: Vec<f64> =
            lane_msgs.iter().map(|m| m.len() as f64 * net_server).collect();
        let items: Vec<_> = lane_copies
            .into_iter()
            .zip(self.server.free_at.iter().copied())
            .zip(lane_msgs)
            .map(|(((base, copies), free_at), msgs)| (base, copies, free_at, msgs))
            .collect();
        let outcomes = fanout_owned(
            self.cfg.parallelism,
            self.cfg.sched,
            &lane_costs,
            items,
            |lane, item: (usize, Vec<Vec<f32>>, f64, Vec<SmashedMsg>)| {
                let (base, mut copies, mut free_at, msgs) = item;
                let mut updates = vec![0u64; copies.len()];
                let mut losses = Vec::with_capacity(msgs.len());
                let mut gnorms = Vec::with_capacity(msgs.len());
                let mut timeline = Timeline::default();
                let mut grads = Vec::new();
                for m in msgs {
                    let start = free_at.max(m.arrival);
                    let done = start + net_server;
                    let slot = shard_map.shard_of(m.client) - base;
                    match align {
                        Some(clip) => {
                            // Aligning drain: the same server update,
                            // via the fwd/bwd path that also returns
                            // the true cut-layer gradient.
                            let out = engine.server_fwd_bwd(
                                &copies[slot],
                                &m.smashed,
                                &m.labels,
                                lr,
                                m.seed,
                                clip,
                            )?;
                            copies[slot] = out.new_server;
                            losses.push(out.loss);
                            gnorms.push(out.grad_norm);
                            grads.push(AlignGrad {
                                client: m.client,
                                seed: m.seed,
                                grad: out.grad_smashed,
                                done,
                            });
                        }
                        None => {
                            let out = engine.server_train_step(
                                &copies[slot],
                                &m.smashed,
                                &m.labels,
                                lr,
                                m.seed,
                            )?;
                            copies[slot] = out.new_server;
                            losses.push(out.loss);
                            gnorms.push(out.grad_norm);
                        }
                    }
                    updates[slot] += 1;
                    free_at = done;
                    let label = if lanes == 1 {
                        format!("update c{}", m.client)
                    } else {
                        format!("update c{} s{lane}", m.client)
                    };
                    timeline.record_in_lane(SpanKind::ServerUpdate, None, lane, start, done, label);
                }
                Ok(LaneOutcome { copies, free_at, updates, losses, gnorms, timeline, grads })
            },
        )?;
        // Merge in canonical lane order (the bit-determinism contract);
        // copies are re-assembled in ascending copy-index order.
        let mut losses = Vec::new();
        let mut gnorms = Vec::new();
        let mut grads = Vec::new();
        for (lane, o) in outcomes.into_iter().enumerate() {
            let base = if lanes == 1 { 0 } else { lane };
            for (j, (copy, ups)) in o.copies.into_iter().zip(o.updates).enumerate() {
                debug_assert_eq!(self.server.copies.len(), base + j);
                self.server.copies.push(copy);
                self.server.updates += ups;
                self.server.shard_updates[base + j] += ups;
            }
            self.server.free_at[lane] = o.free_at;
            self.timeline.append(o.timeline);
            losses.extend(o.losses);
            gnorms.extend(o.gnorms);
            grads.extend(o.grads);
        }
        Ok((losses, gnorms, grads))
    }

    /// The sage alignment pass (alignment rounds only): consume the
    /// drain loop's true gradients in **canonical client-id order**
    /// (regardless of lane routing or arrival order — the
    /// bit-determinism contract) and run [`align_one_client`] for each,
    /// over the resident client vector or the carried population
    /// cohort. The rng snapshot is taken once, so every split is a
    /// non-mutating per-(round, client) tag off the trainer stream.
    fn align_estimators(
        &mut self,
        lr: f32,
        clip: f32,
        mut grads: Vec<AlignGrad>,
        client_gnorms: &mut Vec<f32>,
    ) -> Result<(), EngineError> {
        grads.sort_by_key(|g| g.client);
        let grad_bytes = self.smashed_bytes();
        let compression = self.cfg.spec.compression;
        let engine = self.engine;
        let round_rng = self.rng.clone();
        for g in grads {
            let i = g.client;
            let (gnorm, timeline, ledger) = match self.population.as_mut() {
                Some(pop) => {
                    let c = pop.carry.get_mut(&i).expect("aligned client not carried");
                    let out = align_one_client(
                        engine, lr, clip, compression, grad_bytes, &round_rng, g, c,
                    )?;
                    // Busy fold in span-record order, as everywhere the
                    // population engine replays resident spans.
                    for s in &out.1.spans {
                        if let Some(who) = s.who {
                            *pop.busy.entry(who).or_insert(0.0) += s.end - s.start;
                        }
                    }
                    out
                }
                None => {
                    let c = &mut self.clients[i];
                    align_one_client(
                        engine, lr, clip, compression, grad_bytes, &round_rng, g, c,
                    )?
                }
            };
            client_gnorms.push(gnorm);
            self.timeline.append(timeline);
            self.ledger.merge(&ledger);
        }
        Ok(())
    }

    /// One communication round of the streaming population engine: the
    /// same phases as `run_round` — sample, train, drain, mark dirty,
    /// aggregate, evaluate, record — driven over a lazily-activated
    /// cohort instead of the resident client vector.
    fn run_round_population(&mut self, t: usize) -> Result<(), EngineError> {
        let lr = self.cfg.lr_at(t - 1) as f32;
        let server_lr = (self.cfg.lr_at(t - 1) * self.cfg.server_lr_scale) as f32;
        let mut participants = self.select_participants();
        // Churn: who of the sampled cohort shows up (availability model
        // + quorum re-sampling). Draws come per (round, id) from
        // non-mutated roots, so the filter perturbs no other stream;
        // the default full-availability model never draws. `Iid { p }`
        // replays the legacy `availability = p` knob's draw sequence
        // bit for bit (pinned by `tests/churn_properties.rs`).
        self.apply_churn(t, &mut participants);
        let h = self.cfg.spec.upload.batches_at(t);
        // The sage rule's alignment trigger — the same condition as the
        // resident dispatch, so the two engines align the same rounds.
        let align = match self.cfg.spec.update {
            ClientUpdate::SageEstimate { align_every, clip } if t % align_every == 0 => {
                Some(clip)
            }
            _ => None,
        };
        self.activate_cohort(&participants);
        let mut train_losses = Vec::new();
        let mut client_gnorms = Vec::new();
        let mut msgs: Vec<SmashedMsg> = Vec::new();
        self.local_round_population(
            &participants,
            h,
            lr,
            &mut train_losses,
            &mut client_gnorms,
            &mut msgs,
        )?;
        // Clients that actually trained go dirty (a mid-round failure
        // produced no message and never touched its model; a straggler
        // cut below trained — only its upload is dropped).
        let trained: Vec<usize> = msgs.iter().map(|m| m.client).collect();
        // Arrivals, dropouts, stragglers: the event queue replays the
        // upload wave in time order; late arrivals past the straggler
        // window (`ResiliencePolicy::Cutoff`) never reach the server's
        // dataQueue.
        let ordered = self.order_arrivals(msgs);
        let (server_losses, server_gnorms, grads) =
            self.drain_ordered(server_lr, ordered, align)?;
        if let Some(clip) = align {
            self.align_estimators(lr, clip, grads, &mut client_gnorms)?;
        }
        // Retire the cohort's batch buffers only now: the alignment
        // pass consumes the round's last batch after the drain.
        self.retire_batch_buffers(&participants);
        {
            let pop = self.population.as_mut().expect("population run");
            pop.dirty.extend(trained);
        }
        if t % self.cfg.agg_every == 0 {
            self.aggregate_population()?;
        }
        let do_eval = self.cfg.eval_every > 0 && t % self.cfg.eval_every == 0;
        let acc = if do_eval { Some(self.eval_probe(self.cfg.eval_max_batches)?) } else { None };
        let mean = |v: &[f32]| {
            if v.is_empty() {
                0.0
            } else {
                v.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64
            }
        };
        // Round-end clock: the timeline is missing only the broadcast
        // `Download` spans, whose running end-max streams separately.
        let sim_time = {
            let pop = self.population.as_ref().expect("population run");
            self.timeline.end_time().max(pop.dl_end_max)
        };
        self.records.push(RoundRecord {
            round: t,
            sim_time,
            lr: lr as f64,
            train_loss: mean(&train_losses),
            server_loss: mean(&server_losses),
            up_bytes: self.ledger.up_bytes(),
            down_bytes: self.ledger.down_bytes(),
            accuracy: acc,
            client_grad_norm: self.cfg.track_grad_norms.then(|| mean(&client_gnorms)),
            server_grad_norm: self.cfg.track_grad_norms.then(|| mean(&server_gnorms)),
        });
        Ok(())
    }

    /// Materialize any not-yet-carried participants (lazy activation):
    /// build the [`ClientState`] exactly as `Trainer::new` would — the
    /// same constructor arguments, the same per-id streams — then
    /// replay every aggregation broadcast the client missed (busy fold,
    /// ready time, current global model). Re-activating a retired
    /// carried client only refills its model buffers from the global
    /// model (what the resident broadcast wrote into it at the last
    /// barrier).
    fn activate_cohort(&mut self, participants: &[usize]) {
        let payload =
            self.engine.batch() as u64 * (self.wires.smashed_per_sample + self.wires.label);
        let h_hint = self.cfg.spec.h_hint();
        let batch = self.engine.batch();
        let pop = self.population.as_mut().expect("population run");
        for &id in participants {
            if let Some(c) = pop.carry.get_mut(&id) {
                if c.xc.is_empty() {
                    c.xc = pop.global_xc.clone();
                    c.ac = pop.global_ac.clone();
                }
                continue;
            }
            let profile = pop.net.profile_for(&pop.prof_root, id as u64);
            let mut c = ClientState::new(
                id,
                pop.global_xc.clone(),
                pop.global_ac.clone(),
                pop.source.shard_of(id),
                batch,
                profile,
                pop.client_root.split(1_000 + id as u64),
            );
            // Replay missed broadcasts in record order: the busy fold
            // and final ready time are bit-identical to the download
            // spans a resident client would have accrued by now.
            let mut busy = 0.0;
            for ev in &pop.aggs {
                let mut drng = ev.rng.split(id as u64 ^ 0xD7);
                let t_down = c.profile.download_delay(ev.bytes, &mut drng);
                busy += t_down;
                c.ready_at = ev.agg_done + t_down;
            }
            pop.busy.insert(id, busy);
            pop.costs.seed(id, sched::profile_cost(&c.profile, h_hint, payload));
            pop.carry.insert(id, c);
        }
    }

    /// The population cohort's aux-local round: the shared round body
    /// ([`run_local_client`]) fanned over the carried cohort states and
    /// merged in canonical participant order — the same machinery as
    /// the resident `local_round`, minus the resident client vector.
    fn local_round_population(
        &mut self,
        participants: &[usize],
        h: usize,
        lr: f32,
        train_losses: &mut Vec<f32>,
        client_gnorms: &mut Vec<f32>,
        msgs: &mut Vec<SmashedMsg>,
    ) -> Result<(), EngineError> {
        let engine = self.engine;
        let train = self.train;
        let compression = self.cfg.spec.compression;
        let fail_rate = self.cfg.churn.fail_rate;
        let smashed_bytes = self.smashed_bytes();
        let label_bytes = self.label_bytes();
        let round_rng = self.rng.clone();
        let pop = self.population.as_mut().expect("population run");
        let costs: Vec<f64> = participants.iter().map(|&i| pop.costs.estimate(i)).collect();
        // Disjoint `&mut` cohort states in ascending id order (BTreeMap
        // iteration), mirroring `fanout_clients`' borrow dance over the
        // resident vector.
        let mut refs: Vec<&mut ClientState> = Vec::with_capacity(participants.len());
        {
            let mut want = participants.iter().copied().peekable();
            for (&id, c) in pop.carry.iter_mut() {
                if want.peek() == Some(&id) {
                    want.next();
                    refs.push(c);
                }
            }
            assert!(want.peek().is_none(), "participant not activated");
        }
        let outcomes = fanout_owned(
            self.cfg.parallelism,
            self.cfg.sched,
            &costs,
            refs,
            |pos, c: &mut ClientState| {
                run_local_client(
                    engine,
                    train,
                    h,
                    lr,
                    compression,
                    fail_rate,
                    smashed_bytes,
                    label_bytes,
                    &round_rng,
                    participants[pos],
                    c,
                )
            },
        )?;
        for (pos, o) in outcomes.into_iter().enumerate() {
            let observed: f64 = o.timeline.spans.iter().map(|s| s.end - s.start).sum();
            pop.costs.observe(participants[pos], observed);
            // Busy fold in span-record order — the resident
            // critical-path accumulation, replayed incrementally.
            for s in &o.timeline.spans {
                if let Some(who) = s.who {
                    *pop.busy.entry(who).or_insert(0.0) += s.end - s.start;
                }
            }
            train_losses.extend_from_slice(&o.losses);
            client_gnorms.extend_from_slice(&o.gnorms);
            self.timeline.append(o.timeline);
            self.ledger.merge(&o.ledger);
            match o.msg {
                Some(m) => msgs.push(m),
                None => self.churn_stats.partial_failures += 1,
            }
        }
        Ok(())
    }

    /// Retire the cohort's batch buffers between rounds: they are
    /// rebuilt by the next `load_batch` and would otherwise pin
    /// O(working set · batch · sample) floats. Called at round end —
    /// after the drain *and* any sage alignment pass, both of which
    /// consume the round's last batch.
    fn retire_batch_buffers(&mut self, participants: &[usize]) {
        let pop = self.population.as_mut().expect("population run");
        for &i in participants {
            let c = pop.carry.get_mut(&i).expect("activated");
            c.idx_buf = Vec::new();
            c.images = Vec::new();
            c.labels = Vec::new();
        }
    }

    /// Replay the round's upload wave through the [`EventQueue`]:
    /// arrivals pop in time order with FIFO ties — enqueued in
    /// participant order, that reproduces the resident engine's stable
    /// sort bit-for-bit — and, under [`ResiliencePolicy::Cutoff`],
    /// arrivals later than the window past the wave's first are dropped
    /// before they ever reach the server's dataQueue (the population
    /// counterpart of [`Trainer::apply_cutoff`]).
    fn order_arrivals(&mut self, msgs: Vec<SmashedMsg>) -> Vec<SmashedMsg> {
        let cutoff = self.cfg.churn.policy.cutoff();
        let pop = self.population.as_mut().expect("population run");
        let mut q = EventQueue::new();
        for m in msgs {
            q.schedule_at(m.arrival, m);
        }
        let mut ordered = Vec::with_capacity(q.len());
        let mut first_arrival: Option<f64> = None;
        while let Some((at, m)) = q.pop() {
            let first = *first_arrival.get_or_insert(at);
            pop.arrivals += 1;
            match cutoff {
                Some(cut) if at > first + cut => {
                    self.churn_stats.stragglers_dropped += 1
                }
                _ => ordered.push(m),
            }
        }
        ordered
    }

    /// The population aggregation barrier: identical contributor-side
    /// arithmetic to [`Trainer`]'s resident `aggregate` (same streams,
    /// same span order), with the O(n) broadcast replayed as a
    /// streaming sweep — bulk wire records, a running download-end max,
    /// per-client busy folds, and model-buffer retirement for the
    /// carried working set — instead of n recorded `Download` spans and
    /// n resident model writes.
    fn aggregate_population(&mut self) -> Result<(), EngineError> {
        let contributors: Vec<usize> = {
            let pop = self.population.as_ref().expect("population run");
            pop.dirty.iter().copied().collect()
        };
        if contributors.is_empty() {
            return Ok(());
        }
        // Contributor uploads (client model + aux riders — both
        // streaming update rules train the aux net) in ascending id
        // order.
        let mut last_arrival = self.server.free_at_max();
        {
            let pop = self.population.as_mut().expect("population run");
            for &i in &contributors {
                let c = pop.carry.get_mut(&i).expect("dirty client not carried");
                let mut drng = self.rng.split(i as u64 ^ 0xC4);
                self.ledger.record(i, MsgKind::ClientModelUpload, self.wires.client_model);
                self.ledger.record(i, MsgKind::AuxModelUpload, self.wires.aux_model);
                let bytes = self.wires.client_model + self.wires.aux_model;
                let t_up = c.profile.upload_delay(bytes, &mut drng);
                self.timeline.record(
                    SpanKind::Upload,
                    Some(i),
                    c.ready_at,
                    c.ready_at + t_up,
                    "model",
                );
                *pop.busy.get_mut(&i).expect("carried busy") += t_up;
                last_arrival = last_arrival.max(c.ready_at + t_up);
                self.server.client_acc.add(&c.xc, 1.0);
                self.server.aux_acc.add(&c.ac, 1.0);
            }
        }
        let agg_start = last_arrival.max(self.server.free_at_max());
        let agg_cost = 1e-3; // FedAvg itself is cheap vs model transfer
        let agg_done = agg_start + agg_cost;
        self.server.sync_free_at(agg_done);
        self.timeline.record(SpanKind::Aggregate, None, agg_start, agg_done, "fedavg");

        let mut xc_new = vec![0.0f32; self.engine.client_size()];
        self.server.client_acc.finish_into(&mut xc_new);
        let mut ac_new = vec![0.0f32; self.engine.aux_size()];
        self.server.aux_acc.finish_into(&mut ac_new);
        self.server.aggregate_copies();

        // Broadcast to all n clients, streamed. Wire totals via bulk
        // records (the server-side view of n identical downloads);
        // download ends via one O(n) sweep that also retires the
        // carried working set's model buffers. The trainer stream is
        // snapshotted so never-activated clients can replay their
        // per-id download draw later ([`AggEvent`]).
        let bytes = self.wires.client_model + self.wires.aux_model;
        let snapshot = self.rng.clone();
        let pop = self.population.as_mut().expect("population run");
        self.ledger.record_bulk(
            MsgKind::ClientModelDownload,
            pop.n as u64,
            self.wires.client_model,
        );
        self.ledger.record_bulk(MsgKind::AuxModelDownload, pop.n as u64, self.wires.aux_model);
        pop.global_xc = xc_new;
        pop.global_ac = ac_new;
        for id in 0..pop.n {
            let mut drng = snapshot.split(id as u64 ^ 0xD7);
            let t_down = match pop.carry.get(&id) {
                Some(c) => c.profile.download_delay(bytes, &mut drng),
                None => pop
                    .net
                    .profile_for(&pop.prof_root, id as u64)
                    .download_delay(bytes, &mut drng),
            };
            pop.dl_end_max = pop.dl_end_max.max(agg_done + t_down);
            if let Some(c) = pop.carry.get_mut(&id) {
                // Retire after upload: model buffers drop; the next
                // activation refills them from the global model.
                c.xc = Vec::new();
                c.ac = Vec::new();
                c.ready_at = agg_done + t_down;
                *pop.busy.get_mut(&id).expect("carried busy") += t_down;
            }
        }
        pop.aggs.push(AggEvent { agg_done, rng: snapshot, bytes });
        pop.dirty.clear();
        Ok(())
    }

    /// Global aggregation (Step 4, Eq. (14)): dirty clients upload their
    /// client-side models (+ aux), the server averages and redistributes
    /// to everyone; the multi-copy server states (per-client copies or
    /// shard copies) additionally FedAvg their copies — the cross-shard
    /// FedAvg that resynchronizes the sharded server phase. Aggregation
    /// is a global barrier: every executor lane's clock is advanced to
    /// the aggregation end.
    fn aggregate(&mut self, _t: usize) -> Result<(), EngineError> {
        let contributors: Vec<usize> =
            (0..self.clients.len()).filter(|&i| self.dirty[i]).collect();
        if contributors.is_empty() {
            return Ok(());
        }
        // Aux networks ride along with the model exchange exactly when
        // the update axis trains them (the aux-local head and the sage
        // estimator both do).
        let aux_riders = matches!(
            self.cfg.spec.update,
            ClientUpdate::AuxLocal | ClientUpdate::SageEstimate { .. }
        );
        // Upload client models (+ aux) — wire cost + arrival times.
        let mut last_arrival = self.server.free_at_max();
        for &i in &contributors {
            let c = &mut self.clients[i];
            let mut drng = self.rng.split(i as u64 ^ 0xC4);
            let mut bytes = self.wires.client_model;
            self.ledger.record(i, MsgKind::ClientModelUpload, self.wires.client_model);
            if aux_riders {
                bytes += self.wires.aux_model;
                self.ledger.record(i, MsgKind::AuxModelUpload, self.wires.aux_model);
            }
            let t_up = c.profile.upload_delay(bytes, &mut drng);
            self.timeline.record(
                SpanKind::Upload,
                Some(i),
                c.ready_at,
                c.ready_at + t_up,
                "model",
            );
            last_arrival = last_arrival.max(c.ready_at + t_up);
            self.server.client_acc.add(&c.xc, 1.0);
            if aux_riders {
                self.server.aux_acc.add(&c.ac, 1.0);
            }
        }
        // Server aggregation (barrier: needs every contributor and every
        // shard executor).
        let agg_start = last_arrival.max(self.server.free_at_max());
        let agg_cost = 1e-3; // FedAvg itself is cheap vs model transfer
        let agg_done = agg_start + agg_cost;
        self.server.sync_free_at(agg_done);
        self.timeline.record(SpanKind::Aggregate, None, agg_start, agg_done, "fedavg");

        let mut xc_new = vec![0.0f32; self.engine.client_size()];
        self.server.client_acc.finish_into(&mut xc_new);
        let ac_new = if aux_riders {
            let mut v = vec![0.0f32; self.engine.aux_size()];
            self.server.aux_acc.finish_into(&mut v);
            Some(v)
        } else {
            self.server.aux_acc.reset();
            None
        };
        self.server.aggregate_copies();

        // Redistribute to ALL clients ("the aggregated models are used as
        // the initial model for the next round").
        for c in &mut self.clients {
            c.xc.copy_from_slice(&xc_new);
            let mut bytes = self.wires.client_model;
            self.ledger.record(c.id, MsgKind::ClientModelDownload, self.wires.client_model);
            if let Some(ac) = &ac_new {
                c.ac.copy_from_slice(ac);
                bytes += self.wires.aux_model;
                self.ledger.record(c.id, MsgKind::AuxModelDownload, self.wires.aux_model);
            }
            let mut drng = self.rng.split(c.id as u64 ^ 0xD7);
            let t_down = c.profile.download_delay(bytes, &mut drng);
            self.timeline.record(
                SpanKind::Download,
                Some(c.id),
                agg_done,
                agg_done + t_down,
                "model",
            );
            c.ready_at = agg_done + t_down;
        }
        self.dirty.iter_mut().for_each(|d| *d = false);
        Ok(())
    }

    /// Evaluation probe: accuracy of (FedAvg of client models, mean of
    /// server copies) on the test set. No wire traffic.
    ///
    /// The population branch replays the resident [`fedavg`] reduction
    /// (`+= v * inv` in id order, f32) without n resident models:
    /// carried diverged models where they exist, the post-aggregation
    /// global model everywhere else — bit-identical output, O(working
    /// set) memory.
    fn eval_probe(&self, max_batches: usize) -> Result<f64, EngineError> {
        let xc = match &self.population {
            Some(pop) => {
                let mut xc = vec![0.0f32; self.engine.client_size()];
                let inv = 1.0 / pop.n as f32;
                for id in 0..pop.n {
                    let m: &[f32] = match pop.carry.get(&id) {
                        Some(c) if !c.xc.is_empty() => &c.xc,
                        _ => &pop.global_xc,
                    };
                    for (o, &v) in xc.iter_mut().zip(m) {
                        *o += v * inv;
                    }
                }
                xc
            }
            None => {
                let refs: Vec<&[f32]> =
                    self.clients.iter().map(|c| c.xc.as_slice()).collect();
                fedavg(&refs)
            }
        };
        let xs = self.server.eval_model();
        accuracy(self.engine, &xc, &xs, self.test, max_batches)
    }

    /// Per-round records accumulated so far.
    pub fn records(&self) -> &[RoundRecord] {
        &self.records
    }
}
