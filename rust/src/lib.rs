//! CSE-FSL: Communication and Storage Efficient Federated Split Learning.
//!
//! Rust reproduction of Mu & Shen (2025) as a three-layer stack:
//! Pallas kernels (L1) and JAX split models (L2) are AOT-compiled to HLO
//! at build time (`make artifacts`); this crate is the L3 coordinator that
//! loads those artifacts via PJRT and runs the full federated-split-
//! learning system — clients, event-triggered (optionally sharded)
//! server, aggregation, communication/storage accounting, and every
//! experiment in the paper.
//!
//! # Module map
//!
//! * [`coordinator`] — the system contribution: methods, config, client
//!   and (sharded) server state, and the deterministic parallel round
//!   engine.
//! * [`runtime`] — the `SplitEngine` compute interface, its PJRT and
//!   mock implementations, and the AOT artifact manifest.
//! * [`sched`] — cost-aware scheduling for the parallel engine: dealing
//!   policies (round-robin / cost-weighted / work-stealing), the LPT
//!   bin packer behind the load-balanced shard map, and per-client cost
//!   estimation.
//! * [`comm`] / [`storage`] — measured wire ledger, Table II closed
//!   forms, and server-storage accounting.
//! * [`sim`] — deterministic clock, network/heterogeneity models, and
//!   timeline recording.
//! * [`data`] / [`model`] — synthetic datasets + partitioners; flat
//!   parameter layouts, init, and FedAvg.
//! * [`exp`] / [`metrics`] — figure/table drivers with cached runs;
//!   evaluation and run records.
//! * [`util`] — the zero-dependency substrate (prng, json, cli, bench,
//!   prop, csv, logging).
//!
//! `ARCHITECTURE.md` at the repository root walks the round data-flow
//! and the two cross-cutting contracts (bit-determinism merge order;
//! `RunSpec::key` completeness).

#![warn(missing_docs)]

pub mod comm;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod sched;
pub mod storage;
pub mod sim;
pub mod util;
