//! CSE-FSL: Communication and Storage Efficient Federated Split Learning.
//!
//! Rust reproduction of Mu & Shen (2025) as a three-layer stack:
//! Pallas kernels (L1) and JAX split models (L2) are AOT-compiled to HLO
//! at build time (`make artifacts`); this crate is the L3 coordinator that
//! loads those artifacts via PJRT and runs the full federated-split-
//! learning system — clients, event-triggered server, aggregation,
//! communication/storage accounting, and every experiment in the paper.

pub mod comm;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod storage;
pub mod sim;
pub mod util;
