//! Storage accounting (paper Table II "Server storage" column and the
//! Table V "Storage (M)" comparison), driven by the method spec.
//!
//! The paper measures storage in *millions of parameters*: everything the
//! server must hold during training — server-side model copies (n under
//! the per-client topology, 1 under the paper's shared topology), plus
//! the client-side models and auxiliary networks it receives at
//! aggregation time. Of the spec's three axes, **topology** decides the
//! server-side copy count and the **update rule** decides whether aux
//! networks are resident; the upload schedule never touches storage.
//! The sharded server phase (`TrainConfig::server_shards = k`)
//! interpolates the shared topology's copy count between the endpoints:
//! k copies, reducing to the paper's Table II at k = 1 and matching the
//! per-client topology's storage at k = n. The copies term itself is the
//! closed form in [`crate::comm::accounting::storage`].

use crate::comm::accounting::storage as storage_form;
use crate::coordinator::methods::{ClientUpdate, MethodSpec, ServerTopology};

/// Parameter counts of the three model parts.
#[derive(Clone, Copy, Debug)]
pub struct ModelSizes {
    /// Client-side partial model |w_c|.
    pub client: usize,
    /// Server-side partial model |w_s|.
    pub server: usize,
    /// Auxiliary network |a|.
    pub aux: usize,
}

/// Server-side model copies held during training with `server_shards`
/// shard copies on the shared topology (the per-client topology always
/// holds n).
pub fn server_model_copies_sharded(
    spec: &MethodSpec,
    n_clients: usize,
    server_shards: usize,
) -> usize {
    match spec.topology {
        ServerTopology::PerClient => n_clients,
        ServerTopology::Shared => server_shards,
    }
}

/// Server-side model copies at the paper's operating point (k = 1).
pub fn server_model_copies(spec: &MethodSpec, n_clients: usize) -> usize {
    server_model_copies_sharded(spec, n_clients, 1)
}

/// Total parameters resident at the server (Table V accounting) with
/// `server_shards` shard copies: server-side copies + n client models
/// (aggregation) + n aux models (the aux-local update rule).
pub fn server_storage_params_sharded(
    spec: &MethodSpec,
    n_clients: usize,
    server_shards: usize,
    sizes: &ModelSizes,
) -> usize {
    let copies = server_model_copies_sharded(spec, n_clients, server_shards);
    let server =
        storage_form::server_copies_params(copies as u64, sizes.server as u64) as usize;
    let clients = n_clients * sizes.client;
    let aux = match spec.update {
        ClientUpdate::AuxLocal | ClientUpdate::SageEstimate { .. } => n_clients * sizes.aux,
        ClientUpdate::ServerGrad { .. } => 0,
    };
    server + clients + aux
}

/// Total parameters resident at the server at the paper's operating
/// point (k = 1 — Table V accounting).
pub fn server_storage_params(spec: &MethodSpec, n_clients: usize, sizes: &ModelSizes) -> usize {
    server_storage_params_sharded(spec, n_clients, 1, sizes)
}

/// In millions of parameters, as Table V reports.
pub fn server_storage_m(spec: &MethodSpec, n_clients: usize, sizes: &ModelSizes) -> f64 {
    server_storage_params(spec, n_clients, sizes) as f64 / 1e6
}

/// Client-side storage (params a single client holds).
pub fn client_storage_params(spec: &MethodSpec, sizes: &ModelSizes) -> usize {
    sizes.client
        + match spec.update {
            ClientUpdate::AuxLocal | ClientUpdate::SageEstimate { .. } => sizes.aux,
            ClientUpdate::ServerGrad { .. } => 0,
        }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::methods::Method;

    const CIFAR: ModelSizes = ModelSizes { client: 107_328, server: 960_970, aux: 23_050 };
    const FEMNIST: ModelSizes = ModelSizes { client: 18_816, server: 1_187_774, aux: 571_454 };

    #[test]
    fn matches_paper_table5_cifar() {
        // Paper Table V (n=5): MC 5.34M, OC 1.50M, AN 5.46M, CSE 1.61M.
        let m = |meth: Method| server_storage_m(&meth.spec(), 5, &CIFAR);
        assert!((m(Method::FslMc) - 5.34).abs() < 0.01, "{}", m(Method::FslMc));
        assert!((m(Method::FslOc) - 1.50).abs() < 0.01, "{}", m(Method::FslOc));
        assert!((m(Method::FslAn) - 5.46).abs() < 0.01, "{}", m(Method::FslAn));
        assert!((m(Method::CseFsl) - 1.61).abs() < 0.01, "{}", m(Method::CseFsl));
    }

    #[test]
    fn matches_paper_table5_femnist() {
        // Paper Table V (n=5, aux=MLP): MC 6.03M, OC 1.28M, AN 8.89M,
        // CSE 4.14M.
        let m = |meth: Method| server_storage_m(&meth.spec(), 5, &FEMNIST);
        assert!((m(Method::FslMc) - 6.03).abs() < 0.01, "{}", m(Method::FslMc));
        assert!((m(Method::FslOc) - 1.28).abs() < 0.01, "{}", m(Method::FslOc));
        assert!((m(Method::FslAn) - 8.89).abs() < 0.01, "{}", m(Method::FslAn));
        assert!((m(Method::CseFsl) - 4.14).abs() < 0.01, "{}", m(Method::CseFsl));
    }

    #[test]
    fn cse_storage_independent_of_n_in_server_copies() {
        // The paper's headline: server-side model count does not scale
        // with n on the shared topology.
        assert_eq!(server_model_copies(&Method::CseFsl.spec(), 5), 1);
        assert_eq!(server_model_copies(&Method::CseFsl.spec(), 5000), 1);
        assert_eq!(server_model_copies(&Method::FslMc.spec(), 5000), 5000);
        // and the *server model* storage gap grows linearly
        let gap = |n: usize| {
            server_storage_params(&Method::FslMc.spec(), n, &CIFAR)
                - server_storage_params(&Method::CseFsl.spec(), n, &CIFAR)
        };
        assert!(gap(100) > gap(10));
    }

    #[test]
    fn sharded_copies_interpolate_between_paper_endpoints() {
        // k = 1 is Table II's single copy; k = n matches the per-client
        // topology's copy count; intermediate k interpolates linearly.
        for k in 1..=5usize {
            assert_eq!(server_model_copies_sharded(&Method::CseFsl.spec(), 5, k), k);
            assert_eq!(server_model_copies_sharded(&Method::FslOc.spec(), 5, k), k);
            // The per-client topology ignores the shard knob.
            assert_eq!(server_model_copies_sharded(&Method::FslMc.spec(), 5, k), 5);
            assert_eq!(server_model_copies_sharded(&Method::FslAn.spec(), 5, k), 5);
        }
        // Totals: the k = 1 reduction is exactly the historical fn, and
        // each extra shard adds exactly one server-side model.
        assert_eq!(
            server_storage_params_sharded(&Method::CseFsl.spec(), 5, 1, &CIFAR),
            server_storage_params(&Method::CseFsl.spec(), 5, &CIFAR)
        );
        let at = |k| server_storage_params_sharded(&Method::CseFsl.spec(), 5, k, &CIFAR);
        assert_eq!(at(3) - at(2), CIFAR.server);
        // k = n: the server-side copy term equals FSL_MC's n·|w_s|.
        let copy_term =
            |m: Method, k| server_model_copies_sharded(&m.spec(), 5, k) * CIFAR.server;
        assert_eq!(copy_term(Method::CseFsl, 5), copy_term(Method::FslMc, 1));
    }

    #[test]
    fn storage_follows_axes_not_presets() {
        // The upload schedule never moves storage: the spec-only
        // "FSL_AN with h>1" point stores exactly what FSL_AN does.
        assert_eq!(
            server_storage_params(&Method::FslAn.spec().with_period(4), 5, &CIFAR),
            server_storage_params(&Method::FslAn.spec(), 5, &CIFAR)
        );
        // The update axis alone decides the aux term.
        let aux_term = server_storage_params(&Method::CseFsl.spec(), 5, &CIFAR)
            - server_storage_params(&Method::FslOc.spec(), 5, &CIFAR);
        assert_eq!(aux_term, 5 * CIFAR.aux);
    }

    #[test]
    fn sage_stores_exactly_what_aux_local_does() {
        // The estimator is the aux net retrained to a different target;
        // storage is identical to the aux-local rule at any period.
        use crate::coordinator::methods::{ClientUpdate, MethodSpec};
        for a in [1usize, 4, 100] {
            let sage = MethodSpec {
                update: ClientUpdate::SageEstimate { align_every: a, clip: 0.0 },
                ..Method::CseFsl.spec()
            };
            assert_eq!(
                server_storage_params(&sage, 5, &CIFAR),
                server_storage_params(&Method::CseFsl.spec(), 5, &CIFAR),
                "align_every={a}"
            );
            assert_eq!(
                client_storage_params(&sage, &CIFAR),
                client_storage_params(&Method::CseFsl.spec(), &CIFAR)
            );
        }
    }

    #[test]
    fn client_storage() {
        assert_eq!(client_storage_params(&Method::FslMc.spec(), &CIFAR), 107_328);
        assert_eq!(
            client_storage_params(&Method::CseFsl.spec(), &CIFAR),
            107_328 + 23_050
        );
    }
}
