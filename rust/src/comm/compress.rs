//! Lossy wire compression for split-layer activations (FedLite-style).
//!
//! CSE-FSL reduces *how often* smashed data crosses the wire; FedLite
//! (arXiv 2201.11865) shows the complementary lever is *how many bits*
//! each crossing costs, via quantization or top-k sketching of the
//! split-layer activations. [`Compression`] is that lever as a
//! first-class algorithm axis: the coordinator applies it at the wire
//! boundary (uplink smashed activations, and — for the server-grad
//! update rule — the returned gradient downlink), and
//! [`crate::comm::accounting::predict`] uses the *same*
//! [`Compression::wire_bytes`] integer arithmetic for its closed forms,
//! so ledgered bytes and predicted bytes agree exactly by construction.
//!
//! Two invariants the rest of the system leans on:
//!
//! * **Determinism** — [`Compression::apply`] is a pure function of
//!   `(self, input, rng)`. The coordinator derives the rng from the
//!   round snapshot via a non-mutating [`Rng::split`], so parallel and
//!   sequential schedules stay bit-identical
//!   (`tests/determinism_golden.rs`).
//! * **Exact byte accounting** — [`Compression::wire_bytes`] is integer
//!   arithmetic on element counts, shared by the trainer's ledger and
//!   the closed-form predictions (`tests/comm_properties.rs`).

use crate::util::prng::Rng;

/// Wire-compression axis of a method spec.
///
/// `None` is the historical uncompressed wire (4 bytes per f32
/// element); the other variants are lossy codecs applied to each
/// smashed-activation upload (and, under the server-grad update rule,
/// to each gradient download) as a compress → decompress round trip:
/// the receiving side trains on the dequantized values, while the
/// ledger records the compressed wire size.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Compression {
    /// No compression: full-precision f32 on the wire.
    None,
    /// Uniform `bits`-bit quantization over the tensor's `[min, max]`
    /// range with seeded stochastic rounding (unbiased in expectation).
    /// Wire cost: an 8-byte range header + `bits` bits per element.
    Quantize {
        /// Bits per element, `1..=16`.
        bits: u8,
    },
    /// Magnitude top-k sparsification: keep the `ceil(frac * n)`
    /// largest-|x| entries, zero the rest. Wire cost: 8 bytes (value +
    /// index) per kept entry.
    TopK {
        /// Fraction of entries kept, in `(0, 1]`.
        frac: f32,
    },
}

impl Compression {
    /// Canonical cache-key / label tag for the non-`None` variants
    /// (`q4`, `t0.25`, ...). `None` has *no* tag — it is deliberately
    /// unrepresented so every pre-axis key string survives byte-
    /// identically (`tests/spec_equivalence.rs`).
    pub fn tag(&self) -> String {
        match self {
            Compression::None => String::new(),
            Compression::Quantize { bits } => format!("q{bits}"),
            Compression::TopK { frac } => format!("t{frac}"),
        }
    }

    /// Check the axis point is runnable; returns a human-readable
    /// reason when it is not.
    pub fn validate(&self) -> Result<(), String> {
        match *self {
            Compression::None => Ok(()),
            Compression::Quantize { bits } => {
                if bits == 0 {
                    Err("quantize bits must be >= 1".into())
                } else if bits > 16 {
                    Err(format!(
                        "quantize bits must be <= 16 (got {bits}; full precision is \
                         --compress none)"
                    ))
                } else {
                    Ok(())
                }
            }
            Compression::TopK { frac } => {
                if !frac.is_finite() || frac <= 0.0 || frac > 1.0 {
                    Err(format!("top-k frac must be in (0, 1] (got {frac})"))
                } else {
                    Ok(())
                }
            }
        }
    }

    /// Number of entries a `TopK { frac }` codec keeps out of `n`:
    /// `ceil(frac * n)`, clamped to `[1, n]` for non-empty tensors.
    /// Shared by [`Compression::apply`], [`Compression::wire_bytes`]
    /// and the property tests, so the three can never drift.
    pub fn kept_count(frac: f32, n: u64) -> u64 {
        if n == 0 {
            return 0;
        }
        (((frac as f64) * n as f64).ceil() as u64).clamp(1, n)
    }

    /// Exact wire size in bytes of one `raw_elems`-element f32 tensor
    /// under this codec. Integer arithmetic only — this is the single
    /// source of truth for both the trainer's ledger and the
    /// closed-form predictions in [`crate::comm::accounting::predict`].
    pub fn wire_bytes(&self, raw_elems: u64) -> u64 {
        match *self {
            Compression::None => raw_elems * 4,
            // 8-byte header (f32 min + f32 scale) + bits per element,
            // bit-packed and rounded up to whole bytes.
            Compression::Quantize { bits } => 8 + (raw_elems * bits as u64).div_ceil(8),
            // 4-byte value + 4-byte index per kept entry.
            Compression::TopK { frac } => Self::kept_count(frac, raw_elems) * 8,
        }
    }

    /// The lossy compress → decompress round trip: what the receiver
    /// sees after this codec crosses the wire. Pure in `(self, v, rng)`;
    /// the caller passes an rng split off the round snapshot so the
    /// result is schedule-independent.
    ///
    /// Quantization uses stochastic rounding on a uniform grid over
    /// `[min, max]`: each element lands on one of the two neighboring
    /// levels with probability proportional to proximity, so the error
    /// is bounded by one step (not half a step) but unbiased in
    /// expectation. Top-k keeps the `ceil(frac * n)` largest-|x|
    /// entries (ties broken toward the lower index) and zeroes the
    /// rest — deterministic, no rng consumed.
    pub fn apply(&self, v: &[f32], rng: &Rng) -> Vec<f32> {
        match *self {
            Compression::None => v.to_vec(),
            Compression::Quantize { bits } => {
                if v.is_empty() {
                    return Vec::new();
                }
                let min = v.iter().copied().fold(f32::INFINITY, f32::min);
                let max = v.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let levels = (1u32 << bits) - 1;
                if max <= min || levels == 0 {
                    // Degenerate range: every element is the shared min.
                    return vec![min; v.len()];
                }
                let step = (max - min) / levels as f32;
                let mut r = rng.clone();
                v.iter()
                    .map(|&x| {
                        // One rng draw per element, endpoints included,
                        // so the stream stays aligned whatever the data.
                        let u = r.uniform();
                        if x == max {
                            // The top of the range is an exact grid
                            // point, but (max-min)/step can land just
                            // below `levels` in f32 — snap it.
                            return max;
                        }
                        let pos = ((x - min) / step) as f64;
                        let lo = pos.floor();
                        let up = (u < pos - lo) as u32;
                        let level = (lo as u32 + up).min(levels);
                        // Reconstruct; the top level snaps to max so the
                        // output can never escape the input range.
                        if level == levels {
                            max
                        } else {
                            min + level as f32 * step
                        }
                    })
                    .collect()
            }
            Compression::TopK { frac } => {
                let n = v.len();
                let keep = Self::kept_count(frac, n as u64) as usize;
                // Rank by |x| descending, index ascending on ties.
                let mut order: Vec<usize> = (0..n).collect();
                order.sort_by(|&a, &b| {
                    v[b].abs()
                        .partial_cmp(&v[a].abs())
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.cmp(&b))
                });
                let mut out = vec![0.0f32; n];
                for &i in order.iter().take(keep) {
                    out[i] = v[i];
                }
                out
            }
        }
    }
}

impl std::fmt::Display for Compression {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Compression::None => write!(f, "none"),
            Compression::Quantize { bits } => write!(f, "quantize{bits}"),
            Compression::TopK { frac } => write!(f, "topk{frac}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_bytes_closed_forms() {
        // None: 4 bytes per element.
        assert_eq!(Compression::None.wire_bytes(6), 24);
        assert_eq!(Compression::None.wire_bytes(0), 0);
        // Quantize: 8-byte header + ceil(elems * bits / 8).
        assert_eq!(Compression::Quantize { bits: 8 }.wire_bytes(6), 8 + 6);
        assert_eq!(Compression::Quantize { bits: 4 }.wire_bytes(6), 8 + 3);
        assert_eq!(Compression::Quantize { bits: 1 }.wire_bytes(9), 8 + 2);
        assert_eq!(Compression::Quantize { bits: 16 }.wire_bytes(3), 8 + 6);
        // TopK: 8 bytes per kept entry, kept = ceil(frac * n) >= 1.
        assert_eq!(Compression::TopK { frac: 0.5 }.wire_bytes(6), 3 * 8);
        assert_eq!(Compression::TopK { frac: 0.25 }.wire_bytes(6), 2 * 8);
        assert_eq!(Compression::TopK { frac: 0.01 }.wire_bytes(6), 8);
        assert_eq!(Compression::TopK { frac: 1.0 }.wire_bytes(6), 48);
        assert_eq!(Compression::TopK { frac: 0.5 }.wire_bytes(0), 0);
    }

    #[test]
    fn kept_count_boundaries() {
        assert_eq!(Compression::kept_count(0.5, 0), 0);
        assert_eq!(Compression::kept_count(0.001, 5), 1, "non-empty keeps at least one");
        assert_eq!(Compression::kept_count(1.0, 5), 5);
        assert_eq!(Compression::kept_count(0.5, 5), 3, "ceil(2.5)");
        assert_eq!(Compression::kept_count(0.4, 5), 2);
    }

    #[test]
    fn validation_rules() {
        assert!(Compression::None.validate().is_ok());
        assert!(Compression::Quantize { bits: 1 }.validate().is_ok());
        assert!(Compression::Quantize { bits: 16 }.validate().is_ok());
        assert!(Compression::Quantize { bits: 0 }.validate().is_err());
        assert!(Compression::Quantize { bits: 17 }.validate().is_err());
        assert!(Compression::TopK { frac: 1.0 }.validate().is_ok());
        assert!(Compression::TopK { frac: 0.25 }.validate().is_ok());
        assert!(Compression::TopK { frac: 0.0 }.validate().is_err());
        assert!(Compression::TopK { frac: -0.5 }.validate().is_err());
        assert!(Compression::TopK { frac: 1.5 }.validate().is_err());
        assert!(Compression::TopK { frac: f32::NAN }.validate().is_err());
    }

    #[test]
    fn tags_and_display() {
        assert_eq!(Compression::None.tag(), "");
        assert_eq!(Compression::Quantize { bits: 4 }.tag(), "q4");
        assert_eq!(Compression::TopK { frac: 0.25 }.tag(), "t0.25");
        assert_eq!(Compression::None.to_string(), "none");
        assert_eq!(Compression::Quantize { bits: 8 }.to_string(), "quantize8");
        assert_eq!(Compression::TopK { frac: 0.5 }.to_string(), "topk0.5");
    }

    #[test]
    fn apply_is_deterministic_given_equal_rng() {
        let rng = Rng::new(7).split_str("compress-test");
        let v: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).sin()).collect();
        for c in [
            Compression::None,
            Compression::Quantize { bits: 4 },
            Compression::Quantize { bits: 8 },
            Compression::TopK { frac: 0.25 },
        ] {
            assert_eq!(c.apply(&v, &rng), c.apply(&v, &rng), "{c}");
        }
    }

    #[test]
    fn none_is_identity_and_quantize_stays_in_range() {
        let rng = Rng::new(3);
        let v: Vec<f32> = vec![-1.5, 0.0, 0.25, 2.0, 0.75];
        assert_eq!(Compression::None.apply(&v, &rng), v);
        let q = Compression::Quantize { bits: 4 }.apply(&v, &rng);
        assert_eq!(q.len(), v.len());
        for &y in &q {
            assert!((-1.5..=2.0).contains(&y), "{y} outside input range");
        }
        // Range endpoints are exact grid points, so min/max quantize to
        // themselves regardless of the stochastic draw.
        assert_eq!(q[0], -1.5);
        assert_eq!(q[3], 2.0);
    }

    #[test]
    fn quantize_degenerate_range_is_constant() {
        let rng = Rng::new(5);
        let v = vec![0.7f32; 9];
        assert_eq!(Compression::Quantize { bits: 4 }.apply(&v, &rng), v);
        assert!(Compression::Quantize { bits: 8 }.apply(&[], &rng).is_empty());
    }

    #[test]
    fn topk_keeps_largest_magnitudes() {
        let rng = Rng::new(1);
        let v = vec![0.1f32, -3.0, 0.5, 2.0, -0.2];
        let out = Compression::TopK { frac: 0.4 }.apply(&v, &rng);
        // ceil(0.4 * 5) = 2 kept: |-3.0| and |2.0|.
        assert_eq!(out, vec![0.0, -3.0, 0.0, 2.0, 0.0]);
        // frac = 1 keeps everything.
        assert_eq!(Compression::TopK { frac: 1.0 }.apply(&v, &rng), v);
        // Ties break toward the lower index.
        let tied = vec![1.0f32, -1.0, 1.0];
        assert_eq!(Compression::TopK { frac: 0.34 }.apply(&tied, &rng), vec![1.0, 0.0, 0.0]);
    }
}
