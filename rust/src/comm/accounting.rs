//! Communication accounting: the measured ledger and the paper's Table II
//! closed forms.
//!
//! Every message the coordinator sends is recorded here with its byte
//! size, direction, and kind; figures 9 and Table V read the ledger, and
//! `table2.rs` cross-checks the measured totals against the closed forms
//! (they must agree exactly — that is a test).

use std::collections::BTreeMap;

/// Message direction relative to the server.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Dir {
    Up,
    Down,
}

/// Message kinds on the FSL wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum MsgKind {
    /// Client -> server: smashed activations for one batch.
    SmashedUpload,
    /// Client -> server: labels accompanying smashed data.
    LabelUpload,
    /// Server -> client: cut-layer gradients (FSL_MC / FSL_OC only).
    GradDownload,
    /// Client -> server: client-side model for aggregation.
    ClientModelUpload,
    /// Client -> server: auxiliary network for aggregation.
    AuxModelUpload,
    /// Server -> client: aggregated client-side model.
    ClientModelDownload,
    /// Server -> client: aggregated auxiliary network.
    AuxModelDownload,
}

impl MsgKind {
    pub const ALL: [MsgKind; 7] = [
        MsgKind::SmashedUpload,
        MsgKind::LabelUpload,
        MsgKind::GradDownload,
        MsgKind::ClientModelUpload,
        MsgKind::AuxModelUpload,
        MsgKind::ClientModelDownload,
        MsgKind::AuxModelDownload,
    ];

    pub fn dir(self) -> Dir {
        match self {
            MsgKind::SmashedUpload
            | MsgKind::LabelUpload
            | MsgKind::ClientModelUpload
            | MsgKind::AuxModelUpload => Dir::Up,
            MsgKind::GradDownload
            | MsgKind::ClientModelDownload
            | MsgKind::AuxModelDownload => Dir::Down,
        }
    }
}

/// The measured communication ledger.
#[derive(Clone, Debug, Default)]
pub struct CommLedger {
    bytes: BTreeMap<MsgKind, u64>,
    counts: BTreeMap<MsgKind, u64>,
    per_client_bytes: BTreeMap<usize, u64>,
}

impl CommLedger {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, client: usize, kind: MsgKind, bytes: u64) {
        *self.bytes.entry(kind).or_default() += bytes;
        *self.counts.entry(kind).or_default() += 1;
        *self.per_client_bytes.entry(client).or_default() += bytes;
    }

    pub fn bytes_of(&self, kind: MsgKind) -> u64 {
        self.bytes.get(&kind).copied().unwrap_or(0)
    }

    pub fn count_of(&self, kind: MsgKind) -> u64 {
        self.counts.get(&kind).copied().unwrap_or(0)
    }

    pub fn up_bytes(&self) -> u64 {
        self.bytes.iter().filter(|(k, _)| k.dir() == Dir::Up).map(|(_, &b)| b).sum()
    }

    pub fn down_bytes(&self) -> u64 {
        self.bytes.iter().filter(|(k, _)| k.dir() == Dir::Down).map(|(_, &b)| b).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.up_bytes() + self.down_bytes()
    }

    pub fn client_bytes(&self, client: usize) -> u64 {
        self.per_client_bytes.get(&client).copied().unwrap_or(0)
    }

    pub fn total_gb(&self) -> f64 {
        self.total_bytes() as f64 / 1e9
    }

    /// Pretty per-kind breakdown (for run summaries).
    pub fn breakdown(&self) -> Vec<(MsgKind, u64, u64)> {
        MsgKind::ALL
            .iter()
            .filter(|k| self.count_of(**k) > 0)
            .map(|&k| (k, self.count_of(k), self.bytes_of(k)))
            .collect()
    }
}

/// Per-epoch byte sizes used by both the live coordinator and the closed
/// forms (f32 = 4 bytes; labels are i32).
#[derive(Clone, Copy, Debug)]
pub struct WireSizes {
    /// q: bytes of smashed data per *sample*.
    pub smashed_per_sample: u64,
    /// bytes of one label.
    pub label: u64,
    /// α|w| bytes: client-side model.
    pub client_model: u64,
    /// |a| bytes: auxiliary network.
    pub aux_model: u64,
}

impl WireSizes {
    pub fn new(smashed_size: usize, client_params: usize, aux_params: usize) -> Self {
        WireSizes {
            smashed_per_sample: (smashed_size * 4) as u64,
            label: 4,
            client_model: (client_params * 4) as u64,
            aux_model: (aux_params * 4) as u64,
        }
    }
}

/// Table II closed forms: total bytes for ONE GLOBAL EPOCH (every client
/// walks its |D_i| local samples once; one aggregation).
///
/// Smashed-data terms follow the paper (`q` already includes whatever the
/// paper counts per sample; we add labels explicitly since the pipeline
/// sends them).
pub mod table2 {
    use super::WireSizes;

    /// FSL_MC (SplitFed, multi-copy): 2·n·q·|D| smashed+grad, 2·n·α|w|
    /// model exchange.
    pub fn fsl_mc(n: u64, d_i: u64, w: &WireSizes) -> u64 {
        let smashed = n * d_i * (w.smashed_per_sample + w.label);
        let grads = n * d_i * w.smashed_per_sample;
        let models = 2 * n * w.client_model;
        smashed + grads + models
    }

    /// FSL_OC: identical wire profile to FSL_MC (single server copy only
    /// changes storage, not traffic).
    pub fn fsl_oc(n: u64, d_i: u64, w: &WireSizes) -> u64 {
        fsl_mc(n, d_i, w)
    }

    /// FSL_AN: n·q·|D| upstream only, no grad downlink, plus aux nets in
    /// the model exchange: 2·n·α(|w|+|a|).
    pub fn fsl_an(n: u64, d_i: u64, w: &WireSizes) -> u64 {
        let smashed = n * d_i * (w.smashed_per_sample + w.label);
        let models = 2 * n * (w.client_model + w.aux_model);
        smashed + models
    }

    /// CSE_FSL_h: smashed upstream divided by h.
    pub fn cse_fsl(n: u64, d_i: u64, h: u64, w: &WireSizes) -> u64 {
        let smashed = n * (d_i / h) * (w.smashed_per_sample + w.label);
        let models = 2 * n * (w.client_model + w.aux_model);
        smashed + models
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wires() -> WireSizes {
        WireSizes::new(2304, 107_328, 23_050)
    }

    #[test]
    fn ledger_sums_directions() {
        let mut l = CommLedger::new();
        l.record(0, MsgKind::SmashedUpload, 100);
        l.record(0, MsgKind::LabelUpload, 4);
        l.record(1, MsgKind::GradDownload, 50);
        l.record(1, MsgKind::ClientModelDownload, 10);
        assert_eq!(l.up_bytes(), 104);
        assert_eq!(l.down_bytes(), 60);
        assert_eq!(l.total_bytes(), 164);
        assert_eq!(l.client_bytes(0), 104);
        assert_eq!(l.client_bytes(1), 60);
        assert_eq!(l.count_of(MsgKind::SmashedUpload), 1);
        assert_eq!(l.breakdown().len(), 4);
    }

    #[test]
    fn cse_reduces_smashed_by_h() {
        let w = wires();
        let (n, d) = (5, 1000);
        let h1 = table2::cse_fsl(n, d, 1, &w);
        let h10 = table2::cse_fsl(n, d, 10, &w);
        // model-exchange term is constant; smashed term shrinks 10x
        let model_term = 2 * n * (w.client_model + w.aux_model);
        assert_eq!((h1 - model_term), (h10 - model_term) * 10);
    }

    #[test]
    fn ordering_matches_paper_table2() {
        // paper: CSE_FSL_h < FSL_AN < FSL_MC for h>1 and |a| << q|D|
        let w = wires();
        let (n, d) = (5, 10_000);
        let mc = table2::fsl_mc(n, d, &w);
        let oc = table2::fsl_oc(n, d, &w);
        let an = table2::fsl_an(n, d, &w);
        let cse5 = table2::cse_fsl(n, d, 5, &w);
        assert_eq!(mc, oc);
        assert!(an < mc, "AN {an} !< MC {mc}");
        assert!(cse5 < an, "CSE {cse5} !< AN {an}");
        // MC ≈ 2x AN minus aux overhead
        assert!((mc as f64) / (an as f64) > 1.8);
    }

    #[test]
    fn table5_scale_sanity() {
        // Paper Table V: FSL_MC on CIFAR-10 = 172.46 GB over 200 epochs
        // (n=5, |D_i|=10k). Our closed form with labels included should
        // land in the same ballpark (same order, within ~15%).
        let w = wires();
        let total_200 = 200.0 * table2::fsl_mc(5, 10_000, &w) as f64 / 1e9;
        assert!(
            (140.0..230.0).contains(&total_200),
            "200-epoch FSL_MC total {total_200} GB out of family vs paper 172.46"
        );
    }
}
