//! Communication + storage accounting: the measured ledger and the
//! paper's Table II closed forms.
//!
//! Every message the coordinator sends is recorded here with its byte
//! size, direction, and kind; figures 9 and Table V read the ledger, and
//! `table2.rs` cross-checks the measured totals against the closed forms
//! (they must agree exactly — that is a test). [`storage`] holds the
//! matching server-storage closed form, generalized to the sharded
//! server phase's k copies.

use std::collections::BTreeMap;

/// Message direction relative to the server.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Dir {
    /// Client → server (uplink).
    Up,
    /// Server → client (downlink).
    Down,
}

/// Message kinds on the FSL wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum MsgKind {
    /// Client -> server: smashed activations for one batch.
    SmashedUpload,
    /// Client -> server: labels accompanying smashed data.
    LabelUpload,
    /// Server -> client: cut-layer gradients (FSL_MC / FSL_OC only).
    GradDownload,
    /// Client -> server: client-side model for aggregation.
    ClientModelUpload,
    /// Client -> server: auxiliary network for aggregation.
    AuxModelUpload,
    /// Server -> client: aggregated client-side model.
    ClientModelDownload,
    /// Server -> client: aggregated auxiliary network.
    AuxModelDownload,
}

impl MsgKind {
    /// Every wire message kind, in canonical report order.
    pub const ALL: [MsgKind; 7] = [
        MsgKind::SmashedUpload,
        MsgKind::LabelUpload,
        MsgKind::GradDownload,
        MsgKind::ClientModelUpload,
        MsgKind::AuxModelUpload,
        MsgKind::ClientModelDownload,
        MsgKind::AuxModelDownload,
    ];

    /// The direction this kind travels, relative to the server.
    pub fn dir(self) -> Dir {
        match self {
            MsgKind::SmashedUpload
            | MsgKind::LabelUpload
            | MsgKind::ClientModelUpload
            | MsgKind::AuxModelUpload => Dir::Up,
            MsgKind::GradDownload
            | MsgKind::ClientModelDownload
            | MsgKind::AuxModelDownload => Dir::Down,
        }
    }
}

/// The measured communication ledger.
///
/// Every message is recorded under two views that must stay conserved:
/// the **server-side view** (totals per [`MsgKind`]) and the
/// **client-side view** (per-client, per-kind totals). Ledgers are
/// mergeable: the parallel round engine gives each client worker its own
/// ledger and folds them into the trainer's in canonical client order,
/// which yields a map-for-map identical ledger to the sequential
/// schedule (BTreeMaps are order-insensitive, so equality is exact).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CommLedger {
    bytes: BTreeMap<MsgKind, u64>,
    counts: BTreeMap<MsgKind, u64>,
    per_client_bytes: BTreeMap<usize, u64>,
    per_client_kind: BTreeMap<(usize, MsgKind), u64>,
}

impl CommLedger {
    /// An empty ledger.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one message of `kind`, `bytes` long, attributed to
    /// `client`.
    pub fn record(&mut self, client: usize, kind: MsgKind, bytes: u64) {
        *self.bytes.entry(kind).or_default() += bytes;
        *self.counts.entry(kind).or_default() += 1;
        *self.per_client_bytes.entry(client).or_default() += bytes;
        *self.per_client_kind.entry((client, kind)).or_default() += bytes;
    }

    /// Record `count` identical messages of `kind`, `bytes_each` long,
    /// without attributing them to individual clients.
    ///
    /// This is the streaming population engine's broadcast path: an
    /// aggregated-model download to n = 10⁶ clients must not grow the
    /// per-client maps by a million entries per aggregation. The
    /// server-side view (totals and counts per kind) stays exact — it is
    /// what `up_bytes`/`down_bytes` and the Table II cross-checks read —
    /// but the **client-side view is deliberately not updated**, so
    /// `per_kind_views_are_conserved`-style conservation between the two
    /// views holds only for ledgers that never used this method. Use
    /// [`CommLedger::record`] whenever the client attribution matters.
    pub fn record_bulk(&mut self, kind: MsgKind, count: u64, bytes_each: u64) {
        *self.bytes.entry(kind).or_default() += count * bytes_each;
        *self.counts.entry(kind).or_default() += count;
    }

    /// Fold another ledger into this one (all views summed).
    pub fn merge(&mut self, other: &CommLedger) {
        for (&k, &b) in &other.bytes {
            *self.bytes.entry(k).or_default() += b;
        }
        for (&k, &c) in &other.counts {
            *self.counts.entry(k).or_default() += c;
        }
        for (&c, &b) in &other.per_client_bytes {
            *self.per_client_bytes.entry(c).or_default() += b;
        }
        for (&ck, &b) in &other.per_client_kind {
            *self.per_client_kind.entry(ck).or_default() += b;
        }
    }

    /// Bytes of `kind` attributed to `client` (client-side view).
    pub fn client_kind_bytes(&self, client: usize, kind: MsgKind) -> u64 {
        self.per_client_kind.get(&(client, kind)).copied().unwrap_or(0)
    }

    /// All client ids with recorded traffic, ascending.
    pub fn clients(&self) -> Vec<usize> {
        self.per_client_bytes.keys().copied().collect()
    }

    /// Total bytes of one message kind (server-side view).
    pub fn bytes_of(&self, kind: MsgKind) -> u64 {
        self.bytes.get(&kind).copied().unwrap_or(0)
    }

    /// Number of messages of one kind.
    pub fn count_of(&self, kind: MsgKind) -> u64 {
        self.counts.get(&kind).copied().unwrap_or(0)
    }

    /// Total uplink bytes.
    pub fn up_bytes(&self) -> u64 {
        self.bytes.iter().filter(|(k, _)| k.dir() == Dir::Up).map(|(_, &b)| b).sum()
    }

    /// Total downlink bytes.
    pub fn down_bytes(&self) -> u64 {
        self.bytes.iter().filter(|(k, _)| k.dir() == Dir::Down).map(|(_, &b)| b).sum()
    }

    /// Total bytes in both directions.
    pub fn total_bytes(&self) -> u64 {
        self.up_bytes() + self.down_bytes()
    }

    /// Total bytes attributed to one client.
    pub fn client_bytes(&self, client: usize) -> u64 {
        self.per_client_bytes.get(&client).copied().unwrap_or(0)
    }

    /// Total traffic in gigabytes (Table V / Fig. 9 units).
    pub fn total_gb(&self) -> f64 {
        self.total_bytes() as f64 / 1e9
    }

    /// Pretty per-kind breakdown (for run summaries).
    pub fn breakdown(&self) -> Vec<(MsgKind, u64, u64)> {
        MsgKind::ALL
            .iter()
            .filter(|k| self.count_of(**k) > 0)
            .map(|&k| (k, self.count_of(k), self.bytes_of(k)))
            .collect()
    }
}

/// Per-epoch byte sizes used by both the live coordinator and the closed
/// forms (f32 = 4 bytes; labels are i32).
#[derive(Clone, Copy, Debug)]
pub struct WireSizes {
    /// q: bytes of smashed data per *sample*.
    pub smashed_per_sample: u64,
    /// bytes of one label.
    pub label: u64,
    /// α|w| bytes: client-side model.
    pub client_model: u64,
    /// |a| bytes: auxiliary network.
    pub aux_model: u64,
}

impl WireSizes {
    /// Derive wire sizes from parameter/element counts (4 bytes each).
    pub fn new(smashed_size: usize, client_params: usize, aux_params: usize) -> Self {
        WireSizes {
            smashed_per_sample: (smashed_size * 4) as u64,
            label: 4,
            client_model: (client_params * 4) as u64,
            aux_model: (aux_params * 4) as u64,
        }
    }
}

/// Table II closed forms: total bytes for ONE GLOBAL EPOCH (every client
/// walks its |D_i| local samples once; one aggregation).
///
/// Smashed-data terms follow the paper (`q` already includes whatever the
/// paper counts per sample; we add labels explicitly since the pipeline
/// sends them).
pub mod table2 {
    use super::WireSizes;

    /// FSL_MC (SplitFed, multi-copy): 2·n·q·|D| smashed+grad, 2·n·α|w|
    /// model exchange.
    pub fn fsl_mc(n: u64, d_i: u64, w: &WireSizes) -> u64 {
        let smashed = n * d_i * (w.smashed_per_sample + w.label);
        let grads = n * d_i * w.smashed_per_sample;
        let models = 2 * n * w.client_model;
        smashed + grads + models
    }

    /// FSL_OC: identical wire profile to FSL_MC (single server copy only
    /// changes storage, not traffic).
    pub fn fsl_oc(n: u64, d_i: u64, w: &WireSizes) -> u64 {
        fsl_mc(n, d_i, w)
    }

    /// FSL_AN: n·q·|D| upstream only, no grad downlink, plus aux nets in
    /// the model exchange: 2·n·α(|w|+|a|).
    pub fn fsl_an(n: u64, d_i: u64, w: &WireSizes) -> u64 {
        let smashed = n * d_i * (w.smashed_per_sample + w.label);
        let models = 2 * n * (w.client_model + w.aux_model);
        smashed + models
    }

    /// CSE_FSL_h: smashed upstream divided by h.
    pub fn cse_fsl(n: u64, d_i: u64, h: u64, w: &WireSizes) -> u64 {
        let smashed = n * (d_i / h) * (w.smashed_per_sample + w.label);
        let models = 2 * n * (w.client_model + w.aux_model);
        smashed + models
    }
}

/// Table II "server storage" closed form, generalized to the sharded
/// server phase's k copies.
///
/// Wire traffic is shard-independent (the same messages flow whichever
/// copy serves them — checked by `tests/comm_properties.rs`), so the
/// shard knob moves **storage only**: `copies × |w_s|` parameters
/// resident server-side.
pub mod storage {
    /// Parameters of `copies` resident server-side partial models:
    /// `copies × |w_s|`. Reduces to the paper's Table II server-storage
    /// column at both endpoints — `1 × |w_s|` (FSL_OC / CSE_FSL) and
    /// `n × |w_s|` (FSL_MC / FSL_AN) — and interpolates linearly along
    /// the shard axis in between. The live counterpart is
    /// `ServerState::resident_params`.
    ///
    /// ```
    /// use cse_fsl::comm::accounting::storage;
    ///
    /// let ws = 960_970u64; // paper CIFAR-10 server-side model
    /// assert_eq!(storage::server_copies_params(1, ws), ws); // OC / CSE (k=1)
    /// assert_eq!(storage::server_copies_params(5, ws), 5 * ws); // MC / AN (n=5)
    /// // each extra shard copy costs exactly one more server model
    /// assert_eq!(
    ///     storage::server_copies_params(3, ws) - storage::server_copies_params(2, ws),
    ///     ws
    /// );
    /// ```
    pub fn server_copies_params(copies: u64, server_model_params: u64) -> u64 {
        copies * server_model_params
    }
}

/// Generalized closed forms for a FULL RUN at full participation —
/// `rounds` communication rounds with an aggregation every `agg_every`
/// rounds. The per-epoch Table II forms are the special case
/// `rounds = (|D_i|/batch)/h`, `agg_every = rounds` (asserted by
/// `tests/comm_properties.rs`); the property suite checks the live
/// `CommLedger` against these for random configurations.
///
/// # Example: reproducing a Table II epoch form
///
/// One global epoch of CSE_FSL_h is `(|D_i|/batch)/h` communication
/// rounds with a single aggregation; the generalized run totals then
/// reduce exactly to [`table2::cse_fsl`]:
///
/// ```
/// use cse_fsl::comm::accounting::{predict, table2, WireSizes};
/// use cse_fsl::comm::compress::Compression;
///
/// let w = WireSizes::new(2304, 107_328, 23_050); // paper CIFAR-10 sizes
/// let (n, batch, h, rounds) = (5u64, 50u64, 5u64, 8u64);
/// let d_i = batch * h * rounds; // |D_i|: samples walked once per epoch
/// let p = predict::TrafficProfile::AuxLocal;
/// let (up, down) = predict::run_totals(p, Compression::None, n, batch, rounds, rounds, &w);
/// assert_eq!(up + down, table2::cse_fsl(n, d_i, h, &w));
/// ```
pub mod predict {
    use super::{MsgKind, WireSizes};
    use crate::comm::compress::Compression;

    /// The wire-relevant projection of a method spec (decoupled from
    /// `coordinator::methods::MethodSpec` so `comm` stays a leaf
    /// module; build one via `MethodSpec::traffic`). Of the spec axes
    /// only the **client-update rule** (here) and the **compression
    /// codec** (passed alongside) move bytes: the upload schedule
    /// changes how many rounds an epoch takes (never bytes per round —
    /// each round is one smashed upload whatever h is), and the server
    /// topology moves storage only.
    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    pub enum TrafficProfile {
        /// Server returns cut-layer gradients per batch; no aux nets in
        /// the model exchange (the SplitFed rule — FSL_MC / FSL_OC).
        ServerGrad,
        /// No gradient downlink; client aux nets ride along with model
        /// aggregation (the local-update rule — FSL_AN / CSE_FSL).
        AuxLocal,
        /// Gradient-estimator rule (FSL-SAGE): aux nets ride along with
        /// model aggregation like [`TrafficProfile::AuxLocal`], but every
        /// `align_every`-th round additionally triggers a true-gradient
        /// downlink used to re-align the estimator. The gradient-downlink
        /// term reduces **exactly** to [`TrafficProfile::ServerGrad`]'s at
        /// `align_every = 1` and vanishes once `align_every > rounds`, at
        /// which point the whole profile equals
        /// [`TrafficProfile::AuxLocal`]'s byte totals.
        SageEstimate {
            /// Alignment period in rounds (>= 1).
            align_every: u64,
        },
    }

    /// Expected bytes per message kind over a whole run, full
    /// participation of `n` clients with per-upload batch size `batch`.
    ///
    /// The compression codec `c` applies to the lossy tensor messages
    /// only — each round's smashed upload and (under the server-grad
    /// rule) the matching gradient download. Labels and model
    /// aggregation exchanges always cross the wire at full precision.
    /// The per-message wire size is [`Compression::wire_bytes`] on the
    /// `batch × smashed_elems` tensor — the very function the live
    /// trainer records into its ledger, so measured and predicted bytes
    /// agree exactly (`tests/comm_properties.rs`).
    pub fn run_kind_bytes(
        p: TrafficProfile,
        c: Compression,
        n: u64,
        batch: u64,
        rounds: u64,
        agg_every: u64,
        w: &WireSizes,
    ) -> Vec<(MsgKind, u64)> {
        let aggs = rounds / agg_every;
        // smashed_per_sample is bytes of f32s (4 bytes each); the codec
        // works in elements of the per-upload batch tensor.
        let smashed_elems = batch * (w.smashed_per_sample / 4);
        let smashed_wire = c.wire_bytes(smashed_elems);
        let mut out = vec![
            (MsgKind::SmashedUpload, rounds * n * smashed_wire),
            (MsgKind::LabelUpload, rounds * n * batch * w.label),
            (
                MsgKind::GradDownload,
                match p {
                    TrafficProfile::ServerGrad => rounds * n * smashed_wire,
                    TrafficProfile::AuxLocal => 0,
                    // One alignment downlink every align_every-th round:
                    // rounds/align_every of them, each the same codec-wired
                    // smashed tensor the per-batch rule sends. align_every=1
                    // is exactly the ServerGrad term; align_every > rounds
                    // is exactly the AuxLocal (zero) term.
                    TrafficProfile::SageEstimate { align_every } => {
                        (rounds / align_every) * n * smashed_wire
                    }
                },
            ),
            (MsgKind::ClientModelUpload, aggs * n * w.client_model),
            (MsgKind::ClientModelDownload, aggs * n * w.client_model),
        ];
        match p {
            TrafficProfile::AuxLocal | TrafficProfile::SageEstimate { .. } => {
                out.push((MsgKind::AuxModelUpload, aggs * n * w.aux_model));
                out.push((MsgKind::AuxModelDownload, aggs * n * w.aux_model));
            }
            TrafficProfile::ServerGrad => {
                out.push((MsgKind::AuxModelUpload, 0));
                out.push((MsgKind::AuxModelDownload, 0));
            }
        }
        out
    }

    /// Realized per-kind message counts of a finished (possibly churned)
    /// run. The full-participation closed form [`run_kind_bytes`] fixes
    /// these a priori (`rounds * n` uploads, …); under churn the cohort
    /// that actually uploads varies per round, so the prediction is
    /// instead parameterized by the counts the run realized — every
    /// *byte* stays a closed-form function of them, which is what
    /// `tests/churn_properties.rs` pins against the live ledger.
    #[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
    pub struct RealizedCounts {
        /// Complete smashed uploads served (each carries its labels).
        pub uploads_ok: u64,
        /// Mid-round deaths after a partial upload: half the smashed
        /// wire bytes crossed, no labels ([`ChurnConfig::fail_rate`]).
        ///
        /// [`ChurnConfig::fail_rate`]: crate::sim::churn::ChurnConfig
        pub partial_uploads: u64,
        /// Cut-layer gradient downloads served.
        pub grad_downloads: u64,
        /// Client-model uploads received across all aggregations.
        pub model_uploads: u64,
        /// Aggregated-model downloads sent across all aggregations.
        pub model_downloads: u64,
    }

    impl RealizedCounts {
        /// Read the realized counts back out of a run's ledger.
        /// `partial_failures` is the trainer's churn-stat count of
        /// mid-round deaths (partial uploads share the `SmashedUpload`
        /// kind with complete ones, so the ledger alone cannot split
        /// them).
        pub fn from_ledger(ledger: &super::CommLedger, partial_failures: u64) -> Self {
            RealizedCounts {
                uploads_ok: ledger.count_of(MsgKind::SmashedUpload) - partial_failures,
                partial_uploads: partial_failures,
                grad_downloads: ledger.count_of(MsgKind::GradDownload),
                model_uploads: ledger.count_of(MsgKind::ClientModelUpload),
                model_downloads: ledger.count_of(MsgKind::ClientModelDownload),
            }
        }

        /// The counts a full-participation, failure-free run realizes —
        /// under which [`realized_kind_bytes`] reduces exactly to
        /// [`run_kind_bytes`] (pinned by a unit test below).
        pub fn full(p: TrafficProfile, n: u64, rounds: u64, agg_every: u64) -> Self {
            let aggs = rounds / agg_every;
            RealizedCounts {
                uploads_ok: rounds * n,
                partial_uploads: 0,
                grad_downloads: match p {
                    TrafficProfile::ServerGrad => rounds * n,
                    TrafficProfile::AuxLocal => 0,
                    TrafficProfile::SageEstimate { align_every } => {
                        (rounds / align_every) * n
                    }
                },
                model_uploads: aggs * n,
                model_downloads: aggs * n,
            }
        }
    }

    /// Expected bytes per message kind given the cohort/failure counts a
    /// run actually realized — the churn-proof form of
    /// [`run_kind_bytes`]. Per-message wire sizes are identical to the a
    /// priori form (codec-wired smashed tensors, full-precision labels
    /// and model exchanges); a partial upload crosses exactly
    /// `wire / 2` bytes (integer division — the same expression the live
    /// trainer ledgers) and carries no labels. Aux-net riders follow the
    /// model-exchange counts under the aux-local profiles and are zero
    /// under the server-grad rule, exactly as on the live wire.
    pub fn realized_kind_bytes(
        p: TrafficProfile,
        c: Compression,
        batch: u64,
        w: &WireSizes,
        r: &RealizedCounts,
    ) -> Vec<(MsgKind, u64)> {
        let smashed_elems = batch * (w.smashed_per_sample / 4);
        let smashed_wire = c.wire_bytes(smashed_elems);
        let aux = match p {
            TrafficProfile::ServerGrad => 0,
            TrafficProfile::AuxLocal | TrafficProfile::SageEstimate { .. } => 1,
        };
        vec![
            (
                MsgKind::SmashedUpload,
                r.uploads_ok * smashed_wire + r.partial_uploads * (smashed_wire / 2),
            ),
            (MsgKind::LabelUpload, r.uploads_ok * batch * w.label),
            (MsgKind::GradDownload, r.grad_downloads * smashed_wire),
            (MsgKind::ClientModelUpload, r.model_uploads * w.client_model),
            (MsgKind::ClientModelDownload, r.model_downloads * w.client_model),
            (MsgKind::AuxModelUpload, aux * r.model_uploads * w.aux_model),
            (MsgKind::AuxModelDownload, aux * r.model_downloads * w.aux_model),
        ]
    }

    /// (uplink, downlink) byte totals for a whole run.
    pub fn run_totals(
        p: TrafficProfile,
        c: Compression,
        n: u64,
        batch: u64,
        rounds: u64,
        agg_every: u64,
        w: &WireSizes,
    ) -> (u64, u64) {
        let mut up = 0;
        let mut down = 0;
        for (kind, bytes) in run_kind_bytes(p, c, n, batch, rounds, agg_every, w) {
            match kind.dir() {
                super::Dir::Up => up += bytes,
                super::Dir::Down => down += bytes,
            }
        }
        (up, down)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wires() -> WireSizes {
        WireSizes::new(2304, 107_328, 23_050)
    }

    #[test]
    fn merge_equals_single_ledger() {
        let mut whole = CommLedger::new();
        let mut a = CommLedger::new();
        let mut b = CommLedger::new();
        for (ledger_pair, client, kind, bytes) in [
            (0, 0usize, MsgKind::SmashedUpload, 100u64),
            (0, 0, MsgKind::LabelUpload, 4),
            (1, 1, MsgKind::SmashedUpload, 100),
            (1, 0, MsgKind::GradDownload, 64),
        ] {
            whole.record(client, kind, bytes);
            if ledger_pair == 0 {
                a.record(client, kind, bytes);
            } else {
                b.record(client, kind, bytes);
            }
        }
        let mut merged = CommLedger::new();
        merged.merge(&a);
        merged.merge(&b);
        assert_eq!(merged, whole);
        assert_eq!(merged.client_kind_bytes(0, MsgKind::SmashedUpload), 100);
        assert_eq!(merged.client_kind_bytes(1, MsgKind::SmashedUpload), 100);
        assert_eq!(merged.clients(), vec![0, 1]);
    }

    #[test]
    fn per_kind_views_are_conserved() {
        let mut l = CommLedger::new();
        l.record(0, MsgKind::SmashedUpload, 10);
        l.record(2, MsgKind::SmashedUpload, 30);
        l.record(2, MsgKind::GradDownload, 7);
        for kind in MsgKind::ALL {
            let client_sum: u64 =
                l.clients().iter().map(|&c| l.client_kind_bytes(c, kind)).sum();
            assert_eq!(client_sum, l.bytes_of(kind), "{kind:?}");
        }
        for c in l.clients() {
            let kind_sum: u64 =
                MsgKind::ALL.iter().map(|&k| l.client_kind_bytes(c, k)).sum();
            assert_eq!(kind_sum, l.client_bytes(c));
        }
    }

    #[test]
    fn record_bulk_matches_n_records_in_the_server_view() {
        // The population broadcast path: one bulk record must equal n
        // individual records in every server-side total...
        let mut bulk = CommLedger::new();
        bulk.record_bulk(MsgKind::ClientModelDownload, 1000, 64);
        let mut loop_ledger = CommLedger::new();
        for c in 0..1000 {
            loop_ledger.record(c, MsgKind::ClientModelDownload, 64);
        }
        assert_eq!(bulk.bytes_of(MsgKind::ClientModelDownload), 64_000);
        assert_eq!(bulk.count_of(MsgKind::ClientModelDownload), 1000);
        assert_eq!(bulk.bytes_of(MsgKind::ClientModelDownload), loop_ledger.bytes_of(MsgKind::ClientModelDownload));
        assert_eq!(bulk.count_of(MsgKind::ClientModelDownload), loop_ledger.count_of(MsgKind::ClientModelDownload));
        assert_eq!(bulk.down_bytes(), loop_ledger.down_bytes());
        // ...while leaving the per-client view untouched (that is the
        // point: O(1) memory per broadcast).
        assert!(bulk.clients().is_empty());
        assert_eq!(bulk.client_bytes(3), 0);
        // Bulk entries merge like any others.
        let mut merged = CommLedger::new();
        merged.merge(&bulk);
        merged.merge(&bulk);
        assert_eq!(merged.count_of(MsgKind::ClientModelDownload), 2000);
    }

    #[test]
    fn predict_reduces_to_table2_epoch_forms() {
        use crate::comm::compress::Compression;
        let w = wires();
        let (n, batch) = (5u64, 50u64);
        // One epoch of CSE_FSL_h: |D_i| = batch*h*rounds, one aggregation.
        for h in [1u64, 5, 10] {
            let rounds = 8;
            let d_i = batch * h * rounds;
            let p = predict::TrafficProfile::AuxLocal;
            let (up, down) =
                predict::run_totals(p, Compression::None, n, batch, rounds, rounds, &w);
            assert_eq!(up + down, table2::cse_fsl(n, d_i, h, &w), "h={h}");
        }
        // One epoch of FSL_MC: h=1, rounds = |D_i|/batch.
        let rounds = 12;
        let d_i = batch * rounds;
        let p = predict::TrafficProfile::ServerGrad;
        let (up, down) =
            predict::run_totals(p, Compression::None, n, batch, rounds, rounds, &w);
        assert_eq!(up + down, table2::fsl_mc(n, d_i, &w));
        // One epoch of FSL_AN: no grad downlink, aux rides along.
        let p = predict::TrafficProfile::AuxLocal;
        let (up, down) =
            predict::run_totals(p, Compression::None, n, batch, rounds, rounds, &w);
        assert_eq!(up + down, table2::fsl_an(n, d_i, &w));
    }

    #[test]
    fn predict_compressed_forms_touch_only_lossy_tensor_kinds() {
        use crate::comm::compress::Compression;
        let w = wires();
        let (n, batch, rounds, agg_every) = (5u64, 50u64, 12u64, 4u64);
        for p in [
            predict::TrafficProfile::ServerGrad,
            predict::TrafficProfile::AuxLocal,
            predict::TrafficProfile::SageEstimate { align_every: 3 },
        ] {
            let base: std::collections::BTreeMap<_, _> =
                predict::run_kind_bytes(p, Compression::None, n, batch, rounds, agg_every, &w)
                    .into_iter()
                    .collect();
            for c in [
                Compression::Quantize { bits: 4 },
                Compression::Quantize { bits: 8 },
                Compression::TopK { frac: 0.25 },
            ] {
                let smashed_elems = batch * (w.smashed_per_sample / 4);
                let wire = c.wire_bytes(smashed_elems);
                let got: std::collections::BTreeMap<_, _> =
                    predict::run_kind_bytes(p, c, n, batch, rounds, agg_every, &w)
                        .into_iter()
                        .collect();
                for (kind, &bytes) in &got {
                    match kind {
                        MsgKind::SmashedUpload => {
                            assert_eq!(bytes, rounds * n * wire, "{p:?} {c}")
                        }
                        MsgKind::GradDownload => {
                            let want = match p {
                                predict::TrafficProfile::ServerGrad => rounds * n * wire,
                                predict::TrafficProfile::AuxLocal => 0,
                                predict::TrafficProfile::SageEstimate { align_every } => {
                                    (rounds / align_every) * n * wire
                                }
                            };
                            assert_eq!(bytes, want, "{p:?} {c}");
                        }
                        // Labels and model exchanges are never compressed.
                        other => assert_eq!(bytes, base[other], "{p:?} {c} {other:?}"),
                    }
                }
                // Compressed smashed traffic is strictly below full precision.
                assert!(wire < Compression::None.wire_bytes(smashed_elems), "{c}");
            }
        }
    }

    #[test]
    fn sage_profile_reduces_to_both_neighbours() {
        use crate::comm::compress::Compression;
        let w = wires();
        let (n, batch, rounds, agg_every) = (5u64, 50u64, 12u64, 4u64);
        for c in [
            Compression::None,
            Compression::Quantize { bits: 4 },
            Compression::TopK { frac: 0.25 },
        ] {
            // align_every = 1: byte-for-byte the ServerGrad gradient
            // downlink, plus AuxLocal's aux-aggregation riders.
            let sage1: std::collections::BTreeMap<_, _> = predict::run_kind_bytes(
                predict::TrafficProfile::SageEstimate { align_every: 1 },
                c, n, batch, rounds, agg_every, &w,
            )
            .into_iter()
            .collect();
            let grad: std::collections::BTreeMap<_, _> = predict::run_kind_bytes(
                predict::TrafficProfile::ServerGrad,
                c, n, batch, rounds, agg_every, &w,
            )
            .into_iter()
            .collect();
            let aux: std::collections::BTreeMap<_, _> = predict::run_kind_bytes(
                predict::TrafficProfile::AuxLocal,
                c, n, batch, rounds, agg_every, &w,
            )
            .into_iter()
            .collect();
            assert_eq!(
                sage1[&MsgKind::GradDownload],
                grad[&MsgKind::GradDownload],
                "{c}"
            );
            for k in [MsgKind::AuxModelUpload, MsgKind::AuxModelDownload] {
                assert_eq!(sage1[&k], aux[&k], "{c} {k:?}");
            }
            // align_every > rounds: the whole profile IS AuxLocal.
            let sage_inf = predict::run_kind_bytes(
                predict::TrafficProfile::SageEstimate { align_every: rounds + 1 },
                c, n, batch, rounds, agg_every, &w,
            );
            let aux_vec = predict::run_kind_bytes(
                predict::TrafficProfile::AuxLocal,
                c, n, batch, rounds, agg_every, &w,
            );
            assert_eq!(sage_inf, aux_vec, "{c}");
            // In between, the downlink is monotone non-increasing in the
            // alignment period and strictly between the two neighbours.
            let mut last = u64::MAX;
            for a in 1..=rounds + 1 {
                let (_, down) = predict::run_totals(
                    predict::TrafficProfile::SageEstimate { align_every: a },
                    c, n, batch, rounds, agg_every, &w,
                );
                assert!(down <= last, "a={a} {c}");
                last = down;
            }
        }
    }

    #[test]
    fn realized_counts_reduce_to_the_full_participation_form() {
        use crate::comm::compress::Compression;
        let w = wires();
        let (n, batch, rounds, agg_every) = (5u64, 50u64, 12u64, 4u64);
        for p in [
            predict::TrafficProfile::ServerGrad,
            predict::TrafficProfile::AuxLocal,
            predict::TrafficProfile::SageEstimate { align_every: 3 },
        ] {
            for c in [
                Compression::None,
                Compression::Quantize { bits: 4 },
                Compression::TopK { frac: 0.25 },
            ] {
                let full = predict::RealizedCounts::full(p, n, rounds, agg_every);
                assert_eq!(
                    predict::realized_kind_bytes(p, c, batch, &w, &full),
                    predict::run_kind_bytes(p, c, n, batch, rounds, agg_every, &w),
                    "{p:?} {c}"
                );
            }
        }
    }

    #[test]
    fn partial_uploads_cost_half_the_wire_and_no_labels() {
        use crate::comm::compress::Compression;
        let w = wires();
        let batch = 50u64;
        let p = predict::TrafficProfile::AuxLocal;
        for c in [Compression::None, Compression::Quantize { bits: 4 }] {
            let smashed_wire = c.wire_bytes(batch * (w.smashed_per_sample / 4));
            let base = predict::RealizedCounts {
                uploads_ok: 40,
                partial_uploads: 0,
                grad_downloads: 0,
                model_uploads: 10,
                model_downloads: 10,
            };
            let churned = predict::RealizedCounts { partial_uploads: 3, ..base };
            let b: std::collections::BTreeMap<_, _> =
                predict::realized_kind_bytes(p, c, batch, &w, &base).into_iter().collect();
            let ch: std::collections::BTreeMap<_, _> =
                predict::realized_kind_bytes(p, c, batch, &w, &churned)
                    .into_iter()
                    .collect();
            // Each death adds exactly half a smashed wire message...
            assert_eq!(
                ch[&MsgKind::SmashedUpload] - b[&MsgKind::SmashedUpload],
                3 * (smashed_wire / 2),
                "{c}"
            );
            // ...and nothing else: labels ride only with complete uploads.
            for k in MsgKind::ALL {
                if k != MsgKind::SmashedUpload {
                    assert_eq!(ch[&k], b[&k], "{c} {k:?}");
                }
            }
        }
    }

    #[test]
    fn realized_counts_read_back_from_a_ledger() {
        let mut l = CommLedger::new();
        for _ in 0..4 {
            l.record(0, MsgKind::SmashedUpload, 100);
        }
        l.record(1, MsgKind::SmashedUpload, 50); // the partial one
        l.record(0, MsgKind::GradDownload, 100);
        l.record(0, MsgKind::ClientModelUpload, 8);
        l.record_bulk(MsgKind::ClientModelDownload, 3, 8);
        let r = predict::RealizedCounts::from_ledger(&l, 1);
        assert_eq!(
            r,
            predict::RealizedCounts {
                uploads_ok: 4,
                partial_uploads: 1,
                grad_downloads: 1,
                model_uploads: 1,
                model_downloads: 3,
            }
        );
    }

    #[test]
    fn ledger_sums_directions() {
        let mut l = CommLedger::new();
        l.record(0, MsgKind::SmashedUpload, 100);
        l.record(0, MsgKind::LabelUpload, 4);
        l.record(1, MsgKind::GradDownload, 50);
        l.record(1, MsgKind::ClientModelDownload, 10);
        assert_eq!(l.up_bytes(), 104);
        assert_eq!(l.down_bytes(), 60);
        assert_eq!(l.total_bytes(), 164);
        assert_eq!(l.client_bytes(0), 104);
        assert_eq!(l.client_bytes(1), 60);
        assert_eq!(l.count_of(MsgKind::SmashedUpload), 1);
        assert_eq!(l.breakdown().len(), 4);
    }

    #[test]
    fn cse_reduces_smashed_by_h() {
        let w = wires();
        let (n, d) = (5, 1000);
        let h1 = table2::cse_fsl(n, d, 1, &w);
        let h10 = table2::cse_fsl(n, d, 10, &w);
        // model-exchange term is constant; smashed term shrinks 10x
        let model_term = 2 * n * (w.client_model + w.aux_model);
        assert_eq!((h1 - model_term), (h10 - model_term) * 10);
    }

    #[test]
    fn ordering_matches_paper_table2() {
        // paper: CSE_FSL_h < FSL_AN < FSL_MC for h>1 and |a| << q|D|
        let w = wires();
        let (n, d) = (5, 10_000);
        let mc = table2::fsl_mc(n, d, &w);
        let oc = table2::fsl_oc(n, d, &w);
        let an = table2::fsl_an(n, d, &w);
        let cse5 = table2::cse_fsl(n, d, 5, &w);
        assert_eq!(mc, oc);
        assert!(an < mc, "AN {an} !< MC {mc}");
        assert!(cse5 < an, "CSE {cse5} !< AN {an}");
        // MC ≈ 2x AN minus aux overhead
        assert!((mc as f64) / (an as f64) > 1.8);
    }

    #[test]
    fn storage_closed_form_endpoints() {
        let ws = 960_970u64;
        // Table II endpoints and linear interpolation along k.
        assert_eq!(storage::server_copies_params(1, ws), ws);
        assert_eq!(storage::server_copies_params(5, ws), 5 * ws);
        for k in 1..5 {
            assert_eq!(
                storage::server_copies_params(k + 1, ws)
                    - storage::server_copies_params(k, ws),
                ws
            );
        }
    }

    #[test]
    fn table5_scale_sanity() {
        // Paper Table V: FSL_MC on CIFAR-10 = 172.46 GB over 200 epochs
        // (n=5, |D_i|=10k). Our closed form with labels included should
        // land in the same ballpark (same order, within ~15%).
        let w = wires();
        let total_200 = 200.0 * table2::fsl_mc(5, 10_000, &w) as f64 / 1e9;
        assert!(
            (140.0..230.0).contains(&total_200),
            "200-epoch FSL_MC total {total_200} GB out of family vs paper 172.46"
        );
    }
}
