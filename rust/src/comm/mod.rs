//! Communication accounting (measured ledger + Table II closed forms).

pub mod accounting;
