//! Communication accounting (measured ledger + Table II closed forms)
//! and lossy wire compression.

pub mod accounting;
pub mod compress;
