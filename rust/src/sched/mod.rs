//! Cost-aware scheduling for the deterministic parallel round engine.
//!
//! The coordinator fans per-client (and per-shard-executor) work out
//! over scoped worker threads. *Which worker runs which item when* is
//! this module's job; *what the run computes* never depends on it —
//! results are always merged back in canonical item order, so every
//! [`SchedPolicy`] produces bit-identical output and only wall-clock
//! changes (the determinism contract of `coordinator::round`, enforced
//! by `tests/determinism_golden.rs`).
//!
//! Three pieces:
//!
//! * [`policy`] — [`SchedPolicy`] (round-robin / cost-weighted /
//!   work-stealing), the [`lpt`] longest-processing-time bin packer it
//!   shares with `ShardMap::balanced`, and the greedy makespan bound
//!   the property suite checks against.
//! * [`cost`] — per-client cost estimates: a prior from the persistent
//!   [`ClientProfile`](crate::sim::netmodel::ClientProfile)
//!   (compute + uplink closed form) blended with an EWMA of the spans
//!   the client actually produced in earlier rounds ([`CostTracker`]).
//! * [`mod@fanout`] — the [`fanout()`] executor: static dealing for the
//!   two static policies, and an atomic-index queue over
//!   cost-descending items for [`SchedPolicy::WorkStealing`].
//!
//! # Example
//!
//! ```
//! use cse_fsl::sched::{fanout, lpt, SchedPolicy};
//!
//! // Two heavy items (8.0) among six light ones (1.0): LPT puts the
//! // heavy pair in different bins...
//! let costs = [8.0, 1.0, 1.0, 1.0, 8.0, 1.0, 1.0, 1.0];
//! let bins = lpt(&costs, 2);
//! assert_ne!(bins[0].contains(&0), bins[0].contains(&4));
//!
//! // ...and whatever the policy, fan-out results come back in
//! // canonical item order (the bit-determinism contract).
//! let items: Vec<usize> = (0..8).collect();
//! let out = fanout(SchedPolicy::WorkStealing, 2, items, &costs, |_pos, x| {
//!     Ok::<_, String>(x * 10)
//! })
//! .unwrap();
//! assert_eq!(out, vec![0, 10, 20, 30, 40, 50, 60, 70]);
//! ```

pub mod cost;
pub mod fanout;
pub mod policy;

pub use cost::{profile_cost, CostTracker};
pub use fanout::{fanout, FanoutFailure};
pub use policy::{greedy_bound, lpt, sanitize_costs, SchedPolicy};
