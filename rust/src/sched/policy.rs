//! Work-dealing policies and the LPT bin packer they share with the
//! load-balanced `ShardMap`.

/// Work-dealing policy of the deterministic parallel fan-out.
///
/// The policy decides only *which worker runs which item when* — results
/// are always merged in canonical item order, so every policy produces
/// bit-identical output (the determinism contract of
/// `coordinator::round`); only wall-clock changes. Like
/// `coordinator::config::Parallelism`, the policy is therefore excluded
/// from the experiment cache key (`exp::common::RunSpec::key`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Deal item `i` to worker `i mod workers` — the historical dealing.
    /// Ignores costs; can stack several heavy items on one worker.
    #[default]
    RoundRobin,
    /// LPT bin packing on the cost estimates ([`lpt`]): heaviest item
    /// first into the least-loaded worker. Static like `RoundRobin`, but
    /// balanced when costs are heterogeneous *and the estimates are
    /// good*.
    CostWeighted,
    /// Dynamic: workers claim the next item from a shared atomic-index
    /// queue over the items pre-sorted cost-descending. Balances even
    /// when cost estimates are wrong, at one atomic increment (plus one
    /// mutex handoff) per item.
    WorkStealing,
}

impl SchedPolicy {
    /// Every policy, in the order benches and sweeps report them.
    pub const ALL: [SchedPolicy; 3] =
        [SchedPolicy::RoundRobin, SchedPolicy::CostWeighted, SchedPolicy::WorkStealing];
}

impl std::fmt::Display for SchedPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            SchedPolicy::RoundRobin => "rr",
            SchedPolicy::CostWeighted => "cost",
            SchedPolicy::WorkStealing => "steal",
        };
        write!(f, "{s}")
    }
}

impl std::str::FromStr for SchedPolicy {
    type Err = String;

    /// `rr` / `roundrobin` / `round-robin`; `cost` / `costweighted` /
    /// `cost-weighted`; `steal` / `worksteal` / `workstealing` /
    /// `work-stealing`.
    fn from_str(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "rr" | "roundrobin" | "round-robin" => Ok(SchedPolicy::RoundRobin),
            "cost" | "costweighted" | "cost-weighted" => Ok(SchedPolicy::CostWeighted),
            "steal" | "worksteal" | "workstealing" | "work-stealing" => {
                Ok(SchedPolicy::WorkStealing)
            }
            other => Err(format!("bad sched policy {other:?} (expected rr | cost | steal)")),
        }
    }
}

/// Replace non-finite or non-positive costs with the mean of the
/// positive ones (or 1.0 when there are none), so degenerate estimates
/// cannot produce empty LPT bins or a useless claim order.
pub fn sanitize_costs(costs: &[f64]) -> Vec<f64> {
    let mut sum = 0.0;
    let mut count = 0usize;
    for &c in costs {
        if c.is_finite() && c > 0.0 {
            sum += c;
            count += 1;
        }
    }
    let fallback = if count > 0 { sum / count as f64 } else { 1.0 };
    costs
        .iter()
        .map(|&c| if c.is_finite() && c > 0.0 { c } else { fallback })
        .collect()
}

/// Longest-processing-time (LPT) bin packing: items sorted
/// cost-descending (ties broken by ascending index) are greedily placed
/// into the currently least-loaded bin (ties broken by ascending bin
/// index). Returns one ascending-sorted index list per bin.
///
/// Deterministic in `(costs, bins)` — which is what lets both
/// [`SchedPolicy::CostWeighted`] dealing and `ShardMap::balanced` use
/// it without touching any randomness or the bit-determinism contract.
/// Callers with untrusted costs should [`sanitize_costs`] first: with
/// all-zero costs every item ties into bin 0.
pub fn lpt(costs: &[f64], bins: usize) -> Vec<Vec<usize>> {
    assert!(bins >= 1, "lpt needs at least one bin");
    let mut order: Vec<usize> = (0..costs.len()).collect();
    order.sort_by(|&a, &b| costs[b].total_cmp(&costs[a]).then(a.cmp(&b)));
    let mut out: Vec<Vec<usize>> = (0..bins).map(|_| Vec::new()).collect();
    let mut loads = vec![0.0f64; bins];
    for idx in order {
        let mut best = 0;
        for (b, &load) in loads.iter().enumerate() {
            if load < loads[best] {
                best = b;
            }
        }
        out[best].push(idx);
        loads[best] += costs[idx];
    }
    for bin in &mut out {
        bin.sort_unstable();
    }
    out
}

/// The greedy list-scheduling makespan bound: any greedy placement
/// (LPT included) has `max bin load <= total/bins + (1 - 1/bins) * max
/// cost`. The scheduling property suite checks [`lpt`]'s output against
/// it.
pub fn greedy_bound(costs: &[f64], bins: usize) -> f64 {
    assert!(bins >= 1, "greedy_bound needs at least one bin");
    let total: f64 = costs.iter().sum();
    let cmax = costs.iter().copied().fold(0.0f64, f64::max);
    total / bins as f64 + (1.0 - 1.0 / bins as f64) * cmax
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::str::FromStr;

    #[test]
    fn parse_display_roundtrip() {
        for p in SchedPolicy::ALL {
            assert_eq!(SchedPolicy::from_str(&p.to_string()), Ok(p));
        }
        assert_eq!(SchedPolicy::from_str("round-robin"), Ok(SchedPolicy::RoundRobin));
        assert_eq!(SchedPolicy::from_str("WorkStealing"), Ok(SchedPolicy::WorkStealing));
        assert_eq!(SchedPolicy::from_str("cost-weighted"), Ok(SchedPolicy::CostWeighted));
        assert!(SchedPolicy::from_str("sideways").is_err());
        assert_eq!(SchedPolicy::default(), SchedPolicy::RoundRobin);
    }

    #[test]
    fn lpt_spreads_heavy_items() {
        // Two heavy items must land in different bins.
        let costs = [8.0, 1.0, 1.0, 1.0, 9.0];
        let bins = lpt(&costs, 2);
        assert_eq!(bins.len(), 2);
        let bin_of = |i: usize| bins.iter().position(|b| b.contains(&i)).unwrap();
        assert_ne!(bin_of(0), bin_of(4));
        // Every item exactly once.
        let mut all: Vec<usize> = bins.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3, 4]);
        // Bins come back ascending.
        for b in &bins {
            assert!(b.windows(2).all(|w| w[0] < w[1]));
        }
        // Max load respects the greedy bound.
        let load = |b: &Vec<usize>| b.iter().map(|&i| costs[i]).sum::<f64>();
        let max_load = bins.iter().map(load).fold(0.0f64, f64::max);
        assert!(max_load <= greedy_bound(&costs, 2) + 1e-12, "{max_load}");
    }

    #[test]
    fn lpt_uniform_costs_balance_counts() {
        let costs = vec![1.0; 10];
        let bins = lpt(&costs, 3);
        let sizes: Vec<usize> = bins.iter().map(|b| b.len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 10);
        assert!(sizes.iter().all(|&s| s == 3 || s == 4), "{sizes:?}");
    }

    #[test]
    fn lpt_more_bins_than_items_leaves_empties() {
        let bins = lpt(&[2.0, 1.0], 4);
        assert_eq!(bins.iter().filter(|b| !b.is_empty()).count(), 2);
        assert!(lpt(&[], 2).iter().all(|b| b.is_empty()));
    }

    #[test]
    fn sanitize_replaces_degenerate_costs() {
        let s = sanitize_costs(&[2.0, 0.0, f64::NAN, 4.0, -1.0]);
        assert_eq!(s[0], 2.0);
        assert_eq!(s[3], 4.0);
        // Degenerates become the mean of the positives (3.0).
        assert_eq!(s[1], 3.0);
        assert_eq!(s[2], 3.0);
        assert_eq!(s[4], 3.0);
        // No positives at all: everything becomes 1.0 (so LPT still
        // spreads items over bins instead of stacking bin 0).
        assert_eq!(sanitize_costs(&[0.0, 0.0]), vec![1.0, 1.0]);
        assert!(sanitize_costs(&[]).is_empty());
    }
}
