//! The policy-driven fan-out executor.
//!
//! One function, [`fanout`], runs `work(position, item)` once per item
//! over scoped worker threads and returns the results **in item order**
//! — the canonical merge order of the deterministic parallel engine.
//! The [`SchedPolicy`] only decides which worker runs which item when;
//! nothing about the dealing can leak into the results because every
//! result lands in its position-indexed slot and the merge walks slots
//! in canonical order.
//!
//! Error contract: a worker stops taking new work after its first
//! error; the merge reports the error at the smallest canonical
//! position among the items actually attempted. With the static
//! policies every earlier-position item in the failing worker's bucket
//! was attempted first, so this is exactly sequential error reporting;
//! under [`SchedPolicy::WorkStealing`] the attempted set can depend on
//! timing when *several* items fail, but some failing item is always
//! reported and the caller discards the run either way.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Mutex};

use super::policy::{lpt, sanitize_costs, SchedPolicy};

/// Why a fan-out did not return a full result set.
#[derive(Debug)]
pub enum FanoutFailure<E> {
    /// The work closure failed; this is the error at the smallest
    /// canonical position among the attempted items.
    Work(E),
    /// A worker dropped a result without reporting an error. Defensive:
    /// unreachable with the shipped policies.
    Lost,
}

/// Run `work(position, item)` once per item, dealt to (at most)
/// `workers` scoped threads according to `policy`, and return the
/// results in item order.
///
/// `costs` are per-item cost estimates (same length as `items`, or
/// empty for uniform). Only the cost-aware policies read them, and only
/// to steer dealing — any estimates, even wildly wrong ones, yield the
/// same results. `workers <= 1` runs the reference sequential loop with
/// no thread machinery at all.
pub fn fanout<I, T, E, F>(
    policy: SchedPolicy,
    workers: usize,
    items: Vec<I>,
    costs: &[f64],
    work: F,
) -> Result<Vec<T>, FanoutFailure<E>>
where
    I: Send,
    T: Send,
    E: Send,
    F: Fn(usize, I) -> Result<T, E> + Sync,
{
    let n = items.len();
    assert!(
        costs.is_empty() || costs.len() == n,
        "cost vector length {} != item count {n}",
        costs.len()
    );
    let workers = workers.clamp(1, n.max(1));
    if workers <= 1 {
        let mut out = Vec::with_capacity(n);
        for (pos, item) in items.into_iter().enumerate() {
            match work(pos, item) {
                Ok(v) => out.push(v),
                Err(e) => return Err(FanoutFailure::Work(e)),
            }
        }
        return Ok(out);
    }
    let slots = match policy {
        SchedPolicy::WorkStealing => run_stealing(workers, items, costs, &work),
        SchedPolicy::RoundRobin | SchedPolicy::CostWeighted => {
            run_static(policy, workers, items, costs, &work)
        }
    };
    merge(slots)
}

/// Positions each worker owns under a static policy, each bucket
/// ascending (workers process their bucket in canonical order, which is
/// what makes the error contract sequential-exact for these policies).
fn static_buckets(policy: SchedPolicy, workers: usize, costs: &[f64], n: usize) -> Vec<Vec<usize>> {
    match policy {
        SchedPolicy::RoundRobin => {
            let mut buckets: Vec<Vec<usize>> = (0..workers).map(|_| Vec::new()).collect();
            for pos in 0..n {
                buckets[pos % workers].push(pos);
            }
            buckets
        }
        SchedPolicy::CostWeighted => {
            let c = if costs.is_empty() { vec![1.0; n] } else { sanitize_costs(costs) };
            lpt(&c, workers)
        }
        SchedPolicy::WorkStealing => unreachable!("work stealing has no static buckets"),
    }
}

fn run_static<I, T, E, F>(
    policy: SchedPolicy,
    workers: usize,
    items: Vec<I>,
    costs: &[f64],
    work: &F,
) -> Vec<Option<Result<T, E>>>
where
    I: Send,
    T: Send,
    E: Send,
    F: Fn(usize, I) -> Result<T, E> + Sync,
{
    let n = items.len();
    let dealing = static_buckets(policy, workers, costs, n);
    // Move each item into the bucket that owns its position.
    let mut cells: Vec<Option<I>> = items.into_iter().map(Some).collect();
    let mut buckets: Vec<Vec<(usize, I)>> = Vec::with_capacity(dealing.len());
    for positions in dealing {
        let mut bucket = Vec::with_capacity(positions.len());
        for pos in positions {
            bucket.push((pos, cells[pos].take().expect("position dealt twice")));
        }
        buckets.push(bucket);
    }
    std::thread::scope(|scope| {
        let (tx, rx) = mpsc::channel::<(usize, Result<T, E>)>();
        for bucket in buckets {
            let tx = tx.clone();
            scope.spawn(move || {
                for (pos, item) in bucket {
                    let result = work(pos, item);
                    let failed = result.is_err();
                    if tx.send((pos, result)).is_err() || failed {
                        break;
                    }
                }
            });
        }
        drop(tx);
        let mut slots: Vec<Option<Result<T, E>>> = (0..n).map(|_| None).collect();
        for (pos, result) in rx {
            slots[pos] = Some(result);
        }
        slots
    })
}

fn run_stealing<I, T, E, F>(
    workers: usize,
    items: Vec<I>,
    costs: &[f64],
    work: &F,
) -> Vec<Option<Result<T, E>>>
where
    I: Send,
    T: Send,
    E: Send,
    F: Fn(usize, I) -> Result<T, E> + Sync,
{
    let n = items.len();
    let c = if costs.is_empty() { vec![1.0; n] } else { sanitize_costs(costs) };
    // Claim order: cost-descending (heavy items first, so no worker is
    // left finishing a giant item alone at the end), ties by ascending
    // canonical position.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| c[b].total_cmp(&c[a]).then(a.cmp(&b)));
    // Each position's item is claimed exactly once (the atomic cursor
    // hands every order index to exactly one worker); the mutex is just
    // the safe ownership handoff for that single take.
    let cells: Vec<Mutex<Option<I>>> = items.into_iter().map(|i| Mutex::new(Some(i))).collect();
    let cursor = AtomicUsize::new(0);
    let failed = AtomicBool::new(false);
    let (order, cells, cursor, failed) = (&order, &cells, &cursor, &failed);
    std::thread::scope(|scope| {
        let (tx, rx) = mpsc::channel::<(usize, Result<T, E>)>();
        for _ in 0..workers {
            let tx = tx.clone();
            scope.spawn(move || loop {
                if failed.load(Ordering::Relaxed) {
                    break;
                }
                let k = cursor.fetch_add(1, Ordering::Relaxed);
                if k >= order.len() {
                    break;
                }
                let pos = order[k];
                let item = cells[pos]
                    .lock()
                    .expect("fanout cell poisoned")
                    .take()
                    .expect("item claimed twice");
                let result = work(pos, item);
                let stop = result.is_err();
                if stop {
                    failed.store(true, Ordering::Relaxed);
                }
                if tx.send((pos, result)).is_err() || stop {
                    break;
                }
            });
        }
        drop(tx);
        let mut slots: Vec<Option<Result<T, E>>> = (0..n).map(|_| None).collect();
        for (pos, result) in rx {
            slots[pos] = Some(result);
        }
        slots
    })
}

/// Walk slots in canonical order: the first error wins; a missing slot
/// with no error anywhere is [`FanoutFailure::Lost`].
fn merge<T, E>(mut slots: Vec<Option<Result<T, E>>>) -> Result<Vec<T>, FanoutFailure<E>> {
    let mut out = Vec::with_capacity(slots.len());
    let mut lost = false;
    for slot in slots.iter_mut() {
        match slot.take() {
            Some(Ok(v)) => out.push(v),
            Some(Err(e)) => return Err(FanoutFailure::Work(e)),
            None => lost = true,
        }
    }
    if lost {
        return Err(FanoutFailure::Lost);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn double(pos: usize, x: usize) -> Result<usize, String> {
        assert_eq!(pos, x, "work sees its canonical position");
        Ok(x * 2)
    }

    #[test]
    fn all_policies_return_canonical_order() {
        let costs: Vec<f64> = (0..13).map(|i| ((i * 7) % 5) as f64 + 0.5).collect();
        for policy in SchedPolicy::ALL {
            for workers in [1usize, 2, 3, 8, 32] {
                let items: Vec<usize> = (0..13).collect();
                let out = fanout(policy, workers, items, &costs, double).unwrap();
                assert_eq!(out, (0..13).map(|x| x * 2).collect::<Vec<_>>(), "{policy} w={workers}");
            }
        }
    }

    #[test]
    fn empty_and_uniform_costs_accepted() {
        for policy in SchedPolicy::ALL {
            let out = fanout(policy, 4, (0..6).collect::<Vec<usize>>(), &[], double).unwrap();
            assert_eq!(out.len(), 6);
            let out = fanout(policy, 4, Vec::<usize>::new(), &[], double).unwrap();
            assert!(out.is_empty());
        }
    }

    #[test]
    fn errors_propagate_for_every_policy() {
        for policy in SchedPolicy::ALL {
            let items: Vec<usize> = (0..9).collect();
            let r = fanout(policy, 3, items, &[], |_pos, x: usize| {
                if x == 4 {
                    Err(format!("boom {x}"))
                } else {
                    Ok(x)
                }
            });
            match r {
                Err(FanoutFailure::Work(e)) => assert_eq!(e, "boom 4", "{policy}"),
                other => panic!("{policy}: expected Work error, got {other:?}"),
            }
        }
    }

    #[test]
    fn static_error_reporting_is_sequential_exact() {
        // Positions 2 and 5 both fail; the smallest canonical failing
        // position must be reported for the static policies (each worker
        // walks its bucket ascending).
        for policy in [SchedPolicy::RoundRobin, SchedPolicy::CostWeighted] {
            let items: Vec<usize> = (0..8).collect();
            let r = fanout(policy, 3, items, &[], |_pos, x: usize| {
                if x == 2 || x == 5 {
                    Err(x)
                } else {
                    Ok(x)
                }
            });
            match r {
                Err(FanoutFailure::Work(e)) => assert_eq!(e, 2, "{policy}"),
                other => panic!("{policy}: expected Work(2), got {other:?}"),
            }
        }
    }

    #[test]
    #[should_panic(expected = "cost vector length")]
    fn mismatched_costs_panic() {
        let _ = fanout(SchedPolicy::CostWeighted, 2, vec![1usize, 2], &[1.0], double);
    }

    #[test]
    fn round_robin_buckets_match_modulo() {
        let b = static_buckets(SchedPolicy::RoundRobin, 3, &[], 7);
        assert_eq!(b, vec![vec![0, 3, 6], vec![1, 4], vec![2, 5]]);
    }
}
