//! Per-client cost estimates feeding the cost-aware policies.
//!
//! Estimates only steer *dealing*; they never touch results (the
//! bit-determinism contract), so they can be cheap and approximate. The
//! prior is a closed form over the client's persistent
//! [`ClientProfile`]; once a client has actually run, an exponentially
//! weighted moving average of its measured per-round span total takes
//! over ([`CostTracker`]) — "last-round timeline spans" in the
//! scheduling docs.

use crate::sim::netmodel::ClientProfile;

/// EWMA weight of the newest observation (0.5 reacts within a couple of
/// rounds while smoothing per-round jitter). Public so the population
/// engine's sparse tracker (`coordinator::population::SparseCosts`)
/// blends with the identical weight.
pub const EWMA_ALPHA: f64 = 0.5;

/// Predicted simulated cost (seconds) of one client round from the
/// persistent profile alone: `h` local batches of compute plus one
/// smashed+label upload of `payload_bytes`. Deliberately jitter-free —
/// the scheduler wants the expectation, not a sample (and must not
/// consume any random stream).
pub fn profile_cost(profile: &ClientProfile, h: usize, payload_bytes: u64) -> f64 {
    profile.batch_time * h.max(1) as f64
        + profile.rtt
        + payload_bytes as f64 / profile.up_bps
}

/// Exponentially weighted moving average of measured per-client round
/// costs, seeded from the [`profile_cost`] priors.
///
/// The trainer calls [`CostTracker::observe`] with each participant's
/// measured span total after every round (in canonical merge order, so
/// the tracker state is as deterministic as everything else), and
/// [`CostTracker::estimate`] when dealing the next round's work.
#[derive(Clone, Debug)]
pub struct CostTracker {
    est: Vec<f64>,
}

impl CostTracker {
    /// Start from per-client priors (index = client id).
    pub fn new(priors: Vec<f64>) -> Self {
        CostTracker { est: priors }
    }

    /// Number of tracked clients.
    pub fn len(&self) -> usize {
        self.est.len()
    }

    /// Whether the tracker tracks no clients.
    pub fn is_empty(&self) -> bool {
        self.est.is_empty()
    }

    /// Current cost estimate for `client`.
    pub fn estimate(&self, client: usize) -> f64 {
        self.est[client]
    }

    /// Fold one measured round cost into `client`'s estimate. Non-finite
    /// or negative measurements are ignored (a skipped round is not
    /// evidence the client got faster).
    pub fn observe(&mut self, client: usize, measured: f64) {
        if measured.is_finite() && measured >= 0.0 {
            let e = &mut self.est[client];
            *e = (1.0 - EWMA_ALPHA) * *e + EWMA_ALPHA * measured;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::netmodel::NetModel;
    use crate::util::prng::Rng;

    fn profile() -> ClientProfile {
        NetModel::homogeneous().sample_profile(&mut Rng::new(1))
    }

    #[test]
    fn profile_cost_closed_form() {
        let p = profile();
        let c = profile_cost(&p, 3, 1_000_000);
        let expect = p.batch_time * 3.0 + p.rtt + 1_000_000.0 / p.up_bps;
        assert!((c - expect).abs() < 1e-12, "{c} vs {expect}");
        // h = 0 is treated as one batch (a participant always does work).
        assert_eq!(profile_cost(&p, 0, 0), profile_cost(&p, 1, 0));
        // More batches cost more; bigger payloads cost more.
        assert!(profile_cost(&p, 5, 0) > profile_cost(&p, 1, 0));
        assert!(profile_cost(&p, 1, 1 << 20) > profile_cost(&p, 1, 1 << 10));
    }

    #[test]
    fn tracker_converges_toward_observations() {
        let mut t = CostTracker::new(vec![1.0, 10.0]);
        assert_eq!(t.len(), 2);
        assert!(!t.is_empty());
        for _ in 0..16 {
            t.observe(0, 4.0);
        }
        assert!((t.estimate(0) - 4.0).abs() < 1e-3, "{}", t.estimate(0));
        // Untouched clients keep their prior.
        assert_eq!(t.estimate(1), 10.0);
        // Degenerate observations are ignored.
        t.observe(1, f64::NAN);
        t.observe(1, -3.0);
        assert_eq!(t.estimate(1), 10.0);
    }
}
