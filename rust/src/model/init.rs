//! Parameter initialization from layout init specs.
//!
//! The Rust binary is self-contained after `make artifacts`: parameters
//! are initialized here (He-normal convs / Glorot heads / zero biases, as
//! recorded per-tensor by `python/compile/models.py`), not shipped from
//! Python. Each model part gets its own derived RNG stream so client i's
//! init is independent of client count and ordering.

use crate::util::prng::Rng;

use super::layout::{InitSpec, Layout};

/// Initialize a flat parameter vector for `layout`.
pub fn init_flat(layout: &Layout, rng: &mut Rng) -> Vec<f32> {
    let mut out = vec![0f32; layout.total];
    for t in &layout.tensors {
        match t.init {
            InitSpec::Zero => {}
            InitSpec::Normal { std } => {
                for v in &mut out[t.offset..t.offset + t.size] {
                    *v = rng.normal_ms(0.0, std) as f32;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn layout() -> Layout {
        Layout::from_json(
            &Json::parse(
                r#"[
              {"name":"w","shape":[1000],"offset":0,"size":1000,
               "init":{"kind":"normal","std":0.1}},
              {"name":"b","shape":[10],"offset":1000,"size":10,
               "init":{"kind":"zero"}}
            ]"#,
            )
            .unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn zero_tensors_zero_normal_tensors_scaled() {
        let mut rng = Rng::new(1);
        let p = init_flat(&layout(), &mut rng);
        assert_eq!(p.len(), 1010);
        assert!(p[1000..].iter().all(|&v| v == 0.0));
        let mean = p[..1000].iter().sum::<f32>() / 1000.0;
        let var = p[..1000].iter().map(|v| (v - mean).powi(2)).sum::<f32>() / 1000.0;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var.sqrt() - 0.1).abs() < 0.02, "{}", var.sqrt());
    }

    #[test]
    fn deterministic_per_stream() {
        let a = init_flat(&layout(), &mut Rng::new(2));
        let b = init_flat(&layout(), &mut Rng::new(2));
        let c = init_flat(&layout(), &mut Rng::new(3));
        assert_eq!(a, b);
        assert_ne!(a, c);
    }
}
