//! FedAvg aggregation over flat parameter vectors (paper Eq. (14)).
//!
//! The server aggregates client-side models and auxiliary networks after
//! every C batches: x^{t+1} = (1/n) Σ_i x_i^{t+1}. Weighted variants are
//! provided for partial participation with unequal shard sizes, and an
//! in-place accumulator (`Accumulator`) keeps the hot aggregation loop
//! allocation-free.

/// Uniform FedAvg: mean of equally-weighted parameter vectors.
pub fn fedavg(models: &[&[f32]]) -> Vec<f32> {
    assert!(!models.is_empty(), "fedavg of zero models");
    let n = models[0].len();
    assert!(models.iter().all(|m| m.len() == n), "length mismatch");
    let mut out = vec![0f32; n];
    let inv = 1.0 / models.len() as f32;
    for m in models {
        for (o, &v) in out.iter_mut().zip(m.iter()) {
            *o += v * inv;
        }
    }
    out
}

/// Weighted FedAvg with per-model weights (normalized internally).
pub fn fedavg_weighted(models: &[&[f32]], weights: &[f64]) -> Vec<f32> {
    assert_eq!(models.len(), weights.len());
    assert!(!models.is_empty());
    let total: f64 = weights.iter().sum();
    assert!(total > 0.0, "zero total weight");
    let n = models[0].len();
    let mut out = vec![0f32; n];
    for (m, &w) in models.iter().zip(weights) {
        assert_eq!(m.len(), n);
        let scale = (w / total) as f32;
        for (o, &v) in out.iter_mut().zip(m.iter()) {
            *o += v * scale;
        }
    }
    out
}

/// Streaming accumulator: clients can be folded in as they arrive
/// (asynchronous aggregation) without holding all vectors alive.
#[derive(Clone, Debug)]
pub struct Accumulator {
    sum: Vec<f64>,
    weight: f64,
}

impl Accumulator {
    /// An empty accumulator for vectors of length `len`.
    pub fn new(len: usize) -> Self {
        Accumulator { sum: vec![0f64; len], weight: 0.0 }
    }

    /// Length of the accumulated vectors.
    pub fn len(&self) -> usize {
        self.sum.len()
    }

    /// Whether no contributions have been folded in.
    pub fn is_empty(&self) -> bool {
        self.weight == 0.0
    }

    /// Total weight folded in so far.
    pub fn count_weight(&self) -> f64 {
        self.weight
    }

    /// Fold one model in with the given positive weight.
    pub fn add(&mut self, model: &[f32], weight: f64) {
        assert_eq!(model.len(), self.sum.len());
        assert!(weight > 0.0);
        for (s, &v) in self.sum.iter_mut().zip(model) {
            *s += v as f64 * weight;
        }
        self.weight += weight;
    }

    /// Finalize into `out` (len must match) and reset the accumulator.
    pub fn finish_into(&mut self, out: &mut [f32]) {
        assert!(self.weight > 0.0, "finish with no contributions");
        assert_eq!(out.len(), self.sum.len());
        let inv = 1.0 / self.weight;
        for (o, s) in out.iter_mut().zip(self.sum.iter()) {
            *o = (*s * inv) as f32;
        }
        self.reset();
    }

    /// Drop all contributions (ready for the next aggregation window).
    pub fn reset(&mut self) {
        self.sum.iter_mut().for_each(|s| *s = 0.0);
        self.weight = 0.0;
    }
}

/// L2 norm of a parameter vector (used for convergence traces).
pub fn l2_norm(v: &[f32]) -> f64 {
    v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// Max |a-b| — convergence/equality diagnostics in tests.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::{prng::Rng, prop};

    #[test]
    fn fedavg_mean() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [3.0f32, 2.0, 1.0];
        assert_eq!(fedavg(&[&a, &b]), vec![2.0, 2.0, 2.0]);
    }

    #[test]
    fn single_model_identity() {
        let a = [0.5f32, -1.5];
        assert_eq!(fedavg(&[&a]), a.to_vec());
    }

    #[test]
    fn weighted_matches_uniform_when_equal() {
        let a = [1.0f32, 0.0];
        let b = [0.0f32, 1.0];
        let u = fedavg(&[&a, &b]);
        let w = fedavg_weighted(&[&a, &b], &[5.0, 5.0]);
        assert_eq!(u, w);
        let skew = fedavg_weighted(&[&a, &b], &[3.0, 1.0]);
        assert!((skew[0] - 0.75).abs() < 1e-6);
    }

    #[test]
    fn accumulator_streaming_equals_batch() {
        prop::check("accumulator == fedavg_weighted", |rng| {
            let n = 1 + rng.below(64) as usize;
            let k = 1 + rng.below(6) as usize;
            let models: Vec<Vec<f32>> = (0..k)
                .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
                .collect();
            let weights: Vec<f64> = (0..k).map(|_| rng.uniform() + 0.1).collect();
            let refs: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
            let batch = fedavg_weighted(&refs, &weights);
            let mut acc = Accumulator::new(n);
            for (m, &w) in models.iter().zip(&weights) {
                acc.add(m, w);
            }
            let mut out = vec![0f32; n];
            acc.finish_into(&mut out);
            prop_assert!(
                max_abs_diff(&batch, &out) < 1e-5,
                "diff {}",
                max_abs_diff(&batch, &out)
            );
            prop_assert!(acc.is_empty(), "accumulator not reset");
            Ok(())
        });
    }

    #[test]
    fn fedavg_idempotent_on_identical_models() {
        prop::check("fedavg(x,x,..) == x", |rng| {
            let n = 1 + rng.below(128) as usize;
            let m: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let k = 1 + rng.below(5) as usize;
            let refs: Vec<&[f32]> = (0..k).map(|_| m.as_slice()).collect();
            let avg = fedavg(&refs);
            prop_assert!(max_abs_diff(&avg, &m) < 1e-6, "not idempotent");
            Ok(())
        });
    }

    #[test]
    fn fedavg_permutation_invariant() {
        prop::check("fedavg order-invariant", |rng| {
            let n = 1 + rng.below(64) as usize;
            let k = 2 + rng.below(5) as usize;
            let models: Vec<Vec<f32>> = (0..k)
                .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
                .collect();
            let mut order: Vec<usize> = (0..k).collect();
            rng.shuffle(&mut order);
            let refs1: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
            let refs2: Vec<&[f32]> = order.iter().map(|&i| models[i].as_slice()).collect();
            prop_assert!(
                max_abs_diff(&fedavg(&refs1), &fedavg(&refs2)) < 1e-5,
                "order changed result"
            );
            Ok(())
        });
    }

    #[test]
    fn norms() {
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 1.0]), 1.0);
    }

    #[test]
    #[should_panic]
    fn fedavg_empty_panics() {
        fedavg(&[]);
    }

    #[test]
    fn rng_seeded_models_average_toward_mean() {
        let mut rng = Rng::new(9);
        let models: Vec<Vec<f32>> =
            (0..32).map(|_| (0..16).map(|_| rng.normal() as f32).collect()).collect();
        let refs: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
        let avg = fedavg(&refs);
        // mean of 32 N(0,1) coords has std 1/sqrt(32) ≈ 0.18
        assert!(l2_norm(&avg) < 2.0);
    }
}
