//! Flat-parameter layout tables (the L2↔L3 ABI).
//!
//! The AOT manifest records, for each model part (client / server / aux),
//! an ordered list of tensors with shapes, offsets into the flat f32
//! vector, and init specs. Rust never needs tensor semantics — only this
//! table — to initialize, aggregate, serialize, and byte-account models.

use crate::util::json::{Json, JsonError};

/// How a tensor's parameters are initialized.
#[derive(Clone, Debug, PartialEq)]
pub enum InitSpec {
    /// All zeros (biases).
    Zero,
    /// Gaussian with the given standard deviation.
    Normal { /** Standard deviation. */ std: f64 },
}

/// One tensor's slot in a flat parameter vector.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    /// Tensor name (as the manifest records it).
    pub name: String,
    /// Logical shape.
    pub shape: Vec<usize>,
    /// Offset into the flat f32 vector.
    pub offset: usize,
    /// Element count (= product of `shape`).
    pub size: usize,
    /// Initialization spec.
    pub init: InitSpec,
}

/// Ordered tensor table of one model part (client / server / aux).
#[derive(Clone, Debug)]
pub struct Layout {
    /// Tensors in flat-vector order (contiguous, offset-checked).
    pub tensors: Vec<TensorSpec>,
    /// Total element count of the flat vector.
    pub total: usize,
}

impl Layout {
    /// Parse a manifest layout array, checking shapes against sizes and
    /// offsets against the running total.
    pub fn from_json(j: &Json) -> Result<Layout, JsonError> {
        let mut tensors = Vec::new();
        let mut total = 0usize;
        for item in j.as_arr()? {
            let name = item.get("name")?.as_str()?.to_string();
            let shape = item.get("shape")?.as_usize_vec()?;
            let offset = item.get("offset")?.as_usize()?;
            let size = item.get("size")?.as_usize()?;
            let init_j = item.get("init")?;
            let init = match init_j.get("kind")?.as_str()? {
                "zero" => InitSpec::Zero,
                "normal" => InitSpec::Normal { std: init_j.get("std")?.as_f64()? },
                other => {
                    return Err(JsonError::Access(format!("unknown init kind {other:?}")))
                }
            };
            let expect: usize = shape.iter().product();
            if expect != size {
                return Err(JsonError::Access(format!(
                    "tensor {name}: shape product {expect} != size {size}"
                )));
            }
            if offset != total {
                return Err(JsonError::Access(format!(
                    "tensor {name}: offset {offset} != running total {total}"
                )));
            }
            total += size;
            tensors.push(TensorSpec { name, shape, offset, size, init });
        }
        Ok(Layout { tensors, total })
    }

    /// Look a tensor up by name.
    pub fn tensor(&self, name: &str) -> Option<&TensorSpec> {
        self.tensors.iter().find(|t| t.name == name)
    }

    /// Bytes of one serialized parameter vector (f32).
    pub fn bytes(&self) -> u64 {
        (self.total * 4) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout_json() -> Json {
        Json::parse(
            r#"[
              {"name":"w","shape":[2,3],"offset":0,"size":6,
               "init":{"kind":"normal","std":0.5}},
              {"name":"b","shape":[3],"offset":6,"size":3,
               "init":{"kind":"zero"}}
            ]"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_and_totals() {
        let l = Layout::from_json(&layout_json()).unwrap();
        assert_eq!(l.total, 9);
        assert_eq!(l.bytes(), 36);
        assert_eq!(l.tensor("w").unwrap().shape, vec![2, 3]);
        assert_eq!(l.tensor("b").unwrap().init, InitSpec::Zero);
        assert!(l.tensor("nope").is_none());
    }

    #[test]
    fn rejects_inconsistent_offsets() {
        let j = Json::parse(
            r#"[{"name":"w","shape":[2],"offset":5,"size":2,
                 "init":{"kind":"zero"}}]"#,
        )
        .unwrap();
        assert!(Layout::from_json(&j).is_err());
    }

    #[test]
    fn rejects_shape_size_mismatch() {
        let j = Json::parse(
            r#"[{"name":"w","shape":[2,2],"offset":0,"size":3,
                 "init":{"kind":"zero"}}]"#,
        )
        .unwrap();
        assert!(Layout::from_json(&j).is_err());
    }
}
