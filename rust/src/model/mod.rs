//! Model-parameter plumbing: flat-vector [`layout`] tables (the L2↔L3
//! ABI), He/Glorot [`init`] from manifest specs, and FedAvg
//! [`aggregate`]-ion (paper Eq. (14)).

pub mod aggregate;
pub mod init;
pub mod layout;
