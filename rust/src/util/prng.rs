//! Deterministic, splittable PRNG for the whole Rust layer.
//!
//! Everything in the coordinator (data synthesis, partitioning, client
//! delays, arrival shuffles, parameter init) must be reproducible from one
//! experiment seed — the paper averages five independent runs, and Fig. 6
//! compares *exact* arrival orders, which only works with a deterministic
//! stream per component. `rand` is not available offline, so this is a
//! self-contained xoshiro256++ with SplitMix64 seeding (public-domain
//! reference algorithms by Blackman & Vigna).

/// SplitMix64 avalanche finalizer — shared by [`Rng::new`]'s seeding
/// and the property harness's sub-seed derivation (`util::prop`).
pub(crate) fn splitmix64_mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG with SplitMix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Range-shrink divisor for [`Rng::below`] (1 = off). Used by the
    /// property harness (`util::prop`) to bias generated sizes/choices
    /// toward small values when hunting a minimal counterexample.
    shrink: u64,
    /// Time-dimension shrink divisor for [`Rng::below_time`] (1 = off).
    /// Orthogonal to `shrink`: the property harness tries capping *time
    /// extents* (round counts, schedule lengths) first, so a failing
    /// trainer property replays fewer rounds before any other input is
    /// reduced.
    time_shrink: u64,
}

impl Rng {
    /// Seed from a single u64 via SplitMix64 (never yields the all-zero
    /// state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            splitmix64_mix(sm)
        };
        Rng { s: [next(), next(), next(), next()], shrink: 1, time_shrink: 1 }
    }

    /// Seed like [`Rng::new`] but cap every [`Rng::below`] range to
    /// `max(n / shrink, 1)`, biasing draws toward small sizes and
    /// first-listed choices. `shrink = 1` is exactly [`Rng::new`].
    /// Derived streams ([`Rng::split`] / [`Rng::split_str`]) do NOT
    /// inherit the cap: it shrinks the *generator* stream the property
    /// harness drives, never the simulation streams seeded from it.
    pub fn with_shrink(seed: u64, shrink: u64) -> Self {
        Rng::with_shrink_dims(seed, shrink, 1)
    }

    /// Seed like [`Rng::with_shrink`] with an additional *time*-dimension
    /// cap: [`Rng::below_time`] ranges are divided by `time_shrink`
    /// before the ordinary `shrink` cap applies. Both factors at 1 is
    /// exactly [`Rng::new`]; derived streams inherit neither cap.
    pub fn with_shrink_dims(seed: u64, shrink: u64, time_shrink: u64) -> Self {
        assert!(shrink >= 1, "shrink factor must be >= 1");
        assert!(time_shrink >= 1, "time-shrink factor must be >= 1");
        let mut r = Rng::new(seed);
        r.shrink = shrink;
        r.time_shrink = time_shrink;
        r
    }

    /// Derive an independent stream for a named subcomponent. Streams
    /// derived with different tags are (statistically) independent.
    pub fn split(&self, tag: u64) -> Rng {
        // Mix the current state with the tag through SplitMix64.
        let mix = self.s[0]
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(self.s[2].rotate_left(17))
            ^ tag.wrapping_mul(0xD134_2543_DE82_EF95);
        Rng::new(mix)
    }

    /// Derive a stream from a string label (stable across runs).
    pub fn split_str(&self, label: &str) -> Rng {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in label.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        self.split(h)
    }

    /// The next raw 64-bit output of the stream.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Uses Lemire's multiply-shift rejection.
    /// Under a shrink factor ([`Rng::with_shrink`]) the range is capped
    /// to `max(n / shrink, 1)`.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let n = if self.shrink > 1 { (n / self.shrink).max(1) } else { n };
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [0, n) for a **time-extent** draw (round
    /// counts, schedule lengths). Behaves exactly like [`Rng::below`]
    /// under [`Rng::new`]; under [`Rng::with_shrink_dims`] the range is
    /// first capped to `max(n / time_shrink, 1)`, so the property
    /// harness can hunt counterexamples that replay a shorter *time
    /// prefix* (fewer rounds) before shrinking any other input.
    pub fn below_time(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below_time(0)");
        let n = if self.time_shrink > 1 { (n / self.time_shrink).max(1) } else { n };
        self.below(n)
    }

    /// Standard normal via Box–Muller (one value per call; simple and
    /// branch-free enough for init workloads).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            return r * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Normal with the given mean and standard deviation.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang; used for Dirichlet sampling in
    /// the non-IID partitioner.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^{1/a}
            let g = self.gamma(shape + 1.0);
            let u: f64 = self.uniform().max(f64::MIN_POSITIVE);
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.uniform();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha * 1_k) sample of length k.
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let s: f64 = v.iter().sum();
        if s <= 0.0 {
            // Degenerate draw (possible for tiny alpha): fall back to a
            // one-hot on a uniform coordinate.
            let mut out = vec![0.0; k];
            out[self.below(k as u64) as usize] = 1.0;
            return out;
        }
        for x in &mut v {
            *x /= s;
        }
        v
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical: zero total weight");
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }

    /// Sample `k` distinct indices from 0..n (partial Fisher–Yates).
    ///
    /// Implemented sparsely — a hash map of displaced slots instead of a
    /// materialized `0..n` vector — so memory is O(k) regardless of `n`
    /// (the streaming population engine samples cohorts from millions of
    /// clients). The draw sequence and outputs are **bit-identical** to
    /// the dense partial Fisher–Yates this replaces (one `below(n - i)`
    /// per output; `tests` pin the equivalence), so cached results and
    /// golden schedules are unchanged.
    pub fn choose(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "choose({k}) from {n}");
        // `displaced[x]` is the value a dense Fisher–Yates array would
        // hold at slot x, for the slots that no longer hold their own
        // index; every other slot x still holds x.
        let mut displaced: std::collections::HashMap<usize, usize> =
            std::collections::HashMap::with_capacity(k.saturating_mul(2));
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            let vj = displaced.get(&j).copied().unwrap_or(j);
            let vi = displaced.get(&i).copied().unwrap_or(i);
            // swap(i, j): slot j receives slot i's value; slot i's value
            // (vj) is emitted and never read again (future draws index
            // strictly above i).
            displaced.insert(j, vi);
            out.push(vj);
        }
        out
    }

    /// Exponential with the given mean (for arrival/delay models).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u: f64 = loop {
            let u = self.uniform();
            if u > 0.0 {
                break u;
            }
        };
        -mean * u.ln()
    }

    /// Log-normal parameterized by the *target* mean and sigma of the
    /// underlying normal (used for heterogeneous client speeds).
    pub fn lognormal(&mut self, mean: f64, sigma: f64) -> f64 {
        // E[LN(mu, sigma)] = exp(mu + sigma^2/2) = mean
        let mu = mean.ln() - 0.5 * sigma * sigma;
        (mu + sigma * self.normal()).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shrink_caps_ranges_and_spares_derived_streams() {
        // shrink = 1 is byte-for-byte Rng::new.
        let mut plain = Rng::new(42);
        let mut s1 = Rng::with_shrink(42, 1);
        for _ in 0..32 {
            assert_eq!(plain.next_u64(), s1.next_u64());
        }
        // A factor caps below() draws; choices collapse toward 0.
        let mut s8 = Rng::with_shrink(7, 8);
        for _ in 0..256 {
            assert!(s8.below(100) < 13, "100/8 = 12 caps the range");
            assert_eq!(s8.below(4), 0, "4/8 -> max(0,1) = 1 forces the first choice");
        }
        // Derived streams do not inherit the cap.
        let mut child = Rng::with_shrink(7, 8).split(3);
        let mut seen_big = false;
        for _ in 0..256 {
            if child.below(100) >= 13 {
                seen_big = true;
            }
        }
        assert!(seen_big, "split streams must sample the full range");
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn split_streams_independent_and_stable() {
        let root = Rng::new(7);
        let mut a1 = root.split(1);
        let mut a2 = root.split(1);
        let mut b = root.split(2);
        assert_eq!(a1.next_u64(), a2.next_u64());
        assert_ne!(a1.next_u64(), b.next_u64());
        let mut s1 = root.split_str("data");
        let mut s2 = root.split_str("data");
        assert_eq!(s1.next_u64(), s2.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(13);
        for &shape in &[0.3, 1.0, 4.5] {
            let n = 20_000;
            let m = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!((m - shape).abs() / shape < 0.1, "shape {shape} mean {m}");
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(17);
        for &alpha in &[0.1, 1.0, 10.0] {
            let v = r.dirichlet(alpha, 8);
            assert_eq!(v.len(), 8);
            assert!((v.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(v.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn choose_distinct() {
        let mut r = Rng::new(23);
        for _ in 0..100 {
            let c = r.choose(10, 4);
            let mut s = c.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 4);
            assert!(c.iter().all(|&x| x < 10));
        }
    }

    /// The dense partial Fisher–Yates `choose` used to materialize
    /// `0..n`; the sparse rewrite must replay the identical draw
    /// sequence and outputs for every (seed, n, k).
    fn choose_dense_reference(rng: &mut Rng, n: usize, k: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + rng.below((n - i) as u64) as usize;
            v.swap(i, j);
        }
        v.truncate(k);
        v
    }

    #[test]
    fn sparse_choose_matches_dense_reference() {
        for seed in 0..32u64 {
            for &(n, k) in &[(1usize, 1usize), (10, 4), (10, 10), (97, 13), (1000, 1), (1000, 64)]
            {
                let mut a = Rng::new(seed);
                let mut b = Rng::new(seed);
                assert_eq!(
                    a.choose(n, k),
                    choose_dense_reference(&mut b, n, k),
                    "seed={seed} n={n} k={k}"
                );
                // Both consumed the same stream: subsequent draws agree.
                assert_eq!(a.next_u64(), b.next_u64(), "stream diverged at seed={seed}");
            }
        }
    }

    #[test]
    fn sparse_choose_is_memory_sparse_at_scale() {
        // k draws from a million-element domain must be instant and
        // distinct — the O(n) vector would dominate this test's runtime
        // and memory otherwise.
        let mut r = Rng::new(9);
        let c = r.choose(1_000_000, 256);
        let mut s = c.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 256);
        assert!(c.iter().all(|&x| x < 1_000_000));
    }

    #[test]
    fn time_shrink_caps_only_time_draws() {
        // No factors: below_time is exactly below.
        let mut a = Rng::new(4);
        let mut b = Rng::new(4);
        for _ in 0..64 {
            assert_eq!(a.below_time(37), b.below(37));
        }
        // A time factor caps below_time but leaves below untouched.
        let mut t8 = Rng::with_shrink_dims(7, 1, 8);
        let mut seen_big_range = false;
        for _ in 0..256 {
            assert!(t8.below_time(100) < 13, "100/8 = 12 caps the time range");
            if t8.below(100) >= 13 {
                seen_big_range = true;
            }
        }
        assert!(seen_big_range, "range draws must not inherit the time cap");
        // Both factors compose: 100/4 = 25, then 25/5 = 5.
        let mut both = Rng::with_shrink_dims(7, 5, 4);
        for _ in 0..256 {
            assert!(both.below_time(100) < 5);
        }
        // Derived streams inherit neither cap.
        let mut child = Rng::with_shrink_dims(7, 1, 8).split(3);
        let mut seen_big = false;
        for _ in 0..256 {
            if child.below_time(100) >= 13 {
                seen_big = true;
            }
        }
        assert!(seen_big, "split streams must sample the full time range");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(29);
        let w = [0.0, 3.0, 1.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[1] as f64 / counts[2] as f64;
        assert!((ratio - 3.0).abs() < 0.4, "ratio {ratio}");
    }

    #[test]
    fn lognormal_mean() {
        let mut r = Rng::new(31);
        let n = 40_000;
        let m = (0..n).map(|_| r.lognormal(2.0, 0.5)).sum::<f64>() / n as f64;
        assert!((m - 2.0).abs() < 0.1, "mean {m}");
    }
}
