//! Mini benchmarking harness (criterion is unavailable offline).
//!
//! `cargo bench` runs each bench target with `harness = false`; targets
//! build `Bench` groups with closures and get warmup, calibrated iteration
//! counts, and robust statistics (median / p10 / p90 / mean) printed in a
//! fixed-width table that EXPERIMENTS.md quotes directly.

use std::path::Path;
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// One measured statistic set, all in nanoseconds per iteration.
#[derive(Clone, Debug)]
pub struct Stats {
    /// `group/name` of the benchmark.
    pub name: String,
    /// Measured iterations.
    pub iters: u64,
    /// Mean ns per iteration.
    pub mean_ns: f64,
    /// Median ns per iteration.
    pub median_ns: f64,
    /// 10th-percentile ns per iteration.
    pub p10_ns: f64,
    /// 90th-percentile ns per iteration.
    pub p90_ns: f64,
    /// Optional user-supplied throughput denominator (items per iter).
    pub items_per_iter: Option<f64>,
}

impl Stats {
    /// Items per second, if a denominator was supplied.
    pub fn throughput_per_sec(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n * 1e9 / self.mean_ns)
    }

    /// One snapshot row (see [`write_snapshot`]).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("iters", Json::num(self.iters as f64)),
            ("mean_ns", Json::num(self.mean_ns)),
            ("median_ns", Json::num(self.median_ns)),
            ("p10_ns", Json::num(self.p10_ns)),
            ("p90_ns", Json::num(self.p90_ns)),
            (
                "items_per_iter",
                self.items_per_iter.map(Json::num).unwrap_or(Json::Null),
            ),
        ])
    }
}

/// Serialize a bench run as a `BENCH_*.json` snapshot: a stable schema
/// the perf trajectory can diff across commits. Bench targets call this
/// when `CSE_FSL_BENCH_JSON` names an output path.
pub fn snapshot_json(generated_by: &str, stats: &[Stats]) -> Json {
    Json::obj(vec![
        ("schema", Json::num(1.0)),
        ("generated_by", Json::str(generated_by)),
        ("results", Json::Arr(stats.iter().map(Stats::to_json).collect())),
    ])
}

/// Write a snapshot produced by [`snapshot_json`] to `path`.
pub fn write_snapshot(
    path: impl AsRef<Path>,
    generated_by: &str,
    stats: &[Stats],
) -> std::io::Result<()> {
    std::fs::write(path, snapshot_json(generated_by, stats).pretty())
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// A named group of benchmarks with shared settings.
pub struct Bench {
    group: String,
    warmup: Duration,
    measure: Duration,
    min_iters: u64,
    results: Vec<Stats>,
}

impl Bench {
    /// A new group with CI-friendly default warmup/measure budgets.
    pub fn new(group: &str) -> Self {
        // Keep total bench time bounded: these run in CI on one core.
        Bench {
            group: group.to_string(),
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(900),
            min_iters: 5,
            results: Vec::new(),
        }
    }

    /// Override the warmup and measurement budgets.
    pub fn with_times(mut self, warmup: Duration, measure: Duration) -> Self {
        self.warmup = warmup;
        self.measure = measure;
        self
    }

    /// Benchmark `f`, which performs ONE logical iteration per call and
    /// returns something observable (guarding against dead-code elim).
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, f: F) -> &Stats {
        self.run_with_items(name, None, f)
    }

    /// Benchmark with a throughput denominator (e.g. samples per call).
    pub fn run_with_items<T, F: FnMut() -> T>(
        &mut self,
        name: &str,
        items: Option<f64>,
        mut f: F,
    ) -> &Stats {
        // Warmup and calibration.
        let warm_start = Instant::now();
        let mut warm_iters: u64 = 0;
        while warm_start.elapsed() < self.warmup || warm_iters < 2 {
            std::hint::black_box(f());
            warm_iters += 1;
        }
        let per_iter = warm_start.elapsed().as_nanos() as f64 / warm_iters as f64;
        let target = (self.measure.as_nanos() as f64 / per_iter.max(1.0)) as u64;
        let iters = target.clamp(self.min_iters, 1_000_000);

        // Measure each iteration separately for robust percentiles.
        let mut samples = Vec::with_capacity(iters as usize);
        for _ in 0..iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let q = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
        let stats = Stats {
            name: format!("{}/{}", self.group, name),
            iters,
            mean_ns: mean,
            median_ns: q(0.5),
            p10_ns: q(0.10),
            p90_ns: q(0.90),
            items_per_iter: items,
        };
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// Print the results table for this group.
    pub fn report(&self) {
        println!("\n== bench group: {} ==", self.group);
        println!(
            "{:<48} {:>10} {:>12} {:>12} {:>12} {:>14}",
            "name", "iters", "median", "p10", "p90", "throughput"
        );
        for s in &self.results {
            let tp = s
                .throughput_per_sec()
                .map(|t| {
                    if t >= 1e6 {
                        format!("{:.2} M/s", t / 1e6)
                    } else if t >= 1e3 {
                        format!("{:.2} K/s", t / 1e3)
                    } else {
                        format!("{t:.1} /s")
                    }
                })
                .unwrap_or_else(|| "-".into());
            println!(
                "{:<48} {:>10} {:>12} {:>12} {:>12} {:>14}",
                s.name,
                s.iters,
                fmt_ns(s.median_ns),
                fmt_ns(s.p10_ns),
                fmt_ns(s.p90_ns),
                tp
            );
        }
    }

    /// All results measured in this group so far.
    pub fn results(&self) -> &[Stats] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bench::new("t").with_times(
            Duration::from_millis(5),
            Duration::from_millis(20),
        );
        let s = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(s.mean_ns > 0.0);
        assert!(s.median_ns <= s.p90_ns);
        assert!(s.p10_ns <= s.median_ns);
        assert!(s.iters >= 5);
    }

    #[test]
    fn throughput_math() {
        let s = Stats {
            name: "x".into(),
            iters: 1,
            mean_ns: 1e9,
            median_ns: 1e9,
            p10_ns: 1e9,
            p90_ns: 1e9,
            items_per_iter: Some(50.0),
        };
        assert!((s.throughput_per_sec().unwrap() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn snapshot_schema_roundtrips() {
        let s = Stats {
            name: "g/row".into(),
            iters: 7,
            mean_ns: 2e6,
            median_ns: 1.5e6,
            p10_ns: 1e6,
            p90_ns: 3e6,
            items_per_iter: Some(64.0),
        };
        let j = snapshot_json("bench_test", &[s]);
        let parsed = Json::parse(&j.pretty()).unwrap();
        assert_eq!(parsed.get("schema").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(parsed.get("generated_by").unwrap().as_str().unwrap(), "bench_test");
        let rows = parsed.get("results").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("name").unwrap().as_str().unwrap(), "g/row");
        assert_eq!(rows[0].get("median_ns").unwrap().as_f64().unwrap(), 1.5e6);
        assert_eq!(rows[0].get("items_per_iter").unwrap().as_f64().unwrap(), 64.0);
        // No denominator serializes as null, not 0.
        let none = Stats { items_per_iter: None, ..rows_src() };
        let j = snapshot_json("x", &[none]);
        assert!(j.pretty().contains("\"items_per_iter\": null"));
    }

    fn rows_src() -> Stats {
        Stats {
            name: "g/row".into(),
            iters: 1,
            mean_ns: 1.0,
            median_ns: 1.0,
            p10_ns: 1.0,
            p90_ns: 1.0,
            items_per_iter: Some(1.0),
        }
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
