//! CSV writing for experiment curves (accuracy-vs-round, loss traces).
//!
//! Every figure driver dumps its series as CSV next to the printed table
//! so curves can be re-plotted without re-running training.

use std::io::Write;
use std::path::Path;

/// A simple in-memory CSV table with a fixed header.
#[derive(Clone, Debug)]
pub struct Csv {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Csv {
    /// An empty table with the given column header.
    pub fn new(header: &[&str]) -> Self {
        Csv { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append one row (width must match the header).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.to_vec());
    }

    /// Convenience: format every cell with Display.
    pub fn row_display(&mut self, cells: &[&dyn std::fmt::Display]) {
        let v: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&v);
    }

    /// Number of data rows (header excluded).
    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    fn escape(cell: &str) -> String {
        if cell.contains([',', '"', '\n']) {
            format!("\"{}\"", cell.replace('"', "\"\""))
        } else {
            cell.to_string()
        }
    }

    /// Serialize to `path`, creating parent directories.
    pub fn write_to(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_string().as_bytes())
    }
}

// `to_string()` comes from the blanket ToString impl.
impl std::fmt::Display for Csv {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let line = |f: &mut std::fmt::Formatter<'_>, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    f.write_str(",")?;
                }
                f.write_str(&Self::escape(c))?;
            }
            f.write_str("\n")
        };
        line(f, &self.header)?;
        for row in &self.rows {
            line(f, row)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let mut c = Csv::new(&["round", "acc"]);
        c.row(&["1".into(), "0.5".into()]);
        c.row_display(&[&2, &0.75]);
        let s = c.to_string();
        assert_eq!(s, "round,acc\n1,0.5\n2,0.75\n");
        assert_eq!(c.n_rows(), 2);
    }

    #[test]
    fn escaping() {
        let mut c = Csv::new(&["a"]);
        c.row(&["x,y".into()]);
        c.row(&["q\"q".into()]);
        let s = c.to_string();
        assert!(s.contains("\"x,y\""));
        assert!(s.contains("\"q\"\"q\""));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn width_mismatch_panics() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(&["only-one".into()]);
    }

    #[test]
    fn writes_file() {
        let mut c = Csv::new(&["x"]);
        c.row(&["1".into()]);
        let dir = std::env::temp_dir().join("cse_fsl_csv_test");
        let path = dir.join("t.csv");
        c.write_to(&path).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "x\n1\n");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
