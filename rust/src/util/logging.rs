//! Leveled stderr logging with per-run verbosity (log crate facade is
//! vendored but a full env_logger is not; this is the thin subset the
//! coordinator needs: timestamped, leveled, globally toggled).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

/// Log severity, ordered from most to least severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    /// Unrecoverable problems.
    Error = 0,
    /// Suspicious but non-fatal conditions.
    Warn = 1,
    /// Run-level progress (the default).
    Info = 2,
    /// Per-round detail.
    Debug = 3,
    /// Per-message detail.
    Trace = 4,
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

/// Set the global maximum level (messages above it are dropped).
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Parse a level name (unknown names fall back to Info).
pub fn level_from_str(s: &str) -> Level {
    match s.to_ascii_lowercase().as_str() {
        "error" => Level::Error,
        "warn" => Level::Warn,
        "debug" => Level::Debug,
        "trace" => Level::Trace,
        _ => Level::Info,
    }
}

/// Whether messages at `level` currently pass the global filter.
pub fn enabled(level: Level) -> bool {
    (level as u8) <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Emit one timestamped message (prefer the `log_*!` macros).
pub fn log(level: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t0 = START.get_or_init(Instant::now);
    let secs = t0.elapsed().as_secs_f64();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{secs:9.3}s {tag} {module}] {msg}");
}

/// Log at Info level with `format!` arguments.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)+) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info, module_path!(), format_args!($($arg)+))
    };
}

/// Log at Warn level with `format!` arguments.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)+) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)+))
    };
}

/// Log at Debug level with `format!` arguments.
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)+) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)+))
    };
}

/// Log at Error level with `format!` arguments.
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)+) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Error, module_path!(), format_args!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_and_parsing() {
        assert!(Level::Error < Level::Trace);
        assert_eq!(level_from_str("debug"), Level::Debug);
        assert_eq!(level_from_str("bogus"), Level::Info);
    }

    #[test]
    fn enabled_respects_max() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
