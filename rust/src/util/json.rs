//! Minimal self-contained JSON parser/serializer.
//!
//! The AOT manifest (`artifacts/manifest.json`), experiment configs, and
//! result records all cross the Python/Rust boundary as JSON. `serde` is
//! not available offline, so this module implements the subset of JSON we
//! need — which is all of it, minus exotic number formats: objects,
//! arrays, strings (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are kept in a BTreeMap so serialized
/// output is deterministic (stable diffs in EXPERIMENTS.md).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys for deterministic output).
    Obj(BTreeMap<String, Json>),
}

/// Anything that can go wrong parsing or accessing JSON.
#[derive(Debug)]
pub enum JsonError {
    /// Malformed input at a byte position.
    Parse {
        /// Byte offset of the failure.
        pos: usize,
        /// What went wrong.
        msg: String,
    },
    /// A typed accessor was used on the wrong shape of value.
    Access(String),
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Parse { pos, msg } => {
                write!(f, "json parse error at byte {pos}: {msg}")
            }
            JsonError::Access(msg) => write!(f, "json access error: {msg}"),
        }
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ------------------------------------------------------- accessors

    /// Required object field (error on missing key or non-object).
    pub fn get(&self, key: &str) -> Result<&Json, JsonError> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| JsonError::Access(format!("missing key {key:?}"))),
            _ => Err(JsonError::Access(format!("not an object (key {key:?})"))),
        }
    }

    /// Optional object field (None on missing key or non-object).
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The value as an object map.
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>, JsonError> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(JsonError::Access("not an object".into())),
        }
    }

    /// The value as an array slice.
    pub fn as_arr(&self) -> Result<&[Json], JsonError> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(JsonError::Access("not an array".into())),
        }
    }

    /// The value as a number.
    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => Err(JsonError::Access("not a number".into())),
        }
    }

    /// The value as a non-negative integer.
    pub fn as_usize(&self) -> Result<usize, JsonError> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 || x > u64::MAX as f64 {
            return Err(JsonError::Access(format!("not a usize: {x}")));
        }
        Ok(x as usize)
    }

    /// The value as a string.
    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(JsonError::Access("not a string".into())),
        }
    }

    /// The value as a boolean.
    pub fn as_bool(&self) -> Result<bool, JsonError> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(JsonError::Access("not a bool".into())),
        }
    }

    /// `[1,2,3]` -> Vec<usize>; convenience for shape fields.
    pub fn as_usize_vec(&self) -> Result<Vec<usize>, JsonError> {
        self.as_arr()?.iter().map(|j| j.as_usize()).collect()
    }

    // ----------------------------------------------------- construction

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build a number.
    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    /// Build a string.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Build an array.
    pub fn arr(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }

    // ------------------------------------------------------------ parse

    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -------------------------------------------------------- serialize

    /// Compact serialization.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 1-space indent (matches python's
    /// `json.dump(..., indent=1)` well enough for diffing).
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(1), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..(n * depth) {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() && x.fract() == 0.0 && x.abs() < 1e15 {
        let _ = fmt::Write::write_fmt(out, format_args!("{}", x as i64));
    } else if x.is_finite() {
        let _ = fmt::Write::write_fmt(out, format_args!("{x}"));
    } else {
        // JSON has no Inf/NaN; emit null like python's allow_nan=False
        // alternatives would. Callers should avoid non-finite values.
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError::Parse { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected {:?}", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // Handle surrogate pairs.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let combined = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                            self.pos -= 1; // compensated below
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 char (input is &str, so valid).
                    let rest = &self.b[self.pos..];
                    let n = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..n])
                        .map_err(|_| self.err("bad utf8"))?;
                    s.push_str(chunk);
                    self.pos += n;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b0: u8) -> usize {
    match b0 {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.5", "1e-3", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            let v2 = Json::parse(&v.dump()).unwrap();
            assert_eq!(v, v2, "{s}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn parse_real_manifest_shapes() {
        let v = Json::parse(
            r#"{"shape": [50, 32, 32, 3], "dtype": "float32", "size": 107328}"#,
        )
        .unwrap();
        assert_eq!(v.get("shape").unwrap().as_usize_vec().unwrap(), vec![50, 32, 32, 3]);
        assert_eq!(v.get("size").unwrap().as_usize().unwrap(), 107_328);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""aéb""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "aéb");
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn rejects_garbage() {
        for s in ["{", "[1,", "tru", "\"", "{\"a\" 1}", "1 2", ""] {
            assert!(Json::parse(s).is_err(), "{s:?} should fail");
        }
    }

    #[test]
    fn pretty_and_compact_agree() {
        let v = Json::obj(vec![
            ("x", Json::num(1.0)),
            ("y", Json::arr(vec![Json::str("a"), Json::Null])),
        ]);
        assert_eq!(Json::parse(&v.pretty()).unwrap(), v);
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn integer_formatting_has_no_decimal_point() {
        assert_eq!(Json::num(5.0).dump(), "5");
        assert_eq!(Json::num(5.5).dump(), "5.5");
    }

    #[test]
    fn object_keys_sorted_deterministically() {
        let v = Json::parse(r#"{"b":1,"a":2}"#).unwrap();
        assert_eq!(v.dump(), r#"{"a":2,"b":1}"#);
    }
}
