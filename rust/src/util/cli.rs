//! Tiny declarative CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! and generated `--help`. Unknown flags are errors — experiment drivers
//! must not silently ignore typos in sweep parameters.

use std::collections::BTreeMap;

/// Anything that can go wrong parsing a command line.
#[derive(Debug)]
pub enum CliError {
    /// An option not declared on the command.
    Unknown(String),
    /// A value-taking option appeared without a value.
    MissingValue(String),
    /// A value failed to parse.
    Invalid {
        /// Option name.
        key: String,
        /// Raw offending value.
        value: String,
        /// Parse-failure reason.
        why: String,
    },
    /// More positional arguments than declared.
    UnexpectedPositional(String),
    /// A required positional argument was absent.
    MissingPositional(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Unknown(k) => write!(f, "unknown option --{k}"),
            CliError::MissingValue(k) => write!(f, "option --{k} expects a value"),
            CliError::Invalid { key, value, why } => {
                write!(f, "invalid value for --{key}: {value:?} ({why})")
            }
            CliError::UnexpectedPositional(a) => {
                write!(f, "unexpected positional argument {a:?}")
            }
            CliError::MissingPositional(p) => {
                write!(f, "missing required argument <{p}>")
            }
        }
    }
}

impl std::error::Error for CliError {}

/// Option specification.
#[derive(Clone, Debug)]
struct OptSpec {
    name: String,
    help: String,
    takes_value: bool,
    default: Option<String>,
}

/// A declarative command parser.
#[derive(Debug, Default)]
pub struct Command {
    name: String,
    about: String,
    opts: Vec<OptSpec>,
    positionals: Vec<(String, String, bool)>, // (name, help, required)
}

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    positionals: Vec<String>,
    pos_names: BTreeMap<String, usize>,
}

impl Command {
    /// A new command with the given name and one-line description.
    pub fn new(name: &str, about: &str) -> Self {
        Command { name: name.into(), about: about.into(), ..Default::default() }
    }

    /// `--key <value>` option with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.into(),
            help: help.into(),
            takes_value: true,
            default: Some(default.into()),
        });
        self
    }

    /// `--key <value>` option with no default (optional).
    pub fn opt_nodefault(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.into(),
            help: help.into(),
            takes_value: true,
            default: None,
        });
        self
    }

    /// Boolean `--flag`.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.into(),
            help: help.into(),
            takes_value: false,
            default: None,
        });
        self
    }

    /// Required positional argument.
    pub fn positional(mut self, name: &str, help: &str) -> Self {
        self.positionals.push((name.into(), help.into(), true));
        self
    }

    /// Optional positional argument.
    pub fn positional_opt(mut self, name: &str, help: &str) -> Self {
        self.positionals.push((name.into(), help.into(), false));
        self
    }

    /// Render the generated `--help` text.
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.name, self.about, self.name);
        for (p, _, req) in &self.positionals {
            if *req {
                s.push_str(&format!(" <{p}>"));
            } else {
                s.push_str(&format!(" [{p}]"));
            }
        }
        s.push_str(" [OPTIONS]\n");
        if !self.positionals.is_empty() {
            s.push_str("\nARGS:\n");
            for (p, help, _) in &self.positionals {
                s.push_str(&format!("  <{p}>  {help}\n"));
            }
        }
        if !self.opts.is_empty() {
            s.push_str("\nOPTIONS:\n");
            for o in &self.opts {
                let mut line = format!("  --{}", o.name);
                if o.takes_value {
                    line.push_str(" <v>");
                }
                if let Some(d) = &o.default {
                    line.push_str(&format!(" [default: {d}]"));
                }
                s.push_str(&format!("{line}\n      {}\n", o.help));
            }
        }
        s
    }

    /// Parse a token list (not including argv[0]).
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut args = Args::default();
        for o in &self.opts {
            if let Some(d) = &o.default {
                args.values.insert(o.name.clone(), d.clone());
            }
            if !o.takes_value {
                args.flags.insert(o.name.clone(), false);
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(rest) = tok.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| CliError::Unknown(key.clone()))?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError::MissingValue(key.clone()))?
                        }
                    };
                    args.values.insert(key, val);
                } else {
                    args.flags.insert(key, true);
                }
            } else {
                if args.positionals.len() >= self.positionals.len() {
                    return Err(CliError::UnexpectedPositional(tok.clone()));
                }
                args.positionals.push(tok.clone());
            }
            i += 1;
        }
        for (idx, (name, _, required)) in self.positionals.iter().enumerate() {
            if idx < args.positionals.len() {
                args.pos_names.insert(name.clone(), idx);
            } else if *required {
                return Err(CliError::MissingPositional(name.clone()));
            }
        }
        Ok(args)
    }
}

impl Args {
    /// The raw value of a `--key value` option (None if no default and
    /// not given).
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// Whether a boolean `--flag` was passed.
    pub fn flag(&self, key: &str) -> bool {
        self.flags.get(key).copied().unwrap_or(false)
    }

    /// The raw value of a positional argument by declared name.
    pub fn positional(&self, name: &str) -> Option<&str> {
        self.pos_names.get(name).map(|&i| self.positionals[i].as_str())
    }

    /// Parse an option value via `FromStr`, with a descriptive error.
    pub fn parse_as<T: std::str::FromStr>(&self, key: &str) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        let raw = self.get(key).ok_or_else(|| CliError::MissingValue(key.into()))?;
        raw.parse::<T>().map_err(|e| CliError::Invalid {
            key: key.into(),
            value: raw.into(),
            why: e.to_string(),
        })
    }

    /// Comma-separated list, e.g. `--h 1,5,10`.
    pub fn parse_list<T: std::str::FromStr>(&self, key: &str) -> Result<Vec<T>, CliError>
    where
        T::Err: std::fmt::Display,
    {
        let raw = self.get(key).ok_or_else(|| CliError::MissingValue(key.into()))?;
        raw.split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim().parse::<T>().map_err(|e| CliError::Invalid {
                    key: key.into(),
                    value: s.into(),
                    why: e.to_string(),
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn cmd() -> Command {
        Command::new("t", "test")
            .opt("rounds", "10", "rounds")
            .opt_nodefault("out", "output path")
            .flag("verbose", "chatty")
            .positional("dataset", "which dataset")
            .positional_opt("extra", "optional arg")
    }

    #[test]
    fn defaults_and_overrides() {
        let a = cmd().parse(&argv(&["cifar"])).unwrap();
        assert_eq!(a.get("rounds"), Some("10"));
        assert_eq!(a.get("out"), None);
        assert!(!a.flag("verbose"));
        assert_eq!(a.positional("dataset"), Some("cifar"));
        assert_eq!(a.positional("extra"), None);

        let a = cmd()
            .parse(&argv(&["femnist", "--rounds", "5", "--verbose", "--out=x.json"]))
            .unwrap();
        assert_eq!(a.parse_as::<u32>("rounds").unwrap(), 5);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("out"), Some("x.json"));
    }

    #[test]
    fn equals_syntax() {
        let a = cmd().parse(&argv(&["cifar", "--rounds=42"])).unwrap();
        assert_eq!(a.parse_as::<usize>("rounds").unwrap(), 42);
    }

    #[test]
    fn error_cases() {
        assert!(matches!(cmd().parse(&argv(&["c", "--nope"])), Err(CliError::Unknown(_))));
        assert!(matches!(
            cmd().parse(&argv(&["c", "--rounds"])),
            Err(CliError::MissingValue(_))
        ));
        assert!(matches!(cmd().parse(&argv(&[])), Err(CliError::MissingPositional(_))));
        assert!(matches!(
            cmd().parse(&argv(&["a", "b", "c"])),
            Err(CliError::UnexpectedPositional(_))
        ));
        let a = cmd().parse(&argv(&["c", "--rounds", "xyz"])).unwrap();
        assert!(matches!(a.parse_as::<u32>("rounds"), Err(CliError::Invalid { .. })));
    }

    #[test]
    fn lists() {
        let c = Command::new("t", "t").opt("h", "1,5,10", "h values").positional("d", "");
        let a = c.parse(&argv(&["x", "--h", "1, 2,8"])).unwrap();
        assert_eq!(a.parse_list::<u32>("h").unwrap(), vec![1, 2, 8]);
    }

    #[test]
    fn usage_mentions_everything() {
        let u = cmd().usage();
        assert!(u.contains("--rounds"));
        assert!(u.contains("<dataset>"));
        assert!(u.contains("[extra]"));
    }
}
