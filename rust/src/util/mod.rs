//! Dependency-light substrate utilities.
//!
//! The offline vendor set has no serde/clap/criterion/proptest/rand, so
//! this module provides functional equivalents, each unit-tested:
//! [`prng`] (seeded xoshiro256++ with derived streams), [`json`]
//! (parser + serializer for the AOT manifest and configs), [`cli`]
//! (declarative argument parsing), [`bench`] (mini-criterion), [`prop`]
//! (mini property-testing harness), [`csvio`] and [`logging`].

pub mod bench;
pub mod cli;
pub mod csvio;
pub mod json;
pub mod logging;
pub mod prng;
pub mod prop;
