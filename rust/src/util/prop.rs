//! Mini property-testing harness (proptest is unavailable offline).
//!
//! Coordinator invariants (routing, batching, accounting, aggregation)
//! are checked over many random cases drawn from a seeded generator.
//!
//! # Shrinking strategy
//!
//! On failure the harness does not just replay the failing seed — it
//! hunts for a **smaller** counterexample by rerunning the property with
//! *derived sub-seeds* under a range-shrink factor
//! ([`Rng::with_shrink`]): every `below(n)` draw on the generator stream
//! is capped to `max(n / factor, 1)`, which biases sizes (client counts,
//! rounds, model lengths) toward their minima and enum-style choices
//! toward the first variant — the same "prefer simpler" ordering
//! QuickCheck-family shrinkers use. Factors are tried most-aggressive
//! first (16, 8, 4, 2), a handful of sub-seeds each; the first capped
//! rerun that still fails is reported next to the original, with an
//! exact reproduction line. Derived simulation streams
//! ([`Rng::split`]) are deliberately *not* capped, so the property still
//! exercises the real system — only the generated inputs shrink.
//!
//! **Time-prefix shrinking** runs *before* range shrinking: properties
//! that draw their round/step counts through [`Rng::below_time`] get
//! those draws capped first (via [`Rng::with_shrink_dims`]), so a
//! trainer failure at round 37 is first replayed with 4, 9, 18 rounds —
//! a failure that survives replays *fewer rounds* without distorting
//! client counts or model sizes. Only if no time-capped rerun fails does
//! the harness fall back to capping every range.
//!
//! Reproduction: `PROP_SEED=<n> cargo test <name>` replays an original
//! failure exactly; `PROP_SEED=<n> PROP_SHRINK=<factor> PROP_CASES=1`
//! (or `PROP_TIME_SHRINK=<factor>` for a time-shrunk one) replays a
//! shrunk counterexample. `PROP_CASES` overrides the case count.

use super::prng::{splitmix64_mix, Rng};

/// Number of random cases per property (override with PROP_CASES).
pub fn default_cases() -> u64 {
    std::env::var("PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

fn base_seed() -> u64 {
    std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC5EF_51D0_2024_0001)
}

/// Range-shrink factor applied to every case (replay knob for shrunk
/// counterexamples; 1 = off).
fn shrink_factor() -> u64 {
    std::env::var("PROP_SHRINK")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&f| f >= 1)
        .unwrap_or(1)
}

/// Time-shrink factor applied to every case's [`Rng::below_time`] draws
/// (replay knob for time-shrunk counterexamples; 1 = off).
fn time_shrink_factor() -> u64 {
    std::env::var("PROP_TIME_SHRINK")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&f| f >= 1)
        .unwrap_or(1)
}

/// Shrink factors tried on failure, most aggressive first.
const SHRINK_FACTORS: [u64; 4] = [16, 8, 4, 2];

/// Time-prefix shrink factors, tried before range factors.
const TIME_SHRINK_FACTORS: [u64; 3] = [8, 4, 2];

/// Derived sub-seeds tried per factor.
const SHRINK_TRIES: u64 = 6;

/// Distinct, deterministic sub-seed streams per (failing seed, factor,
/// attempt), finalized with the prng's shared SplitMix64 mix.
fn derive_sub_seed(seed: u64, factor: u64, attempt: u64) -> u64 {
    splitmix64_mix(
        seed ^ factor.wrapping_mul(0xA076_1D64_78BD_642F)
            ^ attempt.wrapping_mul(0x9E37_79B9_7F4A_7C15),
    )
}

fn panic_message(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        format!("panicked: {s}")
    } else if let Some(s) = p.downcast_ref::<String>() {
        format!("panicked: {s}")
    } else {
        "panicked".to_string()
    }
}

/// Which draw dimension a shrunk counterexample capped.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum ShrinkDim {
    /// Only [`Rng::below_time`] draws capped — fewer rounds/steps, same
    /// everything else.
    Time,
    /// Every `below` draw capped — smaller inputs across the board.
    Range,
}

fn rerun_capped<F>(prop: &mut F, sub: u64, factor: u64, time_factor: u64) -> Option<String>
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut srng = Rng::with_shrink_dims(sub, factor, time_factor);
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut srng)));
    match outcome {
        Ok(Ok(())) => None,
        Ok(Err(msg)) => Some(msg),
        Err(p) => Some(panic_message(p)),
    }
}

/// Hunt for a smaller failing input: rerun `prop` with derived sub-seeds,
/// first under descending *time* factors (replay fewer rounds via
/// [`Rng::below_time`] caps), then under descending *range* factors; the
/// first capped rerun that fails (by `Err` or by panic) wins. Returns
/// `(dimension, factor, sub_seed, message)`.
fn shrink<F>(prop: &mut F, seed: u64) -> Option<(ShrinkDim, u64, u64, String)>
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for &factor in &TIME_SHRINK_FACTORS {
        for attempt in 0..SHRINK_TRIES {
            // xor keeps time-phase sub-seed streams disjoint from the
            // range phase at equal factors.
            let sub = derive_sub_seed(seed ^ 0x7135_0000, factor, attempt);
            if let Some(msg) = rerun_capped(prop, sub, 1, factor) {
                return Some((ShrinkDim::Time, factor, sub, msg));
            }
        }
    }
    for &factor in &SHRINK_FACTORS {
        for attempt in 0..SHRINK_TRIES {
            let sub = derive_sub_seed(seed, factor, attempt);
            if let Some(msg) = rerun_capped(prop, sub, factor, 1) {
                return Some((ShrinkDim::Range, factor, sub, msg));
            }
        }
    }
    None
}

/// Run `prop` for `default_cases()` seeded cases. The closure receives a
/// per-case RNG and returns `Err(description)` to fail the property; on
/// failure the shrinker (module docs) searches for a smaller
/// counterexample before panicking with reproduction lines for both.
pub fn check<F>(name: &str, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let cases = default_cases();
    let base = base_seed();
    let replay_factor = shrink_factor();
    let time_replay = time_shrink_factor();
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::with_shrink_dims(seed, replay_factor, time_replay);
        if let Err(msg) = prop(&mut rng) {
            let mut report = format!(
                "property {name:?} failed on case {case}/{cases}: {msg}\n\
                 reproduce with: PROP_SEED={base} PROP_CASES={} (case index {case})",
                case + 1
            );
            // Only shrink original-size failures; a capped replay is
            // already minimal-ish and reruns would double-shrink.
            if replay_factor == 1 && time_replay == 1 {
                if let Some((dim, factor, sub, smsg)) = shrink(&mut prop, seed) {
                    let (what, knob) = match dim {
                        ShrinkDim::Time => ("time draws", "PROP_TIME_SHRINK"),
                        ShrinkDim::Range => ("ranges", "PROP_SHRINK"),
                    };
                    report.push_str(&format!(
                        "\nshrunk counterexample ({what} capped ~1/{factor}): {smsg}\n\
                         reproduce shrunk: PROP_SEED={sub} {knob}={factor} PROP_CASES=1"
                    ));
                }
            }
            panic!("{report}");
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

/// Approximate float equality for property bodies.
pub fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum-commutes", |rng| {
            let a = rng.uniform();
            let b = rng.uniform();
            prop_assert!(close(a + b, b + a, 1e-12), "{a} {b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_seed() {
        check("always-fails", |_| Err("nope".into()));
    }

    #[test]
    #[should_panic(expected = "shrunk")]
    fn shrinker_reports_a_smaller_counterexample() {
        // Fails for any draw >= 1 out of a huge range — the capped
        // reruns still fail (ranges never shrink below 1 draw of
        // below(62500) here), so a shrunk reproduction line must appear.
        check("big-draw-fails", |rng| {
            let n = rng.below(1_000_000);
            prop_assert!(n == 0, "drew {n}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "PROP_TIME_SHRINK")]
    fn time_prefix_shrink_is_tried_first() {
        // Fails whenever the below_time draw is >= 1 — any capped rerun
        // still fails, and since the time phase runs before the range
        // phase, the reproduction line must carry the time knob.
        check("long-run-fails", |rng| {
            let rounds = rng.below_time(1_000_000);
            let _unrelated = rng.below(64);
            prop_assert!(rounds == 0, "failed at round {rounds}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "PROP_SHRINK=")]
    fn range_shrink_reached_when_time_caps_mask_the_failure() {
        // Fails only when below_time(2) draws 1 — every time factor
        // (>= 2) caps that range to below(1) == 0, so all time-phase
        // reruns PASS and the shrinker must fall through to the range
        // phase, where below_time stays uncapped and the big range draw
        // keeps failing. Pins the fallback ordering.
        check("time-capped-masks", |rng| {
            let gate = rng.below_time(2);
            let n = rng.below(1_000_000);
            prop_assert!(!(gate == 1 && n >= 1), "gate {gate} n {n}");
            Ok(())
        });
    }

    #[test]
    fn shrunk_failures_replay_exactly() {
        // A shrunk counterexample's reproduction line pins (sub_seed,
        // factor); Rng::with_shrink must replay the identical stream.
        let sub = derive_sub_seed(0xDEAD_BEEF, 8, 3);
        let mut a = Rng::with_shrink(sub, 8);
        let mut b = Rng::with_shrink(sub, 8);
        for _ in 0..64 {
            assert_eq!(a.below(1000), b.below(1000));
        }
        // Same for the time dimension.
        let mut c = Rng::with_shrink_dims(sub, 1, 4);
        let mut d = Rng::with_shrink_dims(sub, 1, 4);
        for _ in 0..64 {
            assert_eq!(c.below_time(1000), d.below_time(1000));
        }
    }

    #[test]
    fn sub_seeds_are_distinct_per_factor_and_attempt() {
        let mut seen = std::collections::BTreeSet::new();
        for &f in &SHRINK_FACTORS {
            for t in 0..SHRINK_TRIES {
                seen.insert(derive_sub_seed(1, f, t));
            }
        }
        assert_eq!(seen.len(), SHRINK_FACTORS.len() * SHRINK_TRIES as usize);
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-13, 1e-12));
        assert!(!close(1.0, 1.1, 1e-12));
    }
}
