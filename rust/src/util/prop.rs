//! Mini property-testing harness (proptest is unavailable offline).
//!
//! Coordinator invariants (routing, batching, accounting, aggregation)
//! are checked over many random cases drawn from a seeded generator.
//!
//! # Shrinking strategy
//!
//! On failure the harness does not just replay the failing seed — it
//! hunts for a **smaller** counterexample by rerunning the property with
//! *derived sub-seeds* under a range-shrink factor
//! ([`Rng::with_shrink`]): every `below(n)` draw on the generator stream
//! is capped to `max(n / factor, 1)`, which biases sizes (client counts,
//! rounds, model lengths) toward their minima and enum-style choices
//! toward the first variant — the same "prefer simpler" ordering
//! QuickCheck-family shrinkers use. Factors are tried most-aggressive
//! first (16, 8, 4, 2), a handful of sub-seeds each; the first capped
//! rerun that still fails is reported next to the original, with an
//! exact reproduction line. Derived simulation streams
//! ([`Rng::split`]) are deliberately *not* capped, so the property still
//! exercises the real system — only the generated inputs shrink.
//!
//! Reproduction: `PROP_SEED=<n> cargo test <name>` replays an original
//! failure exactly; `PROP_SEED=<n> PROP_SHRINK=<factor> PROP_CASES=1`
//! replays a shrunk one. `PROP_CASES` overrides the case count.

use super::prng::{splitmix64_mix, Rng};

/// Number of random cases per property (override with PROP_CASES).
pub fn default_cases() -> u64 {
    std::env::var("PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

fn base_seed() -> u64 {
    std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC5EF_51D0_2024_0001)
}

/// Range-shrink factor applied to every case (replay knob for shrunk
/// counterexamples; 1 = off).
fn shrink_factor() -> u64 {
    std::env::var("PROP_SHRINK")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&f| f >= 1)
        .unwrap_or(1)
}

/// Shrink factors tried on failure, most aggressive first.
const SHRINK_FACTORS: [u64; 4] = [16, 8, 4, 2];

/// Derived sub-seeds tried per factor.
const SHRINK_TRIES: u64 = 6;

/// Distinct, deterministic sub-seed streams per (failing seed, factor,
/// attempt), finalized with the prng's shared SplitMix64 mix.
fn derive_sub_seed(seed: u64, factor: u64, attempt: u64) -> u64 {
    splitmix64_mix(
        seed ^ factor.wrapping_mul(0xA076_1D64_78BD_642F)
            ^ attempt.wrapping_mul(0x9E37_79B9_7F4A_7C15),
    )
}

fn panic_message(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        format!("panicked: {s}")
    } else if let Some(s) = p.downcast_ref::<String>() {
        format!("panicked: {s}")
    } else {
        "panicked".to_string()
    }
}

/// Hunt for a smaller failing input: rerun `prop` with derived sub-seeds
/// under descending shrink factors; the first capped rerun that fails
/// (by `Err` or by panic) wins. Returns `(factor, sub_seed, message)`.
fn shrink<F>(prop: &mut F, seed: u64) -> Option<(u64, u64, String)>
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for &factor in &SHRINK_FACTORS {
        for attempt in 0..SHRINK_TRIES {
            let sub = derive_sub_seed(seed, factor, attempt);
            let mut srng = Rng::with_shrink(sub, factor);
            let outcome =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut srng)));
            let failure = match outcome {
                Ok(Ok(())) => None,
                Ok(Err(msg)) => Some(msg),
                Err(p) => Some(panic_message(p)),
            };
            if let Some(msg) = failure {
                return Some((factor, sub, msg));
            }
        }
    }
    None
}

/// Run `prop` for `default_cases()` seeded cases. The closure receives a
/// per-case RNG and returns `Err(description)` to fail the property; on
/// failure the shrinker (module docs) searches for a smaller
/// counterexample before panicking with reproduction lines for both.
pub fn check<F>(name: &str, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let cases = default_cases();
    let base = base_seed();
    let replay_factor = shrink_factor();
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::with_shrink(seed, replay_factor);
        if let Err(msg) = prop(&mut rng) {
            let mut report = format!(
                "property {name:?} failed on case {case}/{cases}: {msg}\n\
                 reproduce with: PROP_SEED={base} PROP_CASES={} (case index {case})",
                case + 1
            );
            // Only shrink original-size failures; a capped replay is
            // already minimal-ish and reruns would double-shrink.
            if replay_factor == 1 {
                if let Some((factor, sub, smsg)) = shrink(&mut prop, seed) {
                    report.push_str(&format!(
                        "\nshrunk counterexample (ranges capped ~1/{factor}): {smsg}\n\
                         reproduce shrunk: PROP_SEED={sub} PROP_SHRINK={factor} PROP_CASES=1"
                    ));
                }
            }
            panic!("{report}");
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

/// Approximate float equality for property bodies.
pub fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum-commutes", |rng| {
            let a = rng.uniform();
            let b = rng.uniform();
            prop_assert!(close(a + b, b + a, 1e-12), "{a} {b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_seed() {
        check("always-fails", |_| Err("nope".into()));
    }

    #[test]
    #[should_panic(expected = "shrunk")]
    fn shrinker_reports_a_smaller_counterexample() {
        // Fails for any draw >= 1 out of a huge range — the capped
        // reruns still fail (ranges never shrink below 1 draw of
        // below(62500) here), so a shrunk reproduction line must appear.
        check("big-draw-fails", |rng| {
            let n = rng.below(1_000_000);
            prop_assert!(n == 0, "drew {n}");
            Ok(())
        });
    }

    #[test]
    fn shrunk_failures_replay_exactly() {
        // A shrunk counterexample's reproduction line pins (sub_seed,
        // factor); Rng::with_shrink must replay the identical stream.
        let sub = derive_sub_seed(0xDEAD_BEEF, 8, 3);
        let mut a = Rng::with_shrink(sub, 8);
        let mut b = Rng::with_shrink(sub, 8);
        for _ in 0..64 {
            assert_eq!(a.below(1000), b.below(1000));
        }
    }

    #[test]
    fn sub_seeds_are_distinct_per_factor_and_attempt() {
        let mut seen = std::collections::BTreeSet::new();
        for &f in &SHRINK_FACTORS {
            for t in 0..SHRINK_TRIES {
                seen.insert(derive_sub_seed(1, f, t));
            }
        }
        assert_eq!(seen.len(), SHRINK_FACTORS.len() * SHRINK_TRIES as usize);
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-13, 1e-12));
        assert!(!close(1.0, 1.1, 1e-12));
    }
}
