//! Mini property-testing harness (proptest is unavailable offline).
//!
//! Coordinator invariants (routing, batching, accounting, aggregation) are
//! checked over many random cases drawn from a seeded generator. On
//! failure the harness re-runs with a bisected input size to report a
//! smaller counterexample seed, then panics with the reproduction seed —
//! `PROP_SEED=<n> cargo test <name>` replays it exactly.

use super::prng::Rng;

/// Number of random cases per property (override with PROP_CASES).
pub fn default_cases() -> u64 {
    std::env::var("PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

fn base_seed() -> u64 {
    std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC5EF_51D0_2024_0001)
}

/// Run `prop` for `default_cases()` seeded cases. The closure receives a
/// per-case RNG and returns `Err(description)` to fail the property.
pub fn check<F>(name: &str, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let cases = default_cases();
    let base = base_seed();
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name:?} failed on case {case}/{cases}: {msg}\n\
                 reproduce with: PROP_SEED={base} PROP_CASES={} (case index {case})",
                case + 1
            );
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

/// Approximate float equality for property bodies.
pub fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum-commutes", |rng| {
            let a = rng.uniform();
            let b = rng.uniform();
            prop_assert!(close(a + b, b + a, 1e-12), "{a} {b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_seed() {
        check("always-fails", |_| Err("nope".into()));
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-13, 1e-12));
        assert!(!close(1.0, 1.1, 1e-12));
    }
}
