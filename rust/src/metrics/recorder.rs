//! Per-round run records + JSON/CSV export.
//!
//! One [`RoundRecord`] per communication round; a [`RunRecord`] wraps a
//! whole training run with its config echo and final summary. Figure
//! drivers consume these to print the paper's series and to dump CSVs.

use std::path::Path;

use crate::util::csvio::Csv;
use crate::util::json::Json;

/// Everything measured in one communication round.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    /// 1-based communication-round index.
    pub round: usize,
    /// Simulated wall-clock at round end (seconds).
    pub sim_time: f64,
    /// Learning rate in effect this round.
    pub lr: f64,
    /// Mean client local loss this round (auxiliary loss for AN/CSE,
    /// split loss for MC/OC).
    pub train_loss: f64,
    /// Mean server loss over this round's event-triggered updates.
    pub server_loss: f64,
    /// Cumulative uplink wire bytes.
    pub up_bytes: u64,
    /// Cumulative downlink wire bytes.
    pub down_bytes: u64,
    /// Test accuracy if evaluated this round.
    pub accuracy: Option<f64>,
    /// Mean client gradient norm (Props 1-2 probe), if tracked.
    pub client_grad_norm: Option<f64>,
    /// Mean server gradient norm (Props 1-2 probe), if tracked.
    pub server_grad_norm: Option<f64>,
}

/// A whole training run: per-round records plus the final summary the
/// figure/table drivers and the results cache consume.
#[derive(Clone, Debug)]
pub struct RunRecord {
    /// Human-readable run label (method + h).
    pub label: String,
    /// One record per communication round, in order.
    pub rounds: Vec<RoundRecord>,
    /// Full-test-set accuracy after the last round.
    pub final_accuracy: f64,
    /// Total uplink bytes over the run.
    pub total_up_bytes: u64,
    /// Total downlink bytes over the run.
    pub total_down_bytes: u64,
    /// Simulated end-to-end run time (seconds).
    pub sim_time: f64,
    /// Fraction of simulated time the server spent idle.
    pub server_idle_fraction: f64,
    /// Critical-path lower bound on the simulated makespan: the busiest
    /// single actor (client or server executor lane). `sim_time` can
    /// never undercut it; their ratio is [`RunRecord::sched_efficiency`].
    pub critical_path: f64,
    /// Busy seconds per server executor lane, in canonical lane order
    /// (length = executor count: `k` for the sharded single-copy
    /// methods, 1 otherwise).
    pub lane_busy: Vec<f64>,
    /// Table-V-style server-resident parameter count (copies + buffers).
    pub server_storage_params: usize,
    /// Event-triggered updates applied to each server copy, in canonical
    /// shard order (length = copy count: k for the sharded single-copy
    /// methods, n for the per-client-copy methods).
    pub server_updates_per_shard: Vec<u64>,
    /// Shard-skew metric: sample-mass-weighted per-shard
    /// total-variation distance between each shard's aggregate label
    /// distribution and the global one, in `[0, 1]`
    /// (`ShardMap::label_divergence_weighted`; recorded weighted since
    /// cache schema v2). 0 means every
    /// server copy trains on the global label mix — always true for the
    /// single-copy methods at k = 1. The per-client-copy methods
    /// (FSL_MC / FSL_AN) report the skew of their n per-client cohorts,
    /// which is large under any non-IID split by construction. The
    /// locality shard map minimizes it on the sharded non-IID arms.
    pub shard_label_divergence: f64,
    /// Number of distinct clients whose state was materialized at least
    /// once during the run. The resident engine builds every client up
    /// front, so this equals `n`; the streaming population engine only
    /// ever builds the sampled cohorts, so at fleet scale this is the
    /// (much smaller) working-set size that bounds peak memory.
    pub clients_activated: usize,
    /// Sampled participants removed by the availability model
    /// (`sim::churn::ChurnModel`), summed over rounds. 0 for every run
    /// at the default full-availability model.
    pub clients_dropped: u64,
    /// Replacement participants admitted by quorum re-sampling
    /// (`ResiliencePolicy::Quorum { resample: true }`), summed over
    /// rounds.
    pub clients_replaced: u64,
    /// Participants that died mid-round after a partial smashed upload
    /// (`ChurnConfig::fail_rate`), summed over rounds.
    pub partial_failures: u64,
    /// Smashed uploads dropped past the straggler window
    /// (`ResiliencePolicy::Cutoff`), summed over rounds.
    pub stragglers_dropped: u64,
}

impl RunRecord {
    /// Total traffic in gigabytes (Table V / Fig. 9 units).
    pub fn total_gb(&self) -> f64 {
        (self.total_up_bytes + self.total_down_bytes) as f64 / 1e9
    }

    /// Total event-triggered server updates (sum over shards).
    pub fn server_updates(&self) -> u64 {
        self.server_updates_per_shard.iter().sum()
    }

    /// Scheduling efficiency of the simulated schedule: critical path
    /// over makespan, in (0, 1]. 1.0 means the run is as short as its
    /// busiest actor allows; small values mean idle executors or
    /// straggler gaps dominate the wall clock.
    pub fn sched_efficiency(&self) -> f64 {
        if self.sim_time > 0.0 {
            (self.critical_path / self.sim_time).clamp(0.0, 1.0)
        } else {
            0.0
        }
    }

    /// Accuracy series as (round, acc) points.
    pub fn accuracy_curve(&self) -> Vec<(usize, f64)> {
        self.rounds
            .iter()
            .filter_map(|r| r.accuracy.map(|a| (r.round, a)))
            .collect()
    }

    /// Accuracy vs cumulative communication load in GB (Fig. 9 axes).
    pub fn accuracy_vs_load(&self) -> Vec<(f64, f64)> {
        self.rounds
            .iter()
            .filter_map(|r| {
                r.accuracy.map(|a| ((r.up_bytes + r.down_bytes) as f64 / 1e9, a))
            })
            .collect()
    }

    /// The per-round series as a CSV table.
    pub fn to_csv(&self) -> Csv {
        let mut csv = Csv::new(&[
            "round",
            "sim_time",
            "lr",
            "train_loss",
            "server_loss",
            "up_bytes",
            "down_bytes",
            "accuracy",
            "client_grad_norm",
            "server_grad_norm",
        ]);
        for r in &self.rounds {
            csv.row(&[
                r.round.to_string(),
                format!("{:.6}", r.sim_time),
                format!("{:.6}", r.lr),
                format!("{:.6}", r.train_loss),
                format!("{:.6}", r.server_loss),
                r.up_bytes.to_string(),
                r.down_bytes.to_string(),
                r.accuracy.map(|a| format!("{a:.4}")).unwrap_or_default(),
                r.client_grad_norm.map(|g| format!("{g:.6}")).unwrap_or_default(),
                r.server_grad_norm.map(|g| format!("{g:.6}")).unwrap_or_default(),
            ]);
        }
        csv
    }

    /// Write [`RunRecord::to_csv`] to `path` (creating parent dirs).
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        self.to_csv().write_to(path)
    }

    /// The run summary as a JSON object (whole-run scalars only).
    pub fn summary_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::str(self.label.clone())),
            ("rounds", Json::num(self.rounds.len() as f64)),
            ("final_accuracy", Json::num(self.final_accuracy)),
            ("total_gb", Json::num(self.total_gb())),
            ("sim_time", Json::num(self.sim_time)),
            ("server_idle_fraction", Json::num(self.server_idle_fraction)),
            ("critical_path", Json::num(self.critical_path)),
            ("sched_efficiency", Json::num(self.sched_efficiency())),
            (
                "lane_busy",
                Json::Arr(self.lane_busy.iter().map(|&b| Json::num(b)).collect()),
            ),
            ("server_storage_params", Json::num(self.server_storage_params as f64)),
            (
                "server_updates_per_shard",
                Json::Arr(
                    self.server_updates_per_shard
                        .iter()
                        .map(|&u| Json::num(u as f64))
                        .collect(),
                ),
            ),
            ("shard_label_divergence", Json::num(self.shard_label_divergence)),
            ("clients_activated", Json::num(self.clients_activated as f64)),
            ("clients_dropped", Json::num(self.clients_dropped as f64)),
            ("clients_replaced", Json::num(self.clients_replaced as f64)),
            ("partial_failures", Json::num(self.partial_failures as f64)),
            ("stragglers_dropped", Json::num(self.stragglers_dropped as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec() -> RunRecord {
        RunRecord {
            label: "test".into(),
            rounds: vec![
                RoundRecord {
                    round: 1,
                    sim_time: 0.5,
                    lr: 0.1,
                    train_loss: 2.0,
                    server_loss: 2.1,
                    up_bytes: 100,
                    down_bytes: 50,
                    accuracy: None,
                    client_grad_norm: None,
                    server_grad_norm: None,
                },
                RoundRecord {
                    round: 2,
                    sim_time: 1.0,
                    lr: 0.1,
                    train_loss: 1.5,
                    server_loss: 1.6,
                    up_bytes: 200,
                    down_bytes: 100,
                    accuracy: Some(0.8),
                    client_grad_norm: Some(0.5),
                    server_grad_norm: Some(0.4),
                },
            ],
            final_accuracy: 0.8,
            total_up_bytes: 200,
            total_down_bytes: 100,
            sim_time: 1.0,
            server_idle_fraction: 0.25,
            critical_path: 0.75,
            lane_busy: vec![0.5, 0.75],
            server_storage_params: 1_000,
            server_updates_per_shard: vec![3, 5],
            shard_label_divergence: 0.25,
            clients_activated: 4,
            clients_dropped: 2,
            clients_replaced: 1,
            partial_failures: 1,
            stragglers_dropped: 3,
        }
    }

    #[test]
    fn curves() {
        let r = rec();
        assert_eq!(r.accuracy_curve(), vec![(2, 0.8)]);
        let load = r.accuracy_vs_load();
        assert_eq!(load.len(), 1);
        assert!((load[0].0 - 300e-9).abs() < 1e-15);
    }

    #[test]
    fn csv_shape() {
        let csv = rec().to_csv();
        assert_eq!(csv.n_rows(), 2);
        let s = csv.to_string();
        assert!(s.contains("round,sim_time"));
        assert!(s.contains("0.8"));
    }

    #[test]
    fn summary_json_fields() {
        let j = rec().summary_json();
        assert_eq!(j.get("final_accuracy").unwrap().as_f64().unwrap(), 0.8);
        assert!(j.get("total_gb").unwrap().as_f64().unwrap() > 0.0);
        let shards = j.get("server_updates_per_shard").unwrap().as_arr().unwrap();
        assert_eq!(shards.len(), 2);
        assert_eq!(rec().server_updates(), 8);
        assert_eq!(j.get("critical_path").unwrap().as_f64().unwrap(), 0.75);
        assert_eq!(j.get("sched_efficiency").unwrap().as_f64().unwrap(), 0.75);
        assert_eq!(j.get("lane_busy").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(j.get("shard_label_divergence").unwrap().as_f64().unwrap(), 0.25);
        assert_eq!(j.get("clients_activated").unwrap().as_f64().unwrap(), 4.0);
        assert_eq!(j.get("clients_dropped").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(j.get("clients_replaced").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(j.get("partial_failures").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(j.get("stragglers_dropped").unwrap().as_f64().unwrap(), 3.0);
    }

    #[test]
    fn sched_efficiency_bounds() {
        let r = rec();
        assert!((r.sched_efficiency() - 0.75).abs() < 1e-12);
        let mut degenerate = rec();
        degenerate.sim_time = 0.0;
        assert_eq!(degenerate.sched_efficiency(), 0.0);
        // A (numerically) oversized critical path clamps to 1.
        let mut over = rec();
        over.critical_path = 2.0;
        assert_eq!(over.sched_efficiency(), 1.0);
    }
}
