//! Multi-seed summary statistics (the paper reports mean ± std over five
//! independent runs).

/// mean ± std (population std, like numpy's default ddof=0).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MeanStd {
    /// Sample mean.
    pub mean: f64,
    /// Population standard deviation.
    pub std: f64,
    /// Number of samples.
    pub n: usize,
}

impl MeanStd {
    /// Mean ± std of a non-empty sample.
    pub fn of(xs: &[f64]) -> MeanStd {
        assert!(!xs.is_empty());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        MeanStd { mean, std: var.sqrt(), n }
    }

    /// `76.52±0.41` formatting (paper Table V style, percent points).
    pub fn fmt_pct(&self) -> String {
        format!("{:.2}±{:.2}", self.mean * 100.0, self.std * 100.0)
    }

    /// `1.5±0.0`-style formatting with the given decimal digits.
    pub fn fmt_plain(&self, digits: usize) -> String {
        format!("{:.*}±{:.*}", digits, self.mean, digits, self.std)
    }
}

/// Align several per-seed curves (sampled at identical x points) into a
/// per-point MeanStd series. Curves must share x grids.
pub fn curve_mean_std(curves: &[Vec<(usize, f64)>]) -> Vec<(usize, MeanStd)> {
    assert!(!curves.is_empty());
    let grid: Vec<usize> = curves[0].iter().map(|&(x, _)| x).collect();
    for c in curves {
        assert_eq!(
            c.iter().map(|&(x, _)| x).collect::<Vec<_>>(),
            grid,
            "curves must share the x grid"
        );
    }
    grid.iter()
        .enumerate()
        .map(|(i, &x)| {
            let ys: Vec<f64> = curves.iter().map(|c| c[i].1).collect();
            (x, MeanStd::of(&ys))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let m = MeanStd::of(&[1.0, 2.0, 3.0]);
        assert!((m.mean - 2.0).abs() < 1e-12);
        assert!((m.std - (2.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(m.n, 3);
    }

    #[test]
    fn formatting() {
        let m = MeanStd::of(&[0.7652, 0.7693, 0.7611]);
        let s = m.fmt_pct();
        assert!(s.starts_with("76."), "{s}");
        assert!(s.contains('±'));
        assert_eq!(MeanStd::of(&[1.5]).fmt_plain(1), "1.5±0.0");
    }

    #[test]
    fn curves_aggregate() {
        let c1 = vec![(0, 0.1), (10, 0.5)];
        let c2 = vec![(0, 0.3), (10, 0.7)];
        let agg = curve_mean_std(&[c1, c2]);
        assert_eq!(agg.len(), 2);
        assert!((agg[0].1.mean - 0.2).abs() < 1e-12);
        assert!((agg[1].1.mean - 0.6).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "share the x grid")]
    fn mismatched_grids_panic() {
        curve_mean_std(&[vec![(0, 0.1)], vec![(1, 0.1)]]);
    }
}
