//! Metrics: accuracy evaluation, per-round recording, multi-seed summary.

pub mod eval;
pub mod recorder;
pub mod summary;
