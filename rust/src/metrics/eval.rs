//! Top-1 accuracy evaluation through a [`SplitEngine`].
//!
//! Walks the test set in AOT-fixed batch chunks (padding the tail and
//! masking it out of the count) and computes argmax-logits accuracy of
//! the full split model, exactly like the paper's "top-1 accuracy".

use crate::data::batcher::EvalChunks;
use crate::data::Dataset;
use crate::runtime::{EngineError, SplitEngine};

/// Argmax over each row of a flattened [rows, classes] logits buffer.
pub fn argmax_rows(logits: &[f32], classes: usize) -> Vec<usize> {
    assert!(classes > 0);
    assert_eq!(logits.len() % classes, 0);
    logits
        .chunks_exact(classes)
        .map(|row| {
            // first maximal element wins ties (numpy argmax convention)
            let mut best = 0usize;
            for (i, &v) in row.iter().enumerate().skip(1) {
                if v > row[best] {
                    best = i;
                }
            }
            best
        })
        .collect()
}

/// Full-model top-1 accuracy on `ds` (optionally capped to
/// `max_batches` chunks for cheap periodic probes; 0 = whole set).
pub fn accuracy<E: SplitEngine>(
    engine: &E,
    xc: &[f32],
    xs: &[f32],
    ds: &Dataset,
    max_batches: usize,
) -> Result<f64, EngineError> {
    let b = engine.batch();
    let mut images = Vec::new();
    let mut labels = Vec::new();
    let mut correct = 0usize;
    let mut total = 0usize;
    for (chunk_i, (idx, real)) in EvalChunks::new(ds.len(), b).enumerate() {
        if max_batches > 0 && chunk_i >= max_batches {
            break;
        }
        ds.gather(&idx, &mut images, &mut labels);
        let logits = engine.eval_step(xc, xs, &images)?;
        let preds = argmax_rows(&logits, engine.classes());
        for i in 0..real {
            if preds[i] as i32 == labels[i] {
                correct += 1;
            }
        }
        total += real;
    }
    if total == 0 {
        return Ok(0.0);
    }
    Ok(correct as f64 / total as f64)
}

/// Accuracy of the client-side model through its auxiliary head (the
/// "local model" probe used in the aux-architecture analysis).
pub fn aux_accuracy<E: SplitEngine>(
    engine: &E,
    xc: &[f32],
    ac: &[f32],
    ds: &Dataset,
    max_batches: usize,
) -> Result<f64, EngineError> {
    let b = engine.batch();
    let mut images = Vec::new();
    let mut labels = Vec::new();
    let mut correct = 0usize;
    let mut total = 0usize;
    for (chunk_i, (idx, real)) in EvalChunks::new(ds.len(), b).enumerate() {
        if max_batches > 0 && chunk_i >= max_batches {
            break;
        }
        ds.gather(&idx, &mut images, &mut labels);
        let logits = engine.aux_eval_step(xc, ac, &images)?;
        let preds = argmax_rows(&logits, engine.classes());
        for i in 0..real {
            if preds[i] as i32 == labels[i] {
                correct += 1;
            }
        }
        total += real;
    }
    Ok(if total == 0 { 0.0 } else { correct as f64 / total as f64 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::mock::MockEngine;

    #[test]
    fn argmax_basic() {
        let logits = [0.1, 0.9, 0.0, 1.0, 0.2, 0.3];
        assert_eq!(argmax_rows(&logits, 3), vec![1, 0]);
    }

    #[test]
    fn argmax_ties_take_first() {
        assert_eq!(argmax_rows(&[0.5, 0.5], 2), vec![0]);
    }

    #[test]
    fn accuracy_counts_mask_padding() {
        let e = MockEngine::small(1);
        // 7 samples with batch 4 → 2 chunks, 1 padded
        let ds = crate::data::Dataset {
            images: vec![0.1; 7 * e.input_len()],
            labels: vec![0; 7],
            shape: [2, 2, 2],
            classes: 3,
            writers: vec![0; 7],
        };
        let xc = vec![0.0; e.client_size()];
        let xs = vec![0.0; e.server_size()];
        let acc = accuracy(&e, &xc, &xs, &ds, 0).unwrap();
        assert!((0.0..=1.0).contains(&acc));
        // capped probe touches fewer samples but stays in range
        let acc1 = accuracy(&e, &xc, &xs, &ds, 1).unwrap();
        assert!((0.0..=1.0).contains(&acc1));
    }

    #[test]
    fn perfect_model_scores_higher_than_zero_model() {
        // Mock eval: logits = signature * quality; labels assigned from
        // the signature argmax => the "perfect" model gets them right.
        let e = MockEngine::small(2);
        let n = 12;
        let mut images = Vec::new();
        let mut rng = crate::util::prng::Rng::new(3);
        for _ in 0..n * e.input_len() {
            images.push(rng.normal() as f32);
        }
        // label = signature argmax (what eval_step "detects")
        let mut labels = Vec::new();
        for b in 0..n {
            let img = &images[b * e.input_len()..(b + 1) * e.input_len()];
            let mut best = (f32::MIN, 0);
            for c in 0..e.classes() {
                let sig: f32 = img.iter().skip(c).step_by(e.classes()).sum();
                if sig > best.0 {
                    best = (sig, c);
                }
            }
            labels.push(best.1 as i32);
        }
        let ds = crate::data::Dataset {
            images,
            labels,
            shape: [2, 2, 2],
            classes: e.classes(),
            writers: vec![0; n],
        };
        // near-target params -> high quality -> signature dominates
        let (tc, _, ts) = e.targets();
        let (xc, xs) = (tc.to_vec(), ts.to_vec());
        let acc = accuracy(&e, &xc, &xs, &ds, 0).unwrap();
        assert!(acc > 0.5, "mock eval should decode signatures, got {acc}");
    }
}
