//! Coordinator-layer benchmarks (the L3 contribution must not be the
//! bottleneck): full mock-engine rounds per method, FedAvg aggregation at
//! paper model sizes, the streaming population engine at fleet scale,
//! the event queue, and the accounting ledger.
//!
//! Set `CSE_FSL_BENCH_JSON=<path>` to also write the run as a
//! `BENCH_*.json` snapshot (the perf trajectory CI diffs).

use std::time::{Duration, Instant};

use cse_fsl::comm::accounting::{table2, CommLedger, MsgKind, WireSizes};
use cse_fsl::sched::{fanout, SchedPolicy};
use cse_fsl::coordinator::config::{Parallelism, TrainConfig};
use cse_fsl::coordinator::methods::{Compression, Method};
use cse_fsl::coordinator::population::{ClientSource, PopulationSetup};
use cse_fsl::coordinator::round::{Trainer, TrainerSetup};
use cse_fsl::data::partition::iid;
use cse_fsl::data::synthetic::{generate, SyntheticSpec};
use cse_fsl::model::aggregate::{fedavg, Accumulator};
use cse_fsl::sim::churn::{ChurnConfig, ChurnModel, ResiliencePolicy};
use cse_fsl::sim::event::EventQueue;
use cse_fsl::sim::netmodel::NetModel;
use cse_fsl::runtime::mock::MockEngine;
use cse_fsl::util::bench::{write_snapshot, Bench, Stats};
use cse_fsl::util::prng::Rng;

fn main() {
    let mut snapshot: Vec<Stats> = Vec::new();
    // --- full coordinator rounds over the mock engine, per method
    let spec = SyntheticSpec {
        height: 2,
        width: 2,
        channels: 2,
        classes: 3,
        ..SyntheticSpec::cifar_like()
    };
    let train = generate(&spec, 256, 1);
    let test = generate(&spec, 64, 2);
    let mut bench = Bench::new("coordinator/rounds")
        .with_times(Duration::from_millis(200), Duration::from_millis(800));
    for method in Method::ALL {
        bench.run(&format!("{method}_10rounds_4clients"), || {
            let e = MockEngine::small(42);
            let cfg = TrainConfig { eval_every: 0, ..TrainConfig::new(method) }.with_rounds(10);
            let setup = TrainerSetup {
                train: &train,
                test: &test,
                partition: iid(&train, 4, &mut Rng::new(7)),
                net: NetModel::edge_default(),
                client_layout: None,
                server_layout: None,
                aux_layout: None,
                label: "bench".into(),
            };
            let mut tr = Trainer::new(&e, cfg, setup).unwrap();
            tr.run().unwrap()
        });
    }
    bench.report();
    snapshot.extend(bench.results().iter().cloned());

    // --- the parallel round engine: sequential vs threaded client
    // fan-out at 8 mock clients. The engine is sized so one client's
    // local round costs real work (paper-scale flat vectors), making the
    // fan-out, not the harness, the measured quantity. Results are
    // bit-identical across strategies (tests/determinism_golden.rs);
    // only wall-clock may differ.
    let heavy_spec = SyntheticSpec {
        height: 16,
        width: 16,
        channels: 2,
        classes: 10,
        ..SyntheticSpec::cifar_like()
    };
    let heavy_train = generate(&heavy_spec, 1024, 3);
    let heavy_test = generate(&heavy_spec, 64, 4);
    // batch 16, input 512, smashed 256; client 262k / aux 32k / server 64k params.
    let heavy = MockEngine::new(16, 10, 512, 256, 262_144, 32_768, 65_536, 9);
    let n_clients = 8;
    let run_fanout = |par: Parallelism, sched: SchedPolicy| {
        let cfg = TrainConfig {
            eval_every: 0,
            agg_every: 1000,
            lr0: 0.05,
            parallelism: par,
            sched,
            ..TrainConfig::new(Method::CseFsl).with_h(2)
        }
        .with_rounds(6);
        let setup = TrainerSetup {
            train: &heavy_train,
            test: &heavy_test,
            partition: iid(&heavy_train, n_clients, &mut Rng::new(7)),
            net: NetModel::edge_default(),
            client_layout: None,
            server_layout: None,
            aux_layout: None,
            label: "fanout".into(),
        };
        let mut tr = Trainer::new(&heavy, cfg, setup).unwrap();
        tr.run().unwrap()
    };
    let mut bench = Bench::new("coordinator/parallelism")
        .with_times(Duration::from_millis(300), Duration::from_millis(1500));
    let seq_ns = bench
        .run("seq_8clients_h2_6rounds", || {
            run_fanout(Parallelism::Sequential, SchedPolicy::RoundRobin)
        })
        .median_ns;
    let thr2_ns = bench
        .run("threads2_8clients_h2_6rounds", || {
            run_fanout(Parallelism::Threads(2), SchedPolicy::RoundRobin)
        })
        .median_ns;
    let thr4_ns = bench
        .run("threads4_8clients_h2_6rounds", || {
            run_fanout(Parallelism::Threads(4), SchedPolicy::RoundRobin)
        })
        .median_ns;
    let thr8_ns = bench
        .run("threads8_8clients_h2_6rounds", || {
            run_fanout(Parallelism::Threads(8), SchedPolicy::RoundRobin)
        })
        .median_ns;
    // The wire codec on the same fan-out: quantize-4 pays a per-element
    // min/max fold + stochastic round on every smashed upload. This row
    // vs threads4 round-robin is that codec overhead (it changes
    // results, so it is not comparable to the uncompressed rows beyond
    // wall-clock).
    let quant4_ns = bench
        .run("threads4_quantize4_8clients_h2_6rounds", || {
            let cfg = TrainConfig {
                eval_every: 0,
                agg_every: 1000,
                lr0: 0.05,
                parallelism: Parallelism::Threads(4),
                sched: SchedPolicy::RoundRobin,
                ..TrainConfig::new(Method::CseFsl).with_h(2)
            }
            .with_compression(Compression::Quantize { bits: 4 })
            .with_rounds(6);
            let setup = TrainerSetup {
                train: &heavy_train,
                test: &heavy_test,
                partition: iid(&heavy_train, n_clients, &mut Rng::new(7)),
                net: NetModel::edge_default(),
                client_layout: None,
                server_layout: None,
                aux_layout: None,
                label: "fanout-q4".into(),
            };
            let mut tr = Trainer::new(&heavy, cfg, setup).unwrap();
            tr.run().unwrap()
        })
        .median_ns;
    // Work stealing through the full trainer: same results (golden
    // contract), so this row measures pure dealing overhead vs the
    // round-robin threads4 row.
    let steal4_ns = bench
        .run("threads4_steal_8clients_h2_6rounds", || {
            run_fanout(Parallelism::Threads(4), SchedPolicy::WorkStealing)
        })
        .median_ns;
    bench.report();
    snapshot.extend(bench.results().iter().cloned());
    println!(
        "\nfan-out scaling at 8 clients (median): threads2 {:.2}x, threads4 {:.2}x, threads8 {:.2}x vs sequential; steal/rr at threads4 {:.2}x; quantize4 codec overhead at threads4 {:.2}x",
        seq_ns / thr2_ns,
        seq_ns / thr4_ns,
        seq_ns / thr8_ns,
        thr4_ns / steal4_ns,
        quant4_ns / thr4_ns,
    );

    // --- scheduling policies over the raw fan-out: the makespan of 16
    // busy-spin items on 4 workers, dealt per policy. The heavy-tailed
    // profile is adversarial for round-robin: the two 8 ms items sit at
    // positions 0 and 4, so `pos % 4` stacks both on worker 0 (~17 ms
    // makespan) while cost-weighted LPT and work stealing spread them
    // (~8.5 ms). On uniform costs all policies tie — the dealing is
    // free. Results are identical either way; only wall-clock moves.
    let spin = |us: u64| -> u64 {
        let d = Duration::from_micros(us);
        let t0 = Instant::now();
        let mut acc = 0u64;
        while t0.elapsed() < d {
            acc = acc.wrapping_add(1);
            std::hint::black_box(acc);
        }
        acc
    };
    let uniform: Vec<u64> = vec![1_000; 16];
    let heavytail: Vec<u64> =
        (0..16).map(|i| if i == 0 || i == 4 { 8_000 } else { 500 }).collect();
    let sched_workers = 4;
    let mut bench = Bench::new("coordinator/sched")
        .with_times(Duration::from_millis(200), Duration::from_millis(1200));
    let mut medians = std::collections::BTreeMap::new();
    for (profile, spins) in [("uniform", &uniform), ("heavytail", &heavytail)] {
        for policy in SchedPolicy::ALL {
            let costs: Vec<f64> = spins.iter().map(|&us| us as f64).collect();
            let stats = bench.run(&format!("{policy}_{profile}_16items_4workers"), || {
                let out = fanout(policy, sched_workers, spins.clone(), &costs, |_pos, us| {
                    Ok::<_, String>(spin(us))
                })
                .unwrap();
                assert_eq!(out.len(), spins.len());
                out
            });
            medians.insert((policy.to_string(), profile), stats.median_ns);
        }
    }
    bench.report();
    snapshot.extend(bench.results().iter().cloned());
    println!(
        "\nheavy-tailed profile (median makespan): cost-weighted {:.2}x, work-stealing {:.2}x vs round-robin",
        medians[&("rr".to_string(), "heavytail")] / medians[&("cost".to_string(), "heavytail")],
        medians[&("rr".to_string(), "heavytail")] / medians[&("steal".to_string(), "heavytail")],
    );

    // --- the sharded server phase: k server shards (k copies + k event
    // loops, cross-shard FedAvg at aggregation) at the same 8 heavy mock
    // clients. Unlike --parallelism, k changes results — these rows
    // measure the throughput side of the storage/staleness/throughput
    // trade-off (k=1 = CSE-FSL's shared copy, k=8 = FSL_MC-like copies).
    let run_sharded = |shards: usize, par: Parallelism| {
        let cfg = TrainConfig {
            eval_every: 0,
            agg_every: 3,
            lr0: 0.05,
            parallelism: par,
            server_shards: shards,
            ..TrainConfig::new(Method::CseFsl).with_h(2)
        }
        .with_rounds(6);
        let setup = TrainerSetup {
            train: &heavy_train,
            test: &heavy_test,
            partition: iid(&heavy_train, n_clients, &mut Rng::new(7)),
            net: NetModel::edge_default(),
            client_layout: None,
            server_layout: None,
            aux_layout: None,
            label: "sharded".into(),
        };
        let mut tr = Trainer::new(&heavy, cfg, setup).unwrap();
        tr.run().unwrap()
    };
    let mut bench = Bench::new("coordinator/server_shards")
        .with_times(Duration::from_millis(300), Duration::from_millis(1500));
    let k1_ns = bench
        .run("shards1_threads4_8clients", || run_sharded(1, Parallelism::Threads(4)))
        .median_ns;
    let k2_ns = bench
        .run("shards2_threads4_8clients", || run_sharded(2, Parallelism::Threads(4)))
        .median_ns;
    let k4_ns = bench
        .run("shards4_threads4_8clients", || run_sharded(4, Parallelism::Threads(4)))
        .median_ns;
    let k8_ns = bench
        .run("shards8_threads4_8clients", || run_sharded(8, Parallelism::Threads(4)))
        .median_ns;
    bench.report();
    snapshot.extend(bench.results().iter().cloned());
    println!(
        "\nsharded server phase at 8 clients (median): shards2 {:.2}x, shards4 {:.2}x, shards8 {:.2}x vs single copy",
        k1_ns / k2_ns,
        k1_ns / k4_ns,
        k1_ns / k8_ns,
    );

    // --- FedAvg at the paper's exact model sizes (Table II aggregation)
    let mut bench = Bench::new("coordinator/fedavg");
    for (name, size) in [
        ("cifar_client_107k", 107_328usize),
        ("cifar_server_960k", 960_970),
        ("femnist_server_1.19M", 1_187_774),
    ] {
        let mut rng = Rng::new(3);
        let models: Vec<Vec<f32>> =
            (0..5).map(|_| (0..size).map(|_| rng.normal() as f32).collect()).collect();
        let refs: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
        bench.run_with_items(&format!("{name}_5clients"), Some(size as f64), || {
            fedavg(&refs)
        });
        let mut out = vec![0f32; size];
        bench.run_with_items(
            &format!("{name}_accumulator"),
            Some(size as f64),
            || {
                let mut acc = Accumulator::new(size);
                for m in &models {
                    acc.add(m, 1.0);
                }
                acc.finish_into(&mut out);
                out[0]
            },
        );
    }
    bench.report();
    snapshot.extend(bench.results().iter().cloned());

    // --- event queue + ledger (the per-message coordination cost)
    let mut bench = Bench::new("coordinator/plumbing");
    bench.run_with_items("event_queue_push_pop_1k", Some(1000.0), || {
        let mut q = EventQueue::new();
        for i in 0..1000u64 {
            q.schedule_at((i % 37) as f64, i);
        }
        let mut sum = 0u64;
        while let Some((_, e)) = q.pop() {
            sum += e;
        }
        sum
    });
    bench.run_with_items("ledger_record_1k", Some(1000.0), || {
        let mut l = CommLedger::new();
        for i in 0..1000usize {
            l.record(i % 8, MsgKind::SmashedUpload, 9216);
        }
        l.total_bytes()
    });
    bench.run("table2_closed_forms", || {
        let w = WireSizes::new(2304, 107_328, 23_050);
        (table2::fsl_mc(5, 10_000, &w), table2::cse_fsl(5, 10_000, 5, &w))
    });
    bench.report();
    snapshot.extend(bench.results().iter().cloned());

    // --- the streaming population engine: fleet-scale rounds where only
    // the sampled cohort is ever materialized. The resident row at the
    // same n pins the streaming overhead at small scale (results are
    // bit-identical there — tests/population_equivalence.rs); the 100k
    // and 1M rows are the fleet deliverable: per-round work scales with
    // the 64-client cohort, not n (the O(n) parts — broadcast sweep at
    // each aggregation, final eval replay, the one-off skew pass — are
    // cheap scans), and memory stays flat in n. Throughput denominator =
    // population size, so the printed rate reads as clients/s of fleet
    // capacity.
    let run_population = |n: usize, rounds: usize| {
        let e = MockEngine::small(42);
        let source = ClientSource::Pool {
            n_clients: n,
            samples_per_client: 32,
            pool_len: train.len(),
        };
        let setup =
            PopulationSetup::new(&train, &test, source, NetModel::edge_default(), "bench");
        let cfg = TrainConfig {
            eval_every: 0,
            agg_every: 1,
            participation: 64,
            ..TrainConfig::new(Method::CseFsl).with_h(2)
        }
        .with_rounds(rounds);
        let mut tr = Trainer::new_population(&e, cfg, setup).unwrap();
        tr.run().unwrap()
    };
    let mut bench = Bench::new("coordinator/population")
        .with_times(Duration::from_millis(200), Duration::from_millis(1000));
    bench.run("resident_64clients_4rounds", || {
        let e = MockEngine::small(42);
        let cfg = TrainConfig {
            eval_every: 0,
            agg_every: 1,
            participation: 64,
            ..TrainConfig::new(Method::CseFsl).with_h(2)
        }
        .with_rounds(4);
        let setup = TrainerSetup {
            train: &train,
            test: &test,
            partition: iid(&train, 64, &mut Rng::new(7)),
            net: NetModel::edge_default(),
            client_layout: None,
            server_layout: None,
            aux_layout: None,
            label: "bench".into(),
        };
        let mut tr = Trainer::new(&e, cfg, setup).unwrap();
        tr.run().unwrap()
    });
    bench.run_with_items("population_64clients_4rounds", Some(64.0), || {
        let e = MockEngine::small(42);
        let source = ClientSource::Partition(iid(&train, 64, &mut Rng::new(7)));
        let setup =
            PopulationSetup::new(&train, &test, source, NetModel::edge_default(), "bench");
        let cfg = TrainConfig {
            eval_every: 0,
            agg_every: 1,
            participation: 64,
            ..TrainConfig::new(Method::CseFsl).with_h(2)
        }
        .with_rounds(4);
        let mut tr = Trainer::new_population(&e, cfg, setup).unwrap();
        tr.run().unwrap()
    });
    bench.run_with_items("pool_100k_cohort64_3rounds", Some(100_000.0), || {
        run_population(100_000, 3)
    });
    bench.run_with_items("pool_1M_cohort64_2rounds", Some(1_000_000.0), || {
        run_population(1_000_000, 2)
    });
    bench.report();
    snapshot.extend(bench.results().iter().cloned());

    // --- churn over the fleet: the same 100k-pool round with the
    // correlated-outage model, mid-round failures, and quorum
    // re-sampling switched on, vs the churn-free row above. The filter
    // is O(cohort) split-stream draws per round, so this row pins the
    // whole reliability layer's overhead at fleet scale.
    let run_churned_population = |n: usize, rounds: usize| {
        let e = MockEngine::small(42);
        let source = ClientSource::Pool {
            n_clients: n,
            samples_per_client: 32,
            pool_len: train.len(),
        };
        let setup =
            PopulationSetup::new(&train, &test, source, NetModel::edge_default(), "bench");
        let cfg = TrainConfig {
            eval_every: 0,
            agg_every: 1,
            participation: 64,
            ..TrainConfig::new(Method::CseFsl).with_h(2)
        }
        .with_churn(ChurnConfig {
            model: ChurnModel::Correlated { clusters: 32, p_outage: 0.2 },
            fail_rate: 0.05,
            policy: ResiliencePolicy::Quorum { min_frac: 0.8, resample: true },
        })
        .with_rounds(rounds);
        let mut tr = Trainer::new_population(&e, cfg, setup).unwrap();
        tr.run().unwrap()
    };
    let mut bench = Bench::new("coordinator/churn")
        .with_times(Duration::from_millis(200), Duration::from_millis(1000));
    let clean_ns = bench
        .run_with_items("pool_100k_cohort64_3rounds_nochurn", Some(100_000.0), || {
            run_population(100_000, 3)
        })
        .median_ns;
    let churned_ns = bench
        .run_with_items("pool_100k_cohort64_3rounds_churned", Some(100_000.0), || {
            run_churned_population(100_000, 3)
        })
        .median_ns;
    bench.report();
    snapshot.extend(bench.results().iter().cloned());
    println!(
        "\nchurn overhead at 100k clients (median): churned/clean {:.2}x",
        churned_ns / clean_ns,
    );

    if let Ok(path) = std::env::var("CSE_FSL_BENCH_JSON") {
        write_snapshot(&path, "bench_coordinator", &snapshot).unwrap();
        println!("\nbench snapshot written: {path}");
    }
}
