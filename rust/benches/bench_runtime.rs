//! End-to-end hot-path benchmarks through the real PJRT engine: one
//! bench per paper-table workload unit (the per-batch step costs that
//! Table V's load/time trade-offs are built from).
//!
//! Run: `cargo bench --bench bench_runtime` (needs `make artifacts`).

use std::time::Duration;

use cse_fsl::model::init::init_flat;
use cse_fsl::runtime::artifact::Manifest;
use cse_fsl::runtime::pjrt::{PjrtEngine, PjrtRuntime};
use cse_fsl::runtime::{artifacts_dir, SplitEngine};
use cse_fsl::util::bench::Bench;
use cse_fsl::util::prng::Rng;

fn main() {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping bench_runtime: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(&dir).expect("manifest");
    let rt = match PjrtRuntime::new() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping bench_runtime: {e}");
            return;
        }
    };

    for (dataset, aux) in [("femnist", "cnn8"), ("cifar", "cnn27")] {
        let engine = PjrtEngine::new(rt.clone(), &manifest, dataset, aux).expect("engine");
        let cfg = manifest.config(dataset).unwrap();
        let mut rng = Rng::new(1);
        let xc = init_flat(&cfg.client_layout, &mut rng.split_str("c"));
        let ac = init_flat(&cfg.aux(aux).unwrap().layout, &mut rng.split_str("a"));
        let xs = init_flat(&cfg.server_layout, &mut rng.split_str("s"));
        let b = engine.batch();
        let x: Vec<f32> =
            (0..b * engine.input_len()).map(|_| rng.normal() as f32 * 0.5).collect();
        let y: Vec<i32> = (0..b).map(|_| rng.below(engine.classes() as u64) as i32).collect();
        let sm = engine.client_fwd(&xc, &x, 0).expect("fwd");

        let mut bench = Bench::new(&format!("runtime/{dataset}"))
            .with_times(Duration::from_millis(300), Duration::from_millis(1500));
        let items = Some(b as f64);
        bench.run_with_items("client_train_step", items, || {
            engine.client_train_step(&xc, &ac, &x, &y, 0.001, 7).unwrap()
        });
        bench.run_with_items("client_fwd", items, || engine.client_fwd(&xc, &x, 7).unwrap());
        bench.run_with_items("server_train_step", items, || {
            engine.server_train_step(&xs, &sm, &y, 0.001, 7).unwrap()
        });
        bench.run_with_items("server_fwd_bwd", items, || {
            engine.server_fwd_bwd(&xs, &sm, &y, 0.001, 7, 0.0).unwrap()
        });
        bench.run_with_items("client_bwd", items, || {
            engine.client_bwd(&xc, &x, &sm, 0.001, 7, 0.0).unwrap()
        });
        bench.run_with_items("eval_step", items, || engine.eval_step(&xc, &xs, &x).unwrap());
        bench.report();
    }
}
