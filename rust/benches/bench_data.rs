//! Data-substrate benchmarks: synthetic generation and partitioning at
//! experiment scale (these run once per experiment; they must stay far
//! below training cost).

use cse_fsl::data::femnist::{self, FemnistSpec};
use cse_fsl::data::partition::{by_writer, dirichlet, iid};
use cse_fsl::data::synthetic::{generate, SyntheticSpec};
use cse_fsl::util::bench::Bench;
use cse_fsl::util::prng::Rng;

fn main() {
    let mut bench = Bench::new("data/generate");
    bench.run_with_items("cifar_like_1000", Some(1000.0), || {
        generate(&SyntheticSpec::cifar_like(), 1000, 1)
    });
    let fspec = FemnistSpec { writers: 25, samples_per_writer: 40, ..FemnistSpec::default_like() };
    bench.run_with_items("femnist_like_1000", Some(1000.0), || femnist::generate(&fspec, 1));
    bench.report();

    let cifar = generate(&SyntheticSpec::cifar_like(), 2000, 2);
    let fem = femnist::generate(&fspec, 3);
    let mut bench = Bench::new("data/partition");
    bench.run("iid_2000x10", || iid(&cifar, 10, &mut Rng::new(1)));
    bench.run("dirichlet_2000x10", || dirichlet(&cifar, 10, 0.3, &mut Rng::new(2)));
    bench.run("by_writer_1000x10", || by_writer(&fem, 10, &mut Rng::new(3)));
    bench.report();

    let mut bench = Bench::new("data/batching");
    let mut imgs = Vec::new();
    let mut labs = Vec::new();
    let idx: Vec<usize> = (0..50).collect();
    bench.run_with_items("gather_b50_cifar", Some(50.0), || {
        cifar.gather(&idx, &mut imgs, &mut labs);
        imgs.len()
    });
    bench.report();
}
