//! Fault-injection suite for the durable sweep runner (`exp::sweep`):
//! interrupt a sweep after K trials — via the `fail_after` injection
//! hook and via a `kill -9`-style torn journal — then resume and assert
//! journaled trials are not re-executed, the union of work equals the
//! full grid, and the final CSV is byte-identical to an uninterrupted
//! run. Also pins the PR-8 port contract: the three sweep-driven
//! figures (k / h / b) produce CSVs byte-identical to the pre-sweep
//! hand-coded loops, re-rolled verbatim here.

use std::collections::BTreeSet;
use std::io::Write;
use std::path::PathBuf;

use cse_fsl::coordinator::config::{ArrivalOrder, Parallelism, ShardMapKind};
use cse_fsl::coordinator::methods::{Compression, Method};
use cse_fsl::exp::common::{
    cifar_workload, femnist_workload, Dist, EngineChoice, Harness, RunSpec, Scale, Workload,
};
use cse_fsl::exp::figures;
use cse_fsl::exp::sweep::{builtin, recover, run_sweep, SweepOptions, TrialEntry, TrialStatus};
use cse_fsl::sched::SchedPolicy;
use cse_fsl::util::csvio::Csv;

fn tmp(tag: &str, line: u32) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("cse_fsl_{tag}_{}_{line}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The pre-sweep `exp::figures::base_spec`, re-rolled verbatim: the
/// byte-compat pins below must not depend on the refactored code under
/// test for their expected values.
fn old_base_spec(dataset: &str, aux: &str, w: Workload) -> RunSpec {
    RunSpec {
        dataset: dataset.into(),
        aux: aux.into(),
        method: Method::CseFsl.spec(),
        n_clients: 5,
        participation: 0,
        dist: Dist::Iid,
        arrival: ArrivalOrder::ByDelay,
        lr0: if dataset == "cifar" { 0.01 } else { 0.05 },
        seed: 1,
        workload: w,
        parallelism: Parallelism::auto(),
        server_shards: 1,
        sched: SchedPolicy::WorkStealing,
        shard_map: ShardMapKind::Contiguous,
    }
}

#[test]
fn injected_failure_resumes_without_reexecution() {
    // Uninterrupted reference run.
    let dir_a = tmp("sweep_clean", line!());
    let mut ha = Harness::with_engine(&dir_a, EngineChoice::Mock).unwrap();
    let sweeps = builtin("h", Scale::Quick).unwrap();
    // "h" expands to the h × topology grid plus the sage alignment arm;
    // the fault-injection plumbing below exercises the former.
    assert_eq!(sweeps.len(), 2);
    let sw = &sweeps[0];
    let clean = run_sweep(&mut ha, sw, &SweepOptions::default()).unwrap();
    assert_eq!((clean.total, clean.skipped, clean.executed), (4, 0, 4));
    let clean_csv = std::fs::read_to_string(&clean.csv).unwrap();

    // Interrupted run: the injection hook kills the sweep after 2
    // executed trials, leaving exactly 2 journaled lines behind.
    let dir_b = tmp("sweep_fail", line!());
    let mut hb = Harness::with_engine(&dir_b, EngineChoice::Mock).unwrap();
    let err = run_sweep(&mut hb, sw, &SweepOptions { resume: false, fail_after: Some(2) })
        .unwrap_err();
    assert!(err.contains("injected failure"), "{err}");
    let journal_path = dir_b.join("sweeps").join("mock").join("h.jsonl");
    let interrupted = std::fs::read(&journal_path).unwrap();
    assert_eq!(interrupted.iter().filter(|&&b| b == b'\n').count(), 2);

    // Resume: journaled trials are skipped, only the remainder runs,
    // and the journal grows append-only over its interrupted prefix.
    let out = run_sweep(&mut hb, sw, &SweepOptions { resume: true, fail_after: None }).unwrap();
    assert_eq!((out.total, out.skipped, out.executed), (4, 2, 2));
    let resumed = std::fs::read(&journal_path).unwrap();
    assert!(resumed.starts_with(&interrupted), "resume must append, not rewrite");
    assert_eq!(resumed.iter().filter(|&&b| b == b'\n').count(), 4);

    // Union of work == the full grid (by RunSpec::key).
    let (entries, valid) = recover(&resumed);
    assert_eq!(valid, resumed.len());
    let keys: BTreeSet<String> = entries.iter().map(|e| e.key.clone()).collect();
    let want: BTreeSet<String> = sw.trials().unwrap().iter().map(|t| t.spec.key()).collect();
    assert_eq!(keys, want);

    // Final CSV byte-identical to the uninterrupted run.
    assert_eq!(std::fs::read_to_string(&out.csv).unwrap(), clean_csv);

    // A second resume finds everything journaled: fail_after(0) proves
    // zero trials re-execute (it would abort before the first one).
    let again =
        run_sweep(&mut hb, sw, &SweepOptions { resume: true, fail_after: Some(0) }).unwrap();
    assert_eq!((again.skipped, again.executed), (4, 0));
    assert_eq!(std::fs::read_to_string(&again.csv).unwrap(), clean_csv);

    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
}

#[test]
fn torn_journal_line_is_dropped_and_rerun() {
    let dir = tmp("sweep_torn", line!());
    let mut h = Harness::with_engine(&dir, EngineChoice::Mock).unwrap();
    let sweeps = builtin("h", Scale::Quick).unwrap();
    let sw = &sweeps[0];
    let clean = run_sweep(&mut h, sw, &SweepOptions::default()).unwrap();
    let clean_csv = std::fs::read_to_string(&clean.csv).unwrap();

    // kill -9 mid-write: the final journal line is cut mid-bytes.
    let bytes = std::fs::read(&clean.journal).unwrap();
    std::fs::write(&clean.journal, &bytes[..bytes.len() - 7]).unwrap();

    // Resume drops exactly the torn line and re-runs only that trial.
    let out = run_sweep(&mut h, sw, &SweepOptions { resume: true, fail_after: None }).unwrap();
    assert_eq!((out.skipped, out.executed), (3, 1));
    assert_eq!(std::fs::read_to_string(&out.csv).unwrap(), clean_csv);
    let healed = std::fs::read(&clean.journal).unwrap();
    assert_eq!(recover(&healed).1, healed.len(), "healed journal is fully valid");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn duplicate_alien_and_failed_entries_do_not_confuse_resume() {
    let dir = tmp("sweep_dup", line!());
    let mut h = Harness::with_engine(&dir, EngineChoice::Mock).unwrap();
    let sweeps = builtin("h", Scale::Quick).unwrap();
    let sw = &sweeps[0];
    let clean = run_sweep(&mut h, sw, &SweepOptions::default()).unwrap();
    let clean_csv = std::fs::read_to_string(&clean.csv).unwrap();

    // Append a duplicate of the first entry, an Ok entry under a key
    // outside this sweep's expansion, and a Failed retread of the
    // second entry — none of which may change what resume skips.
    let (entries, _) = recover(&std::fs::read(&clean.journal).unwrap());
    let alien = TrialEntry { key: "alien-grid-key".to_string(), ..entries[0].clone() };
    let failed = TrialEntry {
        status: TrialStatus::Failed,
        digest: 0,
        record: String::new(),
        ..entries[1].clone()
    };
    let mut extra = String::new();
    for e in [&entries[0], &alien, &failed] {
        extra.push_str(&e.to_line());
        extra.push('\n');
    }
    let mut f = std::fs::OpenOptions::new().append(true).open(&clean.journal).unwrap();
    f.write_all(extra.as_bytes()).unwrap();
    drop(f);

    let out =
        run_sweep(&mut h, sw, &SweepOptions { resume: true, fail_after: Some(0) }).unwrap();
    assert_eq!((out.skipped, out.executed), (4, 0));
    assert_eq!(std::fs::read_to_string(&out.csv).unwrap(), clean_csv);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fig_h_csv_is_byte_identical_to_pre_sweep_loop() {
    let dir = tmp("fig_h_pin", line!());
    let mut harness = Harness::with_engine(&dir, EngineChoice::Mock).unwrap();
    // The old fig_h body at Quick scale, verbatim.
    let base = old_base_spec("cifar", "cnn27", cifar_workload(Scale::Quick));
    let mut csv = Csv::new(&[
        "series",
        "h",
        "topology",
        "final_accuracy",
        "load_gb",
        "server_storage_params",
        "sim_time",
    ]);
    for &h in &[1usize, 2] {
        let arms = [
            (Method::FslAn.spec().with_period(h), "per-client"),
            (Method::CseFsl.spec().with_period(h), "shared"),
        ];
        for (method, topo) in arms {
            let spec = RunSpec { method, ..base.clone() };
            let rec = harness.run_cached(&spec).unwrap();
            csv.row(&[
                rec.label.clone(),
                h.to_string(),
                topo.to_string(),
                format!("{:.4}", rec.final_accuracy),
                format!("{:.6}", rec.total_gb()),
                rec.server_storage_params.to_string(),
                format!("{:.4}", rec.sim_time),
            ]);
        }
    }
    let report = figures::fig_h(&mut harness, Scale::Quick).unwrap();
    assert!(report.contains("Upload period h x server topology"), "{report}");
    assert_eq!(std::fs::read_to_string(dir.join("fig_h.csv")).unwrap(), csv.to_string());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fig_b_csv_is_byte_identical_to_pre_sweep_loop() {
    let dir = tmp("fig_b_pin", line!());
    let mut harness = Harness::with_engine(&dir, EngineChoice::Mock).unwrap();
    // The old fig_b body at Quick scale, verbatim.
    let base = old_base_spec("cifar", "cnn27", cifar_workload(Scale::Quick));
    let mut csv = Csv::new(&["series", "codec", "final_accuracy", "load_gb", "sim_time"]);
    for &codec in &[Compression::None, Compression::Quantize { bits: 4 }] {
        let spec = RunSpec {
            method: Method::CseFsl.spec().with_period(2).with_compression(codec),
            ..base.clone()
        };
        let rec = harness.run_cached(&spec).unwrap();
        csv.row(&[
            rec.label.clone(),
            codec.to_string(),
            format!("{:.4}", rec.final_accuracy),
            format!("{:.6}", rec.total_gb()),
            format!("{:.4}", rec.sim_time),
        ]);
    }
    let report = figures::fig_b(&mut harness, Scale::Quick).unwrap();
    assert!(report.contains("Accuracy vs wire precision"), "{report}");
    assert_eq!(std::fs::read_to_string(dir.join("fig_b.csv")).unwrap(), csv.to_string());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fig_staleness_csvs_are_byte_identical_to_pre_sweep_loops() {
    let dir = tmp("fig_k_pin", line!());
    let mut harness = Harness::with_engine(&dir, EngineChoice::Mock).unwrap();
    let n_clients = 8usize;
    let h = 2usize; // Quick scale

    // The old fig_staleness IID arm at Quick scale, verbatim.
    let w = cifar_workload(Scale::Quick);
    let mut specs = Vec::new();
    for &k in &[1usize, 2, 4, 8] {
        let base = RunSpec {
            method: Method::CseFsl.spec().with_period(h),
            n_clients,
            server_shards: k,
            shard_map: ShardMapKind::Contiguous,
            ..old_base_spec("cifar", "cnn27", w)
        };
        specs.push(base.clone());
        if k > 1 {
            specs.push(RunSpec { shard_map: ShardMapKind::Balanced, ..base });
        }
    }
    let mut csv = Csv::new(&[
        "series",
        "k",
        "shard_map",
        "final_accuracy",
        "server_storage_params",
        "sim_time",
        "sched_efficiency",
        "shard_divergence",
    ]);
    for spec in &specs {
        let rec = harness.run_cached(spec).unwrap();
        csv.row(&[
            rec.label.clone(),
            spec.server_shards.to_string(),
            spec.shard_map.to_string(),
            format!("{:.4}", rec.final_accuracy),
            rec.server_storage_params.to_string(),
            format!("{:.4}", rec.sim_time),
            format!("{:.4}", rec.sched_efficiency()),
            format!("{:.4}", rec.shard_label_divergence),
        ]);
    }

    // The old non-IID placement arm at Quick scale, verbatim.
    let mut csv_noniid = Csv::new(&[
        "series",
        "dataset",
        "dist",
        "k",
        "shard_map",
        "final_accuracy",
        "shard_divergence",
        "sim_time",
    ]);
    for (dataset, aux, dist, h) in [
        ("cifar", "cnn27", Dist::NonIidDirichlet, h),
        ("femnist", "cnn8", Dist::NonIidWriter, 2),
    ] {
        let w = match dataset {
            "cifar" => cifar_workload(Scale::Quick),
            _ => femnist_workload(Scale::Quick),
        };
        for &k in &[2usize, 4] {
            for map in
                [ShardMapKind::Contiguous, ShardMapKind::Balanced, ShardMapKind::Locality]
            {
                let spec = RunSpec {
                    method: Method::CseFsl.spec().with_period(h),
                    n_clients,
                    dist,
                    server_shards: k,
                    shard_map: map,
                    ..old_base_spec(dataset, aux, w)
                };
                let rec = harness.run_cached(&spec).unwrap();
                csv_noniid.row(&[
                    rec.label.clone(),
                    dataset.to_string(),
                    dist.tag().to_string(),
                    k.to_string(),
                    map.to_string(),
                    format!("{:.4}", rec.final_accuracy),
                    format!("{:.4}", rec.shard_label_divergence),
                    format!("{:.4}", rec.sim_time),
                ]);
            }
        }
    }

    let report = figures::fig_staleness(&mut harness, Scale::Quick).unwrap();
    assert!(report.contains("Accuracy vs server shards k"), "{report}");
    assert!(report.contains("Shard placement on non-IID splits"), "{report}");
    assert_eq!(
        std::fs::read_to_string(dir.join("fig_staleness.csv")).unwrap(),
        csv.to_string()
    );
    assert_eq!(
        std::fs::read_to_string(dir.join("fig_staleness_noniid.csv")).unwrap(),
        csv_noniid.to_string()
    );
    let _ = std::fs::remove_dir_all(&dir);
}
