//! Wire- and storage-accounting properties (util/prop harness): across
//! random `(method, n, h, agg_every, rounds, parallelism, server_shards,
//! compression)` configurations the live `CommLedger` must equal the
//! generalized closed forms in `comm::accounting::predict` (which reduce
//! to the paper's Table II per-epoch forms at `Compression::None`), the
//! ledger's client-side and server-side views must conserve bytes per
//! message kind, and the server's resident parameters must equal the
//! `comm::accounting::storage` closed form for every shard count k.

use cse_fsl::comm::accounting::{predict, storage as storage_form, table2, MsgKind, WireSizes};
use cse_fsl::coordinator::config::{Parallelism, TrainConfig};
use cse_fsl::coordinator::methods::{Compression, Method, ServerTopology};
use cse_fsl::coordinator::round::{Trainer, TrainerSetup};
use cse_fsl::data::partition::iid;
use cse_fsl::data::synthetic::{generate, SyntheticSpec};
use cse_fsl::prop_assert;
use cse_fsl::runtime::mock::MockEngine;
use cse_fsl::runtime::SplitEngine;
use cse_fsl::sim::netmodel::NetModel;
use cse_fsl::util::prng::Rng;
use cse_fsl::util::prop;

fn spec() -> SyntheticSpec {
    SyntheticSpec { height: 2, width: 2, channels: 2, classes: 3, ..SyntheticSpec::cifar_like() }
}

fn random_parallelism(rng: &mut Rng) -> Parallelism {
    if rng.below(2) == 0 {
        Parallelism::Sequential
    } else {
        Parallelism::Threads(1 + rng.below(4) as usize)
    }
}

fn random_compression(rng: &mut Rng) -> Compression {
    match rng.below(3) {
        0 => Compression::None,
        1 => Compression::Quantize { bits: 2 + rng.below(7) as u8 },
        // frac on a fixed grid inside (0, 1] — the formulas must hold
        // at any kept fraction, including frac = 1 (all entries kept).
        _ => Compression::TopK { frac: (1 + rng.below(20) as u32) as f32 / 20.0 },
    }
}

/// A random trainer run; returns the trainer (ledger inspection) plus
/// the configuration numbers the closed forms need.
struct RandomRun {
    method: Method,
    n: usize,
    h: usize,
    rounds: usize,
    agg_every: usize,
    server_shards: usize,
    compression: Compression,
    batch: usize,
    server_size: usize,
    wires: WireSizes,
    ledger: cse_fsl::comm::accounting::CommLedger,
    resident_params: usize,
    record: cse_fsl::metrics::recorder::RunRecord,
}

fn run_random(rng: &mut Rng, participation: usize) -> Result<RandomRun, String> {
    let n = 1 + rng.below(5) as usize;
    let method = Method::ALL[rng.below(4) as usize];
    // Any aux-local preset takes a random period — including FSL_AN,
    // whose h > 1 points are the spec-only scenarios the open API
    // unlocked (the closed forms must hold there too: bytes per round
    // are h-independent).
    let h = if method.spec().update.uses_aux() { 1 + rng.below(4) as usize } else { 1 };
    let rounds = 1 + rng.below(10) as usize;
    let agg_every = 1 + rng.below(rounds as u64 + 3) as usize;
    // Random shard count on the shared topology (wire traffic must be
    // shard-independent; storage must follow the closed form).
    let server_shards = match method.spec().topology {
        ServerTopology::PerClient => 1,
        ServerTopology::Shared => 1 + rng.below(n as u64) as usize,
    };
    // The wire codec composes with every preset (it is a spec axis, not
    // a method): the closed forms must track the ledger at any point.
    let compression = random_compression(rng);
    let e = MockEngine::small(rng.next_u64());
    let train = generate(&spec(), n * 16, rng.next_u64());
    let test = generate(&spec(), 8, rng.next_u64());
    let cfg = TrainConfig {
        rounds,
        agg_every,
        eval_every: 0,
        participation: participation.min(n),
        parallelism: random_parallelism(rng),
        server_shards,
        ..TrainConfig::new(method).with_h(h).with_compression(compression)
    };
    let setup = TrainerSetup {
        train: &train,
        test: &test,
        partition: iid(&train, n, &mut Rng::new(rng.next_u64())),
        net: NetModel::edge_default(),
        client_layout: None,
        server_layout: None,
        aux_layout: None,
        label: "prop".into(),
    };
    let mut tr = Trainer::new(&e, cfg, setup)?;
    let record = tr.run().map_err(|e| e.to_string())?;
    Ok(RandomRun {
        method,
        n,
        h,
        rounds,
        agg_every,
        server_shards,
        compression,
        batch: e.batch,
        server_size: e.server_size(),
        wires: WireSizes::new(e.smashed_len, e.client_size(), e.aux_size()),
        ledger: tr.ledger.clone(),
        resident_params: tr.server.resident_params(),
        record,
    })
}

#[test]
fn prop_ledger_matches_generalized_closed_forms() {
    prop::check("ledger == predict closed forms", |rng| {
        // Full participation: the closed forms count every client each
        // round and every client at each aggregation.
        let r = run_random(rng, 0)?;
        let p = r.method.spec().traffic();
        let expected = predict::run_kind_bytes(
            p,
            r.compression,
            r.n as u64,
            r.batch as u64,
            r.rounds as u64,
            r.agg_every as u64,
            &r.wires,
        );
        for (kind, bytes) in expected {
            prop_assert!(
                r.ledger.bytes_of(kind) == bytes,
                "{} {} n={} h={} rounds={} agg={}: {kind:?} measured {} != predicted {bytes}",
                r.method,
                r.compression,
                r.n,
                r.h,
                r.rounds,
                r.agg_every,
                r.ledger.bytes_of(kind)
            );
        }
        let (up, down) = predict::run_totals(
            p,
            r.compression,
            r.n as u64,
            r.batch as u64,
            r.rounds as u64,
            r.agg_every as u64,
            &r.wires,
        );
        prop_assert!(
            r.ledger.up_bytes() == up,
            "uplink measured {} != predicted {up}",
            r.ledger.up_bytes()
        );
        prop_assert!(
            r.ledger.down_bytes() == down,
            "downlink measured {} != predicted {down}",
            r.ledger.down_bytes()
        );
        Ok(())
    });
}

#[test]
fn prop_ledger_views_conserve_bytes_per_kind() {
    prop::check("client view == server view", |rng| {
        // Partial participation allowed: conservation is schedule-free.
        let participation = rng.below(4) as usize; // 0 = all
        let r = run_random(rng, participation)?;
        for kind in MsgKind::ALL {
            let client_sum: u64 = r
                .ledger
                .clients()
                .iter()
                .map(|&c| r.ledger.client_kind_bytes(c, kind))
                .sum();
            prop_assert!(
                client_sum == r.ledger.bytes_of(kind),
                "{kind:?}: client-side view {client_sum} != server-side {}",
                r.ledger.bytes_of(kind)
            );
        }
        for c in r.ledger.clients() {
            let kind_sum: u64 =
                MsgKind::ALL.iter().map(|&k| r.ledger.client_kind_bytes(c, k)).sum();
            prop_assert!(
                kind_sum == r.ledger.client_bytes(c),
                "client {c}: per-kind sum {kind_sum} != client total {}",
                r.ledger.client_bytes(c)
            );
        }
        prop_assert!(
            r.ledger.up_bytes() + r.ledger.down_bytes() == r.ledger.total_bytes(),
            "direction split does not cover the total"
        );
        Ok(())
    });
}

#[test]
fn prop_generalized_forms_reduce_to_table2_epoch_forms() {
    prop::check("predict reduces to Table II", |rng| {
        let n = 1 + rng.below(50);
        let batch = 1 + rng.below(100);
        let h = 1 + rng.below(10);
        let rounds = 1 + rng.below(50);
        let w = WireSizes::new(
            1 + rng.below(4096) as usize,
            1 + rng.below(200_000) as usize,
            1 + rng.below(50_000) as usize,
        );
        // CSE_FSL_h epoch: |D_i| = batch*h*rounds, aggregate once. The
        // Table II forms predate the wire codec, so the reduction holds
        // at Compression::None (the codec-free point of the axis).
        let d_cse = batch * h * rounds;
        let p = predict::TrafficProfile::AuxLocal;
        let (up, down) =
            predict::run_totals(p, Compression::None, n, batch, rounds, rounds, &w);
        prop_assert!(
            up + down == table2::cse_fsl(n, d_cse, h, &w),
            "CSE: {} != table2 {}",
            up + down,
            table2::cse_fsl(n, d_cse, h, &w)
        );
        // FSL_MC / FSL_AN epochs: h = 1, |D_i| = batch*rounds.
        let d1 = batch * rounds;
        let p = predict::TrafficProfile::ServerGrad;
        let (up, down) =
            predict::run_totals(p, Compression::None, n, batch, rounds, rounds, &w);
        prop_assert!(up + down == table2::fsl_mc(n, d1, &w), "MC mismatch");
        let p = predict::TrafficProfile::AuxLocal;
        let (up, down) =
            predict::run_totals(p, Compression::None, n, batch, rounds, rounds, &w);
        prop_assert!(up + down == table2::fsl_an(n, d1, &w), "AN mismatch");
        Ok(())
    });
}

#[test]
fn prop_sharded_storage_matches_closed_form_for_all_k() {
    prop::check("resident storage == copies x |w_s| closed form", |rng| {
        let r = run_random(rng, 0)?;
        let copies = cse_fsl::storage::server_model_copies_sharded(
            &r.method.spec(),
            r.n,
            r.server_shards,
        );
        // Live server-resident parameters equal the closed form
        // (copies × partial-model size) for every shard count k —
        // reducing to Table II at k = 1 and k = n.
        let expect =
            storage_form::server_copies_params(copies as u64, r.server_size as u64);
        prop_assert!(
            r.resident_params as u64 == expect,
            "{} n={} k={}: resident {} != closed form {expect}",
            r.method,
            r.n,
            r.server_shards,
            r.resident_params
        );
        // The RunRecord reports the full Table-V-style total for the
        // same (method, n, k).
        let sizes = cse_fsl::storage::ModelSizes {
            client: (r.wires.client_model / 4) as usize,
            server: r.server_size,
            aux: (r.wires.aux_model / 4) as usize,
        };
        let total = cse_fsl::storage::server_storage_params_sharded(
            &r.method.spec(),
            r.n,
            r.server_shards,
            &sizes,
        );
        prop_assert!(
            r.record.server_storage_params == total,
            "{} n={} k={}: recorded {} != accounted {total}",
            r.method,
            r.n,
            r.server_shards,
            r.record.server_storage_params
        );
        // Per-shard update counts conserve the total and match the copy
        // count.
        prop_assert!(
            r.record.server_updates_per_shard.len() == copies,
            "per-shard vector has {} entries for {copies} copies",
            r.record.server_updates_per_shard.len()
        );
        // Wire-traffic shard-independence is covered by
        // `prop_ledger_matches_generalized_closed_forms`: it runs the
        // same random-k configurations against closed forms that have
        // no k term, so any k-dependent ledger regression fails there.
        Ok(())
    });
}
