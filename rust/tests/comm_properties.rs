//! Wire-accounting properties (util/prop harness): across random
//! `(method, n, h, agg_every, rounds, parallelism)` configurations the
//! live `CommLedger` must equal the generalized closed forms in
//! `comm::accounting::predict` (which reduce to the paper's Table II
//! per-epoch forms), and the ledger's client-side and server-side views
//! must conserve bytes per message kind.

use cse_fsl::comm::accounting::{predict, table2, MsgKind, WireSizes};
use cse_fsl::coordinator::config::{Parallelism, TrainConfig};
use cse_fsl::coordinator::methods::Method;
use cse_fsl::coordinator::round::{Trainer, TrainerSetup};
use cse_fsl::data::partition::iid;
use cse_fsl::data::synthetic::{generate, SyntheticSpec};
use cse_fsl::prop_assert;
use cse_fsl::runtime::mock::MockEngine;
use cse_fsl::runtime::SplitEngine;
use cse_fsl::sim::netmodel::NetModel;
use cse_fsl::util::prng::Rng;
use cse_fsl::util::prop;

fn spec() -> SyntheticSpec {
    SyntheticSpec { height: 2, width: 2, channels: 2, classes: 3, ..SyntheticSpec::cifar_like() }
}

fn random_parallelism(rng: &mut Rng) -> Parallelism {
    if rng.below(2) == 0 {
        Parallelism::Sequential
    } else {
        Parallelism::Threads(1 + rng.below(4) as usize)
    }
}

/// A random trainer run; returns the trainer (ledger inspection) plus
/// the configuration numbers the closed forms need.
struct RandomRun {
    method: Method,
    n: usize,
    h: usize,
    rounds: usize,
    agg_every: usize,
    batch: usize,
    wires: WireSizes,
    ledger: cse_fsl::comm::accounting::CommLedger,
}

fn run_random(rng: &mut Rng, participation: usize) -> Result<RandomRun, String> {
    let n = 1 + rng.below(5) as usize;
    let method = Method::ALL[rng.below(4) as usize];
    let h = if method.supports_h() { 1 + rng.below(4) as usize } else { 1 };
    let rounds = 1 + rng.below(10) as usize;
    let agg_every = 1 + rng.below(rounds as u64 + 3) as usize;
    let e = MockEngine::small(rng.next_u64());
    let train = generate(&spec(), n * 16, rng.next_u64());
    let test = generate(&spec(), 8, rng.next_u64());
    let cfg = TrainConfig {
        h,
        rounds,
        agg_every,
        eval_every: 0,
        participation: participation.min(n),
        parallelism: random_parallelism(rng),
        ..TrainConfig::new(method)
    };
    let setup = TrainerSetup {
        train: &train,
        test: &test,
        partition: iid(&train, n, &mut Rng::new(rng.next_u64())),
        net: NetModel::edge_default(),
        client_layout: None,
        server_layout: None,
        aux_layout: None,
        label: "prop".into(),
    };
    let mut tr = Trainer::new(&e, cfg, setup)?;
    tr.run().map_err(|e| e.to_string())?;
    Ok(RandomRun {
        method,
        n,
        h,
        rounds,
        agg_every,
        batch: e.batch,
        wires: WireSizes::new(e.smashed_len, e.client_size(), e.aux_size()),
        ledger: tr.ledger.clone(),
    })
}

#[test]
fn prop_ledger_matches_generalized_closed_forms() {
    prop::check("ledger == predict closed forms", |rng| {
        // Full participation: the closed forms count every client each
        // round and every client at each aggregation.
        let r = run_random(rng, 0)?;
        let p = predict::TrafficProfile {
            grad_downlink: r.method.grad_downlink(),
            uses_aux: r.method.uses_aux(),
        };
        let expected = predict::run_kind_bytes(
            p,
            r.n as u64,
            r.batch as u64,
            r.rounds as u64,
            r.agg_every as u64,
            &r.wires,
        );
        for (kind, bytes) in expected {
            prop_assert!(
                r.ledger.bytes_of(kind) == bytes,
                "{} n={} h={} rounds={} agg={}: {kind:?} measured {} != predicted {bytes}",
                r.method,
                r.n,
                r.h,
                r.rounds,
                r.agg_every,
                r.ledger.bytes_of(kind)
            );
        }
        let (up, down) = predict::run_totals(
            p,
            r.n as u64,
            r.batch as u64,
            r.rounds as u64,
            r.agg_every as u64,
            &r.wires,
        );
        prop_assert!(
            r.ledger.up_bytes() == up,
            "uplink measured {} != predicted {up}",
            r.ledger.up_bytes()
        );
        prop_assert!(
            r.ledger.down_bytes() == down,
            "downlink measured {} != predicted {down}",
            r.ledger.down_bytes()
        );
        Ok(())
    });
}

#[test]
fn prop_ledger_views_conserve_bytes_per_kind() {
    prop::check("client view == server view", |rng| {
        // Partial participation allowed: conservation is schedule-free.
        let participation = rng.below(4) as usize; // 0 = all
        let r = run_random(rng, participation)?;
        for kind in MsgKind::ALL {
            let client_sum: u64 = r
                .ledger
                .clients()
                .iter()
                .map(|&c| r.ledger.client_kind_bytes(c, kind))
                .sum();
            prop_assert!(
                client_sum == r.ledger.bytes_of(kind),
                "{kind:?}: client-side view {client_sum} != server-side {}",
                r.ledger.bytes_of(kind)
            );
        }
        for c in r.ledger.clients() {
            let kind_sum: u64 =
                MsgKind::ALL.iter().map(|&k| r.ledger.client_kind_bytes(c, k)).sum();
            prop_assert!(
                kind_sum == r.ledger.client_bytes(c),
                "client {c}: per-kind sum {kind_sum} != client total {}",
                r.ledger.client_bytes(c)
            );
        }
        prop_assert!(
            r.ledger.up_bytes() + r.ledger.down_bytes() == r.ledger.total_bytes(),
            "direction split does not cover the total"
        );
        Ok(())
    });
}

#[test]
fn prop_generalized_forms_reduce_to_table2_epoch_forms() {
    prop::check("predict reduces to Table II", |rng| {
        let n = 1 + rng.below(50);
        let batch = 1 + rng.below(100);
        let h = 1 + rng.below(10);
        let rounds = 1 + rng.below(50);
        let w = WireSizes::new(
            1 + rng.below(4096) as usize,
            1 + rng.below(200_000) as usize,
            1 + rng.below(50_000) as usize,
        );
        // CSE_FSL_h epoch: |D_i| = batch*h*rounds, aggregate once.
        let d_cse = batch * h * rounds;
        let p = predict::TrafficProfile { grad_downlink: false, uses_aux: true };
        let (up, down) = predict::run_totals(p, n, batch, rounds, rounds, &w);
        prop_assert!(
            up + down == table2::cse_fsl(n, d_cse, h, &w),
            "CSE: {} != table2 {}",
            up + down,
            table2::cse_fsl(n, d_cse, h, &w)
        );
        // FSL_MC / FSL_AN epochs: h = 1, |D_i| = batch*rounds.
        let d1 = batch * rounds;
        let p = predict::TrafficProfile { grad_downlink: true, uses_aux: false };
        let (up, down) = predict::run_totals(p, n, batch, rounds, rounds, &w);
        prop_assert!(up + down == table2::fsl_mc(n, d1, &w), "MC mismatch");
        let p = predict::TrafficProfile { grad_downlink: false, uses_aux: true };
        let (up, down) = predict::run_totals(p, n, batch, rounds, rounds, &w);
        prop_assert!(up + down == table2::fsl_an(n, d1, &w), "AN mismatch");
        Ok(())
    });
}
