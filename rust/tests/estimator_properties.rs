//! Gradient-estimator (FSL-SAGE) alignment properties: across random
//! `(align_every, codec, h, agg_every, rounds, parallelism)`
//! configurations the live `CommLedger` of a sage run must equal the
//! `comm::accounting::predict` closed forms; at `align_every = 1` the
//! gradient-downlink records bit-reduce to the server-grad rule's
//! per-upload shape; once `align_every > rounds` the whole run is
//! byte-identical to the aux-local rule; the estimator error (aux-net
//! distance to its mock target) is non-increasing across alignment
//! events; and the alignment rng splits keep the repo's determinism
//! contract (repeat invocations and thread counts are invisible).

use cse_fsl::comm::accounting::{predict, MsgKind, WireSizes};
use cse_fsl::coordinator::config::{ArrivalOrder, Parallelism, TrainConfig};
use cse_fsl::coordinator::methods::{ClientUpdate, Compression, Method, MethodSpec};
use cse_fsl::coordinator::round::{Trainer, TrainerSetup};
use cse_fsl::data::partition::iid;
use cse_fsl::data::synthetic::{generate, SyntheticSpec};
use cse_fsl::data::Dataset;
use cse_fsl::exp::common::run_to_json;
use cse_fsl::prop_assert;
use cse_fsl::runtime::mock::MockEngine;
use cse_fsl::runtime::SplitEngine;
use cse_fsl::sched::SchedPolicy;
use cse_fsl::sim::netmodel::NetModel;
use cse_fsl::util::prng::Rng;
use cse_fsl::util::prop;

fn spec() -> SyntheticSpec {
    SyntheticSpec { height: 2, width: 2, channels: 2, classes: 3, ..SyntheticSpec::cifar_like() }
}

fn dataset(n: usize, seed: u64) -> Dataset {
    generate(&spec(), n, seed)
}

fn sage_spec(align_every: usize, clip: f32) -> MethodSpec {
    MethodSpec {
        update: ClientUpdate::SageEstimate { align_every, clip },
        ..Method::CseFsl.spec()
    }
}

fn setup<'a>(train: &'a Dataset, test: &'a Dataset, n_clients: usize) -> TrainerSetup<'a> {
    TrainerSetup {
        train,
        test,
        partition: iid(train, n_clients, &mut Rng::new(7)),
        net: NetModel::edge_default(),
        client_layout: None,
        server_layout: None,
        aux_layout: None,
        label: "sage".to_string(),
    }
}

#[test]
fn prop_sage_ledger_matches_predict_closed_forms() {
    prop::check("sage ledger == predict closed forms", |rng| {
        // Random alignment period × codec × schedule, full participation
        // (the closed forms count every client at every alignment).
        let n = 1 + rng.below(5) as usize;
        let align_every = 1 + rng.below(6) as usize;
        let h = 1 + rng.below(4) as usize;
        let rounds = 1 + rng.below(10) as usize;
        let agg_every = 1 + rng.below(rounds as u64 + 3) as usize;
        let compression = match rng.below(3) {
            0 => Compression::None,
            1 => Compression::Quantize { bits: 2 + rng.below(7) as u8 },
            _ => Compression::TopK { frac: (1 + rng.below(20) as u32) as f32 / 20.0 },
        };
        let clip = if rng.below(2) == 0 { 0.0 } else { 0.5 };
        let parallelism = if rng.below(2) == 0 {
            Parallelism::Sequential
        } else {
            Parallelism::Threads(1 + rng.below(4) as usize)
        };
        let e = MockEngine::small(rng.next_u64());
        let train = generate(&spec(), n * 16, rng.next_u64());
        let test = generate(&spec(), 8, rng.next_u64());
        let cfg = TrainConfig {
            rounds,
            agg_every,
            eval_every: 0,
            parallelism,
            ..TrainConfig::from_spec(
                sage_spec(align_every, clip)
                    .with_period(h)
                    .with_compression(compression),
            )
        };
        let mut tr = Trainer::new(&e, cfg, setup(&train, &test, n))?;
        tr.run().map_err(|e| e.to_string())?;
        let w = WireSizes::new(e.smashed_len, e.client_size(), e.aux_size());
        let p = predict::TrafficProfile::SageEstimate { align_every: align_every as u64 };
        for (kind, bytes) in predict::run_kind_bytes(
            p,
            compression,
            n as u64,
            e.batch as u64,
            rounds as u64,
            agg_every as u64,
            &w,
        ) {
            prop_assert!(
                tr.ledger.bytes_of(kind) == bytes,
                "a={align_every} {compression} n={n} h={h} rounds={rounds} agg={agg_every}: \
                 {kind:?} measured {} != predicted {bytes}",
                tr.ledger.bytes_of(kind)
            );
        }
        let (up, down) = predict::run_totals(
            p,
            compression,
            n as u64,
            e.batch as u64,
            rounds as u64,
            agg_every as u64,
            &w,
        );
        prop_assert!(
            tr.ledger.up_bytes() == up && tr.ledger.down_bytes() == down,
            "totals measured ({}, {}) != predicted ({up}, {down})",
            tr.ledger.up_bytes(),
            tr.ledger.down_bytes()
        );
        // The alignment downlink count is exactly one record per client
        // per alignment round.
        prop_assert!(
            tr.ledger.count_of(MsgKind::GradDownload)
                == (rounds / align_every) as u64 * n as u64,
            "a={align_every} rounds={rounds}: {} downlink records",
            tr.ledger.count_of(MsgKind::GradDownload)
        );
        Ok(())
    });
}

fn run_trainer<'a, 'b>(
    e: &'a MockEngine,
    cfg: TrainConfig,
    train: &'b Dataset,
    test: &'b Dataset,
) -> Trainer<'a, MockEngine>
where
    'b: 'a,
{
    let mut tr = Trainer::new(e, cfg, setup(train, test, 5)).unwrap();
    tr.run().unwrap();
    tr
}

fn base_cfg(spec_point: MethodSpec, rounds: usize) -> TrainConfig {
    TrainConfig {
        agg_every: 4,
        eval_every: 3,
        eval_max_batches: 2,
        lr0: 1.0,
        track_grad_norms: true,
        ..TrainConfig::from_spec(spec_point)
    }
    .with_rounds(rounds)
}

#[test]
fn align_every_one_bit_reduces_to_server_grad_record_shape() {
    // At a = 1 every upload triggers the true-gradient downlink: the
    // GradDownload records (count, per-record bytes, per-client bytes)
    // are exactly the server-grad rule's per-upload shape.
    let train = dataset(120, 31);
    let test = dataset(24, 32);
    let e = MockEngine::small(42);
    for codec in [Compression::None, Compression::Quantize { bits: 4 }] {
        let sage = run_trainer(
            &e,
            base_cfg(sage_spec(1, 0.0).with_compression(codec), 12),
            &train,
            &test,
        );
        let grad = run_trainer(
            &e,
            base_cfg(Method::FslOc.spec().with_compression(codec), 12),
            &train,
            &test,
        );
        assert_eq!(
            sage.ledger.count_of(MsgKind::GradDownload),
            grad.ledger.count_of(MsgKind::GradDownload),
            "{codec}: record count"
        );
        assert_eq!(
            sage.ledger.bytes_of(MsgKind::GradDownload),
            grad.ledger.bytes_of(MsgKind::GradDownload),
            "{codec}: record bytes"
        );
        for c in 0..5 {
            assert_eq!(
                sage.ledger.client_kind_bytes(c, MsgKind::GradDownload),
                grad.ledger.client_kind_bytes(c, MsgKind::GradDownload),
                "{codec}: client {c} downlink bytes"
            );
        }
        // 12 rounds × 5 clients, one record each.
        assert_eq!(sage.ledger.count_of(MsgKind::GradDownload), 60, "{codec}");
    }
}

#[test]
fn align_every_beyond_rounds_is_byte_identical_to_aux_local() {
    // Once align_every > rounds no alignment ever fires: the run IS the
    // aux-local rule — identical ledger (every view), identical final
    // models, identical per-round records.
    let train = dataset(120, 33);
    let test = dataset(24, 34);
    let e = MockEngine::small(42);
    let mut sage = Trainer::new(
        &e,
        base_cfg(sage_spec(13, 0.0), 12),
        setup(&train, &test, 5),
    )
    .unwrap();
    let sage_rec = sage.run().unwrap();
    let mut aux = Trainer::new(
        &e,
        base_cfg(Method::CseFsl.spec(), 12),
        setup(&train, &test, 5),
    )
    .unwrap();
    let aux_rec = aux.run().unwrap();
    assert_eq!(sage.ledger, aux.ledger, "ledgers diverged");
    assert_eq!(sage.ledger.bytes_of(MsgKind::GradDownload), 0);
    let models = |tr: &Trainer<'_, MockEngine>| {
        (
            tr.clients.iter().map(|c| c.xc.clone()).collect::<Vec<_>>(),
            tr.clients.iter().map(|c| c.ac.clone()).collect::<Vec<_>>(),
        )
    };
    assert_eq!(models(&sage), models(&aux), "model trajectories diverged");
    assert_eq!(
        run_to_json(&sage_rec).pretty().as_bytes(),
        run_to_json(&aux_rec).pretty().as_bytes(),
        "per-round records diverged"
    );
}

#[test]
fn estimator_error_non_increasing_across_alignment_events() {
    // The mock's aux dynamics contract toward the target every training
    // step, and the alignment re-fit is one more such step — so the
    // estimator error (mean aux distance to target) measured after k
    // alignment events is non-increasing in k. `lr_at` depends only on
    // the round index, so a shorter run is a bit-identical prefix of a
    // longer one and "after k events" is simply rounds = k·a.
    let train = dataset(120, 35);
    let test = dataset(24, 36);
    let e = MockEngine::small(42);
    let aux_err = |tr: &Trainer<'_, MockEngine>| {
        let (_, target_aux, _) = e.targets();
        let dist = |ac: &[f32]| {
            ac.iter()
                .zip(target_aux)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>()
                .sqrt() as f64
        };
        tr.clients.iter().map(|c| dist(&c.ac)).sum::<f64>() / tr.clients.len() as f64
    };
    let align_every = 3;
    let mut last = f64::INFINITY;
    for events in 1..=3usize {
        let tr = run_trainer(
            &e,
            base_cfg(sage_spec(align_every, 0.0), align_every * events),
            &train,
            &test,
        );
        let err = aux_err(&tr);
        assert!(
            err <= last,
            "estimator error rose across alignment event {events}: {err} > {last}"
        );
        assert!(err.is_finite() && err > 0.0);
        last = err;
    }
}

#[test]
fn alignment_rng_split_is_deterministic() {
    // Repeat invocations replay bit-for-bit, and the alignment pass —
    // which consumes drain-loop gradients sorted into canonical client
    // order — keeps the golden contract under shuffled arrivals and any
    // thread count × dealing policy.
    let train = dataset(120, 37);
    let test = dataset(24, 38);
    let e = MockEngine::small(42);
    let run_with = |parallelism: Parallelism, sched: SchedPolicy| {
        let cfg = TrainConfig {
            arrival: ArrivalOrder::Shuffled,
            parallelism,
            sched,
            ..base_cfg(
                sage_spec(3, 0.5).with_compression(Compression::Quantize { bits: 4 }),
                12,
            )
        };
        let mut tr = Trainer::new(&e, cfg, setup(&train, &test, 5)).unwrap();
        let rec = tr.run().unwrap();
        (run_to_json(&rec).pretty(), tr.ledger.clone())
    };
    let (seq_json, seq_ledger) = run_with(Parallelism::Sequential, SchedPolicy::RoundRobin);
    let (again_json, again_ledger) =
        run_with(Parallelism::Sequential, SchedPolicy::RoundRobin);
    assert_eq!(seq_json.as_bytes(), again_json.as_bytes(), "repeat invocation diverged");
    assert_eq!(seq_ledger, again_ledger);
    for sched in SchedPolicy::ALL {
        for threads in [1usize, 4] {
            let (par_json, par_ledger) = run_with(Parallelism::Threads(threads), sched);
            assert_eq!(
                seq_json.as_bytes(),
                par_json.as_bytes(),
                "sched={sched} threads={threads}: RunRecord diverged"
            );
            assert_eq!(seq_ledger, par_ledger, "sched={sched} threads={threads}");
        }
    }
}
