//! Golden determinism: the parallel round engine must be invisible.
//!
//! The contract (coordinator/README.md): for any method, any server
//! shard count, any scheduling policy, and any thread count,
//! `Parallelism::Threads(n)` with any `SchedPolicy` produces a
//! **bit-identical** run to `Parallelism::Sequential` — same
//! `RunRecord` JSON (every loss, byte count, and simulated timestamp),
//! same timeline span sequence, same communication ledger, same final
//! model states. These tests pin that contract over the mock engine for
//! all four methods, for the sharded server phase
//! (`server_shards` ∈ {1, 2, n}), and for every dealing policy.
//! Changing the *shard count* or the *shard map* is allowed (and
//! expected) to change results — which is exactly why both are part of
//! `RunSpec::key` — but the thread count and dealing policy never may.

use cse_fsl::comm::accounting::CommLedger;
use cse_fsl::coordinator::config::{ArrivalOrder, Parallelism, ShardMapKind, TrainConfig};
use cse_fsl::coordinator::methods::{
    ClientUpdate, Compression, Method, MethodSpec, ServerTopology, UploadSchedule,
};
use cse_fsl::coordinator::round::{Trainer, TrainerSetup};
use cse_fsl::data::partition::{iid, Partition};
use cse_fsl::data::synthetic::{generate, SyntheticSpec};
use cse_fsl::data::Dataset;
use cse_fsl::exp::common::run_to_json;
use cse_fsl::metrics::recorder::RunRecord;
use cse_fsl::runtime::mock::MockEngine;
use cse_fsl::runtime::SplitEngine;
use cse_fsl::sched::SchedPolicy;
use cse_fsl::sim::netmodel::NetModel;
use cse_fsl::sim::timeline::Timeline;
use cse_fsl::util::prng::Rng;

fn spec() -> SyntheticSpec {
    SyntheticSpec { height: 2, width: 2, channels: 2, classes: 3, ..SyntheticSpec::cifar_like() }
}

fn dataset(n: usize, seed: u64) -> Dataset {
    generate(&spec(), n, seed)
}

fn setup_net<'a>(
    train: &'a Dataset,
    test: &'a Dataset,
    n_clients: usize,
    net: NetModel,
) -> TrainerSetup<'a> {
    let mut rng = Rng::new(7);
    TrainerSetup {
        train,
        test,
        partition: iid(train, n_clients, &mut rng),
        net,
        client_layout: None,
        server_layout: None,
        aux_layout: None,
        label: "golden".to_string(),
    }
}

fn setup<'a>(train: &'a Dataset, test: &'a Dataset, n_clients: usize) -> TrainerSetup<'a> {
    setup_net(train, test, n_clients, NetModel::edge_default())
}

/// Everything observable about a finished run.
struct Fingerprint {
    json: String,
    timeline: Timeline,
    ledger: CommLedger,
    client_models: Vec<Vec<f32>>,
    client_aux: Vec<Vec<f32>>,
    server_copies: Vec<Vec<f32>>,
    server_updates: u64,
    shard_updates: Vec<u64>,
    shard_of: Vec<usize>,
    divergence: f64,
}

fn fingerprint<E: SplitEngine>(tr: &Trainer<'_, E>, rec: &RunRecord) -> Fingerprint {
    Fingerprint {
        json: run_to_json(rec).pretty(),
        timeline: tr.timeline.clone(),
        ledger: tr.ledger.clone(),
        client_models: tr.clients.iter().map(|c| c.xc.clone()).collect(),
        client_aux: tr.clients.iter().map(|c| c.ac.clone()).collect(),
        server_copies: tr.server.copies.clone(),
        server_updates: tr.server.updates,
        shard_updates: tr.server.shard_updates.clone(),
        shard_of: (0..tr.clients.len()).map(|c| tr.server.shard_map.shard_of(c)).collect(),
        divergence: rec.shard_label_divergence,
    }
}

#[allow(clippy::too_many_arguments)]
fn run_sched(
    method: Method,
    h: usize,
    participation: usize,
    arrival: ArrivalOrder,
    parallelism: Parallelism,
    rounds: usize,
    server_shards: usize,
    sched: SchedPolicy,
    shard_map: ShardMapKind,
    net: NetModel,
    train: &Dataset,
    test: &Dataset,
) -> Fingerprint {
    let e = MockEngine::small(42);
    let cfg = TrainConfig {
        participation,
        arrival,
        parallelism,
        server_shards,
        sched,
        shard_map,
        agg_every: 4,
        eval_every: 3,
        eval_max_batches: 2,
        lr0: 1.0,
        track_grad_norms: true,
        ..TrainConfig::new(method).with_h(h)
    }
    .with_rounds(rounds);
    let mut tr = Trainer::new(&e, cfg, setup_net(train, test, 5, net)).unwrap();
    let rec = tr.run().unwrap();
    fingerprint(&tr, &rec)
}

/// `run_sched` with an explicit (non-IID) partition and an explicit
/// shard map — the locality-map golden cases pin behavior on crafted
/// label-skewed partitions where the expected grouping is provable.
#[allow(clippy::too_many_arguments)]
fn run_part(
    method: Method,
    h: usize,
    parallelism: Parallelism,
    rounds: usize,
    server_shards: usize,
    sched: SchedPolicy,
    shard_map: ShardMapKind,
    net: NetModel,
    partition: Partition,
    train: &Dataset,
    test: &Dataset,
) -> Fingerprint {
    let e = MockEngine::small(42);
    let cfg = TrainConfig {
        parallelism,
        server_shards,
        sched,
        shard_map,
        agg_every: 4,
        eval_every: 3,
        eval_max_batches: 2,
        lr0: 1.0,
        track_grad_norms: true,
        ..TrainConfig::new(method).with_h(h)
    }
    .with_rounds(rounds);
    let setup = TrainerSetup {
        train,
        test,
        partition,
        net,
        client_layout: None,
        server_layout: None,
        aux_layout: None,
        label: "golden".to_string(),
    };
    let mut tr = Trainer::new(&e, cfg, setup).unwrap();
    let rec = tr.run().unwrap();
    fingerprint(&tr, &rec)
}

/// Deal whole samples to clients sorted by label: client shards are
/// contiguous runs of the label-sorted index list — the pathological
/// label-skew grouping (each client holds 1-2 labels).
fn label_sorted_partition(train: &Dataset, n_clients: usize) -> Partition {
    let mut idx: Vec<usize> = (0..train.len()).collect();
    idx.sort_by_key(|&i| (train.labels[i], i));
    let per = idx.len() / n_clients;
    Partition {
        clients: (0..n_clients)
            .map(|c| {
                let end = if c + 1 == n_clients { idx.len() } else { (c + 1) * per };
                idx[c * per..end].to_vec()
            })
            .collect(),
    }
}

/// Pure-label clients whose id order interleaves the labels: client `c`
/// holds only samples of label `c % classes`.
fn interleaved_pure_partition(train: &Dataset, n_clients: usize) -> Partition {
    let classes = train.classes;
    let mut pools: Vec<Vec<usize>> = vec![Vec::new(); classes];
    for (i, &l) in train.labels.iter().enumerate() {
        pools[l as usize].push(i);
    }
    let per = train.len() / n_clients;
    let mut taken = vec![0usize; classes];
    Partition {
        clients: (0..n_clients)
            .map(|c| {
                let l = c % classes;
                let start = taken[l];
                taken[l] += per;
                pools[l][start..start + per].to_vec()
            })
            .collect(),
    }
}

#[allow(clippy::too_many_arguments)]
fn run(
    method: Method,
    h: usize,
    participation: usize,
    arrival: ArrivalOrder,
    parallelism: Parallelism,
    rounds: usize,
    server_shards: usize,
    train: &Dataset,
    test: &Dataset,
) -> Fingerprint {
    run_sched(
        method,
        h,
        participation,
        arrival,
        parallelism,
        rounds,
        server_shards,
        SchedPolicy::RoundRobin,
        ShardMapKind::Contiguous,
        NetModel::edge_default(),
        train,
        test,
    )
}

fn assert_identical(seq: &Fingerprint, par: &Fingerprint, ctx: &str) {
    // Byte-identical serialized RunRecord is the headline contract.
    assert_eq!(seq.json.as_bytes(), par.json.as_bytes(), "{ctx}: RunRecord JSON diverged");
    assert_eq!(seq.timeline, par.timeline, "{ctx}: timeline span sequence diverged");
    assert_eq!(seq.ledger, par.ledger, "{ctx}: communication ledger diverged");
    assert_eq!(seq.client_models, par.client_models, "{ctx}: client models diverged");
    assert_eq!(seq.client_aux, par.client_aux, "{ctx}: aux models diverged");
    assert_eq!(seq.server_copies, par.server_copies, "{ctx}: server copies diverged");
    assert_eq!(seq.server_updates, par.server_updates, "{ctx}: update count diverged");
    assert_eq!(seq.shard_updates, par.shard_updates, "{ctx}: per-shard counts diverged");
    assert_eq!(seq.shard_of, par.shard_of, "{ctx}: shard map diverged");
}

#[test]
fn threads_bit_identical_to_sequential_for_all_methods() {
    let train = dataset(120, 1);
    let test = dataset(24, 2);
    for method in Method::ALL {
        let h = if method == Method::CseFsl { 2 } else { 1 };
        let seq = run(
            method,
            h,
            0,
            ArrivalOrder::ByDelay,
            Parallelism::Sequential,
            10,
            1,
            &train,
            &test,
        );
        for threads in [1usize, 2, 4, 8] {
            let par = run(
                method,
                h,
                0,
                ArrivalOrder::ByDelay,
                Parallelism::Threads(threads),
                10,
                1,
                &train,
                &test,
            );
            assert_identical(&seq, &par, &format!("{method} threads={threads}"));
        }
    }
}

#[test]
fn sharded_golden_bit_identical_across_thread_counts() {
    // The sharded server phase (k copies, k event-loop executors) must
    // keep the contract at every k for both single-copy methods —
    // including k = n, where each client has a private shard.
    let train = dataset(120, 9);
    let test = dataset(24, 10);
    for method in [Method::CseFsl, Method::FslOc] {
        let h = if method == Method::CseFsl { 2 } else { 1 };
        for shards in [1usize, 2, 5] {
            let seq = run(
                method,
                h,
                0,
                ArrivalOrder::ByDelay,
                Parallelism::Sequential,
                10,
                shards,
                &train,
                &test,
            );
            for threads in [1usize, 4] {
                let par = run(
                    method,
                    h,
                    0,
                    ArrivalOrder::ByDelay,
                    Parallelism::Threads(threads),
                    10,
                    shards,
                    &train,
                    &test,
                );
                assert_identical(
                    &seq,
                    &par,
                    &format!("{method} shards={shards} threads={threads}"),
                );
            }
            // Per-shard counts: one counter per copy, conserving the
            // total, and every shard actually serves its client group.
            assert_eq!(seq.shard_updates.len(), shards);
            assert_eq!(seq.shard_updates.iter().sum::<u64>(), seq.server_updates);
            assert!(
                seq.shard_updates.iter().all(|&u| u > 0),
                "{method} shards={shards}: idle shard in {:?}",
                seq.shard_updates
            );
            assert_eq!(seq.server_copies.len(), shards);
        }
    }
}

#[test]
fn shards_one_bit_identical_to_default_single_copy() {
    // --server-shards 1 must be the historical single-copy run exactly:
    // the default config (which never mentions shards) and an explicit
    // k=1 produce the same fingerprint.
    let train = dataset(120, 11);
    let test = dataset(24, 12);
    let explicit = run(
        Method::CseFsl,
        2,
        0,
        ArrivalOrder::ByDelay,
        Parallelism::Sequential,
        8,
        1,
        &train,
        &test,
    );
    let e = MockEngine::small(42);
    // Built without touching server_shards at all.
    let cfg = TrainConfig {
        agg_every: 4,
        eval_every: 3,
        eval_max_batches: 2,
        lr0: 1.0,
        track_grad_norms: true,
        ..TrainConfig::new(Method::CseFsl).with_h(2)
    }
    .with_rounds(8);
    let mut tr = Trainer::new(&e, cfg, setup(&train, &test, 5)).unwrap();
    let rec = tr.run().unwrap();
    assert_eq!(
        explicit.json,
        run_to_json(&rec).pretty(),
        "default config must equal explicit k=1"
    );
}

#[test]
fn shard_count_changes_results() {
    // Sharding is a *semantic* knob (disjoint shard trajectories between
    // aggregations), not a scheduling knob — this is why server_shards
    // is part of RunSpec::key while parallelism is not.
    let train = dataset(120, 13);
    let test = dataset(24, 14);
    let k1 = run(
        Method::CseFsl,
        2,
        0,
        ArrivalOrder::ByDelay,
        Parallelism::Sequential,
        10,
        1,
        &train,
        &test,
    );
    let k2 = run(
        Method::CseFsl,
        2,
        0,
        ArrivalOrder::ByDelay,
        Parallelism::Sequential,
        10,
        2,
        &train,
        &test,
    );
    assert_ne!(k1.json, k2.json, "k=2 must not silently replay the k=1 run");
}

#[test]
fn golden_holds_under_partial_participation() {
    // k-of-n sampling exercises non-contiguous sorted participant sets
    // in the fan-out (disjoint-borrow collection + round-robin buckets).
    let train = dataset(120, 3);
    let test = dataset(24, 4);
    for method in [Method::CseFsl, Method::FslMc] {
        let seq = run(
            method,
            1,
            3,
            ArrivalOrder::ByDelay,
            Parallelism::Sequential,
            12,
            1,
            &train,
            &test,
        );
        let par = run(
            method,
            1,
            3,
            ArrivalOrder::ByDelay,
            Parallelism::Threads(4),
            12,
            1,
            &train,
            &test,
        );
        assert_identical(&seq, &par, &format!("{method} participation=3"));
    }
    // Sharded + partial participation: some shards may sit idle in a
    // round; determinism must survive the uneven lane loads.
    let seq = run(
        Method::CseFsl,
        2,
        2,
        ArrivalOrder::ByDelay,
        Parallelism::Sequential,
        12,
        2,
        &train,
        &test,
    );
    let par = run(
        Method::CseFsl,
        2,
        2,
        ArrivalOrder::ByDelay,
        Parallelism::Threads(4),
        12,
        2,
        &train,
        &test,
    );
    assert_identical(&seq, &par, "CSE_FSL shards=2 participation=2");
}

#[test]
fn golden_holds_under_shuffled_arrival_order() {
    // The Fig. 6 shuffled arm consumes the trainer RNG *after* the
    // fan-out; the parallel engine must leave that stream untouched.
    let train = dataset(120, 5);
    let test = dataset(24, 6);
    let seq = run(
        Method::CseFsl,
        3,
        0,
        ArrivalOrder::Shuffled,
        Parallelism::Sequential,
        9,
        1,
        &train,
        &test,
    );
    let par = run(
        Method::CseFsl,
        3,
        0,
        ArrivalOrder::Shuffled,
        Parallelism::Threads(3),
        9,
        1,
        &train,
        &test,
    );
    assert_identical(&seq, &par, "CSE_FSL shuffled arrivals");
}

#[test]
fn sched_policies_bit_identical_across_threads() {
    // Acceptance pin: RoundRobin / CostWeighted / WorkStealing produce
    // bit-identical RunRecords at threads {1, 4}, for a local-update
    // method and a SplitFed baseline (both fan-out shapes).
    let train = dataset(120, 15);
    let test = dataset(24, 16);
    for method in [Method::CseFsl, Method::FslMc] {
        let h = if method == Method::CseFsl { 2 } else { 1 };
        let reference = run(
            method,
            h,
            0,
            ArrivalOrder::ByDelay,
            Parallelism::Sequential,
            10,
            1,
            &train,
            &test,
        );
        for sched in SchedPolicy::ALL {
            for threads in [1usize, 4] {
                let par = run_sched(
                    method,
                    h,
                    0,
                    ArrivalOrder::ByDelay,
                    Parallelism::Threads(threads),
                    10,
                    1,
                    sched,
                    ShardMapKind::Contiguous,
                    NetModel::edge_default(),
                    &train,
                    &test,
                );
                assert_identical(
                    &reference,
                    &par,
                    &format!("{method} sched={sched} threads={threads}"),
                );
            }
        }
    }
    // The sharded server phase fans its drain loops through the same
    // scheduler: pin the policies there too.
    let reference = run(
        Method::CseFsl,
        2,
        0,
        ArrivalOrder::ByDelay,
        Parallelism::Sequential,
        10,
        2,
        &train,
        &test,
    );
    for sched in SchedPolicy::ALL {
        for threads in [1usize, 4] {
            let par = run_sched(
                Method::CseFsl,
                2,
                0,
                ArrivalOrder::ByDelay,
                Parallelism::Threads(threads),
                10,
                2,
                sched,
                ShardMapKind::Contiguous,
                NetModel::edge_default(),
                &train,
                &test,
            );
            assert_identical(
                &reference,
                &par,
                &format!("CSE_FSL shards=2 sched={sched} threads={threads}"),
            );
        }
    }
}

#[test]
fn balanced_shard_map_deterministic_and_result_changing() {
    // The balanced ShardMap (LPT on client costs) keeps the
    // bit-determinism contract — sequential and threaded runs agree for
    // every policy — while its *assignment* (and therefore results)
    // legitimately differs from contiguous, which is why the map kind
    // joins RunSpec::key.
    let train = dataset(120, 17);
    let test = dataset(24, 18);
    let run_map = |map: ShardMapKind, par: Parallelism, sched: SchedPolicy| {
        run_sched(
            Method::CseFsl,
            2,
            0,
            ArrivalOrder::ByDelay,
            par,
            10,
            2,
            sched,
            map,
            NetModel::heavy_tailed(),
            &train,
            &test,
        )
    };
    let bal = run_map(ShardMapKind::Balanced, Parallelism::Sequential, SchedPolicy::RoundRobin);
    // The balanced partition covers every client and leaves no shard
    // empty (LPT over sanitized positive costs).
    assert_eq!(bal.shard_of.len(), 5);
    for shard in 0..2 {
        assert!(
            bal.shard_of.iter().any(|&s| s == shard),
            "empty shard {shard} in {:?}",
            bal.shard_of
        );
    }
    for sched in SchedPolicy::ALL {
        for threads in [1usize, 4] {
            let par = run_map(ShardMapKind::Balanced, Parallelism::Threads(threads), sched);
            assert_identical(
                &bal,
                &par,
                &format!("balanced sched={sched} threads={threads}"),
            );
        }
    }
    let cont =
        run_map(ShardMapKind::Contiguous, Parallelism::Sequential, SchedPolicy::RoundRobin);
    // Under the heavy-tailed profile the LPT assignment regroups the
    // clients; whenever it does, results must change with it (the
    // RunSpec::key argument). With 5 heterogeneous client costs the
    // assignments virtually always differ — but guard anyway so the
    // assertion can never go stale silently.
    if bal.shard_of != cont.shard_of {
        assert_ne!(bal.json, cont.json, "regrouped shards must change results");
    } else {
        assert_eq!(bal.json, cont.json, "identical maps must replay identical runs");
    }
}

#[test]
fn locality_shard_map_deterministic_and_below_contiguous_skew() {
    // The locality map over a label-sorted partition (each client holds
    // 1-2 labels): bit-determinism at k ∈ {2, 4} × threads {1, 4} for
    // every dealing policy, non-empty shards with ±1 client counts, and
    // a shard-skew metric no worse than the contiguous grouping — at
    // k = 2 strictly better, for *any* client cost draw (under the
    // client-weighted skew now recorded, the contiguous map scores 0.4
    // on this partition while every grouping the wave dealing can
    // produce stays ≤ 0.34).
    let train = dataset(120, 19);
    let test = dataset(24, 20);
    for shards in [2usize, 4] {
        let seq = run_part(
            Method::CseFsl,
            2,
            Parallelism::Sequential,
            10,
            shards,
            SchedPolicy::RoundRobin,
            ShardMapKind::Locality,
            NetModel::edge_default(),
            label_sorted_partition(&train, 5),
            &train,
            &test,
        );
        for sched in SchedPolicy::ALL {
            for threads in [1usize, 4] {
                let par = run_part(
                    Method::CseFsl,
                    2,
                    Parallelism::Threads(threads),
                    10,
                    shards,
                    sched,
                    ShardMapKind::Locality,
                    NetModel::edge_default(),
                    label_sorted_partition(&train, 5),
                    &train,
                    &test,
                );
                assert_identical(
                    &seq,
                    &par,
                    &format!("locality shards={shards} sched={sched} threads={threads}"),
                );
            }
        }
        // Every shard serves a cohort; counts differ by at most one
        // (each dealing wave touches a shard at most once).
        let counts: Vec<usize> =
            (0..shards).map(|s| seq.shard_of.iter().filter(|&&x| x == s).count()).collect();
        assert!(counts.iter().all(|&c| c > 0), "empty shard in {counts:?}");
        let (min, max) =
            (counts.iter().min().unwrap(), counts.iter().max().unwrap());
        assert!(max - min <= 1, "unbalanced counts {counts:?}");
        // Skew vs the contiguous grouping of the same partition.
        let cont = run_part(
            Method::CseFsl,
            2,
            Parallelism::Sequential,
            10,
            shards,
            SchedPolicy::RoundRobin,
            ShardMapKind::Contiguous,
            NetModel::edge_default(),
            label_sorted_partition(&train, 5),
            &train,
            &test,
        );
        if shards == 2 {
            assert!(
                seq.divergence < cont.divergence,
                "locality {} must beat contiguous {} at k=2",
                seq.divergence,
                cont.divergence
            );
        } else {
            assert!(
                seq.divergence <= cont.divergence + 1e-12,
                "locality {} worse than contiguous {} at k=4",
                seq.divergence,
                cont.divergence
            );
        }
        // Different grouping must mean different results (the
        // RunSpec::key argument for the locality map).
        if seq.shard_of != cont.shard_of {
            assert_ne!(seq.json, cont.json, "regrouped shards must change results");
        } else {
            assert_eq!(seq.json, cont.json, "identical maps must replay identical runs");
        }
    }
}

#[test]
fn locality_beats_balanced_on_interleaved_golden_partition() {
    // Acceptance pin: on a golden non-IID config the locality map
    // reports a strictly lower shard-skew metric than the cost-only
    // balanced map. The config makes both maps provable: a 2-class
    // dataset (labels cycle 0,1) dealt as pure-label clients whose id
    // order interleaves the labels, under the homogeneous net model —
    // every client cost is identical, so LPT's deterministic tie-breaks
    // deal ids round-robin over the bins ({0,2} | {1,3}: same-label
    // cohorts, maximal skew 0.5) while the locality waves stratify by
    // label ({0,1} | {2,3}: every copy sees the global mix, skew 0).
    let spec2 = SyntheticSpec {
        height: 2,
        width: 2,
        channels: 2,
        classes: 2,
        ..SyntheticSpec::cifar_like()
    };
    let train = generate(&spec2, 96, 21);
    let test = generate(&spec2, 16, 22);
    let run_map = |map: ShardMapKind, par: Parallelism| {
        run_part(
            Method::CseFsl,
            2,
            par,
            8,
            2,
            SchedPolicy::RoundRobin,
            map,
            NetModel::homogeneous(),
            interleaved_pure_partition(&train, 4),
            &train,
            &test,
        )
    };
    let bal = run_map(ShardMapKind::Balanced, Parallelism::Sequential);
    let loc = run_map(ShardMapKind::Locality, Parallelism::Sequential);
    assert_eq!(bal.shard_of, vec![0, 1, 0, 1], "equal costs: LPT deals ids round-robin");
    assert_eq!(loc.shard_of, vec![0, 0, 1, 1], "locality stratifies the label blocks");
    assert!((bal.divergence - 0.5).abs() < 1e-9, "balanced skew {}", bal.divergence);
    assert!(loc.divergence < 1e-12, "locality skew {}", loc.divergence);
    assert!(loc.divergence < bal.divergence);
    assert_ne!(loc.json, bal.json, "different cohorts must change results");
    // And the locality run keeps the bit-determinism contract.
    let par = run_map(ShardMapKind::Locality, Parallelism::Threads(4));
    assert_identical(&loc, &par, "locality interleaved threads=4");
}

#[test]
fn aux_period_per_client_scenario_golden() {
    // The spec-only scenario the closed Method enum could not express:
    // AuxLocal × Period(2) × PerClient ("FSL_AN with h = 2"). Fresh
    // pinned goldens: (a) it runs end-to-end, (b) it keeps the
    // bit-determinism contract across thread counts and policies,
    // (c) it is reproducible across invocations, and (d) it is a
    // genuinely new point — different results from both neighbouring
    // presets (FSL_AN at h = 1, CSE_FSL shared at the same h).
    let train = dataset(120, 23);
    let test = dataset(24, 24);
    let novel = MethodSpec {
        update: ClientUpdate::AuxLocal,
        upload: UploadSchedule::period(2),
        topology: ServerTopology::PerClient,
        compression: Compression::None,
    };
    assert_eq!(novel, Method::FslAn.spec().with_period(2));
    assert_eq!(novel.preset(), None, "must be a spec-only point");
    let run_novel = |parallelism: Parallelism, sched: SchedPolicy| {
        let e = MockEngine::small(42);
        let cfg = TrainConfig {
            parallelism,
            sched,
            agg_every: 4,
            eval_every: 3,
            eval_max_batches: 2,
            lr0: 1.0,
            track_grad_norms: true,
            ..TrainConfig::from_spec(novel)
        }
        .with_rounds(10);
        let mut tr = Trainer::new(&e, cfg, setup(&train, &test, 5)).unwrap();
        let rec = tr.run().unwrap();
        fingerprint(&tr, &rec)
    };
    let seq = run_novel(Parallelism::Sequential, SchedPolicy::RoundRobin);
    // Per-client topology: one server copy per client, identity map.
    assert_eq!(seq.server_copies.len(), 5);
    assert_eq!(seq.shard_of, vec![0, 1, 2, 3, 4]);
    for sched in SchedPolicy::ALL {
        for threads in [1usize, 4] {
            let par = run_novel(Parallelism::Threads(threads), sched);
            assert_identical(
                &seq,
                &par,
                &format!("aux+p2+pc sched={sched} threads={threads}"),
            );
        }
    }
    let again = run_novel(Parallelism::Sequential, SchedPolicy::RoundRobin);
    assert_identical(&seq, &again, "aux+p2+pc repeat invocation");
    // Distinct from both neighbouring presets on the same data.
    let an_h1 = run(
        Method::FslAn,
        1,
        0,
        ArrivalOrder::ByDelay,
        Parallelism::Sequential,
        10,
        1,
        &train,
        &test,
    );
    let cse_h2 = run(
        Method::CseFsl,
        2,
        0,
        ArrivalOrder::ByDelay,
        Parallelism::Sequential,
        10,
        1,
        &train,
        &test,
    );
    assert_ne!(seq.json, an_h1.json, "the period must change results vs FSL_AN");
    assert_ne!(seq.json, cse_h2.json, "the topology must change results vs CSE_FSL h=2");
}

#[test]
fn sage_estimator_golden_bit_identical_and_distinct_from_neighbours() {
    // The gradient-estimator update rule (SageEstimate): alignment
    // rounds interleave a server fwd/bwd drain, a true-gradient client
    // step, and an estimator re-fit — all of it off splits of the round
    // snapshot rng, so the golden contract must hold unchanged: any
    // thread count × any dealing policy is bit-identical to the
    // sequential reference, and repeat invocations replay exactly.
    let train = dataset(120, 25);
    let test = dataset(24, 26);
    let sage = MethodSpec {
        update: ClientUpdate::SageEstimate { align_every: 3, clip: 0.0 },
        ..Method::CseFsl.spec()
    };
    assert_eq!(sage.preset(), None, "must be a spec-only point");
    let run_spec = |spec: MethodSpec, parallelism: Parallelism, sched: SchedPolicy| {
        let e = MockEngine::small(42);
        let cfg = TrainConfig {
            parallelism,
            sched,
            agg_every: 4,
            eval_every: 3,
            eval_max_batches: 2,
            lr0: 1.0,
            track_grad_norms: true,
            ..TrainConfig::from_spec(spec)
        }
        .with_rounds(12);
        let mut tr = Trainer::new(&e, cfg, setup(&train, &test, 5)).unwrap();
        let rec = tr.run().unwrap();
        fingerprint(&tr, &rec)
    };
    let seq = run_spec(sage, Parallelism::Sequential, SchedPolicy::RoundRobin);
    for sched in SchedPolicy::ALL {
        for threads in [1usize, 4] {
            let par = run_spec(sage, Parallelism::Threads(threads), sched);
            assert_identical(
                &seq,
                &par,
                &format!("sage3 sched={sched} threads={threads}"),
            );
        }
    }
    let again = run_spec(sage, Parallelism::Sequential, SchedPolicy::RoundRobin);
    assert_identical(&seq, &again, "sage3 repeat invocation");
    // A genuinely new point on the update axis: distinct fingerprints
    // from BOTH neighbours with the same other axes — the aux-local
    // rule (no alignment ever) and the server-grad rule (per-batch
    // round trips).
    let aux = run_spec(
        MethodSpec { update: ClientUpdate::AuxLocal, ..sage },
        Parallelism::Sequential,
        SchedPolicy::RoundRobin,
    );
    assert_ne!(seq.json, aux.json, "alignment must change results vs AuxLocal");
    let grad = run_spec(
        Method::FslOc.spec(),
        Parallelism::Sequential,
        SchedPolicy::RoundRobin,
    );
    assert_ne!(seq.json, grad.json, "the estimator must change results vs ServerGrad");
    // The alignment wire profile sits strictly between the neighbours'.
    use cse_fsl::comm::accounting::MsgKind;
    let down = |f: &Fingerprint| f.ledger.bytes_of(MsgKind::GradDownload);
    assert_eq!(down(&aux), 0);
    assert!(down(&seq) > 0, "alignment rounds must record the downlink");
    assert!(down(&seq) < down(&grad), "a=3 must downlink less than per-batch");
}

#[test]
fn compressed_rounds_keep_the_bit_determinism_contract() {
    // The wire codec's stochastic rounding draws from a split of the
    // round snapshot rng, never from worker-local state — so compressed
    // runs must satisfy the same contract as everything else: any
    // thread count × any dealing policy is bit-identical to the
    // sequential reference. Covers both codec sites: the smashed-data
    // uplink (CSE_FSL, aux-local) and the gradient downlink of the
    // server-grad rule (FSL_OC phase 2).
    let train = dataset(120, 23);
    let test = dataset(24, 24);
    let run_codec = |method: Method, h: usize, codec: Compression, parallelism, sched| {
        let e = MockEngine::small(42);
        let cfg = TrainConfig {
            parallelism,
            sched,
            agg_every: 4,
            eval_every: 3,
            eval_max_batches: 2,
            lr0: 1.0,
            track_grad_norms: true,
            ..TrainConfig::new(method).with_h(h).with_compression(codec)
        }
        .with_rounds(10);
        let mut tr = Trainer::new(&e, cfg, setup(&train, &test, 5)).unwrap();
        let rec = tr.run().unwrap();
        fingerprint(&tr, &rec)
    };
    let seq_rr = (Parallelism::Sequential, SchedPolicy::RoundRobin);
    // Smashed-uplink site: CSE_FSL h=2 at 4 and 8 bits.
    let uncompressed = run_codec(Method::CseFsl, 2, Compression::None, seq_rr.0, seq_rr.1);
    for bits in [4u8, 8] {
        let codec = Compression::Quantize { bits };
        let seq = run_codec(Method::CseFsl, 2, codec, seq_rr.0, seq_rr.1);
        assert_ne!(
            seq.json, uncompressed.json,
            "quantize{bits} must change results vs full precision"
        );
        for sched in SchedPolicy::ALL {
            for threads in [1usize, 4] {
                let par =
                    run_codec(Method::CseFsl, 2, codec, Parallelism::Threads(threads), sched);
                assert_identical(
                    &seq,
                    &par,
                    &format!("CSE_FSL quantize{bits} sched={sched} threads={threads}"),
                );
            }
        }
        let again = run_codec(Method::CseFsl, 2, codec, seq_rr.0, seq_rr.1);
        assert_identical(&seq, &again, &format!("CSE_FSL quantize{bits} repeat invocation"));
    }
    // Different precisions are different runs (the axis is live).
    let q4 = run_codec(
        Method::CseFsl,
        2,
        Compression::Quantize { bits: 4 },
        Parallelism::Sequential,
        SchedPolicy::RoundRobin,
    );
    let q8 = run_codec(
        Method::CseFsl,
        2,
        Compression::Quantize { bits: 8 },
        Parallelism::Sequential,
        SchedPolicy::RoundRobin,
    );
    assert_ne!(q4.json, q8.json, "4-bit and 8-bit runs must differ");
    // Gradient-downlink site: the server-grad rule compresses the
    // returned gradient too (FSL_OC; phase-2 split off self.rng).
    let oc = run_codec(
        Method::FslOc,
        1,
        Compression::Quantize { bits: 4 },
        Parallelism::Sequential,
        SchedPolicy::RoundRobin,
    );
    let oc_none = run_codec(
        Method::FslOc,
        1,
        Compression::None,
        Parallelism::Sequential,
        SchedPolicy::RoundRobin,
    );
    assert_ne!(oc.json, oc_none.json, "the codec must bite on the grad downlink");
    for sched in SchedPolicy::ALL {
        for threads in [1usize, 4] {
            let par = run_codec(
                Method::FslOc,
                1,
                Compression::Quantize { bits: 4 },
                Parallelism::Threads(threads),
                sched,
            );
            assert_identical(
                &oc,
                &par,
                &format!("FSL_OC quantize4 sched={sched} threads={threads}"),
            );
        }
    }
}

#[test]
fn parallel_runs_are_reproducible_across_invocations() {
    // Threads(n) vs Threads(n) with identical configs: scheduling noise
    // must never leak into results.
    let train = dataset(80, 7);
    let test = dataset(16, 8);
    let a = run(
        Method::CseFsl,
        2,
        0,
        ArrivalOrder::ByDelay,
        Parallelism::Threads(4),
        8,
        2,
        &train,
        &test,
    );
    let b = run(
        Method::CseFsl,
        2,
        0,
        ArrivalOrder::ByDelay,
        Parallelism::Threads(4),
        8,
        2,
        &train,
        &test,
    );
    assert_identical(&a, &b, "Threads(4) shards=2 repeat");
}
